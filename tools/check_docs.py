#!/usr/bin/env python3
"""Offline documentation checker (stdlib only — the build container has no
network and no pip; see requirements-dev.txt for what CI installs).

Checks README.md / DESIGN.md / CHANGES.md for:

  1. **markdown links** ``[text](target)`` — relative targets must exist;
     ``#anchor`` fragments must match a heading slug (GitHub slugify) in
     the target file; ``http(s)://`` links are skipped (offline);
  2. **DESIGN section references** — every ``DESIGN.md §X`` mention must
     have a matching ``## §X`` heading in DESIGN.md. Bare ``§X`` mentions
     are NOT checked: they are ambiguous with the source paper's section
     numbers (e.g. "§5.4" in DESIGN.md means the paper's §5.4);
  3. **backticked file references** — a token like ``core/sampler/mfg.py``
     must resolve against the repo root or a source root (src, src/repro,
     the docs refer to modules by their import-ish path).

Exit code 1 with one line per dangling reference; 0 when clean.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

DOCS = ["README.md", "DESIGN.md", "CHANGES.md"]
SEARCH_ROOTS = ["", "src", "src/repro", "tests", "benchmarks"]
FILE_EXTS = (".py", ".md", ".txt", ".json", ".yml", ".yaml", ".ini", ".toml")

LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
DESIGN_REF_RE = re.compile(r"DESIGN\.md[^§\n]{0,4}§([0-9A-Za-z][\w.-]*)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$", re.MULTILINE)
SECTION_RE = re.compile(r"^##\s+§(\S+)", re.MULTILINE)
# backticked repo paths: at least one '/', a known extension
CODE_PATH_RE = re.compile(r"`([\w.-]+(?:/[\w.-]+)+\.(?:%s))`"
                          % "|".join(e.lstrip(".") for e in FILE_EXTS))


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->dashes."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_]", "", s)
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def heading_slugs(text: str) -> set[str]:
    return {github_slug(m.group(2)) for m in HEADING_RE.finditer(text)}


def resolve_path(root: Path, token: str) -> bool:
    return any((root / sr / token).exists() for sr in SEARCH_ROOTS)


def check_file(root: Path, name: str, design_sections: set[str]
               ) -> list[str]:
    path = root / name
    if not path.exists():
        return [f"{name}: file missing"]
    text = path.read_text(encoding="utf-8")
    errors = []

    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        frag = None
        if "#" in target:
            target, frag = target.split("#", 1)
        if target:
            tpath = (path.parent / target).resolve()
            if not tpath.exists():
                errors.append(f"{name}: dangling link target {target!r}")
                continue
        else:
            tpath = path
        if frag is not None and tpath.suffix == ".md":
            if frag not in heading_slugs(tpath.read_text(encoding="utf-8")):
                errors.append(f"{name}: dangling anchor "
                              f"{target or name}#{frag}")

    for m in DESIGN_REF_RE.finditer(text):
        sec = m.group(1).rstrip(".,;:")
        if sec not in design_sections:
            errors.append(f"{name}: dangling section reference "
                          f"DESIGN.md §{sec} (have §{sorted(design_sections)})")

    for m in CODE_PATH_RE.finditer(text):
        token = m.group(1)
        if not resolve_path(root, token):
            errors.append(f"{name}: dangling file reference `{token}`")
    return errors


def check_all(root: Path) -> list[str]:
    design = root / "DESIGN.md"
    sections = (set(SECTION_RE.findall(design.read_text(encoding="utf-8")))
                if design.exists() else set())
    errors = []
    for name in DOCS:
        errors.extend(check_file(root, name, sections))
    return errors


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    errors = check_all(root)
    for e in errors:
        print(f"ERROR: {e}")
    if errors:
        print(f"{len(errors)} dangling reference(s)")
        return 1
    print(f"docs OK: {', '.join(DOCS)} checked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
