#!/usr/bin/env python3
"""Offline documentation checker (stdlib only — the build container has no
network and no pip; see requirements-dev.txt for what CI installs).

Checks README.md / DESIGN.md / CHANGES.md for:

  1. **markdown links** ``[text](target)`` — relative targets must exist;
     ``#anchor`` fragments must match a heading slug (GitHub slugify) in
     the target file; ``http(s)://`` links are skipped (offline);
  2. **DESIGN section references** — every ``DESIGN.md §X`` mention must
     have a matching ``## §X`` heading in DESIGN.md. Bare ``§X`` mentions
     are NOT checked: they are ambiguous with the source paper's section
     numbers (e.g. "§5.4" in DESIGN.md means the paper's §5.4);
  3. **backticked file references** — a token like ``core/sampler/mfg.py``
     must resolve against the repo root or a source root (src, src/repro,
     the docs refer to modules by their import-ish path);
  4. **the DESIGN.md §8 API table** — every backticked ``repro.*`` dotted
     name in that section must exist: resolved by real import when the
     third-party deps are installed, by a stdlib AST scan of the module
     file otherwise (the docs-check CI job runs without numpy/jax);
  5. **the API boundary** — ``MinibatchPipeline`` / ``EdgeMinibatchPipeline``
     may only be CONSTRUCTED inside ``src/repro/api/`` (and their defining
     module); everything else, examples included, must go through the
     ``repro.api`` loaders. Tests and benchmarks are exempt.

Exit code 1 with one line per dangling reference; 0 when clean.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path
from typing import Optional

DOCS = ["README.md", "DESIGN.md", "CHANGES.md"]
SEARCH_ROOTS = ["", "src", "src/repro", "tests", "benchmarks"]
FILE_EXTS = (".py", ".md", ".txt", ".json", ".yml", ".yaml", ".ini", ".toml")

LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
DESIGN_REF_RE = re.compile(r"DESIGN\.md[^§\n]{0,4}§([0-9A-Za-z][\w.-]*)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$", re.MULTILINE)
SECTION_RE = re.compile(r"^##\s+§(\S+)", re.MULTILINE)
# backticked repo paths: at least one '/', a known extension
CODE_PATH_RE = re.compile(r"`([\w.-]+(?:/[\w.-]+)+\.(?:%s))`"
                          % "|".join(e.lstrip(".") for e in FILE_EXTS))


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->dashes."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_]", "", s)
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def heading_slugs(text: str) -> set[str]:
    return {github_slug(m.group(2)) for m in HEADING_RE.finditer(text)}


def resolve_path(root: Path, token: str) -> bool:
    return any((root / sr / token).exists() for sr in SEARCH_ROOTS)


def check_file(root: Path, name: str, design_sections: set[str]
               ) -> list[str]:
    path = root / name
    if not path.exists():
        return [f"{name}: file missing"]
    text = path.read_text(encoding="utf-8")
    errors = []

    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        frag = None
        if "#" in target:
            target, frag = target.split("#", 1)
        if target:
            tpath = (path.parent / target).resolve()
            if not tpath.exists():
                errors.append(f"{name}: dangling link target {target!r}")
                continue
        else:
            tpath = path
        if frag is not None and tpath.suffix == ".md":
            if frag not in heading_slugs(tpath.read_text(encoding="utf-8")):
                errors.append(f"{name}: dangling anchor "
                              f"{target or name}#{frag}")

    for m in DESIGN_REF_RE.finditer(text):
        sec = m.group(1).rstrip(".,;:")
        if sec not in design_sections:
            errors.append(f"{name}: dangling section reference "
                          f"DESIGN.md §{sec} (have §{sorted(design_sections)})")

    for m in CODE_PATH_RE.finditer(text):
        token = m.group(1)
        if not resolve_path(root, token):
            errors.append(f"{name}: dangling file reference `{token}`")
    return errors


# ---------------------------------------------------------------------------
# DESIGN.md §8 API table: every `repro.*` name must exist
# ---------------------------------------------------------------------------

API_NAME_RE = re.compile(r"`(repro(?:\.\w+)+)`")


def _ast_exported_names(py: Path) -> set[str]:
    """Top-level names a module defines, importable-deps-free: defs,
    classes, assignment targets, import-from aliases, and __all__ literal
    entries (covers lazily-exported names behind module __getattr__)."""
    tree = ast.parse(py.read_text(encoding="utf-8"))
    names: set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for a in node.names:
                names.add((a.asname or a.name).split(".")[0])
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    names.add(tgt.id)
                    if tgt.id == "__all__":
                        try:
                            names.update(ast.literal_eval(node.value))
                        except ValueError:
                            pass
    return names


def _resolve_api_name(root: Path, dotted: str) -> Optional[str]:
    """None if ``dotted`` (e.g. repro.api.DistGraph.node_split) resolves,
    else an error string. Tries a real import first; falls back to an AST
    scan of the module file when third-party deps are unavailable."""
    parts = dotted.split(".")
    # longest module prefix that is a file/package under src/
    mod_end = len(parts)
    while mod_end > 0:
        p = root / "src" / Path(*parts[:mod_end])
        if (p / "__init__.py").exists() or p.with_suffix(".py").exists():
            break
        mod_end -= 1
    if mod_end == 0:
        return f"module for {dotted!r} not found under src/"
    attrs = parts[mod_end:]
    module = ".".join(parts[:mod_end])
    sys.path.insert(0, str(root / "src"))
    try:
        import importlib
        obj = importlib.import_module(module)
        for a in attrs:
            obj = getattr(obj, a)
        return None
    except AttributeError:
        return f"{module} has no attribute {'.'.join(attrs)}"
    except ImportError:
        # deps missing (the no-deps docs-check CI job): AST fallback on
        # the first attribute only (methods of a class need the import)
        p = root / "src" / Path(*parts[:mod_end])
        py = (p / "__init__.py") if (p / "__init__.py").exists() \
            else p.with_suffix(".py")
        if not attrs or attrs[0] in _ast_exported_names(py):
            return None
        return f"{module} does not define {attrs[0]} (AST scan)"
    finally:
        sys.path.pop(0)


def check_api_table(root: Path) -> list[str]:
    """Verify every `repro.*` dotted name in DESIGN.md §8 exists."""
    design = root / "DESIGN.md"
    if not design.exists():
        return []
    text = design.read_text(encoding="utf-8")
    m = re.search(r"^## §8 .*$", text, re.MULTILINE)
    if m is None:
        return []
    section = text[m.end():]
    nxt = re.search(r"^## ", section, re.MULTILINE)
    if nxt:
        section = section[:nxt.start()]
    errors = []
    for name in sorted({m.group(1) for m in API_NAME_RE.finditer(section)}):
        err = _resolve_api_name(root, name)
        if err:
            errors.append(f"DESIGN.md: §8 API table name `{name}`: {err}")
    return errors


# ---------------------------------------------------------------------------
# API boundary: pipelines are constructed only in src/repro/api/
# ---------------------------------------------------------------------------

PIPELINE_CTOR_RE = re.compile(
    r"(?<!class )\b(?:Edge)?MinibatchPipeline\s*\(")
BOUNDARY_ALLOWED = ("src/repro/api/", "src/repro/core/pipeline/minibatch.py")


def check_api_boundary(root: Path) -> list[str]:
    """`DistGNNTrainer`, launch/, and the examples must consume the
    repro.api loaders — no direct pipeline construction (DESIGN.md §8)."""
    errors = []
    for base in ("src", "examples"):
        d = root / base
        if not d.exists():
            continue
        for py in sorted(d.rglob("*.py")):
            rel = py.relative_to(root).as_posix()
            if any(rel.startswith(a) for a in BOUNDARY_ALLOWED):
                continue
            for i, line in enumerate(
                    py.read_text(encoding="utf-8").splitlines(), 1):
                if PIPELINE_CTOR_RE.search(line):
                    errors.append(
                        f"{rel}:{i}: direct pipeline construction outside "
                        f"repro.api — use NodeDataLoader/EdgeDataLoader "
                        f"(DESIGN.md §8)")
    return errors


def check_all(root: Path) -> list[str]:
    design = root / "DESIGN.md"
    sections = (set(SECTION_RE.findall(design.read_text(encoding="utf-8")))
                if design.exists() else set())
    errors = []
    for name in DOCS:
        errors.extend(check_file(root, name, sections))
    errors.extend(check_api_table(root))
    errors.extend(check_api_boundary(root))
    return errors


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    errors = check_all(root)
    for e in errors:
        print(f"ERROR: {e}")
    if errors:
        print(f"{len(errors)} dangling reference(s)")
        return 1
    print(f"docs OK: {', '.join(DOCS)} checked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
