"""Link prediction with DistDGLv2-style edge mini-batches (the paper's
second task, §6: "for link prediction, we may use all edges to train a
model") — through the SAME stack node classification uses.

``DistGNNTrainer(task="link_prediction")`` wires the whole pipeline:
positive-edge scheduling over each trainer's owned edges, uniform negative
sampling with static (B, K) shapes, endpoint ego-networks through the
distributed sampler, CPU feature prefetch (hot-vertex cache eligible),
async pipelining, a jitted dot-product scoring head, and MRR/Hits@k
evaluation. This file is only a thin demo of that path; see
tests/test_linkpred.py for the correctness guarantees.

Run:  PYTHONPATH=src python examples/link_prediction.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.graph import get_dataset
from repro.models.gnn import GNNConfig
from repro.training import DistGNNTrainer, TrainJobConfig


def main(scale=10, epochs=3, batch_edges=16, num_negs=16, seed=0):
    ds = get_dataset("product-sim", scale=scale)
    # 2-layer GraphSAGE encoder; num_classes is the embedding dim here
    cfg = GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                    hidden_dim=64, num_classes=64,
                    fanouts=[10, 5], batch_size=batch_edges)
    job = TrainJobConfig(num_machines=2, trainers_per_machine=1,
                         task="link_prediction", num_negs=num_negs,
                         score_fn="dot", seed=seed)
    tr = DistGNNTrainer(ds, cfg, job)
    print(f"{tr.num_trainers} trainers, {tr.batches_per_epoch} "
          f"edge-batches/epoch, node batch {tr.node_cfg.batch_size}")
    hist = []
    for e in range(epochs):
        m = tr.train_epoch(e)
        hist.append(m["loss"])
        print(f"epoch {e}: loss={m['loss']:.4f} train_mrr={m['train_mrr']:.3f}")
    val = tr.evaluate_lp(num_batches=10)
    tr.stop()
    print(f"eval: mrr={val['mrr']:.3f} hits@1={val['hits@1']:.3f} "
          f"hits@10={val['hits@10']:.3f} ({val['num_edges']} edges)")
    assert hist[-1] < hist[0], "link prediction failed to learn"
    print(f"link prediction learned: {hist[0]:.3f} -> {hist[-1]:.3f}")


if __name__ == "__main__":
    main()
