"""Link prediction with DistDGLv2-style mini-batches (the paper's second
task, §6: "for link prediction, we may use all edges to train a model").

Edge mini-batches: sample positive edges uniformly, gather both endpoints'
ego-networks through the distributed sampler, score with dot products
against uniform negatives, and update through synchronous SGD.

Run:  PYTHONPATH=src python examples/link_prediction.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kvstore import DistKVStore, PartitionPolicy
from repro.core.partition import hierarchical_partition
from repro.core.sampler import DistributedSampler
from repro.graph import get_dataset, to_coo
from repro.models.gnn import GNNConfig, apply_gnn, init_gnn, lp_loss
from repro.optim import adamw_init, adamw_update

NEGS = 4


def main(scale=11, steps=60, batch_edges=48, seed=0):
    ds = get_dataset("product-sim", scale=scale)
    hp = hierarchical_partition(ds.graph, 2, 1, split_mask=ds.split_mask,
                                seed=seed)
    book = hp.book
    feats_new = ds.feats[book.new2old_node]
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets)})
    store.init_data("feat", feats_new.shape[1:], np.float32, "node",
                    full_array=feats_new)
    client = store.client(0)

    src_old, dst_old = to_coo(ds.graph)
    e_src = book.old2new_node[src_old]
    e_dst = book.old2new_node[dst_old]
    rng = np.random.default_rng(seed)

    # 2-layer GraphSAGE encoder (paper's LP setup: 2 layers, fanout 25/15)
    cfg = GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                    hidden_dim=64, num_classes=64,   # output = embedding dim
                    fanouts=[15, 10], batch_size=2 * batch_edges)
    sampler = DistributedSampler(book, hp.partitions, cfg.fanouts,
                                 cfg.batch_size, machine=0, seed=seed)
    params = init_gnn(cfg, jax.random.key(seed))
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch, pos_u, pos_v, neg_v, pair_mask):
        def loss_fn(p):
            h = apply_gnn(cfg, p, batch)       # (batch, emb)
            return lp_loss(h, pos_u, pos_v, neg_v, pair_mask)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw_update(params, grads, opt, lr=3e-3)
        return params, opt, loss

    losses = []
    n = ds.graph.num_nodes
    for it in range(steps):
        eid = rng.integers(0, len(e_src), size=batch_edges)
        u, v = e_src[eid], e_dst[eid]
        seeds = np.concatenate([u, v])
        # pad/dedup: seeds may repeat; sampler tolerates duplicates
        mb = sampler.sample(seeds[:cfg.batch_size])
        mb.input_feats = client.pull("feat", mb.input_gids)
        batch = dict(input_feats=mb.input_feats, labels=None,
                     seed_mask=mb.seed_mask,
                     blocks=[dict(edge_src=b.edge_src, edge_dst=b.edge_dst,
                                  edge_mask=b.edge_mask,
                                  edge_types=b.edge_types)
                             for b in mb.blocks])
        pos_u = np.arange(batch_edges, dtype=np.int32)
        pos_v = np.arange(batch_edges, 2 * batch_edges, dtype=np.int32)
        neg_v = rng.integers(0, 2 * batch_edges,
                             size=(batch_edges, NEGS)).astype(np.int32)
        pmask = np.ones(batch_edges, bool)
        params, opt, loss = step(params, opt, batch, pos_u, pos_v, neg_v,
                                 pmask)
        losses.append(float(loss))
        if (it + 1) % 15 == 0:
            print(f"step {it+1}: loss={np.mean(losses[-15:]):.4f}")
    assert losses[-1] < losses[0], "link prediction failed to learn"
    print("link prediction learned: "
          f"{losses[0]:.3f} -> {np.mean(losses[-10:]):.3f}")


if __name__ == "__main__":
    main()
