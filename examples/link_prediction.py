"""Link prediction with DistDGLv2-style edge mini-batches (the paper's
second task, §6: "for link prediction, we may use all edges to train a
model") — in the SAME DGL loop shape node classification uses::

    for input_nodes, pair_graph, blocks in loader:
        ...

``EdgeDataLoader`` schedules positive-edge batches over this trainer's
owned edges (``DistGraph.edge_split``), draws uniform negatives with
static (B, K) shapes, samples endpoint ego-networks through the
distributed sampler and prefetches features through the async pipeline;
the yielded ``pair_graph`` carries the scoring-head index arrays. The
multi-trainer synchronous driver is ``repro.api.DistGNNTrainer`` with
``task="link_prediction"``; see tests/test_linkpred.py for correctness
guarantees.

Run:  PYTHONPATH=src python examples/link_prediction.py [--smoke]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.api import DistGraph, EdgeDataLoader
from repro.graph import get_dataset
from repro.models.gnn import (GNNConfig, apply_gnn, init_gnn, init_lp_head,
                              lp_loss_from_scores, lp_metrics,
                              lp_pair_scores, lp_ranks)
from repro.optim import adamw_init, adamw_update
from repro.core.sampler import EdgeBatchSampler


def main(scale=10, epochs=3, batch_edges=16, num_negs=16, seed=0):
    ds = get_dataset("product-sim", scale=scale)
    # 2-layer GraphSAGE encoder at the derived endpoint capacity
    # (2B + B*K seeds per node batch, DESIGN.md §6); the model's output
    # is an embedding, so num_classes doubles as the embedding dim
    node_bs = EdgeBatchSampler.required_node_batch(batch_edges, num_negs)
    cfg = GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                    hidden_dim=64, num_classes=64,
                    fanouts=[10, 5], batch_size=node_bs)

    g = DistGraph(ds, num_machines=2, trainers_per_machine=1, seed=seed)
    loader = EdgeDataLoader(g, g.edge_split(), cfg.fanouts,
                            batch_size=batch_edges, num_negs=num_negs,
                            seed=seed)
    print(f"rank {g.rank}: {len(g.edge_split())} owned edges, "
          f"{len(loader)} edge-batches/epoch, node batch {node_bs}")

    params = {"gnn": init_gnn(cfg, jax.random.key(seed)),
              "lp": init_lp_head("dot", 1, cfg.num_classes)}
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        def loss_fn(p):
            h = apply_gnn(cfg, p["gnn"], batch)
            kw = dict(head=p["lp"], score_fn="dot",
                      etypes=batch["edge_etypes"])
            pos = lp_pair_scores(h, batch["pos_u"], batch["pos_v"], **kw)
            neg = lp_pair_scores(h, batch["pos_u"], batch["neg_v"], **kw)
            loss = lp_loss_from_scores(pos, neg, batch["pair_mask"])
            mrr = lp_metrics(lp_ranks(pos, neg), batch["pair_mask"])["mrr"]
            return loss, mrr
        (loss, mrr), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = adamw_update(params, grads, opt, lr=3e-3)
        return params, opt, loss, mrr

    hist = []
    with loader:
        for epoch in range(epochs):
            losses, mrrs = [], []
            for batch in loader:
                input_nodes, pair_graph, blocks = batch     # DGL's triple
                params, opt, loss, mrr = step(params, opt, batch.model_input())
                losses.append(float(loss)); mrrs.append(float(mrr))
            hist.append(float(np.mean(losses)))
            print(f"epoch {epoch}: loss={hist[-1]:.4f} "
                  f"train_mrr={np.mean(mrrs):.3f}")

    # deterministic eval: fresh uniform candidates over every edge, ranks
    # in [1, 50] so hits@10 is a real metric (same protocol as
    # DistGNNTrainer.evaluate_lp)
    import itertools
    B, K = batch_edges, 49
    eval_cfg = GNNConfig(arch="graphsage", in_dim=cfg.in_dim,
                         hidden_dim=cfg.hidden_dim, num_classes=cfg.num_classes,
                         fanouts=cfg.fanouts,
                         batch_size=EdgeBatchSampler.required_node_batch(B, K))
    ev = EdgeDataLoader(g, np.arange(g.num_edges(), dtype=np.int64),
                        eval_cfg.fanouts, batch_size=B, num_negs=K,
                        mode="eval", sampler_seed=seed + 998,
                        edge_seed=seed + 977)
    ranks = []
    for batch in itertools.islice(ev, 10):
        h = apply_gnn(eval_cfg, params["gnn"], batch.model_input())
        kw = dict(head=params["lp"], score_fn="dot",
                  etypes=batch.edge_etypes)
        pos = lp_pair_scores(h, batch.pos_u, batch.pos_v, **kw)
        neg = lp_pair_scores(h, batch.pos_u, batch.neg_v, **kw)
        ranks.append(np.asarray(lp_ranks(pos, neg))[batch.pair_mask])
    r = np.concatenate(ranks).astype(np.float64)
    print(f"eval: mrr={(1.0 / r).mean():.3f} "
          f"hits@1={(r <= 1).mean():.3f} hits@10={(r <= 10).mean():.3f} "
          f"({len(r)} edges)")
    assert hist[-1] < hist[0], "link prediction failed to learn"
    print(f"link prediction learned: {hist[0]:.3f} -> {hist[-1]:.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configuration for CI smoke runs")
    args = ap.parse_args()
    if args.smoke:
        main(scale=9, epochs=2, batch_edges=8, num_negs=8)
    else:
        main()
