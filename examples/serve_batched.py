"""Batched serving example: prefill + streaming decode with ring-buffer KV
cache, across three architecture families (dense / SSM / hybrid) to show
the serve path is family-generic.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.models.lm import init_params, make_decode_step, make_prefill_step


def serve(arch, batch=4, prompt_len=32, gen=16):
    cfg = smoke_variant(get_config(arch))
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.key(0))
    cache_len = prompt_len + gen + 8
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                            (batch, prompt_len)))}
    if cfg.arch_type == "vlm":
        b["image_embeds"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.num_image_tokens, cfg.d_model)), jnp.float32)
        cache_len += cfg.num_image_tokens
    if cfg.arch_type == "audio":
        b["encoder_embeds"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    prefill = jax.jit(make_prefill_step(cfg, cache_len))
    decode = jax.jit(make_decode_step(cfg))
    logits, cache = prefill(params, b)
    tok = logits[:, :cfg.vocab_size].argmax(-1)[:, None].astype(jnp.int32)
    t0 = time.time()
    toks = [tok]
    for _ in range(gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = logits[:, :cfg.vocab_size].argmax(-1)[:, None].astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    seq = np.concatenate([np.asarray(t) for t in toks], axis=1)
    print(f"{arch:22s} [{cfg.arch_type:6s}] {batch}x{gen} tokens "
          f"in {dt:.2f}s -> {seq[0][:10].tolist()}")


def main():
    for arch in ("qwen2-0.5b", "mamba2-2.7b", "zamba2-7b"):
        serve(arch)


if __name__ == "__main__":
    main()
