"""Quickstart: the canonical DGL training loop against the DistDGLv2 stack.

The paper's usability claim (§4) is that distributed training needs
"almost no code modification" over single-machine DGL — and this is that
loop, verbatim, on top of the ``repro.api`` façade::

    for input_nodes, seeds, blocks in loader:
        ...

``DistGraph`` partitions a synthetic power-law graph for a simulated
2-machine cluster and stands up the distributed KVStore; ``node_split``
hands this trainer its owner-aligned seed set; ``NodeDataLoader`` drives
the 5-stage asynchronous mini-batch pipeline underneath the loop. The
multi-trainer synchronous-SGD driver (``repro.api.DistGNNTrainer``) is
built from exactly these pieces.

Run:  PYTHONPATH=src python examples/quickstart.py [--smoke]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import DistGraph, NodeDataLoader
from repro.graph import get_dataset
from repro.models.gnn import GNNConfig, apply_gnn, init_gnn, nc_accuracy, nc_loss
from repro.optim import adamw_init, adamw_update


def main(scale=12, epochs=5, batch_size=32, hidden=128, lr=3e-3, seed=0):
    ds = get_dataset("product-sim", scale=scale)
    cfg = GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                    hidden_dim=hidden, num_classes=ds.num_classes,
                    fanouts=[10, 5], batch_size=batch_size)

    # the distributed graph: hierarchical partition + KVStore shards
    g = DistGraph(ds, num_machines=2, trainers_per_machine=1,
                  partition_method="metis", seed=seed)
    train_nids = g.node_split()          # this trainer's owner-aligned seeds
    loader = NodeDataLoader(g, train_nids, cfg.fanouts,
                            batch_size=batch_size,
                            labels=g.labels[train_nids], seed=seed)
    print(f"{g.num_trainers} trainers | rank {g.rank} holds "
          f"{len(train_nids)} seeds, {len(loader)} batches/epoch | "
          f"features: {g.ndata['feat'].shape} via lazy DistTensor pulls")

    params = init_gnn(cfg, jax.random.key(seed))
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        def loss_fn(p):
            logits = apply_gnn(cfg, p, batch)
            return (nc_loss(logits, batch["labels"], batch["seed_mask"]),
                    nc_accuracy(logits, batch["labels"], batch["seed_mask"]))
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = adamw_update(params, grads, opt, lr=lr)
        return params, opt, loss, acc

    with loader:                          # context manager: clean teardown
        for epoch in range(epochs):
            losses, accs = [], []
            # THE loop — each iteration of `loader` is one epoch of
            # device-ready mini-batches from the async pipeline
            for batch in loader:
                input_nodes, seeds, blocks = batch      # DGL's triple
                params, opt, loss, acc = step(params, opt, batch.model_input())
                losses.append(float(loss)); accs.append(float(acc))
            print(f"epoch {epoch}: loss={np.mean(losses):.3f} "
                  f"acc={np.mean(accs):.2f}")

    # evaluation: a deterministic sequential loader over the val split
    val_nids = g.val_nids
    ev = NodeDataLoader(g, val_nids, cfg.fanouts, batch_size=batch_size,
                        labels=g.labels[val_nids], mode="eval",
                        sampler_seed=seed + 999)
    accs = [float(nc_accuracy(apply_gnn(cfg, params, b.model_input()),
                              jnp.asarray(b.labels), jnp.asarray(b.seed_mask)))
            for b in ev]
    print(f"val acc: {np.mean(accs):.3f}")
    print("loader stats:", {k: v for k, v in loader.stats_report().items()
                            if k != "stages"})
    hist = np.mean(losses)
    assert np.isfinite(hist), "training diverged"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configuration for CI smoke runs")
    args = ap.parse_args()
    if args.smoke:
        main(scale=11, epochs=3, batch_size=16, hidden=32)
    else:
        main()
