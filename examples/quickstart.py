"""Quickstart: the full DistDGLv2 stack in ~60 lines.

Partitions a synthetic power-law graph for a simulated 2-machine x 2-GPU
cluster, stands up the distributed KVStore, splits the training set with
the owner-compute rule, and trains GraphSAGE through the asynchronous
mini-batch pipeline with synchronous SGD across all 4 trainers.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.graph import get_dataset
from repro.models.gnn import GNNConfig
from repro.training import DistGNNTrainer, TrainJobConfig
from repro.core.kvstore import NetworkModel


def main():
    # a ~4k-node power-law graph standing in for ogbn-products
    ds = get_dataset("product-sim", scale=12)
    model = GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                      hidden_dim=128, num_classes=ds.num_classes,
                      fanouts=[10, 5], batch_size=32)
    job = TrainJobConfig(
        num_machines=2, trainers_per_machine=2,
        partition_method="metis",     # multi-constraint min-edge-cut (§5.3)
        use_level2=True,              # per-trainer seed clustering
        sync=False, non_stop=True,    # the full async pipeline (§5.5)
        network=NetworkModel(sleep=True),   # honest wall-clock remote costs
    )
    trainer = DistGNNTrainer(ds, model, job)
    print(f"{trainer.num_trainers} trainers | "
          f"{trainer.batches_per_epoch} batches/epoch | "
          f"seed locality {trainer.locality['mean_local_frac']:.0%}")
    for epoch in range(5):
        m = trainer.train_epoch(epoch)
        print(f"epoch {epoch}: loss={m['loss']:.3f} acc={m['acc']:.2f} "
              f"({m['time_s']:.2f}s)")
    print(f"val acc: {trainer.evaluate(ds.val_nids):.3f}")
    print("sampling stats:", trainer.sampling_stats())
    trainer.stop()


if __name__ == "__main__":
    main()
