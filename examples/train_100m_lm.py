"""End-to-end driver: train a ~100M-parameter qwen2-family model for a few
hundred steps through the async token pipeline (DistDGLv2's pipeline
transferred to the LM data path), with checkpointing.

Run:  PYTHONPATH=src python examples/train_100m_lm.py [--steps 300]
(~100M params is what fits a few-hundred-step budget on this CPU host;
the same driver scales to the full configs on a pod via repro.launch.train.)
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import save_pytree, load_pytree
from repro.configs import get_config
from repro.data import TokenStream
from repro.models.lm import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # ~100M-param member of the qwen2 family (same block structure as the
    # assigned qwen2-0.5b config, scaled down: 8L, d=512, vocab 32k)
    cfg = dataclasses.replace(
        get_config("qwen2-0.5b"), name="qwen2-100m",
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=2, head_dim=64,
        d_ff=2048, vocab_size=32000, remat=False, dtype="float32",
        attn_chunk=128, fsdp=False)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  ~{n_params/1e6:.0f}M params")

    step = jax.jit(make_train_step(cfg, lr=1e-3))
    params, opt = init_train_state(cfg, seed=0)
    stream = TokenStream(vocab=cfg.vocab_size, batch=args.batch,
                         seq=args.seq, cfg=cfg, seed=0)

    losses, t0 = [], time.time()
    for i, batch in enumerate(stream):
        if i >= args.steps:
            break
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
        if (i + 1) % 25 == 0:
            tput = (i + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {i+1:4d}  loss={np.mean(losses[-25:]):.4f}  "
                  f"{tput:.0f} tok/s")
    stream.stop()
    assert losses[-1] < losses[0] * 0.8, "did not learn"

    save_pytree(params, args.ckpt)
    params2 = load_pytree(params, args.ckpt)
    flat_a = jax.tree.leaves(params)
    flat_b = jax.tree.leaves(params2)
    assert all(np.allclose(a, b) for a, b in zip(flat_a, flat_b))
    print(f"checkpoint round-trip OK -> {args.ckpt}")
    print(f"final loss {np.mean(losses[-20:]):.4f} "
          f"(start {np.mean(losses[:20]):.4f})")


if __name__ == "__main__":
    main()
