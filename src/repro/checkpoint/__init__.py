from .checkpoint import load_pytree, save_pytree, save_kvstore, load_kvstore

__all__ = ["load_pytree", "save_pytree", "save_kvstore", "load_kvstore"]
