from .checkpoint import (load_cache, load_kvstore, load_pytree, save_cache,
                         save_kvstore, save_pytree)

__all__ = ["load_pytree", "save_pytree", "save_kvstore", "load_kvstore",
           "save_cache", "load_cache"]
