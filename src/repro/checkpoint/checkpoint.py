"""Checkpointing: pytrees (dense model/optimizer state) and KVStore shards
(features + sparse embeddings + their optimizer rows).

No orbax dependency: each leaf goes to an .npy file, the tree structure and
leaf paths to a JSON manifest. KVStore checkpoints are per-server (per
machine) — on a real cluster each host writes only its own shard, which is
what makes checkpointing billion-node embedding tables feasible.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)
    flat, treedef = leaves_with_paths
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_pytree(tree: Any, directory: str) -> None:
    os.makedirs(directory, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = []
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(directory, fname), np.asarray(leaf))
        manifest.append({"path": p, "file": fname})
    with open(os.path.join(directory, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_pytree(template: Any, directory: str) -> Any:
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, _ = _flatten_with_paths(template)
    by_path = {m["path"]: m["file"] for m in manifest}
    new_leaves = []
    for p, leaf in zip(paths, leaves):
        if p not in by_path:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        arr = np.load(os.path.join(directory, by_path[p]))
        new_leaves.append(arr.astype(np.asarray(leaf).dtype))
    flat_template = jax.tree_util.tree_flatten(template)[1]
    return jax.tree_util.tree_unflatten(flat_template, new_leaves)


def _kv_fname(part: int, name: str) -> str:
    # typed tensors are named "feat:<ntype>"; ':' is not portable in paths
    return f"part{part}_{name.replace(':', '__')}.npy"


def save_kvstore(store, directory: str) -> None:
    os.makedirs(directory, exist_ok=True)
    meta = {"num_parts": store.num_parts, "names": sorted(store._meta)}
    for p, server in enumerate(store.servers):
        for name in store._meta:
            np.save(os.path.join(directory, _kv_fname(p, name)),
                    server.local_view(name))
    with open(os.path.join(directory, "kv_manifest.json"), "w") as f:
        json.dump(meta, f)


def load_kvstore(store, directory: str) -> None:
    with open(os.path.join(directory, "kv_manifest.json")) as f:
        meta = json.load(f)
    assert meta["num_parts"] == store.num_parts
    for p, server in enumerate(store.servers):
        for name in meta["names"]:
            arr = np.load(os.path.join(directory, _kv_fname(p, name)))
            dst = server.local_view(name)
            assert dst.shape == arr.shape, (name, dst.shape, arr.shape)
            dst[...] = arr
    # a restore is a write like any other (DESIGN.md §5): bump mutable
    # tensors' versions AND flush every live cache's entries — unlike
    # pushes, a restore may rewrite even immutable tensors' bytes, so
    # version refusal alone cannot cover it
    for name in meta["names"]:
        if store.is_mutable(name):
            pol = store.policy_for(name)
            store.bump_versions(name, np.arange(pol.total, dtype=np.int64))
        store.invalidate_caches(name)
