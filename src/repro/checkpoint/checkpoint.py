"""Checkpointing: pytrees (dense model/optimizer state), KVStore shards
(features + sparse embeddings + their optimizer rows + row versions) and
trainer-side feature-cache snapshots.

No orbax dependency: each leaf goes to an .npy file, the tree structure and
leaf paths to a JSON manifest. KVStore checkpoints are per-server (per
machine) — on a real cluster each host writes only its own shard, which is
what makes checkpointing billion-node embedding tables feasible.

Restores are strict (DESIGN.md §10): a checkpoint that does not match its
template — missing leaves, extra leaves, shape or dtype drift — raises
instead of silently coercing. ``load_pytree(cast=True)`` is the explicit
escape hatch for intentional dtype migration (e.g. an x64 checkpoint into
an x32 run); it is the ONLY path that loses bits.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)
    flat, treedef = leaves_with_paths
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_pytree(tree: Any, directory: str) -> None:
    os.makedirs(directory, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = []
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(directory, fname), np.asarray(leaf))
        manifest.append({"path": p, "file": fname})
    with open(os.path.join(directory, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_pytree(template: Any, directory: str, *, cast: bool = False) -> Any:
    """Load a :func:`save_pytree` checkpoint into ``template``'s structure.

    Every template leaf must have a checkpointed counterpart (same path)
    with the same shape AND dtype — a float64 leaf saved under x64 and
    restored into a float32 template would otherwise lose bits silently.
    ``cast=True`` opts into ``astype`` coercion for dtype mismatches
    (shape mismatches always raise). Leaves in the checkpoint but not the
    template raise too: a byte-exact recovery cannot ignore state it does
    not know how to restore.
    """
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, _ = _flatten_with_paths(template)
    by_path = {m["path"]: m["file"] for m in manifest}
    extra = sorted(set(by_path) - set(paths))
    if extra:
        raise KeyError(f"checkpoint has {len(extra)} leaves the template "
                       f"does not: {extra[:5]}")
    new_leaves = []
    for p, leaf in zip(paths, leaves):
        if p not in by_path:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        arr = np.load(os.path.join(directory, by_path[p]))
        want = np.asarray(leaf)
        if arr.shape != want.shape:
            raise ValueError(f"leaf {p!r}: checkpoint shape {arr.shape} != "
                             f"template shape {want.shape}")
        if arr.dtype != want.dtype:
            if not cast:
                raise ValueError(
                    f"leaf {p!r}: checkpoint dtype {arr.dtype} != template "
                    f"dtype {want.dtype} — pass cast=True to coerce "
                    f"(lossy for narrowing casts)")
            arr = arr.astype(want.dtype)
        new_leaves.append(arr)
    flat_template = jax.tree_util.tree_flatten(template)[1]
    return jax.tree_util.tree_unflatten(flat_template, new_leaves)


def _kv_fname(part: int, name: str) -> str:
    # typed tensors are named "feat:<ntype>"; ':' is not portable in paths
    return f"part{part}_{name.replace(':', '__')}.npy"


def _versions_fname(name: str) -> str:
    return f"versions_{name.replace(':', '__')}.npy"


def save_kvstore(store, directory: str) -> None:
    """Per-server shards plus, for mutable tensors, the exact per-row
    version tables — the half of the cache-consistency pair that lets a
    restored :class:`~repro.core.kvstore.FeatureCache` snapshot validate
    again (DESIGN.md §10)."""
    os.makedirs(directory, exist_ok=True)
    meta = {"num_parts": store.num_parts, "names": sorted(store._meta),
            "versions": sorted(store.mutable_names())}
    for p, server in enumerate(store.servers):
        for name in store._meta:
            np.save(os.path.join(directory, _kv_fname(p, name)),
                    server.local_view(name))
    for name in meta["versions"]:
        np.save(os.path.join(directory, _versions_fname(name)),
                store.version_table(name))
    with open(os.path.join(directory, "kv_manifest.json"), "w") as f:
        json.dump(meta, f)


def load_kvstore(store, directory: str) -> None:
    with open(os.path.join(directory, "kv_manifest.json")) as f:
        meta = json.load(f)
    assert meta["num_parts"] == store.num_parts
    for p, server in enumerate(store.servers):
        for name in meta["names"]:
            arr = np.load(os.path.join(directory, _kv_fname(p, name)))
            dst = server.local_view(name)
            assert dst.shape == arr.shape, (name, dst.shape, arr.shape)
            dst[...] = arr
    # a restore is a write like any other (DESIGN.md §5): flush every live
    # cache's entries — unlike pushes, a restore may rewrite even immutable
    # tensors' bytes, so version refusal alone cannot cover it. Mutable
    # tensors restore their EXACT checkpointed version tables (so a cache
    # snapshot from the same checkpoint validates, DESIGN.md §10); legacy
    # checkpoints without saved versions fall back to the blanket bump.
    saved_versions = set(meta.get("versions", []))
    for name in meta["names"]:
        if store.is_mutable(name):
            if name in saved_versions:
                store.set_versions(
                    name,
                    np.load(os.path.join(directory, _versions_fname(name))))
            else:
                pol = store.policy_for(name)
                store.bump_versions(name,
                                    np.arange(pol.total, dtype=np.int64))
        store.invalidate_caches(name)
    # the loop above rewrote the PRIMARY shards in place; bring every
    # replica copy back to byte-identity so a post-restore failover read
    # still returns exactly the restored bytes (no-op at replication=1)
    if hasattr(store, "sync_replicas"):
        store.sync_replicas()


def save_cache(cache, directory: str) -> None:
    """Snapshot a trainer's :class:`FeatureCache` (gids + rows + version
    stamps per tensor). Pairs with the ``save_kvstore`` of the same
    checkpoint: the stamps only validate against those version tables."""
    os.makedirs(directory, exist_ok=True)
    state = cache.state_dict()
    manifest = {}
    for name, s in state.items():
        key = name.replace(":", "__")
        files = {"gids": f"cache_{key}_gids.npy",
                 "rows": f"cache_{key}_rows.npy"}
        np.save(os.path.join(directory, files["gids"]), s["gids"])
        np.save(os.path.join(directory, files["rows"]), s["rows"])
        if s["versions"] is not None:
            files["versions"] = f"cache_{key}_versions.npy"
            np.save(os.path.join(directory, files["versions"]), s["versions"])
        manifest[name] = files
    with open(os.path.join(directory, "cache_manifest.json"), "w") as f:
        json.dump(manifest, f)


def load_cache(cache, directory: str) -> int:
    """Restore a :func:`save_cache` snapshot; returns rows admitted.
    Must run AFTER ``load_kvstore`` of the same checkpoint — that call
    both restores the version tables the snapshot's stamps are checked
    against and flushes whatever the cache held before."""
    with open(os.path.join(directory, "cache_manifest.json")) as f:
        manifest = json.load(f)
    state = {}
    for name, files in manifest.items():
        state[name] = {
            "gids": np.load(os.path.join(directory, files["gids"])),
            "rows": np.load(os.path.join(directory, files["rows"])),
            "versions": (np.load(os.path.join(directory, files["versions"]))
                         if "versions" in files else None),
        }
    return cache.load_state_dict(state)
