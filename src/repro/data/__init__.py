from .stream import TokenStream

__all__ = ["TokenStream"]
