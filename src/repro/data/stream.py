"""Token data pipeline for the LM architectures.

This is where DistDGLv2's core idea transfers to sequence models (DESIGN.md
§Arch-applicability): host-side batch assembly runs through the same
:class:`AsyncPipeline` (schedule -> assemble -> host prefetch -> device
prefetch, per-stage bounded queues, non-stop across epochs) so the
accelerator never waits on the input pipeline. The "owner-compute split"
maps to per-host sharding of the sample stream.

Sources: a synthetic structured-token generator (offline default — token
streams with learnable n-gram structure so loss curves are meaningful) or
a memory-mapped token file.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

from ..core.pipeline import AsyncPipeline, Stage
from ..kernels.pack import device_stage


def _synthetic_tokens(rng: np.random.Generator, vocab: int, n: int,
                      order: int = 2, alpha: float = 0.9) -> np.ndarray:
    """Markov-ish stream: next token depends on the previous one (a learnable
    structure; uniform random tokens would give a flat loss)."""
    # deterministic per-token successor table
    table_rng = np.random.default_rng(12345)
    succ = table_rng.integers(0, vocab, size=(vocab, 4))
    out = np.empty(n, dtype=np.int32)
    out[0] = rng.integers(0, vocab)
    picks = rng.integers(0, 4, size=n)
    noise = rng.random(n)
    rand = rng.integers(0, vocab, size=n)
    for i in range(1, n):
        out[i] = succ[out[i - 1], picks[i]] if noise[i] < alpha else rand[i]
    return out


class TokenStream:
    """Iterator of device-ready LM batches through the async pipeline."""

    def __init__(self, vocab: int, batch: int, seq: int, *, cfg=None,
                 seed: int = 0, host_index: int = 0, host_count: int = 1,
                 sync: bool = False, file: Optional[str] = None,
                 depths: Optional[dict] = None, packed: bool = True):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.cfg = cfg
        self.rng = np.random.default_rng(seed + 7919 * host_index)
        self.host_index = host_index
        self.host_count = host_count
        self.file = None
        if file is not None:
            self.file = np.memmap(file, dtype=np.int32, mode="r")
        # packed=True: one device_put per batch (DESIGN.md §9)
        self.packed = packed
        d = {"assemble": 8, "host_prefetch": 4, "device_prefetch": 1}
        d.update(depths or {})
        stages = [
            Stage("assemble", self._assemble, depth=d["assemble"]),
            Stage("host_prefetch", self._host_prefetch,
                  depth=d["host_prefetch"]),
            Stage("device_prefetch", self._device_prefetch,
                  depth=d["device_prefetch"]),
        ]
        self._pipe = AsyncPipeline(self._schedule(), stages, sync=sync,
                                   name="tokenstream")
        self._it = iter(self._pipe)

    # ---- stages -------------------------------------------------------
    def _schedule(self) -> Iterator[int]:
        i = self.host_index          # owner-compute split over hosts
        while True:
            yield i
            i += self.host_count

    def _assemble(self, index: int) -> dict:
        n = self.batch * self.seq
        if self.file is not None:
            total = len(self.file) - n - 1
            off = int(self.rng.integers(0, max(total, 1)))
            toks = np.asarray(self.file[off:off + n], dtype=np.int32)
        else:
            toks = _synthetic_tokens(self.rng, self.vocab, n)
        return {"tokens": toks.reshape(self.batch, self.seq)}

    def _host_prefetch(self, batch: dict) -> dict:
        cfg = self.cfg
        if cfg is not None and cfg.arch_type == "vlm":
            batch["image_embeds"] = self.rng.standard_normal(
                (self.batch, cfg.num_image_tokens, cfg.d_model)
            ).astype(np.float32)
        if cfg is not None and cfg.arch_type == "audio":
            batch["encoder_embeds"] = self.rng.standard_normal(
                (self.batch, cfg.encoder_seq, cfg.d_model)
            ).astype(np.float32)
        return batch

    def _device_prefetch(self, batch: dict) -> dict:
        staged = device_stage(batch, packed=self.packed)
        # LM steps index the dict directly, so unpack to a flat mapping of
        # device arrays (the unpack is a jitted zero-copy static slice)
        return staged.unpack() if self.packed else staged

    # ---- iteration ----------------------------------------------------
    def __iter__(self):
        return self

    def __next__(self):
        return next(self._it)

    def stop(self):
        self._pipe.stop()
