"""Pallas TPU kernel: masked segment-sum (padded-edge GNN aggregation).

TPU adaptation of the scatter-add the paper's GPU backend (cuSPARSE /
segment reduce) performs: TPUs have no fast scatter, but they have an MXU.
We therefore express the per-destination reduction as a *one-hot matmul*:
for a (EB,)-block of edges and an (NB,)-block of destination rows,

    out[NB, FB] += onehot(edge_dst)[EB, NB]^T @ msg[EB, FB]

which runs on the systolic array. The grid is (dst_blocks, feat_blocks,
edge_blocks) with the edge dimension innermost: TPU grids execute
sequentially, so the output block stays resident in VMEM across the whole
edge sweep (standard accumulate-over-last-axis pattern).

Block sizes default to EB=512, NB=128, FB=128 — MXU-aligned (multiples of
128 in the matmul dims) and a VMEM working set of
EB*FB (msg) + NB*FB (acc) + EB*NB (onehot) floats ≈ 0.5 MB ≪ 16 MB VMEM.

Padding rows (edge_mask=0) contribute zero columns in the one-hot, so
padded MFG mini-batches aggregate exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_EB = 512
DEFAULT_NB = 128
DEFAULT_FB = 128


def _kernel(dst_ref, mask_ref, msg_ref, out_ref, *, nb: int):
    i = pl.program_id(0)          # dst block
    k = pl.program_id(2)          # edge block (innermost: accumulation)

    @pl.when(k == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = dst_ref[...]            # (EB,) int32
    mask = mask_ref[...]          # (EB,) bool
    msg = msg_ref[...]            # (EB, FB)
    rows = i * nb + jax.lax.broadcasted_iota(jnp.int32, (dst.shape[0], nb), 1)
    onehot = ((dst[:, None] == rows) & mask[:, None]).astype(msg.dtype)
    out_ref[...] += jnp.dot(onehot.T, msg,
                            preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_dst", "eb", "nb", "fb",
                                             "interpret"))
def segment_sum_pallas(msg: jnp.ndarray, edge_dst: jnp.ndarray,
                       edge_mask: jnp.ndarray, num_dst: int, *,
                       eb: int = DEFAULT_EB, nb: int = DEFAULT_NB,
                       fb: int = DEFAULT_FB, interpret: bool = True
                       ) -> jnp.ndarray:
    e, f = msg.shape
    eb = min(eb, e)
    nb = min(nb, num_dst)
    fb = min(fb, f)
    # pad every axis to its block multiple
    ep = -(-e // eb) * eb
    np_ = -(-num_dst // nb) * nb
    fp = -(-f // fb) * fb
    msg_p = jnp.pad(msg, ((0, ep - e), (0, fp - f)))
    dst_p = jnp.pad(edge_dst.astype(jnp.int32), (0, ep - e),
                    constant_values=-1)
    mask_p = jnp.pad(edge_mask.astype(jnp.bool_), (0, ep - e))

    grid = (np_ // nb, fp // fb, ep // eb)
    out = pl.pallas_call(
        functools.partial(_kernel, nb=nb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((eb,), lambda i, j, k: (k,)),
            pl.BlockSpec((eb,), lambda i, j, k: (k,)),
            pl.BlockSpec((eb, fb), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((nb, fb), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, fp), msg.dtype),
        interpret=interpret,
    )(dst_p, mask_p, msg_p)
    return out[:num_dst, :f]
