"""Pure-jnp oracle for masked segment-sum (GNN neighbor aggregation).

out[d] = sum over edges e with edge_dst[e]==d and edge_mask[e] of msg[e].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(msg: jnp.ndarray, edge_dst: jnp.ndarray,
                    edge_mask: jnp.ndarray, num_dst: int) -> jnp.ndarray:
    """msg: (E, F); edge_dst: (E,) int32; edge_mask: (E,) bool -> (num_dst, F)."""
    msg = jnp.where(edge_mask[:, None], msg, 0)
    return jax.ops.segment_sum(msg, edge_dst.astype(jnp.int32),
                               num_segments=num_dst)


def segment_max_ref(x: jnp.ndarray, edge_dst: jnp.ndarray,
                    edge_mask: jnp.ndarray, num_dst: int,
                    neutral: float = -1e30) -> jnp.ndarray:
    """x: (E,) -> (num_dst,) per-destination max (masked)."""
    x = jnp.where(edge_mask, x, neutral)
    return jax.ops.segment_max(x, edge_dst.astype(jnp.int32),
                               num_segments=num_dst)
