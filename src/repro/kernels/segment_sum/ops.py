"""Public op: masked segment-sum with implementation dispatch.

``impl="auto"`` picks the pure-jnp reference on CPU (XLA's native scatter is
fine there and Pallas interpret mode is an emulator, not a performance
path) and the Pallas kernel on TPU. Tests sweep both and assert allclose.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import segment_sum_pallas
from .ref import segment_sum_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def segment_sum(msg: jnp.ndarray, edge_dst: jnp.ndarray,
                edge_mask: jnp.ndarray, num_dst: int,
                impl: str = "auto") -> jnp.ndarray:
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return segment_sum_ref(msg, edge_dst, edge_mask, num_dst)
    if impl == "pallas":
        return segment_sum_pallas(msg, edge_dst, edge_mask, num_dst,
                                  interpret=not _on_tpu())
    raise ValueError(f"unknown impl {impl!r}")
