"""Packed one-shot device staging (DESIGN.md §9).

The device-prefetch stage used to ship every mini-batch as ~10 independent
``jax.device_put`` calls of small arrays — one per field, four more per MFG
block — so the stage was dominated by per-transfer overhead, not bandwidth.
This module packs the whole batch into **one contiguous host arena** (one
contiguous segment per dtype — at most four: f32 / i64 / i32 / bool) and
issues a **single one-buffer** ``jax.device_put``; the per-field views are
recovered *on device* by a jitted unpack whose byte offsets are
compile-time constants (the padded-MFG capacity contract of DESIGN.md §2
makes every shape static, so the same :class:`PackSpec` — and the same
compiled unpack — is reused for every batch of a run).

Value contract: staging through ``pack -> device_put -> unpack`` is
*byte-identical* to per-array ``device_put`` of the same tree.  Both paths
apply exactly jax's canonicalization casts (with x64 disabled an int64
array lands as int32 either way, applied here on the host while filling
the packed arena), and unpacking is pure static slicing + reshape +
bitcast — ``lax.bitcast_convert_type`` from the arena's uint8 bytes back
to each dtype is bit-exact by definition, and the bool segment is
recovered with ``!= 0`` (exact: NumPy bool storage is 0/1 bytes).  No
arithmetic touches the payload.

Layout: leaves are keyed by their "/"-joined tree path (lists by index,
e.g. ``blocks/0/edge_src``), sorted by key within each dtype segment so
the offset table is a pure function of the spec; dtype segments are laid
out in descending-itemsize order, so every segment's byte offset is a
multiple of its itemsize (alignment for free).  ``None`` leaves are
recorded in the spec and resurface as ``None`` on unpack (a label-less
epoch keeps its ``labels=None`` slot).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"

# dtypes jax silently canonicalizes when x64 is disabled; applied on the
# host while filling the buffer so packed == per-array staging bit-for-bit
_CANON = {np.dtype(np.int64): np.dtype(np.int32),
          np.dtype(np.uint64): np.dtype(np.uint32),
          np.dtype(np.float64): np.dtype(np.float32)}


def _canon_dtype(dt: np.dtype) -> np.dtype:
    if jax.config.jax_enable_x64:
        return dt
    return _CANON.get(dt, dt)


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static description of one packed batch: per-field (path, shape,
    dtype) plus the paths of ``None`` leaves.  Hashable — it is the cache
    key for the compiled unpack program."""

    fields: Tuple[Tuple[str, Tuple[int, ...], str], ...]
    none_paths: Tuple[str, ...] = ()

    @functools.cached_property
    def layout(self) -> Tuple[Tuple[str, Tuple[int, ...], str, int, int], ...]:
        """(path, shape, dtype, offset, size) per field; offsets count
        elements within that dtype's 1-D buffer, in sorted-path order."""
        cursor: Dict[str, int] = {}
        out = []
        for path, shape, dt in sorted(self.fields):
            size = int(np.prod(shape, dtype=np.int64)) if shape else 1
            off = cursor.get(dt, 0)
            out.append((path, shape, dt, off, size))
            cursor[dt] = off + size
        return tuple(out)

    @functools.cached_property
    def buffer_sizes(self) -> Dict[str, int]:
        sizes: Dict[str, int] = {}
        for _, _, dt, off, size in self.layout:
            sizes[dt] = off + size
        return sizes

    @property
    def num_buffers(self) -> int:
        return len(self.buffer_sizes)

    @functools.cached_property
    def arena_layout(self) -> Tuple[Tuple[str, int, int], ...]:
        """(dtype, byte_offset, num_elements) per dtype segment of the
        arena, in descending-itemsize order — each segment's offset is a
        multiple of its itemsize (itemsizes are powers of two)."""
        segs = sorted(self.buffer_sizes.items(),
                      key=lambda kv: (-np.dtype(kv[0]).itemsize, kv[0]))
        out, off = [], 0
        for dt, n in segs:
            out.append((dt, off, n))
            off += n * np.dtype(dt).itemsize
        return tuple(out)

    def total_bytes(self) -> int:
        return sum(n * np.dtype(dt).itemsize
                   for dt, n in self.buffer_sizes.items())


def flatten_tree(tree: Any) -> Tuple[Dict[str, np.ndarray], Tuple[str, ...]]:
    """Nested dict/list/tuple batch -> ({path: array}, none_paths)."""
    flat: Dict[str, np.ndarray] = {}
    nones = []

    def walk(prefix: str, node: Any) -> None:
        if node is None:
            nones.append(prefix)
        elif isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}{SEP}{k}" if prefix else str(k), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}{SEP}{i}" if prefix else str(i), v)
        else:
            flat[prefix] = np.asarray(node)

    walk("", tree)
    return flat, tuple(sorted(nones))


def unflatten_tree(flat: Dict[str, Any], none_paths: Tuple[str, ...] = ()
                   ) -> Any:
    """Inverse of :func:`flatten_tree`: "/"-paths back to nested
    dicts/lists (a node whose keys are all decimal becomes a list)."""
    root: Dict[str, Any] = {}
    for path in list(flat) + list(none_paths):
        parts = path.split(SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = None if path in none_paths else flat[path]

    def rebuild(node: Any) -> Any:
        if not isinstance(node, dict):
            return node
        if node and all(k.isdigit() for k in node):
            return [rebuild(node[str(i)]) for i in range(len(node))]
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


@functools.lru_cache(maxsize=256)
def _spec_cache(fields, none_paths) -> PackSpec:
    # padded-MFG shapes are static across a run (DESIGN.md §2), so every
    # batch hits the same spec — the layout/offset table is computed once
    return PackSpec(fields, none_paths)


def pack(tree: Any) -> Tuple[PackSpec, np.ndarray]:
    """Flatten a host batch into ONE contiguous uint8 arena (one segment
    per dtype, fields at static offsets within their segment)."""
    flat, none_paths = flatten_tree(tree)
    fields = []
    for path, arr in flat.items():
        dt = _canon_dtype(arr.dtype)
        fields.append((path, tuple(arr.shape), dt.str))
    spec = _spec_cache(tuple(sorted(fields)), none_paths)
    arena = np.empty(spec.total_bytes(), dtype=np.uint8)
    views = {dt: arena[boff:boff + n * np.dtype(dt).itemsize].view(dt)
             for dt, boff, n in spec.arena_layout}
    for path, shape, dt, off, size in spec.layout:
        # ravel + canonicalization cast in one copy into the arena
        np.copyto(views[dt][off:off + size].reshape(shape), flat[path],
                  casting="unsafe")
    return spec, arena


@functools.lru_cache(maxsize=None)
def _unpack_fn(spec: PackSpec):
    """Compiled device-side unpack for one spec: static byte slices +
    bitcast back to each dtype + per-field reshape (offsets are python
    ints at trace time -> compile-time constants; every step bit-exact)."""
    segs = {}
    for dt, boff, n in spec.arena_layout:
        segs[dt] = (boff, n, np.dtype(dt))

    def unpack_flat(arena: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        bufs = {}
        for dt, (boff, n, nd) in segs.items():
            raw = arena[boff:boff + n * nd.itemsize]
            if nd == np.dtype(bool):
                bufs[dt] = raw != 0          # exact: bool bytes are 0/1
            else:
                bufs[dt] = jax.lax.bitcast_convert_type(
                    raw.reshape(n, nd.itemsize), nd)
        out = {}
        for path, shape, dt, off, size in spec.layout:
            out[path] = bufs[dt][off:off + size].reshape(shape)
        return out

    return jax.jit(unpack_flat)


def unpack_flat(spec: PackSpec, arena: jnp.ndarray
                ) -> Dict[str, jnp.ndarray]:
    """Device arena -> {path: device array}.  Also traceable inside an
    outer jit (the donation path fuses it into the train step)."""
    return _unpack_fn(spec)(arena)


def unpack(spec: PackSpec, arena: jnp.ndarray) -> Any:
    """Device arena -> the original nested tree (``None`` leaves
    restored), every leaf a view into the packed device arena."""
    return unflatten_tree(unpack_flat(spec, arena), spec.none_paths)


class PackedBatch:
    """One staged mini-batch: the spec + its device-resident uint8 arena.

    ``unpack()`` recovers the nested device tree (cached — slicing a
    resident buffer is cheap but not free); ``buffers`` is the single
    arena array, the donation unit a jitted step can consume with
    ``donate_argnums`` (DESIGN.md §9: donate only on non-CPU backends —
    the CPU runtime warns and ignores).
    """

    __slots__ = ("spec", "buffers", "_tree")

    def __init__(self, spec: PackSpec, buffers: jnp.ndarray):
        self.spec = spec
        self.buffers = buffers
        self._tree = None

    def unpack(self) -> Any:
        if self._tree is None:
            self._tree = unpack(self.spec, self.buffers)
        return self._tree

    def __getitem__(self, key: str) -> Any:
        return self.unpack()[key]

    def total_bytes(self) -> int:
        return self.spec.total_bytes()


@functools.lru_cache(maxsize=1)
def _cpu_backend() -> bool:
    return jax.default_backend() == "cpu"


def _stage_arena(arena: np.ndarray) -> jnp.ndarray:
    # On the CPU backend the dlpack import is the cheapest ingest path
    # (same bytes, lower dispatch overhead than device_put).  On an
    # accelerator it would land the buffer on the HOST device, so there
    # we keep device_put (one H2D transfer of the whole arena).
    if _cpu_backend():
        try:
            return jnp.from_dlpack(arena)
        except Exception:  # pragma: no cover - old jax without dlpack
            pass
    return jax.device_put(arena)


def device_stage(tree: Any, packed: bool = True):
    """The shared device-prefetch helper (both mini-batch pipelines and
    the LM token stream stage through here).

    ``packed=True``: pack -> ONE single-buffer transfer of the uint8
    arena -> :class:`PackedBatch`.  ``packed=False``: the legacy
    per-array path — one ``device_put`` per leaf, ``None`` leaves passed
    through — kept as the ablation baseline the benchmarks compare
    against.
    """
    if not packed:
        return jax.tree.map(jax.device_put, tree)
    spec, arena = pack(tree)
    return PackedBatch(spec, _stage_arena(arena))
