from .ops import (PackSpec, PackedBatch, device_stage, flatten_tree, pack,
                  unflatten_tree, unpack, unpack_flat)

__all__ = ["PackSpec", "PackedBatch", "device_stage", "flatten_tree",
           "pack", "unflatten_tree", "unpack", "unpack_flat"]
