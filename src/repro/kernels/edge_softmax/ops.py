"""Public op: per-destination edge softmax with implementation dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import edge_softmax_pallas
from .ref import edge_softmax_ref


def edge_softmax(scores: jnp.ndarray, edge_dst: jnp.ndarray,
                 edge_mask: jnp.ndarray, num_dst: int,
                 impl: str = "auto") -> jnp.ndarray:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return edge_softmax_ref(scores, edge_dst, edge_mask, num_dst)
    if impl == "pallas":
        return edge_softmax_pallas(scores, edge_dst, edge_mask, num_dst,
                                   interpret=jax.default_backend() != "tpu")
    raise ValueError(f"unknown impl {impl!r}")
