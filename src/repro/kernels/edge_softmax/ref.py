"""Pure-jnp oracle for per-destination edge softmax (GAT attention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = -1e30


def edge_softmax_ref(scores: jnp.ndarray, edge_dst: jnp.ndarray,
                     edge_mask: jnp.ndarray, num_dst: int) -> jnp.ndarray:
    """scores: (E, H); per-dst softmax over incoming edges, masked.

    Padded edges get weight 0. Destinations with no edges produce no
    contributions anywhere, so their (undefined) softmax never surfaces.
    """
    dst = edge_dst.astype(jnp.int32)
    s = jnp.where(edge_mask[:, None], scores, _NEG)
    m = jax.ops.segment_max(s, dst, num_segments=num_dst)       # (N, H)
    m = jnp.where(m <= _NEG / 2, 0.0, m)                        # empty dsts
    ex = jnp.where(edge_mask[:, None], jnp.exp(s - m[dst]), 0.0)
    denom = jax.ops.segment_sum(ex, dst, num_segments=num_dst)  # (N, H)
    denom = jnp.maximum(denom, 1e-30)
    return ex / denom[dst]
