"""Pallas TPU kernel: per-destination edge softmax (GAT), two-phase.

GPU implementations scatter with atomics; the TPU adaptation reuses the
one-hot-matmul trick from the segment-sum kernel, in two pallas_calls:

  Phase 1 (stats): grid (dst_blocks, edge_blocks), edge axis innermost.
    For each dst block keep running per-row max ``m`` and, flash-attention
    style, an *online-rescaled* sum ``s``: when a new edge block raises the
    max, the old sum is rescaled by exp(m_old - m_new). Both live in VMEM
    across the edge sweep.

  Phase 2 (normalize): grid (edge_blocks,). Each edge re-reads its dst's
    (m, s) — a (EB, NB) one-hot matmul against the stats block — and emits
    exp(score - m)/s. Padded edges emit 0.

Head dim H rides along as the trailing (vector-lane) axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30


def _stats_kernel(dst_ref, mask_ref, s_ref, m_out, d_out, *, nb: int):
    i = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        m_out[...] = jnp.full_like(m_out, _NEG)
        d_out[...] = jnp.zeros_like(d_out)

    dst = dst_ref[...]                       # (EB,)
    mask = mask_ref[...]                     # (EB,)
    sc = s_ref[...]                          # (EB, H)
    eb = dst.shape[0]
    rows = i * nb + jax.lax.broadcasted_iota(jnp.int32, (eb, nb), 1)
    onehot = ((dst[:, None] == rows) & mask[:, None])           # (EB, NB)
    sc_masked = jnp.where(mask[:, None], sc, _NEG)              # (EB, H)
    # block max per dst row: (NB, H)
    contrib = jnp.where(onehot[:, :, None], sc_masked[:, None, :], _NEG)
    blk_max = contrib.max(axis=0)
    m_old = m_out[...]
    m_new = jnp.maximum(m_old, blk_max)
    scale = jnp.exp(m_old - m_new)                              # (NB, H)
    ex = jnp.where(onehot[:, :, None],
                   jnp.exp(sc_masked[:, None, :] - m_new[None]), 0.0)
    d_out[...] = d_out[...] * scale + ex.sum(axis=0)
    m_out[...] = m_new


def _norm_kernel(dst_ref, mask_ref, s_ref, m_ref, d_ref, out_ref):
    dst = dst_ref[...]                       # (EB,) global dst ids
    mask = mask_ref[...]
    sc = s_ref[...]                          # (EB, H)
    m = m_ref[dst]                           # (EB, H) gather from full stats
    d = d_ref[dst]
    w = jnp.exp(sc - m) / jnp.maximum(d, 1e-30)
    out_ref[...] = jnp.where(mask[:, None], w, 0.0)


@functools.partial(jax.jit, static_argnames=("num_dst", "eb", "nb",
                                             "interpret"))
def edge_softmax_pallas(scores: jnp.ndarray, edge_dst: jnp.ndarray,
                        edge_mask: jnp.ndarray, num_dst: int, *,
                        eb: int = 512, nb: int = 128,
                        interpret: bool = True) -> jnp.ndarray:
    e, h = scores.shape
    eb = min(eb, e)
    nb = min(nb, num_dst)
    ep = -(-e // eb) * eb
    np_ = -(-num_dst // nb) * nb
    sc = jnp.pad(scores, ((0, ep - e), (0, 0)))
    dst = jnp.pad(edge_dst.astype(jnp.int32), (0, ep - e), constant_values=-1)
    mask = jnp.pad(edge_mask.astype(jnp.bool_), (0, ep - e))

    m, d = pl.pallas_call(
        functools.partial(_stats_kernel, nb=nb),
        grid=(np_ // nb, ep // eb),
        in_specs=[
            pl.BlockSpec((eb,), lambda i, k: (k,)),
            pl.BlockSpec((eb,), lambda i, k: (k,)),
            pl.BlockSpec((eb, h), lambda i, k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((nb, h), lambda i, k: (i, 0)),
            pl.BlockSpec((nb, h), lambda i, k: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((np_, h), scores.dtype),
                   jax.ShapeDtypeStruct((np_, h), scores.dtype)],
        interpret=interpret,
    )(dst, mask, sc)

    # phase 2: per-edge normalize; stats stay fully resident (N is the
    # mini-batch dst count — small), edges stream through in EB blocks.
    dst_c = jnp.clip(dst, 0, np_ - 1)
    out = pl.pallas_call(
        _norm_kernel,
        grid=(ep // eb,),
        in_specs=[
            pl.BlockSpec((eb,), lambda k: (k,)),
            pl.BlockSpec((eb,), lambda k: (k,)),
            pl.BlockSpec((eb, h), lambda k: (k, 0)),
            pl.BlockSpec((np_, h), lambda k: (0, 0)),
            pl.BlockSpec((np_, h), lambda k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((eb, h), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((ep, h), scores.dtype),
        interpret=interpret,
    )(dst_c, mask, sc, m, d)
    return out[:e]
