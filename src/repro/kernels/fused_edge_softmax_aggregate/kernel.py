"""Pallas TPU kernel: fused attention tail — edge softmax + weighted
gather + segment-sum in two pallas_calls, never materializing the
(E, H*Dh) message array.

Phase 1 reuses the edge-softmax stats kernel verbatim (online-rescaled
per-destination max ``m`` and denominator ``d``, flash-attention style).

Phase 2 fuses what used to be three HBM-bound steps (normalize ->
gather+weight -> segment-sum) into one edge sweep: for each edge block it
recomputes the normalized attention weight from the resident stats
(``exp(score - m[dst]) / d[dst]``), gathers the projected source rows from
the feature-block-resident table, applies the per-head weight (repeated
over the head width), and folds the tile into the per-destination
accumulator with the one-hot matmul.  The (EB, F) weighted message tile
only ever lives in VMEM.

Grid (dst_blocks, edge_blocks) with the flattened feature axis F = H*Dh
fully resident: F is a hidden dimension (hundreds), not a graph axis, and
the stats gather needs whole (N, H) stats blocks anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..edge_softmax.kernel import _stats_kernel

DEFAULT_EB = 512
DEFAULT_NB = 128


def _agg_kernel(src_ref, dst_ref, mask_ref, s_ref, h_ref, m_ref, d_ref,
                out_ref, *, nb: int, dh: int, fp: int):
    i = pl.program_id(0)          # dst block
    k = pl.program_id(1)          # edge block (innermost: accumulation)

    @pl.when(k == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    src = src_ref[...]            # (EB,)
    dst = dst_ref[...]            # (EB,) clipped global dst ids
    mask = mask_ref[...]          # (EB,)
    sc = s_ref[...]               # (EB, H)
    m = m_ref[dst]                # (EB, H) gather from full stats block
    d = d_ref[dst]
    w = jnp.exp(sc - m) / jnp.maximum(d, 1e-30)       # (EB, H)
    w = jnp.where(mask[:, None], w, 0.0)
    wf = jnp.repeat(w, dh, axis=1)                    # (EB, H*Dh)
    wf = jnp.pad(wf, ((0, 0), (0, fp - wf.shape[1])))
    msg = h_ref[src] * wf                             # (EB, Fp) in VMEM only
    rows = i * nb + jax.lax.broadcasted_iota(jnp.int32, (dst.shape[0], nb), 1)
    onehot = ((dst[:, None] == rows) & mask[:, None]).astype(msg.dtype)
    out_ref[...] += jnp.dot(onehot.T, msg,
                            preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_dst", "eb", "nb",
                                             "interpret"))
def fused_edge_softmax_aggregate_pallas(h_proj: jnp.ndarray,
                                        scores: jnp.ndarray,
                                        edge_src: jnp.ndarray,
                                        edge_dst: jnp.ndarray,
                                        edge_mask: jnp.ndarray,
                                        num_dst: int, *,
                                        eb: int = DEFAULT_EB,
                                        nb: int = DEFAULT_NB,
                                        interpret: bool = True
                                        ) -> jnp.ndarray:
    v, h, dh = h_proj.shape
    f = h * dh
    e = scores.shape[0]
    eb = min(eb, e)
    nb = min(nb, num_dst)
    ep = -(-e // eb) * eb
    np_ = -(-num_dst // nb) * nb
    fp = -(-f // 128) * 128 if f > 128 else f
    vp = -(-v // 8) * 8
    sc = jnp.pad(scores, ((0, ep - e), (0, 0)))
    src_p = jnp.pad(edge_src.astype(jnp.int32), (0, ep - e))
    dst = jnp.pad(edge_dst.astype(jnp.int32), (0, ep - e),
                  constant_values=-1)
    mask = jnp.pad(edge_mask.astype(jnp.bool_), (0, ep - e))
    h2 = jnp.pad(h_proj.reshape(v, f), ((0, vp - v), (0, fp - f)))

    # phase 1: per-destination (max, denominator) — the edge-softmax stats
    m, d = pl.pallas_call(
        functools.partial(_stats_kernel, nb=nb),
        grid=(np_ // nb, ep // eb),
        in_specs=[
            pl.BlockSpec((eb,), lambda i, k: (k,)),
            pl.BlockSpec((eb,), lambda i, k: (k,)),
            pl.BlockSpec((eb, h), lambda i, k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((nb, h), lambda i, k: (i, 0)),
            pl.BlockSpec((nb, h), lambda i, k: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((np_, h), scores.dtype),
                   jax.ShapeDtypeStruct((np_, h), scores.dtype)],
        interpret=interpret,
    )(dst, mask, sc)

    # phase 2: fused normalize + weighted gather + aggregate
    dst_c = jnp.clip(dst, 0, np_ - 1)
    out = pl.pallas_call(
        functools.partial(_agg_kernel, nb=nb, dh=dh, fp=fp),
        grid=(np_ // nb, ep // eb),
        in_specs=[
            pl.BlockSpec((eb,), lambda i, k: (k,)),
            pl.BlockSpec((eb,), lambda i, k: (k,)),
            pl.BlockSpec((eb,), lambda i, k: (k,)),
            pl.BlockSpec((eb, h), lambda i, k: (k, 0)),
            pl.BlockSpec((vp, fp), lambda i, k: (0, 0)),
            pl.BlockSpec((np_, h), lambda i, k: (0, 0)),
            pl.BlockSpec((np_, h), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((nb, fp), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, fp), h_proj.dtype),
        interpret=interpret,
    )(src_p, dst_c, mask, sc, h2, m, d)
    return out[:num_dst, :f]
