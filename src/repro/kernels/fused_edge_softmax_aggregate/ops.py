"""Public op: fused attention tail with implementation dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import fused_edge_softmax_aggregate_pallas
from .ref import fused_edge_softmax_aggregate_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_edge_softmax_aggregate(h_proj: jnp.ndarray, scores: jnp.ndarray,
                                 edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                                 edge_mask: jnp.ndarray, num_dst: int,
                                 impl: str = "auto") -> jnp.ndarray:
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return fused_edge_softmax_aggregate_ref(h_proj, scores, edge_src,
                                                edge_dst, edge_mask, num_dst)
    if impl == "pallas":
        return fused_edge_softmax_aggregate_pallas(h_proj, scores, edge_src,
                                                   edge_dst, edge_mask,
                                                   num_dst,
                                                   interpret=not _on_tpu())
    raise ValueError(f"unknown impl {impl!r}")
