"""Pure-jnp oracle for the fused attention tail (edge softmax -> weighted
gather -> segment-sum).

Exactly the composition ``gat_layer`` used to inline, so routing the layer
through this op with ``impl="ref"`` produces the SAME jaxpr as before the
fusion existed (pinned by the golden byte-identity tests).
"""
from __future__ import annotations

import jax.numpy as jnp

from ..edge_softmax.ref import edge_softmax_ref
from ..segment_sum.ref import segment_sum_ref


def fused_edge_softmax_aggregate_ref(h_proj: jnp.ndarray,
                                     scores: jnp.ndarray,
                                     edge_src: jnp.ndarray,
                                     edge_dst: jnp.ndarray,
                                     edge_mask: jnp.ndarray,
                                     num_dst: int) -> jnp.ndarray:
    """h_proj: (V, H, Dh); scores: (E, H) -> (num_dst, H*Dh): per-dst
    softmax over incoming edges, attention-weighted sum of source rows."""
    alpha = edge_softmax_ref(scores, edge_dst, edge_mask, num_dst)
    msg = (h_proj[edge_src] * alpha[:, :, None]).reshape(edge_src.shape[0], -1)
    return segment_sum_ref(msg, edge_dst, edge_mask, num_dst)
