from .ops import fused_edge_softmax_aggregate
from .ref import fused_edge_softmax_aggregate_ref
from .kernel import fused_edge_softmax_aggregate_pallas

__all__ = ["fused_edge_softmax_aggregate",
           "fused_edge_softmax_aggregate_ref",
           "fused_edge_softmax_aggregate_pallas"]
