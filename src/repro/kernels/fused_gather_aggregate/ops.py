"""Public op: fused gather->aggregate with implementation dispatch.

``impl="auto"`` picks the jnp reference on CPU (where XLA fuses the gather
and scatter-add fine and Pallas interpret mode is an emulator) and the
fused Pallas kernel on TPU.  The ref path composes EXACTLY the expressions
the layers used to inline, so the CPU default stays byte-identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import fused_gather_aggregate_pallas
from .ref import fused_gather_aggregate_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_gather_aggregate(h_src: jnp.ndarray, edge_src: jnp.ndarray,
                           edge_dst: jnp.ndarray, edge_mask: jnp.ndarray,
                           num_dst: int, impl: str = "auto") -> jnp.ndarray:
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return fused_gather_aggregate_ref(h_src, edge_src, edge_dst,
                                          edge_mask, num_dst)
    if impl == "pallas":
        return fused_gather_aggregate_pallas(h_src, edge_src, edge_dst,
                                             edge_mask, num_dst,
                                             interpret=not _on_tpu())
    raise ValueError(f"unknown impl {impl!r}")
