"""Pallas TPU kernel: fused gather -> masked segment-sum.

The unfused GNN aggregation materializes the (E, F) message array twice
over HBM: the gather writes it, the segment-sum reads it back.  E is the
largest axis of a padded MFG block (cap_edge = cap_dst * fanout_total), so
for wide features that round trip dominates the layer.  This kernel never
materializes it: for each (dst block, feat block) the edge sweep gathers
its (EB, FB) message tile *in VMEM* — an in-register row gather from the
feature-block-resident source table, the same idiom as the edge-softmax
normalize phase — and immediately folds it into the accumulator with the
one-hot matmul from the segment-sum kernel:

    out[NB, FB] += onehot(edge_dst)[EB, NB]^T @ h[edge_src][EB, FB]

Grid (dst_blocks, feat_blocks, edge_blocks), edge axis innermost so the
output tile stays VMEM-resident across the sweep.  The source table rides
along one feature block at a time (index_map ``(0, j)``): V is a
mini-batch ``cap_src`` — thousands, not the full graph — so a (V, FB)
block fits VMEM comfortably (V=8192, FB=128 f32 -> 4 MB).

Padding rows: ``edge_dst`` pads with -1 (matches no one-hot column) and
``edge_src`` pads with 0 (gathers row 0, then the mask zeroes its one-hot
column), so padded edges contribute exactly nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_EB = 512
DEFAULT_NB = 128
DEFAULT_FB = 128


def _kernel(src_ref, dst_ref, mask_ref, h_ref, out_ref, *, nb: int):
    i = pl.program_id(0)          # dst block
    k = pl.program_id(2)          # edge block (innermost: accumulation)

    @pl.when(k == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    src = src_ref[...]            # (EB,) int32
    dst = dst_ref[...]            # (EB,) int32
    mask = mask_ref[...]          # (EB,) bool
    msg = h_ref[src]              # (EB, FB) VMEM row gather — never in HBM
    rows = i * nb + jax.lax.broadcasted_iota(jnp.int32, (dst.shape[0], nb), 1)
    onehot = ((dst[:, None] == rows) & mask[:, None]).astype(msg.dtype)
    out_ref[...] += jnp.dot(onehot.T, msg,
                            preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_dst", "eb", "nb", "fb",
                                             "interpret"))
def fused_gather_aggregate_pallas(h_src: jnp.ndarray, edge_src: jnp.ndarray,
                                  edge_dst: jnp.ndarray,
                                  edge_mask: jnp.ndarray, num_dst: int, *,
                                  eb: int = DEFAULT_EB, nb: int = DEFAULT_NB,
                                  fb: int = DEFAULT_FB,
                                  interpret: bool = True) -> jnp.ndarray:
    v, f = h_src.shape
    e = edge_src.shape[0]
    eb = min(eb, e)
    nb = min(nb, num_dst)
    fb = min(fb, f)
    ep = -(-e // eb) * eb
    np_ = -(-num_dst // nb) * nb
    fp = -(-f // fb) * fb
    vp = -(-v // 8) * 8           # f32 sublane multiple for the row gather
    h_p = jnp.pad(h_src, ((0, vp - v), (0, fp - f)))
    src_p = jnp.pad(edge_src.astype(jnp.int32), (0, ep - e))
    dst_p = jnp.pad(edge_dst.astype(jnp.int32), (0, ep - e),
                    constant_values=-1)
    mask_p = jnp.pad(edge_mask.astype(jnp.bool_), (0, ep - e))

    grid = (np_ // nb, fp // fb, ep // eb)
    out = pl.pallas_call(
        functools.partial(_kernel, nb=nb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((eb,), lambda i, j, k: (k,)),
            pl.BlockSpec((eb,), lambda i, j, k: (k,)),
            pl.BlockSpec((eb,), lambda i, j, k: (k,)),
            pl.BlockSpec((vp, fb), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((nb, fb), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, fp), h_src.dtype),
        interpret=interpret,
    )(src_p, dst_p, mask_p, h_p)
    return out[:num_dst, :f]
