"""Pure-jnp oracle for the fused gather->aggregate op.

Exactly the composition the GNN layers used to inline —
``segment_sum_ref(h_src[edge_src], ...)`` — so routing a layer through
this op with ``impl="ref"`` produces the SAME jaxpr as before the fusion
existed (the golden byte-identity tests pin this).
"""
from __future__ import annotations

import jax.numpy as jnp

from ..segment_sum.ref import segment_sum_ref


def fused_gather_aggregate_ref(h_src: jnp.ndarray, edge_src: jnp.ndarray,
                               edge_dst: jnp.ndarray, edge_mask: jnp.ndarray,
                               num_dst: int) -> jnp.ndarray:
    """h_src: (V, F); edge_src/edge_dst: (E,); -> (num_dst, F) masked sum
    of gathered source rows per destination."""
    return segment_sum_ref(h_src[edge_src], edge_dst, edge_mask, num_dst)
