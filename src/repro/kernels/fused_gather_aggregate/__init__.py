from .ops import fused_gather_aggregate
from .ref import fused_gather_aggregate_ref
from .kernel import fused_gather_aggregate_pallas

__all__ = ["fused_gather_aggregate", "fused_gather_aggregate_ref",
           "fused_gather_aggregate_pallas"]
