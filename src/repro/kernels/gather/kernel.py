"""Pallas TPU kernel: feature-row gather (the mini-batch feature copy).

This is the device half of the paper's "feature copy" hot loop: once input
node features are resident (HBM), every mini-batch gathers the rows for its
input nodes. On TPU the idiomatic implementation is *scalar-prefetch-driven
block DMA*: the row indices are prefetched into SMEM before the kernel runs,
and the ``table`` BlockSpec's index_map reads them to choose which (1, FB)
row-block the next grid step DMAs from HBM into VMEM. The kernel body is a
pure VMEM→VMEM copy; all the work is in the DMA schedule, which Pallas
pipelines across grid steps (double-buffered), exactly what a hand-written
CUDA gather achieves with coalesced loads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, table_ref, out_ref):
    del idx_ref  # consumed by the index_map
    out_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("fb", "interpret"))
def gather_rows_pallas(table: jnp.ndarray, idx: jnp.ndarray, *,
                       fb: int = 512, interpret: bool = True) -> jnp.ndarray:
    v, f = table.shape
    n = idx.shape[0]
    fb = min(fb, f)
    fp = -(-f // fb) * fb
    table_p = jnp.pad(table, ((0, 0), (0, fp - f)))
    grid = (n, fp // fb)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, fb), lambda i, j, idx_ref: (idx_ref[i], j)),
            ],
            out_specs=pl.BlockSpec((1, fb), lambda i, j, idx_ref: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, fp), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), table_p)
    return out[:, :f]
