"""Public op: feature-row gather with implementation dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import gather_rows_pallas
from .ref import gather_rows_ref


def gather_rows(table: jnp.ndarray, idx: jnp.ndarray,
                impl: str = "auto") -> jnp.ndarray:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return gather_rows_ref(table, idx)
    if impl == "pallas":
        return gather_rows_pallas(table, idx,
                                  interpret=jax.default_backend() != "tpu")
    raise ValueError(f"unknown impl {impl!r}")
