"""Pure-jnp oracle for feature-row gather."""
from __future__ import annotations

import jax.numpy as jnp


def gather_rows_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """table: (V, F); idx: (N,) int32 -> (N, F)."""
    return table[idx.astype(jnp.int32)]
