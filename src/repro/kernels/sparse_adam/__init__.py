from .ops import sparse_adam_apply
from .ref import sparse_adam_ref
from .kernel import sparse_adam_pallas

__all__ = ["sparse_adam_apply", "sparse_adam_ref", "sparse_adam_pallas"]
