"""NumPy reference for the fused row-sparse Adam update.

This is VERBATIM the per-shard update ``DistEmbedding.push_grad`` has
always applied (and the exact float32 expression sequence of the dense
oracle in ``tests/test_embedding_oracle.py``) — the ref path mutates the
tables in place with plain NumPy, so the default CPU path stays
bit-identical to every golden value pinned before the kernel existed.

Bias corrections ``1 - beta**t`` are precomputed by the CALLER (in NumPy,
from the int64 step counts): ``beta ** t`` is a transcendental whose
rounding differs between libm and XLA, so it must never enter the device
kernel — dividing by a precomputed correction is a single correctly-
rounded f32 op on both sides.  See :mod:`.kernel` for the rest of the
bitwise contract.
"""
from __future__ import annotations

import numpy as np


def sparse_adam_ref(w: np.ndarray, m: np.ndarray, v: np.ndarray,
                    rows: np.ndarray, grad: np.ndarray,
                    bc1: np.ndarray, bc2: np.ndarray, *,
                    beta1: float, beta2: float, lr: float,
                    eps: float) -> None:
    """In-place row-sparse Adam on full tables.

    w/m/v: (N, D) tables (mutated); rows: (R,) unique row ids;
    grad: (R, D) f32 coalesced gradients; bc1/bc2: (R, 1) f32
    bias corrections ``1 - beta**t`` for the rows' post-increment counts.
    """
    g = grad
    m[rows] = beta1 * m[rows] + (1 - beta1) * g
    v[rows] = beta2 * v[rows] + (1 - beta2) * g * g
    mhat = m[rows] / bc1
    vhat = v[rows] / bc2
    w[rows] -= (lr * mhat / (np.sqrt(vhat) + eps)).astype(w.dtype)
