"""Public op: fused row-sparse Adam with implementation dispatch.

The caller (``DistEmbedding.push_grad``) owns everything stateful: the
int64 step counters ``t`` (incremented host-side — they must never pass
through a device transfer, which would downcast them), the duplicate-id
coalescing, and the transport accounting.  This op only applies one
already-coalesced update to one shard's tables.

Bitwise contract (both impls): identical bytes to the NumPy expressions
in :func:`..sparse_adam.ref.sparse_adam_ref` — which is what the dense
oracle in tests/test_embedding_oracle.py computes.  The ``(1-beta)*g``
terms and bias corrections are computed here in NumPy for BOTH impls (the
transcendental ``beta**t`` and the mul->add-contraction-prone products
must not be recomputed on device; see kernel.py).
"""
from __future__ import annotations

import jax
import numpy as np

from .kernel import sparse_adam_pallas
from .ref import sparse_adam_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sparse_adam_apply(w: np.ndarray, m: np.ndarray, v: np.ndarray,
                      rows: np.ndarray, grad: np.ndarray, t: np.ndarray, *,
                      beta1: float, beta2: float, lr: float, eps: float,
                      impl: str = "auto") -> None:
    """One shard's row-sparse Adam step, in place.

    w/m/v: (N, D) tables (mutated in place); t: (N,) int64 step counters
    (mutated in place — incremented BEFORE the bias correction, exactly
    like the oracle); rows: (R,) unique local row ids; grad: (R, D) f32
    coalesced gradients.
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    t[rows] += 1
    tr = t[rows].astype(np.float32)[:, None]
    bc1 = 1 - beta1 ** tr
    bc2 = 1 - beta2 ** tr
    if impl == "ref":
        sparse_adam_ref(w, m, v, rows, grad, bc1, bc2, beta1=beta1,
                        beta2=beta2, lr=lr, eps=eps)
        return
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    if w.dtype != np.float32:
        # non-f32 tables keep the NumPy path: the bitwise contract is
        # only defined for f32 (and the kernel assumes one dtype)
        sparse_adam_ref(w, m, v, rows, grad, bc1, bc2, beta1=beta1,
                        beta2=beta2, lr=lr, eps=eps)
        return
    g = grad.astype(np.float32)
    cm = (1 - beta1) * g                    # the oracle's exact products
    cv = (1 - beta2) * g * g
    d = w.shape[1]
    w2, m2, v2 = sparse_adam_pallas(
        w, m, v, rows.astype(np.int32), cm, cv,
        np.broadcast_to(bc1, (len(rows), d)).astype(np.float32),
        np.broadcast_to(bc2, (len(rows), d)).astype(np.float32),
        beta1=beta1, beta2=beta2, lr=lr, eps=eps,
        interpret=not _on_tpu())
    # scatter back into the server's storage (the kernel already scattered
    # device-side via aliasing; these copies land the bytes in host numpy)
    np.copyto(w, np.asarray(w2))
    np.copyto(m, np.asarray(m2))
    np.copyto(v, np.asarray(v2))
