"""Pallas TPU kernel: fused row-sparse Adam (gather -> update -> scatter).

The KVStore servers apply Adam to exactly the rows a mini-batch touched.
Expressed naively on an accelerator that is gather / three elementwise
updates / scatter — five HBM round trips over the full tables.  Here the
whole update runs as scalar-prefetch-driven Pallas programs: the row ids
are prefetched to SMEM, each grid step DMAs one (1, D) row of w/m/v in,
updates it, and writes it back through ``input_output_aliases`` — rows
never touched keep their exact bytes because the output IS the input
buffer.

Why TWO pallas_calls (products, then update+scatter) and not one: the
bitwise contract.  The server-side NumPy update is the repo's oracle, and
XLA (CPU *and* TPU) contracts ``a*b + c`` into a fused multiply-add,
which rounds once where NumPy rounds twice — a 1-ulp divergence the
byte-identity tests would catch (``optimization_barrier`` does not
survive XLA:CPU's fusion pass; measured).  The split puts every fmul in
one program and every fadd in the other, so no program contains a
contractible mul->add pair:

  * program 1 (gather + products):  p_m = beta1 * m[row],
    p_v = beta2 * v[row] — multiplies only;
  * host (NumPy, shared with the oracle): c_m = (1-beta1)*g,
    c_v = (1-beta2)*g*g, bias corrections 1 - beta**t;
  * program 2 (update + scatter):  m' = p_m + c_m, v' = p_v + c_v,
    w' = w[row] - lr*(m'/bc1) / (sqrt(v'/bc2) + eps) — the only multiply
    (``lr * mhat``) feeds a divide, which never contracts.

Both calls dispatch eagerly (no enclosing jit), so XLA cannot fuse across
them.  Remaining ops are single correctly-rounded IEEE f32 ops on both
NumPy and XLA: the result is bit-identical to the NumPy reference
(pinned against the dense oracle in tests/test_embedding_oracle.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _products_kernel(rows_ref, m_ref, v_ref, pm_ref, pv_ref, *,
                     beta1: float, beta2: float):
    del rows_ref                    # consumed by the index_maps
    pm_ref[...] = beta1 * m_ref[...]
    pv_ref[...] = beta2 * v_ref[...]


def _update_kernel(rows_ref, w_ref, m_tab_ref, v_tab_ref, pm_ref, pv_ref,
                   cm_ref, cv_ref, bc1_ref, bc2_ref,
                   w_out, m_out, v_out, *, lr: float, eps: float):
    del rows_ref, m_tab_ref, v_tab_ref    # aliased outputs / index_maps
    mm = pm_ref[...] + cm_ref[...]
    vv = pv_ref[...] + cv_ref[...]
    mhat = mm / bc1_ref[...]
    vhat = vv / bc2_ref[...]
    w_out[...] = w_ref[...] - lr * mhat / (jnp.sqrt(vhat) + eps)
    m_out[...] = mm
    v_out[...] = vv


def _row_spec(d):
    return pl.BlockSpec((1, d), lambda i, rows: (rows[i], 0))


def _seq_spec(d):
    return pl.BlockSpec((1, d), lambda i, rows: (i, 0))


def sparse_adam_pallas(w: jnp.ndarray, m: jnp.ndarray, v: jnp.ndarray,
                       rows: jnp.ndarray, cm: jnp.ndarray, cv: jnp.ndarray,
                       bc1: jnp.ndarray, bc2: jnp.ndarray, *,
                       beta1: float, beta2: float, lr: float, eps: float,
                       interpret: bool = True):
    """Full tables in, full tables out; only ``rows`` change.

    w/m/v: (N, D) f32; rows: (R,) unique int32; cm/cv: (R, D) f32 host-
    precomputed ``(1-beta)*g`` terms; bc1/bc2: (R, D) f32 bias corrections
    (row-broadcast by the caller).  Returns (w', m', v').
    """
    n, d = w.shape
    r = rows.shape[0]
    rows = rows.astype(jnp.int32)

    grid_spec = lambda n_in: pltpu.PrefetchScalarGridSpec(   # noqa: E731
        num_scalar_prefetch=1, grid=(r,), in_specs=n_in[0],
        out_specs=n_in[1])

    pm, pv = pl.pallas_call(
        functools.partial(_products_kernel, beta1=beta1, beta2=beta2),
        grid_spec=grid_spec(([_row_spec(d), _row_spec(d)],
                             [_seq_spec(d), _seq_spec(d)])),
        out_shape=[jax.ShapeDtypeStruct((r, d), w.dtype)] * 2,
        interpret=interpret,
    )(rows, m, v)

    # aliased scatter: inputs w/m/v (operand indices 1..3 — the scalar-
    # prefetch rows are operand 0) become the outputs, so untouched rows
    # pass through bit-exactly without ever being read
    w2, m2, v2 = pl.pallas_call(
        functools.partial(_update_kernel, lr=lr, eps=eps),
        grid_spec=grid_spec((
            [_row_spec(d)] * 3 + [_seq_spec(d)] * 6,
            [_row_spec(d)] * 3)),
        out_shape=[jax.ShapeDtypeStruct((n, d), w.dtype)] * 3,
        input_output_aliases={1: 0, 2: 1, 3: 2},
        interpret=interpret,
    )(rows, w, m, v, pm, pv, cm, cv, bc1, bc2)
    return w2, m2, v2
