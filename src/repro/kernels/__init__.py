"""Pallas TPU kernels for the GNN hot spots (+ jnp oracles).

Each kernel package has kernel.py (pl.pallas_call + BlockSpec VMEM tiling,
validated under interpret=True on CPU), ops.py (dispatching wrapper) and
ref.py (pure-jnp oracle).  The ``fused_*`` packages fuse whole layer
tails (gather -> aggregate, softmax -> weighted gather -> aggregate) so
the (E, F) message array never touches HBM; ``sparse_adam`` fuses the
DistEmbedding optimizer's gather -> update -> scatter; ``pack`` is the
packed one-shot device staging used by every device-prefetch stage
(DESIGN.md §9).
"""
from .segment_sum.ops import segment_sum
from .segment_sum.ref import segment_max_ref, segment_sum_ref
from .gather.ops import gather_rows
from .edge_softmax.ops import edge_softmax
from .fused_gather_aggregate.ops import fused_gather_aggregate
from .fused_gather_aggregate.ref import fused_gather_aggregate_ref
from .fused_edge_softmax_aggregate.ops import fused_edge_softmax_aggregate
from .fused_edge_softmax_aggregate.ref import fused_edge_softmax_aggregate_ref
from .sparse_adam.ops import sparse_adam_apply
from .pack.ops import (PackSpec, PackedBatch, device_stage, pack, unpack,
                       unpack_flat)

__all__ = ["segment_sum", "segment_sum_ref", "segment_max_ref",
           "gather_rows", "edge_softmax",
           "fused_gather_aggregate", "fused_gather_aggregate_ref",
           "fused_edge_softmax_aggregate", "fused_edge_softmax_aggregate_ref",
           "sparse_adam_apply",
           "PackSpec", "PackedBatch", "device_stage", "pack", "unpack",
           "unpack_flat"]
