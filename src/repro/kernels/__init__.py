"""Pallas TPU kernels for the GNN hot spots (+ jnp oracles).

Each kernel package has kernel.py (pl.pallas_call + BlockSpec VMEM tiling,
validated under interpret=True on CPU), ops.py (dispatching wrapper) and
ref.py (pure-jnp oracle).
"""
from .segment_sum.ops import segment_sum
from .segment_sum.ref import segment_max_ref, segment_sum_ref
from .gather.ops import gather_rows
from .edge_softmax.ops import edge_softmax

__all__ = ["segment_sum", "segment_sum_ref", "segment_max_ref",
           "gather_rows", "edge_softmax"]
