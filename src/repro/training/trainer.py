"""Distributed synchronous mini-batch GNN training (§5.1, §5.6).

``DistGNNTrainer`` is a thin composition over the public ``repro.api``
surface: one :class:`~repro.api.DistGraph` world (partition book + KVStore
+ typed relation views), per-trainer :class:`~repro.api.NodeDataLoader` /
:class:`~repro.api.EdgeDataLoader` instances over the async pipeline, and
one *synchronous* SGD step per iteration across all trainers (data
parallelism). Anything this class does, a user script can do with the
same façades — the trainer only adds the multi-trainer stacking and the
jitted step (DESIGN.md §8).

On a real TPU pod each trainer is one chip and the gradient all-reduce is
GSPMD's; in this one-host harness the T trainers' mini-batches are stacked
on a leading axis and the step is jitted with that axis sharded over the
mesh's "data" axis (identical program; with one CPU device the psum
degenerates but the math — mean gradient over all trainers' batches — is
exactly synchronous SGD, so convergence behaviour is faithful).

The constructor options are the Fig. 14 ablation axes:
  partition_method="random"|"metis", use_level2, sync (no pipeline),
  non_stop (never drain the pipeline between epochs).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..api.dataloader import EdgeDataLoader, NodeDataLoader
from ..api.dist_graph import DistGraph
from ..checkpoint import (load_cache, load_kvstore, load_pytree, save_cache,
                          save_kvstore, save_pytree)
from ..core.kvstore import CacheConfig, FaultInjector, NetworkModel
from ..core.sampler import EdgeBatchSampler
from ..graph.datasets import GraphDataset
from ..kernels.pack import device_stage
from ..models.gnn import (GNNConfig, apply_gnn, init_gnn, init_lp_head,
                          lp_loss_from_scores, lp_metrics, lp_pair_scores,
                          lp_ranks, nc_accuracy, nc_loss)
from ..optim import adamw_init, adamw_update

TASKS = ("node_classification", "link_prediction")


@dataclasses.dataclass
class TrainJobConfig:
    num_machines: int = 2
    trainers_per_machine: int = 2
    partition_method: str = "metis"      # "metis" | "random" (Euler baseline)
    use_level2: bool = True              # 2-level partition seed split
    sync: bool = False                   # disable the async pipeline
    non_stop: bool = True                # non-stop pipeline across epochs
    lr: float = 3e-3
    network: Optional[NetworkModel] = None
    pipeline_depths: Optional[dict] = None
    cache: Optional[CacheConfig] = None  # per-trainer hot-vertex cache
    # sampling-stage worker pool per trainer (§5.5's multiple sampling
    # workers); batches are byte-identical for any value (DESIGN.md §7)
    sample_workers: int = 1
    # device staging (DESIGN.md §9): True = the stacked per-step batch is
    # flattened into one contiguous host buffer per dtype and shipped with
    # a SINGLE jax.device_put + jitted static-slice unpack; False = legacy
    # per-array transfers. Bytes reaching the jitted step are identical.
    packed_staging: bool = True
    # kernel implementation for the model's aggregations (GNNConfig.impl)
    # and the sparse-Adam path: None = keep the model config's own choice
    # ("auto" → pallas on TPU, jnp oracle elsewhere); "ref"/"pallas" force
    impl: Optional[str] = None
    # ---- workload (the paper trains "various GNN workloads") ----------
    # link_prediction: positive-edge batches over each trainer's owned
    # edges, `num_negs` uniform corrupted dsts per edge, `score_fn` head
    # (dot | distmult-per-relation), MRR/Hits@k eval. For this task the
    # model config's batch_size is the EDGE batch B; the node batch the
    # samplers/model use is derived (2B + B*K, DESIGN.md §6).
    task: str = "node_classification"
    # 16, not DGL's customary handful: with few uniform negatives the BCE
    # objective can settle into the all-scores-zero fixed point (loss
    # 2·ln2) on homophilous graphs, ranking WORSE than an untrained
    # encoder; K=16 reliably escapes it (measured in tests/test_linkpred)
    num_negs: int = 16
    score_fn: str = "dot"                # "dot" | "distmult"
    neg_mode: str = "uniform"            # "uniform" | "in-batch"
    neg_exclude: bool = False            # re-draw batch-positive collisions
    # ---- elastic fault tolerance (DESIGN.md §10) ----------------------
    # consistent checkpoints every `checkpoint_interval` global steps into
    # `checkpoint_dir`; a replacement trainer's recover() restores them
    # and fast-forwards the deterministic schedule to the saved coordinate
    checkpoint_dir: Optional[str] = None
    checkpoint_interval: int = 0         # global steps between saves; 0 = off
    # seeded failure schedule (kill_at death + transient RPC faults),
    # attached to the world's shared transport — tests and the chaos
    # benchmark inject through here, production leaves it None
    fault_injector: Optional[FaultInjector] = None
    seed: int = 0
    # ---- availability (DESIGN.md §12) ----------------------------------
    # r-way replica placement for the KVStore feature plane: reads fail
    # over to a live replica on sustained owner outages (byte-identical —
    # writes are synchronous), so training survives a down server with
    # ZERO restarts. 1 = unreplicated (exactly the pre-§12 behavior).
    replication: int = 1
    # per-destination RPC retry budget (was the MAX_RPC_RETRIES constant)
    max_rpc_retries: int = 8
    # hedged reads: after this many ms without a primary response, race a
    # replica and take the first success; None = off
    hedge_ms: Optional[float] = None


class DistGNNTrainer:
    def __init__(self, ds: GraphDataset, model_cfg: GNNConfig,
                 job: TrainJobConfig):
        self.ds = ds
        if job.impl is not None:
            model_cfg = dataclasses.replace(model_cfg, impl=job.impl)
        self.cfg = model_cfg
        self.job = job
        if job.task not in TASKS:
            raise ValueError(f"unknown task {job.task!r}; have {TASKS}")
        self.task = job.task
        if job.checkpoint_interval and not job.checkpoint_dir:
            raise ValueError("checkpoint_interval > 0 needs a checkpoint_dir")
        if self.task == "link_prediction":
            # cfg.batch_size is the EDGE batch; the node samplers (and the
            # model's capacity formulas) run at the derived endpoint-seed
            # capacity — one config object keeps them in lockstep (§2 rule 4)
            node_bs = EdgeBatchSampler.required_node_batch(
                model_cfg.batch_size, job.num_negs, job.neg_mode)
            self.node_cfg = dataclasses.replace(model_cfg,
                                                batch_size=node_bs)
        else:
            self.node_cfg = model_cfg

        # the world: partition + KVStore + typed views, behind one handle
        self.graph = DistGraph(
            ds, num_machines=job.num_machines,
            trainers_per_machine=job.trainers_per_machine,
            partition_method=job.partition_method,
            hetero=model_cfg.typed, seed=job.seed, network=job.network,
            replication=job.replication,
            max_rpc_retries=job.max_rpc_retries, hedge_ms=job.hedge_ms)
        self.hp = self.graph.hp
        self.partition_time_s = self.graph.partition_time_s
        self.transport = self.graph.transport
        if job.fault_injector is not None:
            # every RPC in the world — feature pulls, gradient pushes —
            # flows through this one transport, so attaching the injector
            # here puts the whole stack under the failure schedule
            self.transport.fault_injector = job.fault_injector
        self.store = self.graph.store
        self.labels_new = self.graph.labels
        self.schema = self.graph.schema
        self.hetero = self.graph.hetero
        self.typed = self.graph.typed

        # per-trainer seed split (§5.6.1): node tasks split the training
        # vertices; link prediction splits each machine's OWNED edge range
        # into equalized per-trainer pools — "we may use all edges to
        # train a model" (§6). Both splits live on DistGraph now.
        if self.task == "link_prediction":
            self.e_src, self.e_dst = self.graph.edge_endpoints()
            self.trainer_edges: List[np.ndarray] = self.graph.edge_splits()
            # locality of the positive SOURCES (dsts are local by
            # construction — edges are owned by their dst's machine)
            self.locality = self.graph.locality_report(
                [self.e_src[e] for e in self.trainer_edges])
        else:
            self.trainer_seeds = self.graph.node_splits(
                self.graph.train_nids, use_level2=job.use_level2,
                seed=job.seed)
            self.locality = self.graph.locality_report(self.trainer_seeds)

        # per-trainer loaders (each owns its sampler, client, cache and
        # async pipeline); the trainer only stacks their batches
        self.num_trainers = self.graph.num_trainers
        self.loaders: List[NodeDataLoader] = []
        for ti in range(self.num_trainers):
            gt = self.graph.trainer_view(ti)
            cache = gt.feature_cache(job.cache)
            if self.task == "link_prediction":
                ld = EdgeDataLoader(
                    gt, self.trainer_edges[ti], self.node_cfg.fanouts,
                    batch_size=model_cfg.batch_size, num_negs=job.num_negs,
                    neg_mode=job.neg_mode, neg_exclude=job.neg_exclude,
                    sync=job.sync, non_stop=job.non_stop,
                    depths=job.pipeline_depths, device_prefetch=False,
                    cache=cache, sample_workers=job.sample_workers,
                    seed=job.seed + 200 + ti,
                    sampler_seed=job.seed + 100 + ti,
                    edge_seed=job.seed + 300 + ti)
            else:
                seeds = self.trainer_seeds[ti]
                ld = NodeDataLoader(
                    gt, seeds, self.node_cfg.fanouts,
                    batch_size=self.node_cfg.batch_size,
                    labels=self.labels_new[seeds], sync=job.sync,
                    non_stop=job.non_stop, depths=job.pipeline_depths,
                    device_prefetch=False, cache=cache,
                    sample_workers=job.sample_workers,
                    seed=job.seed + 200 + ti,
                    sampler_seed=job.seed + 100 + ti)
            self.loaders.append(ld)
        # component views (stats, tests, benchmarks)
        self.samplers = [ld.sampler for ld in self.loaders]
        self.edge_samplers = [ld.edge_sampler for ld in self.loaders
                              if isinstance(ld, EdgeDataLoader)]
        self.pipelines = [ld.pipeline for ld in self.loaders]
        self.caches = [ld.cache for ld in self.loaders]

        self.batches_per_epoch = min(len(ld) for ld in self.loaders)
        if self.batches_per_epoch < 1:
            if self.task == "link_prediction":
                raise ValueError(
                    f"edge batch {model_cfg.batch_size} exceeds the "
                    f"per-trainer owned-edge pool "
                    f"({min(len(e) for e in self.trainer_edges)} edges/"
                    f"trainer) — shrink the batch or the trainer count")
            raise ValueError(
                f"batch_size {model_cfg.batch_size} exceeds the per-trainer "
                f"training-set split ({min(len(s) for s in self.trainer_seeds)} "
                f"seeds/trainer) — shrink the batch or the trainer count")

        self.params = init_gnn(self.node_cfg, jax.random.key(job.seed))
        if self.task == "link_prediction":
            self.params = {"gnn": self.params,
                           "lp": init_lp_head(job.score_fn,
                                              self.node_cfg.num_rels,
                                              self.node_cfg.num_classes)}
        self.opt = adamw_init(self.params)
        self._step = self._build_step()
        self._eval_ranks_fn = None
        self._eval_ranks_key = None
        # optimizer steps taken since construction (or since recover());
        # the checkpoint cadence counts these, not per-epoch batches
        self.global_step = 0
        # (epoch, batch_index) a recover() restored — the next
        # train_epoch() call must target that epoch and fast-forwards to
        # that batch (DESIGN.md §10)
        self._resume: Optional[tuple] = None

    # ------------------------------------------------------------------
    def _lp_scores(self, params, batch, cfg: Optional[GNNConfig] = None):
        """Embeddings -> (pos, neg) scores; shared by train and eval
        (eval passes its own cfg — its endpoint capacity differs)."""
        h = apply_gnn(cfg or self.node_cfg, params["gnn"], batch,
                      etype_id=self.schema.etype_id if self.hetero else None)
        kw = dict(head=params["lp"], score_fn=self.job.score_fn,
                  etypes=batch["edge_etypes"])
        pos = lp_pair_scores(h, batch["pos_u"], batch["pos_v"], **kw)
        neg = lp_pair_scores(h, batch["pos_u"], batch["neg_v"], **kw)
        return pos, neg

    def _build_step(self):
        lr = self.job.lr
        if self.task == "link_prediction":
            @jax.jit
            def step(params, opt, stacked):
                def loss_one(p, batch):
                    pos, neg = self._lp_scores(p, batch)
                    loss = lp_loss_from_scores(pos, neg, batch["pair_mask"])
                    mrr = lp_metrics(lp_ranks(pos, neg),
                                     batch["pair_mask"])["mrr"]
                    return loss, mrr

                def loss_fn(p):
                    losses, mrrs = jax.vmap(lambda b: loss_one(p, b))(stacked)
                    return losses.mean(), mrrs.mean()

                (loss, mrr), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params)
                params2, opt2 = adamw_update(params, grads, opt, lr=lr)
                return params2, opt2, loss, mrr
            return step

        cfg = self.node_cfg
        etype_id = self.schema.etype_id if self.hetero else None

        @jax.jit
        def step(params, opt, stacked):
            def loss_one(p, batch):
                logits = apply_gnn(cfg, p, batch, etype_id=etype_id)
                return (nc_loss(logits, batch["labels"], batch["seed_mask"]),
                        nc_accuracy(logits, batch["labels"], batch["seed_mask"]))

            def loss_fn(p):
                losses, accs = jax.vmap(lambda b: loss_one(p, b))(stacked)
                return losses.mean(), accs.mean()   # sync SGD: mean over trainers

            (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            params2, opt2 = adamw_update(params, grads, opt, lr=lr)
            return params2, opt2, loss, acc
        return step

    def _stack(self, batches: List[dict]) -> dict:
        """Stack the T trainers' host batches on a leading axis and stage
        them on the device.  Packed staging (DESIGN.md §9) stacks in host
        memory and issues ONE ``jax.device_put`` for the whole step's
        input (then a jitted static-slice unpack); the legacy path moves
        each leaf separately.  Device bytes are identical either way."""
        if self.job.packed_staging:
            host = jax.tree.map(lambda *xs: np.stack(xs), *batches)
            return device_stage(host, packed=True).unpack()

        def stack_leaf(*xs):
            return jnp.stack([jnp.asarray(x) for x in xs])
        return jax.tree.map(stack_leaf, *batches)

    # ------------------------------------------------------------------
    def train_epoch(self, epoch: int) -> dict:
        start = 0
        if self._resume is not None:
            r_epoch, r_batch = self._resume
            if epoch != r_epoch:
                raise ValueError(
                    f"recovered at epoch {r_epoch}, batch {r_batch}; the "
                    f"next train_epoch() must target epoch {r_epoch}, "
                    f"got {epoch}")
            self._resume = None
            start = r_batch
        iters = [ld.epoch(epoch, start_batch=start) for ld in self.loaders]
        inj = self.job.fault_injector
        ckpt_every = self.job.checkpoint_interval
        t0 = time.perf_counter()
        losses, accs = [], []
        for k in range(start, self.batches_per_epoch):
            # checkpoint BEFORE consuming batch k: coordinate (epoch, k)
            # means "everything up to batch k-1 is applied", so recovery
            # resumes AT batch k (skip step 0 — that's the initial state)
            if (ckpt_every and self.global_step
                    and self.global_step % ckpt_every == 0):
                self.save_checkpoint(self.job.checkpoint_dir,
                                     epoch=epoch, batch_index=k)
            # injected trainer death fires at the same boundary, so a
            # killed trainer's last completed step is unambiguous
            if inj is not None:
                inj.check_death(epoch, k)
            batches = [next(it).model_input() for it in iters]
            self.params, self.opt, loss, acc = self._step(
                self.params, self.opt, self._stack(batches))
            self.global_step += 1
            losses.append(float(loss))
            accs.append(float(acc))
        # drain every iterator to ITS epoch boundary. With equal
        # per-trainer batch counts (node tasks, homogeneous LP) this pulls
        # nothing in non-stop mode and just exhausts finite pipelines; on
        # the typed LP path per-etype tail-dropping can leave a trainer a
        # few surplus batches, and abandoning those mid-epoch would poison
        # the next epoch with stale-labeled batches (the pre-api trainer
        # silently did exactly that) or force a pipeline rebuild per epoch
        for it in iters:
            for _ in it:
                pass
        dt = time.perf_counter() - t0
        out = {"epoch": epoch, "loss": float(np.mean(losses)),
               "acc": float(np.mean(accs)), "time_s": dt,
               "batches": self.batches_per_epoch - start}
        if self.task == "link_prediction":
            out["train_mrr"] = out["acc"]   # the step's aux metric is MRR
        return out

    def evaluate_lp(self, num_batches: int = 20, seed: int = 977,
                    num_negs: Optional[int] = None,
                    batch_edges: Optional[int] = None) -> dict:
        """MRR / Hits@k over a deterministic sample of the graph's edges,
        ALWAYS against fresh uniform negatives (the paper's LP eval
        protocol: rank the true destination against corrupted ones),
        regardless of the training ``neg_mode``.

        Eval uses its own candidate count — ``num_negs`` defaults to 49,
        so ranks span [1, 50] and Hits@10 is a real metric (ranking
        against only the training K would saturate it) — and therefore
        its own endpoint capacity / jitted rank program, cached per
        (B, K). Exclusion is off regardless of ``neg_exclude``: the eval
        candidates must not depend on ANY training setting. The whole
        protocol is an ``EdgeDataLoader(mode="eval")`` over every edge:
        deterministic schedule, ad-hoc sampler coordinates, dedicated
        sampler (the trainers' samplers are owned by their pipeline
        threads). As with ``evaluate``, eval feature pulls are charged to
        the shared transport (sampling RPCs are not) — read
        ``sampling_stats()`` before evaluating for pure training traffic.
        """
        assert self.task == "link_prediction", "trainer is not an LP job"
        B = batch_edges or min(self.cfg.batch_size, 16)
        K = num_negs or 49
        eval_cfg = dataclasses.replace(
            self.node_cfg,
            batch_size=EdgeBatchSampler.required_node_batch(B, K, "uniform"))
        g0 = self.graph.trainer_view(0)
        all_eids = np.arange(g0.num_edges(), dtype=np.int64)
        loader = EdgeDataLoader(
            g0, all_eids, eval_cfg.fanouts, batch_size=B, num_negs=K,
            neg_mode="uniform", neg_exclude=False, mode="eval",
            sampler_seed=self.job.seed + 998,
            edge_seed=self.job.seed + seed)
        if self._eval_ranks_fn is None or self._eval_ranks_key != (B, K):
            @jax.jit
            def eval_ranks(params, batch):
                pos, neg = self._lp_scores(params, batch, cfg=eval_cfg)
                return lp_ranks(pos, neg)
            self._eval_ranks_fn = eval_ranks
            self._eval_ranks_key = (B, K)
        ranks: List[np.ndarray] = []
        with loader:
            for batch in itertools.islice(loader, num_batches):
                r = np.asarray(self._eval_ranks_fn(self.params,
                                                   batch.model_input()))
                ranks.append(r[batch.pair_mask])
        if not ranks:   # fewer owned edges than one batch: degenerate eval
            return {"mrr": float("nan"), "num_edges": 0,
                    **{f"hits@{k}": float("nan") for k in (1, 3, 10)}}
        r = np.concatenate(ranks).astype(np.float64)
        out = {"mrr": float((1.0 / r).mean()), "num_edges": int(len(r))}
        for k in (1, 3, 10):
            out[f"hits@{k}"] = float((r <= k).mean())
        return out

    def evaluate(self, nids_old: np.ndarray, max_batches: int = 50) -> float:
        """Node-classification accuracy over ``nids_old`` through a
        ``NodeDataLoader(mode="eval")``: sequential batches, dedicated
        sampler (the trainers' samplers are owned by their possibly still
        running non_stop pipeline threads — sharing one would race the
        RNG and stats)."""
        nids = self.graph.to_new_nids(np.asarray(nids_old))
        g0 = self.graph.trainer_view(0)
        loader = NodeDataLoader(
            g0, nids, self.cfg.fanouts, batch_size=self.cfg.batch_size,
            labels=self.labels_new[nids], mode="eval",
            sampler_seed=self.job.seed + 999)
        accs = []
        with loader:
            for batch in itertools.islice(loader, max_batches):
                logits = apply_gnn(self.cfg, self.params, batch.model_input(),
                                   etype_id=self.schema.etype_id
                                   if self.hetero else None)
                accs.append(float(nc_accuracy(logits,
                                              jnp.asarray(batch.labels),
                                              jnp.asarray(batch.seed_mask))))
        return float(np.mean(accs)) if accs else float("nan")

    # ---- elastic fault tolerance (DESIGN.md §10) ----------------------
    def save_checkpoint(self, directory: str, *, epoch: int,
                        batch_index: int) -> None:
        """Consistent checkpoint at coordinate ``(epoch, batch_index)``:
        dense params + optimizer, every KVStore shard WITH its row-version
        tables, and each trainer's feature-cache snapshot. Coordinates
        name the state BEFORE batch ``batch_index`` is consumed. The
        coordinate file is written atomically LAST, so a crash mid-save
        leaves the previous checkpoint intact rather than a torn one."""
        os.makedirs(directory, exist_ok=True)
        save_pytree(self.params, os.path.join(directory, "params"))
        save_pytree(self.opt, os.path.join(directory, "opt"))
        save_kvstore(self.store, os.path.join(directory, "kvstore"))
        for ti, cache in enumerate(self.caches):
            if cache is not None:
                save_cache(cache, os.path.join(directory, f"cache{ti}"))
        state = {"epoch": int(epoch), "batch_index": int(batch_index),
                 "global_step": int(self.global_step),
                 "seed": int(self.job.seed), "task": self.task,
                 "num_trainers": int(self.num_trainers),
                 "batches_per_epoch": int(self.batches_per_epoch)}
        tmp = os.path.join(directory, "state.json.tmp")
        with open(tmp, "w") as f:
            json.dump(state, f)
        os.replace(tmp, os.path.join(directory, "state.json"))

    def recover(self, directory: str) -> dict:
        """Restore a :meth:`save_checkpoint` into THIS trainer and arm the
        deterministic fast-forward: the next ``train_epoch()`` must target
        the saved epoch and resumes at the saved batch, after which every
        remaining batch — schedules, neighbor draws, negatives — is
        byte-identical to the uninterrupted run's (the counter-based RNG
        keys every draw by (seed, epoch, batch, stream), DESIGN.md §7).
        The world must match the checkpoint (same seed/task/trainer
        count/batch count) — anything else cannot replay byte-exactly and
        raises. Returns the checkpoint's coordinate metadata."""
        with open(os.path.join(directory, "state.json")) as f:
            state = json.load(f)
        mine = {"seed": int(self.job.seed), "task": self.task,
                "num_trainers": int(self.num_trainers),
                "batches_per_epoch": int(self.batches_per_epoch)}
        for key, want in mine.items():
            if state[key] != want:
                raise ValueError(
                    f"checkpoint {key}={state[key]!r} does not match this "
                    f"trainer's {key}={want!r} — deterministic replay "
                    f"needs an identically-configured world")
        # fast-forward needs fresh pipelines: drain whatever is in flight
        self.stop()
        self.params = load_pytree(self.params,
                                  os.path.join(directory, "params"))
        self.opt = load_pytree(self.opt, os.path.join(directory, "opt"))
        # order matters: restoring the shards flushes every live cache and
        # reinstates the version tables the cache snapshots validate
        # against — so a restored cache can never serve stale rows
        load_kvstore(self.store, os.path.join(directory, "kvstore"))
        for ti, cache in enumerate(self.caches):
            cdir = os.path.join(directory, f"cache{ti}")
            if cache is not None and os.path.isdir(cdir):
                load_cache(cache, cdir)
        self.global_step = int(state["global_step"])
        self._resume = (int(state["epoch"]), int(state["batch_index"]))
        return state

    def stop(self):
        for ld in self.loaders:
            ld.close()

    def sampling_stats(self) -> dict:
        remote = sum(s.stats.seeds_remote for s in self.samplers)
        total = sum(s.stats.seeds_total for s in self.samplers)
        owner_req = sum(s.stats.owner_requests for s in self.samplers)
        rel_req = sum(s.stats.relation_requests for s in self.samplers)
        out = {"remote_seed_frac": remote / max(total, 1),
               "transport": self.transport.stats(),
               # request-count accounting (§5.5 batched RPCs): requests the
               # coalesced dispatch actually issued vs what a per-relation
               # dispatch would have issued (equal on untyped runs)
               "sampler_requests": {
                   "owner_requests": owner_req,
                   "relation_requests": rel_req,
                   "coalescing_factor": rel_req / max(owner_req, 1),
               },
               "mean_seed_locality": self.locality["mean_local_frac"],
               "partition_time_s": self.partition_time_s}
        live = [c for c in self.caches if c is not None]
        if live:
            per = [c.stats() for c in live]
            hits = sum(p["hits"] for p in per)
            misses = sum(p["misses"] for p in per)
            out["cache"] = {
                "hit_rate": hits / max(hits + misses, 1),
                "used_bytes": sum(p["used_bytes"] for p in per),
                "evictions": sum(p["evictions"] for p in per),
                "stale_hits": sum(p["stale_hits"] for p in per),
                "per_trainer": per,
            }
        if self.hetero:
            per = sum(s.stats.edges_per_etype for s in self.samplers)
            out["edges_per_etype"] = {
                rel: int(per[r]) for r, rel in enumerate(self.schema.etypes)}
        return out
