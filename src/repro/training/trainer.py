"""Distributed synchronous mini-batch GNN training (§5.1, §5.6).

``DistGNNTrainer`` wires the whole DistDGLv2 stack together for a cluster of
``num_machines × trainers_per_machine`` trainers:

  graph -> hierarchical partition -> KVStore shards -> per-trainer seed
  split -> per-trainer async sampling pipelines -> one *synchronous* SGD
  step per iteration across all trainers (data parallelism).

On a real TPU pod each trainer is one chip and the gradient all-reduce is
GSPMD's; in this one-host harness the T trainers' mini-batches are stacked
on a leading axis and the step is jitted with that axis sharded over the
mesh's "data" axis (identical program; with one CPU device the psum
degenerates but the math — mean gradient over all trainers' batches — is
exactly synchronous SGD, so convergence behaviour is faithful).

The constructor options are the Fig. 14 ablation axes:
  partition_method="random"|"metis", use_level2, sync (no pipeline),
  non_stop (never drain the pipeline between epochs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.kvstore import (CacheConfig, DistKVStore, FeatureCache,
                            NetworkModel, PartitionPolicy, Transport,
                            halo_access_counts)
from ..core.partition import (build_typed_partition, hierarchical_partition,
                              locality_report, split_training_set)
from ..core.pipeline import EdgeMinibatchPipeline, MinibatchPipeline
from ..core.sampler import (DistributedSampler, EdgeBatchSampler,
                            edge_endpoints)
from ..graph.datasets import GraphDataset
from ..models.gnn import (GNNConfig, apply_gnn, init_gnn, init_lp_head,
                          lp_loss_from_scores, lp_metrics, lp_pair_scores,
                          lp_ranks, nc_accuracy, nc_loss)
from ..optim import adamw_init, adamw_update

TASKS = ("node_classification", "link_prediction")


@dataclasses.dataclass
class TrainJobConfig:
    num_machines: int = 2
    trainers_per_machine: int = 2
    partition_method: str = "metis"      # "metis" | "random" (Euler baseline)
    use_level2: bool = True              # 2-level partition seed split
    sync: bool = False                   # disable the async pipeline
    non_stop: bool = True                # non-stop pipeline across epochs
    lr: float = 3e-3
    network: Optional[NetworkModel] = None
    pipeline_depths: Optional[dict] = None
    cache: Optional[CacheConfig] = None  # per-trainer hot-vertex cache
    # sampling-stage worker pool per trainer (§5.5's multiple sampling
    # workers); batches are byte-identical for any value (DESIGN.md §7)
    sample_workers: int = 1
    # ---- workload (the paper trains "various GNN workloads") ----------
    # link_prediction: positive-edge batches over each trainer's owned
    # edges, `num_negs` uniform corrupted dsts per edge, `score_fn` head
    # (dot | distmult-per-relation), MRR/Hits@k eval. For this task the
    # model config's batch_size is the EDGE batch B; the node batch the
    # samplers/model use is derived (2B + B*K, DESIGN.md §6).
    task: str = "node_classification"
    # 16, not DGL's customary handful: with few uniform negatives the BCE
    # objective can settle into the all-scores-zero fixed point (loss
    # 2·ln2) on homophilous graphs, ranking WORSE than an untrained
    # encoder; K=16 reliably escapes it (measured in tests/test_linkpred)
    num_negs: int = 16
    score_fn: str = "dot"                # "dot" | "distmult"
    neg_mode: str = "uniform"            # "uniform" | "in-batch"
    neg_exclude: bool = False            # re-draw batch-positive collisions
    seed: int = 0


class DistGNNTrainer:
    def __init__(self, ds: GraphDataset, model_cfg: GNNConfig,
                 job: TrainJobConfig):
        self.ds = ds
        self.cfg = model_cfg
        self.job = job
        if job.task not in TASKS:
            raise ValueError(f"unknown task {job.task!r}; have {TASKS}")
        self.task = job.task
        if self.task == "link_prediction":
            # cfg.batch_size is the EDGE batch; the node samplers (and the
            # model's capacity formulas) run at the derived endpoint-seed
            # capacity — one config object keeps them in lockstep (§2 rule 4)
            node_bs = EdgeBatchSampler.required_node_batch(
                model_cfg.batch_size, job.num_negs, job.neg_mode)
            self.node_cfg = dataclasses.replace(model_cfg,
                                                batch_size=node_bs)
        else:
            self.node_cfg = model_cfg
        t0 = time.perf_counter()
        self.hp = hierarchical_partition(
            ds.graph, job.num_machines, job.trainers_per_machine,
            split_mask=ds.split_mask, method=job.partition_method,
            seed=job.seed)
        self.partition_time_s = time.perf_counter() - t0
        book = self.hp.book

        # KVStore: features (and labels, so remote trainers pull them too)
        self.transport = Transport(job.network or NetworkModel())
        feats_new = ds.feats[book.new2old_node]
        self.labels_new = ds.labels[book.new2old_node]

        # heterograph path: typed per-ntype/per-etype policies + per-ntype
        # feature tensors; activated by a schema'd dataset + per-relation
        # fanouts in the model config (an int-fanout config on the same
        # dataset keeps the legacy fused path)
        self.schema = getattr(ds, "schema", None)
        self.hetero = self.schema is not None and model_cfg.typed
        policies = {"node": PartitionPolicy("node", book.node_offsets),
                    "edge": PartitionPolicy("edge", book.edge_offsets)}
        self.typed = None
        if self.hetero:
            g = ds.graph
            ntypes_new = (None if g.ntypes is None
                          else g.ntypes[book.new2old_node])
            etypes_new = (None if g.etypes is None
                          else g.etypes[book.new2old_edge])
            self.typed = build_typed_partition(book, self.schema,
                                               ntypes_new, etypes_new)
            policies.update(self.typed.policies())
        self.store = DistKVStore(policies, transport=self.transport)
        if self.hetero:
            # each node type registers its own tensor under its own policy;
            # rows are type-local, ordered to match the policy's offsets
            for t, nt in enumerate(self.schema.ntypes):
                rows = ds.feats[book.new2old_node[self.typed.type2node[t]]]
                self.store.init_data(f"feat:{nt}", rows.shape[1:],
                                     np.float32, f"node:{nt}",
                                     full_array=rows)
        else:
            self.store.init_data("feat", feats_new.shape[1:], np.float32,
                                 "node", full_array=feats_new)

        # per-trainer seed split (§5.6.1): node tasks split the training
        # vertices; link prediction splits each machine's OWNED edge range
        # (edges live with their dst vertex) into contiguous per-trainer
        # pools — "we may use all edges to train a model" (§6)
        if self.task == "link_prediction":
            self.e_src, self.e_dst = edge_endpoints(book, ds.graph)
            self.trainer_edges: List[np.ndarray] = []
            T = job.trainers_per_machine
            spans = [(int(book.edge_offsets[m]), int(book.edge_offsets[m + 1]))
                     for m in range(job.num_machines)]
            # equal pool size for EVERY trainer (the global equal-count
            # requirement of §5.6.1: synchronous SGD needs same-size
            # schedules): each machine range is cut into T contiguous
            # chunks and each trainer keeps the first min-across-machines
            # chunk size; the surplus of edge-richer machines is dropped,
            # like the node split's tail
            per = min((ehi - elo) // T for elo, ehi in spans)
            for elo, ehi in spans:
                chunk = (ehi - elo) // T
                for t in range(T):
                    self.trainer_edges.append(np.arange(
                        elo + t * chunk, elo + t * chunk + per,
                        dtype=np.int64))
            # locality of the positive SOURCES (dsts are local by
            # construction — edges are owned by their dst's machine)
            self.locality = locality_report(
                self.hp, [self.e_src[e] for e in self.trainer_edges])
        else:
            train_new = book.old2new_node[ds.train_nids]
            self.trainer_seeds = split_training_set(
                self.hp, train_new, use_level2=job.use_level2, seed=job.seed)
            self.locality = locality_report(self.hp, self.trainer_seeds)

        # per-trainer samplers + pipelines (+ optional hot-vertex caches)
        self.num_trainers = self.hp.num_trainers
        self.samplers: List[DistributedSampler] = []
        self.edge_samplers: List[EdgeBatchSampler] = []
        self.pipelines: List[MinibatchPipeline] = []
        self.caches: List[Optional[FeatureCache]] = []
        for ti in range(self.num_trainers):
            machine = ti // job.trainers_per_machine
            s = DistributedSampler(
                book, self.hp.partitions, self.node_cfg.fanouts,
                self.node_cfg.batch_size, machine=machine,
                transport=self.transport, seed=job.seed + 100 + ti,
                schema=self.schema if self.hetero else None,
                ntype_of_node=(self.typed.ntype_of_node
                               if self.hetero else None))
            client = self.store.client(machine)
            cache = self._build_cache(client, machine) if job.cache else None
            if self.task == "link_prediction":
                es = self._build_edge_sampler(s, self.trainer_edges[ti],
                                              seed=job.seed + 300 + ti)
                p = EdgeMinibatchPipeline(
                    es, client, "feat", sync=job.sync,
                    non_stop=job.non_stop, depths=job.pipeline_depths,
                    to_device=False, seed=job.seed + 200 + ti,
                    typed=self.typed, cache=cache,
                    sample_workers=job.sample_workers)
                self.edge_samplers.append(es)
            else:
                seeds = self.trainer_seeds[ti]
                p = MinibatchPipeline(
                    s, client, "feat", seeds,
                    labels=self.labels_new[seeds], sync=job.sync,
                    non_stop=job.non_stop, depths=job.pipeline_depths,
                    to_device=False, seed=job.seed + 200 + ti,
                    typed=self.typed, cache=cache,
                    sample_workers=job.sample_workers)
            self.samplers.append(s)
            self.pipelines.append(p)
            self.caches.append(cache)
        self.batches_per_epoch = min(p.batches_per_epoch for p in self.pipelines)
        if self.batches_per_epoch < 1:
            if self.task == "link_prediction":
                raise ValueError(
                    f"edge batch {model_cfg.batch_size} exceeds the "
                    f"per-trainer owned-edge pool "
                    f"({min(len(e) for e in self.trainer_edges)} edges/"
                    f"trainer) — shrink the batch or the trainer count")
            raise ValueError(
                f"batch_size {model_cfg.batch_size} exceeds the per-trainer "
                f"training-set split ({min(len(s) for s in self.trainer_seeds)} "
                f"seeds/trainer) — shrink the batch or the trainer count")

        self.params = init_gnn(self.node_cfg, jax.random.key(job.seed))
        if self.task == "link_prediction":
            self.params = {"gnn": self.params,
                           "lp": init_lp_head(job.score_fn,
                                              self.node_cfg.num_rels,
                                              self.node_cfg.num_classes)}
        self.opt = adamw_init(self.params)
        self._step = self._build_step()
        self._eval_ranks_fn = None
        self._eval_ranks_key = None

    # ------------------------------------------------------------------
    def _build_cache(self, client, machine: int) -> FeatureCache:
        """One trainer's hot-vertex cache over remote feature rows,
        registered for every feature tensor and (optionally) pre-warmed
        from the machine partition's halo access counts — the partition
        book's static prediction of which remote rows the sampler will
        keep pulling (§5.3's locality argument, attacked from the other
        side)."""
        cache = FeatureCache(self.job.cache, self.store)
        names = ([f"feat:{nt}" for nt in self.schema.ntypes]
                 if self.hetero else ["feat"])
        for name in names:
            cache.register(self.store, name)
        # NOTE: MinibatchPipeline(cache=...) owns the client<->cache
        # binding; warm() pulls with _bypass_cache and needs no attach
        if self.job.cache.prewarm:
            gids, counts = halo_access_counts(self.hp.partitions[machine])
            if self.hetero:
                types, tids = self.typed.nid2typed(gids)
                for t, nt in enumerate(self.schema.ntypes):
                    m = types == t
                    if m.any():
                        cache.warm(client, f"feat:{nt}", tids[m], counts[m])
            else:
                cache.warm(client, "feat", gids, counts)
        return cache

    # ------------------------------------------------------------------
    def _build_edge_sampler(self, node_sampler: DistributedSampler,
                            owned_eids: np.ndarray, seed: int, *,
                            batch_edges: Optional[int] = None,
                            num_negs: Optional[int] = None,
                            neg_mode: Optional[str] = None,
                            exclude: Optional[bool] = None
                            ) -> EdgeBatchSampler:
        """One positive-edge scheduler + negative sampler over a pool of
        owned edges; typed runs draw type-correct negatives from each
        relation's dst node type. Keyword overrides exist for eval, whose
        protocol differs from the training job's (single construction
        site so the pool rules can never diverge)."""
        job = self.job
        neg_pools = None
        etype_of_edge = None
        schema = None
        if self.hetero:
            schema = self.schema
            etype_of_edge = self.typed.etype_of_edge
            neg_pools = [self.typed.type2node[schema.dst_ntype_id(r)]
                         for r in range(schema.num_etypes)]
        return EdgeBatchSampler(
            node_sampler, self.e_src, self.e_dst, owned_eids,
            batch_edges or self.cfg.batch_size,
            job.num_negs if num_negs is None else num_negs,
            neg_mode=neg_mode or job.neg_mode,
            etype_of_edge=etype_of_edge, schema=schema,
            neg_pools=neg_pools,
            exclude_batch_positives=(job.neg_exclude if exclude is None
                                     else exclude),
            seed=seed)

    # ------------------------------------------------------------------
    def _lp_scores(self, params, batch, cfg: Optional[GNNConfig] = None):
        """Embeddings -> (pos, neg) scores; shared by train and eval
        (eval passes its own cfg — its endpoint capacity differs)."""
        h = apply_gnn(cfg or self.node_cfg, params["gnn"], batch,
                      etype_id=self.schema.etype_id if self.hetero else None)
        kw = dict(head=params["lp"], score_fn=self.job.score_fn,
                  etypes=batch["edge_etypes"])
        pos = lp_pair_scores(h, batch["pos_u"], batch["pos_v"], **kw)
        neg = lp_pair_scores(h, batch["pos_u"], batch["neg_v"], **kw)
        return pos, neg

    def _build_step(self):
        lr = self.job.lr
        if self.task == "link_prediction":
            @jax.jit
            def step(params, opt, stacked):
                def loss_one(p, batch):
                    pos, neg = self._lp_scores(p, batch)
                    loss = lp_loss_from_scores(pos, neg, batch["pair_mask"])
                    mrr = lp_metrics(lp_ranks(pos, neg),
                                     batch["pair_mask"])["mrr"]
                    return loss, mrr

                def loss_fn(p):
                    losses, mrrs = jax.vmap(lambda b: loss_one(p, b))(stacked)
                    return losses.mean(), mrrs.mean()

                (loss, mrr), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params)
                params2, opt2 = adamw_update(params, grads, opt, lr=lr)
                return params2, opt2, loss, mrr
            return step

        cfg = self.node_cfg
        etype_id = self.schema.etype_id if self.hetero else None

        @jax.jit
        def step(params, opt, stacked):
            def loss_one(p, batch):
                logits = apply_gnn(cfg, p, batch, etype_id=etype_id)
                return (nc_loss(logits, batch["labels"], batch["seed_mask"]),
                        nc_accuracy(logits, batch["labels"], batch["seed_mask"]))

            def loss_fn(p):
                losses, accs = jax.vmap(lambda b: loss_one(p, b))(stacked)
                return losses.mean(), accs.mean()   # sync SGD: mean over trainers

            (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            params2, opt2 = adamw_update(params, grads, opt, lr=lr)
            return params2, opt2, loss, acc
        return step

    @staticmethod
    def _stack(batches: List[dict]) -> dict:
        def stack_leaf(*xs):
            return jnp.stack([jnp.asarray(x) for x in xs])
        return jax.tree.map(stack_leaf, *batches)

    def _device_batch(self, mb) -> dict:
        blocks = [dict(edge_src=b.edge_src, edge_dst=b.edge_dst,
                       edge_mask=b.edge_mask, edge_types=b.edge_types)
                  for b in mb.blocks]
        if self.task == "link_prediction":
            return dict(
                input_feats=mb.input_feats,
                seed_mask=mb.seed_mask,
                pos_u=mb.pos_u, pos_v=mb.pos_v, neg_v=mb.neg_v,
                pair_mask=mb.pair_mask, edge_etypes=mb.edge_etypes,
                blocks=blocks,
            )
        return dict(
            input_feats=mb.input_feats,
            labels=mb.labels,
            seed_mask=mb.seed_mask,
            blocks=blocks,
        )

    # ------------------------------------------------------------------
    def train_epoch(self, epoch: int) -> dict:
        iters = [p.epoch(epoch) for p in self.pipelines]
        t0 = time.perf_counter()
        losses, accs = [], []
        for _ in range(self.batches_per_epoch):
            batches = [self._device_batch(next(it)) for it in iters]
            self.params, self.opt, loss, acc = self._step(
                self.params, self.opt, self._stack(batches))
            losses.append(float(loss))
            accs.append(float(acc))
        # drain finite iterators (sync / non-non_stop modes)
        if not (self.pipelines[0].non_stop and not self.job.sync):
            for it in iters:
                for _ in it:
                    pass
        dt = time.perf_counter() - t0
        out = {"epoch": epoch, "loss": float(np.mean(losses)),
               "acc": float(np.mean(accs)), "time_s": dt,
               "batches": self.batches_per_epoch}
        if self.task == "link_prediction":
            out["train_mrr"] = out["acc"]   # the step's aux metric is MRR
        return out

    def evaluate_lp(self, num_batches: int = 20, seed: int = 977,
                    num_negs: Optional[int] = None,
                    batch_edges: Optional[int] = None) -> dict:
        """MRR / Hits@k over a deterministic sample of the graph's edges,
        ALWAYS against fresh uniform negatives (the paper's LP eval
        protocol: rank the true destination against corrupted ones),
        regardless of the training ``neg_mode``.

        Eval uses its own candidate count — ``num_negs`` defaults to 49,
        so ranks span [1, 50] and Hits@10 is a real metric (ranking
        against only the training K would saturate it) — and therefore
        its own endpoint capacity / jitted rank program, cached per
        (B, K). Exclusion is off regardless of ``neg_exclude``: the eval
        candidates must not depend on ANY training setting. The trainers'
        samplers are owned by their pipeline threads, so eval builds
        dedicated ones. As with ``evaluate``, eval feature pulls are
        charged to the shared transport (sampling RPCs are not) — read
        ``sampling_stats()`` before evaluating for pure training traffic.
        """
        assert self.task == "link_prediction", "trainer is not an LP job"
        B = batch_edges or min(self.cfg.batch_size, 16)
        K = num_negs or 49
        book = self.hp.book
        node_bs = EdgeBatchSampler.required_node_batch(B, K, "uniform")
        eval_cfg = dataclasses.replace(self.node_cfg, batch_size=node_bs)
        node_s = DistributedSampler(
            book, self.hp.partitions, eval_cfg.fanouts,
            eval_cfg.batch_size, machine=0, seed=self.job.seed + 998,
            schema=self.schema if self.hetero else None,
            ntype_of_node=self.typed.ntype_of_node if self.hetero else None)
        all_eids = np.arange(int(book.edge_offsets[-1]), dtype=np.int64)
        es = self._build_edge_sampler(node_s, all_eids,
                                      seed=self.job.seed + seed,
                                      batch_edges=B, num_negs=K,
                                      neg_mode="uniform", exclude=False)
        client = self.store.client(0)
        if self._eval_ranks_fn is None or self._eval_ranks_key != (B, K):
            @jax.jit
            def eval_ranks(params, batch):
                pos, neg = self._lp_scores(params, batch, cfg=eval_cfg)
                return lp_ranks(pos, neg)
            self._eval_ranks_fn = eval_ranks
            self._eval_ranks_key = (B, K)
        rng = np.random.default_rng(self.job.seed + seed)
        ranks: List[np.ndarray] = []
        sched = es.schedule(rng, 0)
        for _ in range(num_batches):
            try:
                _e, b, et, eids = next(sched)
            except StopIteration:
                break
            emb = es.sample_edges(eids, etype=et, batch_index=b)
            if self.hetero:
                emb.input_feats = client.pull_typed(
                    "feat", emb.input_gids, self.typed,
                    ntypes=emb.input_ntypes)
            else:
                emb.input_feats = client.pull("feat", emb.input_gids)
            r = np.asarray(self._eval_ranks_fn(self.params,
                                               self._device_batch(emb)))
            ranks.append(r[emb.pair_mask])
        if not ranks:   # fewer owned edges than one batch: degenerate eval
            return {"mrr": float("nan"), "num_edges": 0,
                    **{f"hits@{k}": float("nan") for k in (1, 3, 10)}}
        r = np.concatenate(ranks).astype(np.float64)
        out = {"mrr": float((1.0 / r).mean()), "num_edges": int(len(r))}
        for k in (1, 3, 10):
            out[f"hits@{k}"] = float((r <= k).mean())
        return out

    def evaluate(self, nids_old: np.ndarray, max_batches: int = 50) -> float:
        book = self.hp.book
        nids = book.old2new_node[np.asarray(nids_old)]
        # dedicated sampler: the trainers' samplers are owned by their
        # (possibly still running, non_stop) pipeline sampling threads —
        # sharing one would race the RNG and stats
        sampler = DistributedSampler(
            book, self.hp.partitions, self.cfg.fanouts, self.cfg.batch_size,
            machine=0, seed=self.job.seed + 999,
            schema=self.schema if self.hetero else None,
            ntype_of_node=self.typed.ntype_of_node if self.hetero else None)
        client = self.store.client(0)
        accs = []
        bs = self.cfg.batch_size
        for b in range(min(max_batches, len(nids) // bs)):
            chunk = nids[b * bs:(b + 1) * bs]
            mb = sampler.sample(chunk, labels=self.labels_new[chunk],
                                batch_index=b)
            if self.hetero:
                mb.input_feats = client.pull_typed("feat", mb.input_gids,
                                                   self.typed,
                                                   ntypes=mb.input_ntypes)
            else:
                mb.input_feats = client.pull("feat", mb.input_gids)
            logits = apply_gnn(self.cfg, self.params, self._device_batch(mb),
                               etype_id=self.schema.etype_id
                               if self.hetero else None)
            accs.append(float(nc_accuracy(logits, jnp.asarray(mb.labels),
                                          jnp.asarray(mb.seed_mask))))
        return float(np.mean(accs)) if accs else float("nan")

    def stop(self):
        for p in self.pipelines:
            p.stop()

    def sampling_stats(self) -> dict:
        remote = sum(s.stats.seeds_remote for s in self.samplers)
        total = sum(s.stats.seeds_total for s in self.samplers)
        owner_req = sum(s.stats.owner_requests for s in self.samplers)
        rel_req = sum(s.stats.relation_requests for s in self.samplers)
        out = {"remote_seed_frac": remote / max(total, 1),
               "transport": self.transport.stats(),
               # request-count accounting (§5.5 batched RPCs): requests the
               # coalesced dispatch actually issued vs what a per-relation
               # dispatch would have issued (equal on untyped runs)
               "sampler_requests": {
                   "owner_requests": owner_req,
                   "relation_requests": rel_req,
                   "coalescing_factor": rel_req / max(owner_req, 1),
               },
               "mean_seed_locality": self.locality["mean_local_frac"],
               "partition_time_s": self.partition_time_s}
        live = [c for c in self.caches if c is not None]
        if live:
            per = [c.stats() for c in live]
            hits = sum(p["hits"] for p in per)
            misses = sum(p["misses"] for p in per)
            out["cache"] = {
                "hit_rate": hits / max(hits + misses, 1),
                "used_bytes": sum(p["used_bytes"] for p in per),
                "evictions": sum(p["evictions"] for p in per),
                "stale_hits": sum(p["stale_hits"] for p in per),
                "per_trainer": per,
            }
        if self.hetero:
            per = sum(s.stats.edges_per_etype for s in self.samplers)
            out["edges_per_etype"] = {
                rel: int(per[r]) for r, rel in enumerate(self.schema.etypes)}
        return out
