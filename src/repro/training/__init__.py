from .trainer import DistGNNTrainer, TrainJobConfig

__all__ = ["DistGNNTrainer", "TrainJobConfig"]
