"""Deprecated import location — the public surface moved to ``repro.api``
(DESIGN.md §8). ``from repro.training import DistGNNTrainer`` keeps
working through this shim but emits a :class:`DeprecationWarning`;
``repro.training.trainer`` (the implementation module) stays a regular,
warning-free internal import.
"""
import warnings

__all__ = ["DistGNNTrainer", "TrainJobConfig"]


def __getattr__(name: str):
    if name in __all__:
        warnings.warn(
            f"importing {name} from repro.training is deprecated; "
            f"use `from repro.api import {name}` (DESIGN.md §8)",
            DeprecationWarning, stacklevel=2)
        from . import trainer
        return getattr(trainer, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
