"""Dense optimizers (pure JAX pytree transforms).

Dense parameters take the all-reduce + optimizer path (§5.6); the sparse
embedding path is ``core.kvstore.embedding`` (row-sparse Adam at the
owners). Kept dependency-free (no optax offline).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    # moments in f32 regardless of (possibly bf16) param dtype
    f32_zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(f32_zeros, params),
                      nu=jax.tree.map(f32_zeros, params))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(params, grads, state: AdamWState, *, lr: float,
                 beta1: float = 0.9, beta2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    mu = jax.tree.map(
        lambda m, g: beta1 * m + (1 - beta1) * g.astype(jnp.float32),
        state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: beta2 * v + (1 - beta2) *
        g.astype(jnp.float32) * g.astype(jnp.float32),
        state.nu, grads)
    bc1 = 1 - beta1 ** t
    bc2 = 1 - beta2 ** t

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = lr * (mhat / (jnp.sqrt(vhat) + eps) +
                      weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)


def sgd_update(params, grads, *, lr: float, momentum_state=None,
               momentum: float = 0.0):
    if momentum and momentum_state is not None:
        momentum_state = jax.tree.map(lambda b, g: momentum * b + g,
                                      momentum_state, grads)
        grads = momentum_state
    return jax.tree.map(lambda p, g: p - lr * g, params, grads), momentum_state
