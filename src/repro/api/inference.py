"""Online inference service + offline layer-wise embeddings (DESIGN.md §11).

The paper's motivating workloads (recommendation, fraud detection, search)
are *serving* workloads: a trained GNN answers low-latency predict requests
for individual vertices, and periodically a batch job materializes
embeddings for the whole graph. Both reuse the training stack's pieces:

* :class:`InferenceServer` — accepts single-node / small-batch predict
  requests, samples each request's ego networks through the SAME
  deterministic ad-hoc protocol the eval loader runs
  (:func:`~repro.core.sampler.sample_ego_networks`), pulls features
  through a long-lived halo-prewarmed :class:`FeatureCache`, and
  micro-batches concurrent requests into ONE statically-shaped stacked
  block (§2 capacity contract) staged via ``device_stage(packed=True)``
  so every scheduler tick runs a single jitted forward.

  The serving correctness contract is bitwise: a node's served logits
  equal the eval-mode loader forward for the same node, and micro-batched
  concurrent requests return the same bytes as the same requests served
  one-at-a-time. Both hold by construction: sampling coordinates are a
  pure function of request content (never of arrival order or co-batched
  requests), and the forward is ONE fixed compiled program over
  ``(micro_batch_capacity, ...)`` stacked inputs whose rows are
  element-wise independent — padding rows and neighbors in other slots
  cannot perturb a live row's bytes.

* :func:`offline_embeddings` — DGL's layer-wise ``inference()`` idiom:
  for each layer, pull the previous layer's rows for every chunk's
  full-neighbor frontier through the KVStore, run EXACTLY the training
  forward's layer (:func:`~repro.models.gnn.apply_gnn_layer`), and push
  the chunk's output rows back as a ``DistTensor``. Full neighborhoods
  ride the §2 static-capacity contract via
  :func:`~repro.core.sampler.full_neighbor_fanouts` (fanout = max
  in-degree takes every adjacency list deterministically), so the result
  is exact — byte-equal to a full-neighbor mini-batch forward per node,
  invariant to ``chunk_size``.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.kvstore.cache import CacheConfig, FeatureCache
from ..core.sampler import (DistributedSampler, full_neighbor_fanouts,
                            pull_batch_feats, sample_ego_networks)
from ..core.kvstore.faults import OwnerUnavailable
from ..kernels.pack import device_stage
from ..models.gnn import GNNConfig, apply_gnn, apply_gnn_layer
from .dataloader import _model_blocks
from .dist_graph import DistGraph, DistTensor


class ServerOverloaded(RuntimeError):
    """Admission control shed this request: the micro-batch queue is past
    ``max_pending_chunks`` (DESIGN.md §12). The request was NOT enqueued;
    the caller may retry with backoff."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline budget expired before its chunks reached a
    scheduler tick; the scheduler shed it instead of serving a stale
    answer late (DESIGN.md §12)."""


class PredictionHandle:
    """Future for one predict request: ``result()`` blocks until every
    chunk of the request has been served and returns the ``(n, C)``
    logits rows in request order.

    ``degraded`` is True when any feature row behind the answer was
    salvaged (stale cache / zero-fill) because every copy of its owner
    was down — the answer is best-effort, not byte-exact (DESIGN.md §12).
    """

    def __init__(self, num_chunks: int):
        self._parts: List[Optional[np.ndarray]] = [None] * num_chunks
        self._remaining = num_chunks
        self._error: Optional[BaseException] = None
        self._event = threading.Event()
        self._lock = threading.Lock()
        self.submitted_at = time.perf_counter()
        self.completed_at: Optional[float] = None
        self.degraded = False
        self.deadline_at: Optional[float] = None   # absolute perf_counter

    # -- server side ----------------------------------------------------
    def _deliver(self, chunk: int, rows: np.ndarray) -> None:
        with self._lock:
            if self._error is not None:   # already failed (deadline/close):
                return                    # late rows must not "complete" it
            if self._parts[chunk] is None:
                self._parts[chunk] = rows
                self._remaining -= 1
            if self._remaining == 0:
                self.completed_at = time.perf_counter()
                self._event.set()

    def _fail(self, exc: BaseException) -> None:
        with self._lock:
            self._error = exc
            self.completed_at = time.perf_counter()
            self._event.set()

    # -- client side ----------------------------------------------------
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def latency_s(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self._event.wait(timeout):
            raise TimeoutError("predict request not served within "
                               f"{timeout}s")
        if self._error is not None:
            raise self._error
        return np.concatenate(self._parts, axis=0)


class InferenceServer:
    """Low-latency ego-network serving over a :class:`DistGraph`.

    ``predict(nids)`` / ``submit(nids)`` chunk a request into §2
    capacity blocks (``cfg.batch_size`` seeds each), sample every chunk at
    the deterministic ad-hoc coordinate ``(epoch=-1, batch_index=chunk
    position within the request)`` — the eval loader's protocol, shared
    via :func:`sample_ego_networks` — and hand the featurized blocks to a
    scheduler thread. The scheduler waits up to ``micro_batch_window_ms``
    to coalesce up to ``micro_batch_capacity`` chunks (across requests)
    into one stacked host tree, stages it with ``device_stage(packed=
    True)`` (one device transfer per tick, DESIGN.md §9), and runs ONE
    jitted vmapped forward; each chunk's live logit rows go back to its
    request's :class:`PredictionHandle`.

    ``cache`` is either a :class:`CacheConfig` (the server builds its own
    halo-prewarmed :class:`FeatureCache` via
    :meth:`DistGraph.feature_cache`) or an existing :class:`FeatureCache`
    to SHARE — the long-lived cache persists across requests and may be
    shared with other servers/loaders (it locks internally, and mutable
    rows are version-checked per lookup, so concurrent
    ``DistEmbedding.push_grad`` writers can never make it serve stale
    bytes — DESIGN.md §5).
    """

    def __init__(self, g: DistGraph, cfg: GNNConfig, params, *,
                 cache: Union[CacheConfig, FeatureCache, None] = None,
                 micro_batch_capacity: int = 8,
                 micro_batch_window_ms: float = 2.0,
                 sampler_seed: int = 0,
                 deadline_ms: Optional[float] = None,
                 max_pending_chunks: Optional[int] = None):
        if micro_batch_capacity < 1:
            raise ValueError("micro_batch_capacity must be >= 1")
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError("deadline_ms must be positive")
        if max_pending_chunks is not None and max_pending_chunks < 1:
            raise ValueError("max_pending_chunks must be >= 1")
        self.g = g
        self.cfg = cfg
        self.params = params
        self.capacity = int(micro_batch_capacity)
        self.window_s = float(micro_batch_window_ms) / 1e3
        # availability knobs (DESIGN.md §12): a per-request deadline budget
        # (expired chunks are shed at tick assembly, never served late)
        # and an admission bound on the pending-chunk queue
        self.deadline_s = (None if deadline_ms is None
                           else float(deadline_ms) / 1e3)
        self.max_pending_chunks = (None if max_pending_chunks is None
                                   else int(max_pending_chunks))
        self.sampler = DistributedSampler(
            g.book, g.partitions, cfg.fanouts, cfg.batch_size,
            machine=g.machine, transport=None,   # sampling RPCs uncharged,
            seed=sampler_seed,                   # like eval (DESIGN.md §11)
            schema=g.schema if g.hetero else None,
            ntype_of_node=g.typed.ntype_of_node if g.hetero else None)
        if isinstance(cache, CacheConfig):
            cache = g.feature_cache(cache)
        elif isinstance(cache, FeatureCache):
            # shared instance: make sure this graph's feature tensors are
            # registered (idempotent) so pulls take the cached path
            names = ([f"{g.feat_name}:{nt}" for nt in g.schema.ntypes]
                     if g.hetero else [g.feat_name])
            for name in names:
                cache.register(g.store, name)
        self.cache = cache
        self.client = g.new_client()
        if cache is not None:
            self.client.attach_cache(cache)

        etype_id = g.schema.etype_id if g.hetero else None

        def fwd(params, stacked):
            return jax.vmap(
                lambda b: apply_gnn(cfg, params, b, etype_id=etype_id)
            )(stacked)

        self._fwd = jax.jit(fwd)

        self._cond = threading.Condition()
        self._pending: List[tuple] = []    # (handle, chunk_idx, tree, live)
        self._stop = False
        self._lock = threading.Lock()      # stats
        self.requests = 0
        self.chunks = 0
        self.ticks = 0
        self.tick_chunks: List[int] = []
        self.latencies_s: List[float] = []
        self.degraded_requests = 0
        self.shed_chunks = 0          # deadline-expired at tick assembly
        self.rejected_requests = 0    # admission control (ServerOverloaded)
        self.failed_requests = 0      # handles failed during submit pulls
        self._thread = threading.Thread(target=self._loop,
                                        name="inference-scheduler",
                                        daemon=True)
        self._thread.start()

    # -- request path ---------------------------------------------------
    def _pull_feats(self, mb) -> bool:
        """Featurize one sampled chunk through the degraded-tolerant pull
        (DESIGN.md §12): rows whose owner has no reachable copy come back
        stale-cached or zero-filled instead of raising. Returns True when
        any row was salvaged. Retry exhaustion (the data exists, the
        network is flaky) still raises — the caller fails only the
        owning handle."""
        if self.g.hetero:
            feats, fresh = self.client.pull_typed_degraded(
                self.g.feat_name, mb.input_gids, self.g.typed,
                ntypes=mb.input_ntypes)
        else:
            feats, fresh = self.client.pull_degraded(self.g.feat_name,
                                                     mb.input_gids)
        mb.input_feats = feats
        return not bool(fresh.all())

    def submit(self, nids) -> PredictionHandle:
        """Enqueue a predict request (non-blocking); sampling and feature
        pulls run in the caller's thread, the forward on the scheduler's.
        Requests larger than ``cfg.batch_size`` are split into §2 blocks
        (chunk b at ad-hoc coordinate b, exactly the eval loader's
        numbering).

        Raises :class:`ServerOverloaded` when admission control is on and
        the pending queue cannot take the request's chunks. A pull
        failure during featurization fails ONLY this request's handle
        (the error surfaces from ``result()``); rows whose owner is in a
        sustained outage degrade instead of failing, and the returned
        handle is flagged ``degraded``."""
        nids = np.asarray(nids, dtype=np.int64).reshape(-1)
        if len(nids) == 0:
            raise ValueError("empty predict request")
        if self._stop:
            raise RuntimeError("InferenceServer is closed")
        bs = self.cfg.batch_size
        num_chunks = -(-len(nids) // bs)
        if self.max_pending_chunks is not None:
            with self._cond:
                room = self.max_pending_chunks - len(self._pending)
            if num_chunks > room:
                with self._lock:
                    self.rejected_requests += 1
                raise ServerOverloaded(
                    f"pending queue has room for {max(room, 0)} chunks, "
                    f"request needs {num_chunks} (max_pending_chunks="
                    f"{self.max_pending_chunks})")
        handle = PredictionHandle(num_chunks=num_chunks)
        if self.deadline_s is not None:
            handle.deadline_at = handle.submitted_at + self.deadline_s
        entries = []
        try:
            for b, mb in enumerate(sample_ego_networks(
                    self.sampler, self.client, self.g.feat_name, nids,
                    typed=self.g.typed if self.g.hetero else None,
                    drop_last=False, pull_feats=False)):
                if self._pull_feats(mb):
                    handle.degraded = True
                tree = {"input_feats": mb.input_feats,
                        "blocks": _model_blocks(mb)}
                entries.append((handle, b, tree, int(mb.seed_mask.sum())))
        except Exception as exc:
            # fail THIS handle only — co-batched requests and the
            # scheduler loop never see the error (DESIGN.md §12)
            handle._fail(exc)
            with self._lock:
                self.requests += 1
                self.failed_requests += 1
            return handle
        with self._cond:
            if self._stop:
                raise RuntimeError("InferenceServer is closed")
            self._pending.extend(entries)
            self._cond.notify_all()
        with self._lock:
            self.requests += 1
            self.chunks += len(entries)
            if handle.degraded:
                self.degraded_requests += 1
        return handle

    def predict(self, nids, timeout: Optional[float] = 60.0) -> np.ndarray:
        """Synchronous predict: ``(len(nids), num_classes)`` logits."""
        return self.submit(nids).result(timeout)

    # -- scheduler ------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._pending and not self._stop:
                    self._cond.wait()
                if not self._pending and self._stop:
                    return
                # first chunk arrived: hold the tick open up to the
                # micro-batch window for co-batchable chunks
                deadline = time.perf_counter() + self.window_s
                while len(self._pending) < self.capacity and not self._stop:
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        break
                    self._cond.wait(timeout=remaining)
                take = self._pending[:self.capacity]
                del self._pending[:self.capacity]
            # shed chunks whose request deadline already expired: serving
            # them would spend a tick slot on an answer nobody can use,
            # and under overload that pushes EVERY later request past its
            # own deadline (DESIGN.md §12)
            now = time.perf_counter()
            live = []
            for entry in take:
                handle = entry[0]
                if handle.deadline_at is not None and now > handle.deadline_at:
                    handle._fail(DeadlineExceeded(
                        "request shed: deadline budget "
                        f"{self.deadline_s * 1e3:.1f}ms expired before "
                        f"its tick"))
                    with self._lock:
                        self.shed_chunks += 1
                else:
                    live.append(entry)
            if live:
                self._serve_tick(live)

    def _serve_tick(self, take: List[tuple]) -> None:
        try:
            trees = [t for (_h, _b, t, _n) in take]
            # pad to the static stack capacity by repeating the first
            # chunk: rows are independent, so pad contents never reach a
            # live chunk's bytes and the program compiles exactly once
            trees = trees + [trees[0]] * (self.capacity - len(trees))
            host = jax.tree.map(lambda *xs: np.stack(xs), *trees)
            staged = device_stage(host, packed=True).unpack()
            logits = np.asarray(self._fwd(self.params, staged))
        except BaseException as exc:   # deliver, don't kill the scheduler
            for handle, _b, _t, _n in take:
                handle._fail(exc)
            return
        with self._lock:
            self.ticks += 1
            self.tick_chunks.append(len(take))
        for i, (handle, b, _tree, n_live) in enumerate(take):
            handle._deliver(b, logits[i, :n_live])
            if handle.done() and handle.latency_s is not None:
                with self._lock:
                    self.latencies_s.append(handle.latency_s)

    # -- lifecycle / observability --------------------------------------
    def stats(self) -> dict:
        with self._lock:
            occ = (float(np.mean(self.tick_chunks))
                   if self.tick_chunks else 0.0)
            out = {"requests": self.requests, "chunks": self.chunks,
                   "ticks": self.ticks, "mean_tick_occupancy": occ,
                   "micro_batch_capacity": self.capacity,
                   "micro_batch_window_ms": self.window_s * 1e3,
                   "deadline_ms": (None if self.deadline_s is None
                                   else self.deadline_s * 1e3),
                   "max_pending_chunks": self.max_pending_chunks,
                   "degraded_requests": self.degraded_requests,
                   "shed_chunks": self.shed_chunks,
                   "rejected_requests": self.rejected_requests,
                   "failed_requests": self.failed_requests,
                   "cache": None}
        if self.cache is not None:
            out["cache"] = self.cache.stats()
        return out

    def close(self) -> None:
        """Stop the scheduler. Chunks still queued are failed (their
        ``result()`` raises — a silently-hung future is worse than an
        error), and a scheduler thread that outlives the join timeout is
        an error, not a shrug: a live thread still owns the device and
        the handles it took."""
        with self._cond:
            self._stop = True
            orphaned = self._pending[:]
            self._pending.clear()
            self._cond.notify_all()
        self._thread.join(timeout=30)
        exc = RuntimeError("InferenceServer closed before request served")
        for handle, _b, _t, _n in orphaned:
            handle._fail(exc)
        if self._thread.is_alive():
            raise RuntimeError(
                "inference-scheduler thread did not stop within 30s of "
                "close(); it may still hold the device")

    def __enter__(self) -> "InferenceServer":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


# ---------------------------------------------------------------------------
# offline layer-wise inference (DGL's ``inference()`` idiom)
# ---------------------------------------------------------------------------

def _layer_out_dim(cfg: GNNConfig, params: dict, layer: int) -> int:
    p = params["layers"][layer]
    if cfg.arch == "gat":
        return int(p["b"].shape[0])
    return int(p["w_self"].shape[1])


def offline_embeddings(g: DistGraph, cfg: GNNConfig, params, *,
                       chunk_size: Optional[int] = None,
                       prefix: str = "emb") -> List[DistTensor]:
    """Full-graph layer-wise inference: materialize every layer's output
    for EVERY node as KVStore-resident ``DistTensor``s.

    Layer ``l`` makes one pass over all nodes in ``chunk_size`` blocks:
    each chunk's single-hop FULL-neighbor block is built by the owner-
    compute sampler (static capacity ``chunk_size * (1 + max_in_degree)``,
    see :func:`full_neighbor_fanouts`), the layer's inputs are pulled
    through the KVStore (layer 0: the feature tensors; layer l>0: the
    previous layer's output tensor — so each frontier pull is charged like
    any feature pull), and the chunk's rows are pushed back to
    ``"{prefix}{l}"`` (registered ``mutable=True``: version-tracked, so
    trainer caches can safely register embedding tensors later). The last
    tensor holds the model's logits (GAT's shared head applied).

    Exactness: per node the result is byte-equal to a full-neighbor
    mini-batch forward (the satellite test's oracle) and invariant to
    ``chunk_size`` — every aggregation sees the same per-dst edge order
    (adjacency order) regardless of chunking, and XLA's CPU row-wise ops
    are independent of the number of co-resident rows.
    """
    chunk_size = int(cfg.batch_size if chunk_size is None else chunk_size)
    if chunk_size < 2:
        # a 1-node chunk shrinks the §2 edge capacity onto XLA's
        # small-array reduction codepath, which reassociates the masked
        # segment sum and breaks bitwise chunk-size invariance; every
        # production block (training, eval, serving) is >= 2 seeds, so
        # the floor costs nothing and keeps the invariant exact
        raise ValueError("chunk_size must be >= 2")
    schema = g.schema if g.hetero else None
    fanouts = full_neighbor_fanouts(g.partitions, cfg.num_layers,
                                    schema=schema)
    client = g.new_client()
    all_nids = np.arange(g.num_nodes(), dtype=np.int64)
    etype_id = schema.etype_id if schema is not None else None

    out: List[DistTensor] = []
    prev_name: Optional[str] = None
    for l in range(cfg.num_layers):
        last = l == cfg.num_layers - 1
        d_out = (cfg.num_classes if last and "head" in params
                 else _layer_out_dim(cfg, params, l))
        name = f"{prefix}{l}"
        g.store.init_data(name, (d_out,), np.float32, "node", mutable=True)

        sampler = DistributedSampler(
            g.book, g.partitions, [fanouts[l]], chunk_size,
            machine=g.machine, transport=None, seed=0, schema=schema,
            ntype_of_node=g.typed.ntype_of_node if g.hetero else None)
        rel_offs = None
        if sampler.rel_caps[0] is not None:
            rel_offs = tuple(int(x) for x in sampler.rel_caps[0])

        def layer_fwd(p, h, block, _l=l, _last=last, _ro=rel_offs):
            h = apply_gnn_layer(cfg, p, _l, h, block, chunk_size,
                                rel_offsets=_ro)
            if _last and "head" in p:
                h = h @ p["head"]
            return h

        layer_fwd = jax.jit(layer_fwd)
        for mb in sample_ego_networks(sampler, client, g.feat_name,
                                      all_nids, typed=None,
                                      drop_last=False, pull_feats=False):
            if l == 0:
                h_src = pull_batch_feats(client, g.feat_name, mb,
                                         typed=g.typed if g.hetero
                                         else None)
            else:
                h_src = client.pull(prev_name, mb.input_gids)
            rows = np.asarray(layer_fwd(params, jnp.asarray(h_src),
                                        _model_blocks(mb)[0]))
            n_live = int(mb.seed_mask.sum())
            client.push(name, mb.seeds[:n_live], rows[:n_live],
                        reduce="assign")
        prev_name = name
        out.append(g.ndata[name])
    return out
