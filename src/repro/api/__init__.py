"""``repro.api`` — the public, DGL-compatible surface (DESIGN.md §8).

Everything a training script needs lives here::

    from repro.api import DistGraph, NodeDataLoader, EdgeDataLoader

    g = DistGraph(ds, num_machines=2, trainers_per_machine=2)
    loader = NodeDataLoader(g, g.node_split(), [10, 5], batch_size=32)
    for input_nodes, seeds, blocks in loader:
        ...

``DistGNNTrainer`` (the multi-trainer synchronous-SGD driver) and
``TrainJobConfig`` are re-exported lazily: the trainer itself composes
these façades, so importing it eagerly here would be circular.
"""
from ..core.kvstore.embedding import DistEmbedding, SparseAdamConfig
from ..core.kvstore.faults import (FaultInjector, OwnerDownWindow,
                                   OwnerUnavailable, RPCRetriesExhausted,
                                   TrainerDeath, TransientRPCError)
from .dataloader import (EdgeBatch, EdgeDataLoader, NodeBatch,
                         NodeDataLoader)
from .dist_graph import DistGraph, DistTensor
from .inference import (DeadlineExceeded, InferenceServer, PredictionHandle,
                        ServerOverloaded, offline_embeddings)

__all__ = [
    "DistGraph", "DistTensor", "DistEmbedding", "SparseAdamConfig",
    "NodeDataLoader", "EdgeDataLoader", "NodeBatch", "EdgeBatch",
    "InferenceServer", "PredictionHandle", "offline_embeddings",
    "ServerOverloaded", "DeadlineExceeded",
    "DistGNNTrainer", "TrainJobConfig",
    "FaultInjector", "TransientRPCError", "RPCRetriesExhausted",
    "TrainerDeath", "OwnerDownWindow", "OwnerUnavailable",
]

_LAZY = ("DistGNNTrainer", "TrainJobConfig")


def __getattr__(name: str):
    if name in _LAZY:
        from ..training import trainer
        return getattr(trainer, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
