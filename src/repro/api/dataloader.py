"""DGL-compatible mini-batch loaders over the async pipeline.

:class:`NodeDataLoader` / :class:`EdgeDataLoader` are true Python
iterables wrapping ``MinibatchPipeline`` / ``EdgeMinibatchPipeline``, so
the canonical DGL training loop works verbatim against the distributed
stack::

    loader = NodeDataLoader(g, train_nids, [10, 5], batch_size=32)
    for epoch in range(E):
        for input_nodes, seeds, blocks in loader:      # one epoch
            ...

Contract (DESIGN.md §8):

* each ``iter(loader)`` serves ONE epoch and ends with a clean
  ``StopIteration``; successive iterations advance the epoch counter, and
  in non-stop mode ride the same live pipeline (PR 4's consecutive-epoch
  contract) — per-batch bytes are identical to driving the pipeline
  directly with the same seeds;
* the yielded item unpacks as ``(input_nodes, seeds, blocks)`` (node) /
  ``(input_nodes, pair_graph, blocks)`` (edge) but is a thin view object
  also exposing ``input_feats`` / ``labels`` / ``seed_mask`` / ... and
  ``model_input()`` — the exact dict the jitted train steps consume;
* breaking out mid-epoch (``itertools.islice``, early ``break``) is safe:
  ``close()`` — called by ``__exit__``, by a following ``iter()``, or
  explicitly — drains the in-flight batches, joins every pool/feeder
  thread and rewinds, so the next iteration re-serves the SAME epoch
  byte-identically instead of leaking threads or mislabeled batches;
* ``mode="eval"`` runs the deterministic inline evaluation protocol the
  trainer has always used (sequential batches, ad-hoc epoch coordinates,
  sampling RPCs uncharged) — no pipeline threads at all.

Loaders are the ONLY place pipelines are constructed (enforced by
``tools/check_docs.py``); ``DistGNNTrainer`` and both examples compose
these façades.
"""
from __future__ import annotations

from typing import Iterator, List, Optional

import numpy as np

from ..core.pipeline.minibatch import EdgeMinibatchPipeline, MinibatchPipeline
from ..core.sampler import (DistributedSampler, EdgeBatchSampler,
                            sample_ego_networks)
from .dist_graph import DistGraph

_MODES = ("train", "eval")


def _model_blocks(mb) -> List[dict]:
    """The static per-layer arrays the jitted step consumes."""
    return [dict(edge_src=b.edge_src, edge_dst=b.edge_dst,
                 edge_mask=b.edge_mask, edge_types=b.edge_types)
            for b in mb.blocks]


class NodeBatch:
    """One node mini-batch: unpacks as DGL's ``(input_nodes, seeds,
    blocks)`` triple; attribute access reaches the full padded batch."""

    __slots__ = ("minibatch", "device")

    def __init__(self, minibatch, device: Optional[dict] = None):
        self.minibatch = minibatch
        self.device = device   # device-prefetched arrays, if enabled

    def __iter__(self):
        return iter((self.input_nodes, self.seeds, self.blocks))

    input_nodes = property(lambda self: self.minibatch.input_gids)
    input_ntypes = property(lambda self: self.minibatch.input_ntypes)
    input_feats = property(lambda self: self.minibatch.input_feats)
    seeds = property(lambda self: self.minibatch.seeds)
    seed_mask = property(lambda self: self.minibatch.seed_mask)
    labels = property(lambda self: self.minibatch.labels)
    blocks = property(lambda self: self.minibatch.blocks)
    epoch = property(lambda self: self.minibatch.epoch)
    batch_index = property(lambda self: self.minibatch.batch_index)

    _model_keys = ("input_feats", "labels", "seed_mask", "blocks")

    def model_input(self, packed: bool = False):
        """The dict the jitted step consumes.  ``packed=True`` (requires
        ``device_prefetch=True`` with packed staging) returns the staged
        :class:`~repro.kernels.pack.PackedBatch` itself — one contiguous
        device buffer per dtype, suitable for ``jax.jit`` donation
        (DESIGN.md §9) — instead of the unpacked per-array dict."""
        if packed:
            from ..kernels.pack import PackedBatch
            if not isinstance(self.device, PackedBatch):
                raise ValueError(
                    "packed model_input needs a loader built with "
                    "device_prefetch=True and packed_staging=True")
            return self.device
        if self.device is not None:
            return {k: self.device[k] for k in self._model_keys}
        return self._host_input()

    def _host_input(self) -> dict:
        mb = self.minibatch
        return dict(input_feats=mb.input_feats, labels=mb.labels,
                    seed_mask=mb.seed_mask, blocks=_model_blocks(mb))


class EdgeBatch(NodeBatch):
    """One edge (link-prediction) mini-batch: unpacks as DGL's
    ``(input_nodes, pair_graph, blocks)`` triple."""

    __slots__ = ()

    def __iter__(self):
        return iter((self.input_nodes, self.pair_graph, self.blocks))

    pair_graph = property(lambda self: self.minibatch.pair_graph)
    pos_u = property(lambda self: self.minibatch.pos_u)
    pos_v = property(lambda self: self.minibatch.pos_v)
    neg_v = property(lambda self: self.minibatch.neg_v)
    pair_mask = property(lambda self: self.minibatch.pair_mask)
    edge_etypes = property(lambda self: self.minibatch.edge_etypes)
    pos_src = property(lambda self: self.minibatch.pos_src)
    pos_dst = property(lambda self: self.minibatch.pos_dst)
    neg_dst = property(lambda self: self.minibatch.neg_dst)
    pos_eids = property(lambda self: self.minibatch.pos_eids)
    etype = property(lambda self: self.minibatch.etype)

    _model_keys = ("input_feats", "seed_mask", "pos_u", "pos_v", "neg_v",
                   "pair_mask", "edge_etypes", "blocks")

    def _host_input(self) -> dict:
        emb = self.minibatch
        return dict(input_feats=emb.input_feats, seed_mask=emb.seed_mask,
                    pos_u=emb.pos_u, pos_v=emb.pos_v, neg_v=emb.neg_v,
                    pair_mask=emb.pair_mask, edge_etypes=emb.edge_etypes,
                    blocks=_model_blocks(emb))


class _BaseLoader:
    """Shared loader protocol: epoch iteration, teardown, stats."""

    _wrap_cls = NodeBatch

    def __init__(self, g: DistGraph, mode: str):
        if mode not in _MODES:
            raise ValueError(f"unknown loader mode {mode!r}; have {_MODES}")
        self.g = g
        self.mode = mode
        self.pipeline = None       # set by subclasses (train mode only)
        self.sampler: Optional[DistributedSampler] = None
        self.cache = None
        self._next_epoch = 0
        self._mid_epoch = False

    # -- iteration ------------------------------------------------------
    def __len__(self) -> int:
        raise NotImplementedError

    def _eval_iter(self) -> Iterator:
        raise NotImplementedError

    def _wrap(self, item):
        if isinstance(item, tuple):   # device-prefetch stage: (batch, dev)
            mb, dev = item
            return self._wrap_cls(mb, device=dev)
        return self._wrap_cls(item)

    def epoch(self, epoch: int, start_batch: int = 0) -> Iterator:
        """Iterate one specific epoch's batches (the trainer's driver; in
        non-stop mode epochs must be requested consecutively).

        ``start_batch=k`` is the recovery fast-forward (DESIGN.md §10):
        the epoch's schedule is derived in full and emission begins at
        batch k — byte-identical to the batches a live run would serve
        from position k onward."""
        if self.mode == "eval":
            if start_batch:
                raise ValueError("start_batch is a train-mode recovery "
                                 "feature; eval loaders always run in full")
            yield from self._eval_iter()
            return
        if self._mid_epoch:
            # previous iteration abandoned mid-epoch: drain + rewind so
            # this epoch starts from a clean schedule (byte-identical to
            # a fresh run of the same epoch)
            self.close(_rewind_epoch=False)
        n = len(self)
        served = start_batch
        for item in self.pipeline.epoch(epoch, start_batch=start_batch):
            # only a stream some batch actually left is mid-epoch; a call
            # that errors before its first batch leaves the stream intact
            self._mid_epoch = True
            served += 1
            if served >= n:
                # epoch boundary reached the moment the last batch left
                # the pipeline — a consumer stopping right after it has
                # cleanly finished the epoch
                self._mid_epoch = False
                self._next_epoch = epoch + 1
            yield self._wrap(item)

    def __iter__(self) -> Iterator:
        """One epoch per iteration, auto-advancing; an epoch abandoned
        mid-way does not count and is re-served from scratch."""
        return self.epoch(self._next_epoch)

    # -- teardown -------------------------------------------------------
    def close(self, _rewind_epoch: bool = True) -> None:
        """Drain in-flight batches, join every pipeline thread, rewind.
        A closed loader is reusable; plain iteration restarts from epoch
        0 (explicit ``epoch()`` callers drive their own numbering)."""
        if self.pipeline is not None:
            self.pipeline.stop()
        self._mid_epoch = False
        if _rewind_epoch:
            self._next_epoch = 0

    # alias matching the pipelines' own verb
    stop = close

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- feature pulls (eval path; the pipeline does this in train mode) -
    def _pull_feats(self, mb) -> np.ndarray:
        g = self.g
        if g.hetero:
            return self._client.pull_typed(g.feat_name, mb.input_gids,
                                           g.typed, ntypes=mb.input_ntypes)
        return self._client.pull(g.feat_name, mb.input_gids)

    # -- stats ----------------------------------------------------------
    @property
    def non_stop(self) -> bool:
        return self.pipeline is not None and self.pipeline.non_stop

    def stats_report(self) -> dict:
        """Loader-level observability: per-stage pipeline times, cache
        hit rate, sampler request coalescing — everything the Table 2
        benchmark reads, without reaching into trainer internals."""
        out = {"batches_per_epoch": len(self),
               "stages": ({} if self.pipeline is None
                          else self.pipeline.stats_report()),
               "sampler": self.sampler.stats.as_dict(),
               "cache": None}
        if self.cache is not None:
            c = self.cache.stats()
            c["hit_rate"] = c["hits"] / max(c["hits"] + c["misses"], 1)
            out["cache"] = c
        return out


class NodeDataLoader(_BaseLoader):
    """DGL's ``NodeDataLoader`` over the distributed stack.

    Parameters mirror the trainer's wiring: ``fanouts`` (per layer; int or
    ``{etype: fanout}``), ``batch_size`` seeds per batch, ``labels``
    aligned with ``nids`` (host-resident — label bytes never cross the
    transport, as always), optional per-trainer hot-vertex ``cache``
    (:meth:`DistGraph.feature_cache`), ``sample_workers`` pool threads,
    ``device_prefetch`` to ship batches to the accelerator from the
    pipeline. ``seed`` drives the epoch schedule + pipeline, and
    ``sampler_seed`` the neighbor draws (defaults keep them disjoint).

    ``mode="eval"`` is the deterministic inline evaluation protocol:
    sequential (unshuffled) batches over ``nids``, ad-hoc sampler
    coordinates, no pipeline threads, sampling RPCs uncharged.
    """

    def __init__(self, g: DistGraph, nids: np.ndarray, fanouts, *,
                 batch_size: int, labels: Optional[np.ndarray] = None,
                 shuffle: bool = True, sample_workers: int = 1,
                 cache=None, device_prefetch: bool = False,
                 packed_staging: bool = True,
                 sync: bool = False, non_stop: bool = True,
                 depths: Optional[dict] = None, seed: int = 0,
                 sampler_seed: Optional[int] = None, mode: str = "train"):
        super().__init__(g, mode)
        self.nids = np.asarray(nids, dtype=np.int64)
        self.labels = labels
        self.batch_size = int(batch_size)
        eval_mode = mode == "eval"
        self.sampler = DistributedSampler(
            g.book, g.partitions, fanouts, self.batch_size,
            machine=g.machine,
            transport=None if eval_mode else g.transport,
            seed=seed + 100 if sampler_seed is None else sampler_seed,
            schema=g.schema if g.hetero else None,
            ntype_of_node=g.typed.ntype_of_node if g.hetero else None)
        self._client = g.new_client()
        self.cache = cache
        if not eval_mode:
            self.pipeline = MinibatchPipeline(
                self.sampler, self._client, g.feat_name, self.nids,
                labels=labels, sync=sync, non_stop=non_stop, depths=depths,
                to_device=device_prefetch, packed=packed_staging, seed=seed,
                typed=g.typed, cache=cache, sample_workers=sample_workers,
                shuffle=shuffle)

    def __len__(self) -> int:
        if self.pipeline is not None:
            return self.pipeline.batches_per_epoch
        return len(self.nids) // self.batch_size

    def _eval_iter(self) -> Iterator[NodeBatch]:
        # the shared ad-hoc protocol (core.sampler.ego): the inference
        # server runs the SAME function, which is what makes the serving
        # oracle contract (DESIGN.md §11) structural rather than tested-by
        # -coincidence
        for mb in sample_ego_networks(self.sampler, self._client,
                                      self.g.feat_name, self.nids,
                                      labels=self.labels,
                                      typed=self.g.typed if self.g.hetero
                                      else None):
            yield NodeBatch(mb)


class EdgeDataLoader(_BaseLoader):
    """DGL's ``EdgeDataLoader``: positive-edge mini-batches with negative
    sampling and endpoint ego-networks (DESIGN.md §6), over the same async
    pipeline. ``batch_size`` counts POSITIVE EDGES; the node sampler runs
    at the derived endpoint capacity ``2B + B*K`` automatically.

    ``eids`` is this trainer's positive-edge pool (NEW edge-id space —
    :meth:`DistGraph.edge_split`). On the typed path each scheduled batch
    carries one relation and negatives are drawn type-correctly from the
    relation's declared dst node type. ``edge_seed`` drives the positive
    schedule and negative draws; ``mode="eval"`` runs the deterministic
    evaluation protocol (fresh schedule from ``edge_seed`` each iteration,
    ad-hoc sampler coordinates, sampling RPCs uncharged).
    """

    _wrap_cls = EdgeBatch

    def __init__(self, g: DistGraph, eids: np.ndarray, fanouts, *,
                 batch_size: int, num_negs: int = 16,
                 neg_mode: str = "uniform", neg_exclude: bool = False,
                 sample_workers: int = 1, cache=None,
                 device_prefetch: bool = False,
                 packed_staging: bool = True, sync: bool = False,
                 non_stop: bool = True, depths: Optional[dict] = None,
                 seed: int = 0, sampler_seed: Optional[int] = None,
                 edge_seed: Optional[int] = None, mode: str = "train"):
        super().__init__(g, mode)
        self.batch_size = int(batch_size)
        self.num_negs = int(num_negs)
        eval_mode = mode == "eval"
        node_bs = EdgeBatchSampler.required_node_batch(
            batch_size, num_negs, neg_mode)
        self.sampler = DistributedSampler(
            g.book, g.partitions, fanouts, node_bs, machine=g.machine,
            transport=None if eval_mode else g.transport,
            seed=seed + 100 if sampler_seed is None else sampler_seed,
            schema=g.schema if g.hetero else None,
            ntype_of_node=g.typed.ntype_of_node if g.hetero else None)
        neg_pools = etype_of_edge = schema = None
        if g.hetero:
            schema = g.schema
            etype_of_edge = g.typed.etype_of_edge
            neg_pools = [g.typed.type2node[schema.dst_ntype_id(r)]
                         for r in range(schema.num_etypes)]
        e_src, e_dst = g.edge_endpoints()
        self._edge_seed = seed + 300 if edge_seed is None else edge_seed
        self.edge_sampler = EdgeBatchSampler(
            self.sampler, e_src, e_dst, np.asarray(eids, dtype=np.int64),
            batch_size, num_negs, neg_mode=neg_mode,
            etype_of_edge=etype_of_edge, schema=schema, neg_pools=neg_pools,
            exclude_batch_positives=neg_exclude, seed=self._edge_seed)
        self._client = g.new_client()
        self.cache = cache
        if not eval_mode:
            self.pipeline = EdgeMinibatchPipeline(
                self.edge_sampler, self._client, g.feat_name, sync=sync,
                non_stop=non_stop, depths=depths, to_device=device_prefetch,
                packed=packed_staging, seed=seed, typed=g.typed, cache=cache,
                sample_workers=sample_workers)

    def __len__(self) -> int:
        return self.edge_sampler.batches_per_epoch

    def _eval_iter(self) -> Iterator[EdgeBatch]:
        # the trainer's LP evaluation protocol: a fresh deterministic
        # schedule per iteration, so eval before/after training ranks the
        # same edges against the same candidates
        rng = np.random.default_rng(self._edge_seed)
        for _e, b, et, eids in self.edge_sampler.schedule(rng, 0):
            emb = self.edge_sampler.sample_edges(eids, etype=et,
                                                 batch_index=b)
            emb.input_feats = self._pull_feats(emb)
            yield EdgeBatch(emb)
