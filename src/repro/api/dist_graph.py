"""DGL-compatible distributed-graph façade (the paper's §4 usability claim:
"API compatible with DGL's mini-batch training and heterogeneous graph
API, which enables distributed training with almost no code modification").

:class:`DistGraph` is the per-trainer handle onto the whole substrate —
hierarchical partition, KVStore shards, typed relation views — mirroring
``dgl.distributed.DistGraph``:

* ``g.ndata["feat"]`` / ``g.edata[...]`` are **lazy** :class:`DistTensor`
  views: indexing pulls rows through ``KVClient.pull`` (``pull_typed`` on
  the heterograph path), local rows via shared memory, remote rows through
  the transport-charged (and cache-eligible) KVStore read path. Nothing is
  materialized until indexed.
* ``g.node_split(...)`` / ``g.edge_split()`` reproduce the trainer's seed
  splits: §5.6.1's equal-count contiguous-range node split and the
  owned-edge-range equalized-chunk edge split (DESIGN.md §8).
* ``g.trainer_view(rank)`` hands out sibling per-trainer handles over the
  SAME partition + store (this one-host harness simulates every trainer in
  process; on a real cluster each trainer process would construct its own
  handle against the shared servers).

Construction does what ``DistGNNTrainer`` used to do inline: partition the
dataset hierarchically, stand up the KVStore (per-ntype policies + feature
tensors on the typed path), and register node labels — so the trainer is
now a thin composition over this module plus the data loaders.
"""
from __future__ import annotations

import copy
import time
from typing import Dict, List, Optional

import numpy as np

from ..core.kvstore import (CacheConfig, DistKVStore, FeatureCache,
                            KVClient, NetworkModel, PartitionPolicy,
                            Transport, halo_access_counts)
from ..core.kvstore.store import MAX_RPC_RETRIES
from ..core.partition import (build_typed_partition, hierarchical_partition,
                              locality_report, split_training_set)
from ..core.sampler import edge_endpoints
from ..graph.datasets import GraphDataset


class DistTensor:
    """Lazy distributed-tensor view (``dgl.distributed.DistTensor``).

    ``t[ids]`` gathers rows by global ID through the KVStore read path;
    ``t[ids] = values`` scatters back (only when ``writable`` — feature
    tensors are read-only; mutable tensors such as :class:`DistEmbedding`
    tables accept writes, which bump row versions so trainer caches
    invalidate, DESIGN.md §5). With ``typed`` set, ``name`` is a per-ntype
    tensor family prefix (``"feat"`` -> ``"feat:paper"`` ...) and indexing
    takes *fused* node IDs, routed per type via ``KVClient.pull_typed``.
    """

    def __init__(self, client: KVClient, name: str, *, typed=None,
                 writable: Optional[bool] = None):
        self.client = client
        self.name = name
        self.typed = typed
        store = client.store
        if typed is not None:
            first = f"{name}:{typed.schema.ntypes[0]}"
            self._len = int(typed.node_type_local.shape[0])
            self._row_shape = store.row_shape(first)
            self._dtype = store.dtype_of(first)
            mutable = store.is_mutable(first)
        else:
            self._len = store.policy_for(name).total
            self._row_shape = store.row_shape(name)
            self._dtype = store.dtype_of(name)
            mutable = store.is_mutable(name)
        # default: writes allowed exactly where the store can invalidate
        # caches (version-tracked tensors); features stay read-only
        self.writable = mutable if writable is None else writable

    @property
    def shape(self) -> tuple:
        return (self._len,) + self._row_shape

    @property
    def dtype(self) -> np.dtype:
        return self._dtype

    def __len__(self) -> int:
        return self._len

    def __getitem__(self, ids) -> np.ndarray:
        ids = np.asarray(ids, dtype=np.int64)
        if self.typed is not None:
            return self.client.pull_typed(self.name, ids, self.typed)
        return self.client.pull(self.name, ids)

    def __setitem__(self, ids, values) -> None:
        if not self.writable:
            raise TypeError(f"DistTensor {self.name!r} is read-only "
                            f"(features are immutable; use DistEmbedding "
                            f"for learnable rows)")
        if self.typed is not None:
            raise TypeError("typed DistTensor views are read-only; write "
                            "through the per-ntype tensor instead")
        ids = np.asarray(ids, dtype=np.int64)
        self.client.push(self.name, ids, np.asarray(values, self._dtype),
                         reduce="assign")

    def __repr__(self) -> str:
        rw = "rw" if self.writable else "ro"
        return (f"DistTensor({self.name!r}, shape={self.shape}, "
                f"dtype={self._dtype}, {rw})")


class _DataView:
    """Mapping-style ``g.ndata`` / ``g.edata`` accessor over one policy
    family. Keys are tensor names; per-ntype families (``feat:paper``,
    ``feat:author``, ...) additionally expose their fused-ID prefix
    (``feat``) as a typed view."""

    def __init__(self, g: "DistGraph", kind: str):
        self._g = g
        self._kind = kind   # "node" | "edge"

    def _names(self) -> Dict[str, bool]:
        """{key: is_typed_prefix} for every accessible tensor."""
        g, out = self._g, {}
        for name in g.store.tensor_names():
            pol = g.store.policy_name_of(name)
            if pol == self._kind:
                out[name] = False
            elif pol.startswith(self._kind + ":") and ":" in name:
                out[name] = False                      # type-local tensor
                out[name.split(":", 1)[0]] = True      # fused-ID prefix
        return out

    def keys(self):
        return sorted(self._names())

    def __contains__(self, name: str) -> bool:
        return name in self._names()

    def __iter__(self):
        return iter(self.keys())

    def __getitem__(self, name: str) -> DistTensor:
        names = self._names()
        if name not in names:
            raise KeyError(f"no {self._kind} tensor {name!r}; "
                           f"have {self.keys()}")
        if names[name]:
            return DistTensor(self._g.client, name, typed=self._g.typed)
        return DistTensor(self._g.client, name)


class DistGraph:
    """Per-trainer handle bundling partition book, graph/relation views and
    KVStore-backed data accessors (see module docstring).

    One construction partitions the dataset and stands up the store; sibling
    trainers share it via :meth:`trainer_view`. ``rank`` is the trainer id
    in ``[0, num_trainers)``; ``machine = rank // trainers_per_machine``
    decides which partition is shared-memory-local.
    """

    def __init__(self, ds: GraphDataset, *, num_machines: int = 2,
                 trainers_per_machine: int = 2,
                 partition_method: str = "metis", hetero: Optional[bool] = None,
                 seed: int = 0, network: Optional[NetworkModel] = None,
                 feat_name: str = "feat", replication: int = 1,
                 max_rpc_retries: Optional[int] = None,
                 hedge_ms: Optional[float] = None):
        self.ds = ds
        self.num_machines = num_machines
        self.trainers_per_machine = trainers_per_machine
        self.seed = seed
        self.feat_name = feat_name
        self.rank = 0
        self.schema = getattr(ds, "schema", None)
        self.hetero = (self.schema is not None
                       if hetero is None else bool(hetero and self.schema))

        t0 = time.perf_counter()
        self.hp = hierarchical_partition(
            ds.graph, num_machines, trainers_per_machine,
            split_mask=ds.split_mask, method=partition_method, seed=seed)
        self.partition_time_s = time.perf_counter() - t0
        book = self.hp.book

        self.transport = Transport(network or NetworkModel())
        feats_new = ds.feats[book.new2old_node]
        self.labels = ds.labels[book.new2old_node]

        policies = {"node": PartitionPolicy("node", book.node_offsets),
                    "edge": PartitionPolicy("edge", book.edge_offsets)}
        self.typed = None
        if self.hetero:
            g = ds.graph
            ntypes_new = (None if g.ntypes is None
                          else g.ntypes[book.new2old_node])
            etypes_new = (None if g.etypes is None
                          else g.etypes[book.new2old_edge])
            self.typed = build_typed_partition(book, self.schema,
                                               ntypes_new, etypes_new)
            policies.update(self.typed.policies())
        # availability knobs (DESIGN.md §12): r-way replica placement,
        # configurable retry budget, optional hedged reads — all defaults
        # preserve the unreplicated byte-and-accounting behavior exactly
        self.store = DistKVStore(
            policies, transport=self.transport,
            replication=replication,
            max_rpc_retries=(MAX_RPC_RETRIES if max_rpc_retries is None
                             else max_rpc_retries),
            hedge_delay_s=None if hedge_ms is None else hedge_ms * 1e-3,
            jitter_seed=seed)
        if self.hetero:
            # per-ntype feature tensors over type-local ID spaces
            for t, nt in enumerate(self.schema.ntypes):
                rows = ds.feats[book.new2old_node[self.typed.type2node[t]]]
                self.store.init_data(f"{feat_name}:{nt}", rows.shape[1:],
                                     np.float32, f"node:{nt}",
                                     full_array=rows)
        else:
            self.store.init_data(feat_name, feats_new.shape[1:], np.float32,
                                 "node", full_array=feats_new)
        # labels ride the store too so ``g.ndata["label"]`` works like
        # DGL's; the data loaders still slice the host-resident array
        # (no transport charge) exactly as the trainer always has
        self.store.init_data("label", (), np.int64, "node",
                             full_array=self.labels)
        self._client: Optional[KVClient] = None
        # mutable cell so sibling trainer views share the lazy endpoint
        # arrays (copy.copy shares the dict, not later attribute writes)
        self._endpoints: dict = {}

    # ---- identity -----------------------------------------------------
    @property
    def book(self):
        return self.hp.book

    @property
    def partitions(self):
        return self.hp.partitions

    @property
    def num_trainers(self) -> int:
        return self.hp.num_trainers

    @property
    def machine(self) -> int:
        return self.rank // self.trainers_per_machine

    def num_nodes(self) -> int:
        return int(self.book.node_offsets[-1])

    def num_edges(self) -> int:
        return int(self.book.edge_offsets[-1])

    def trainer_view(self, rank: int) -> "DistGraph":
        """A sibling per-trainer handle sharing this partition + store."""
        if not 0 <= rank < self.num_trainers:
            raise ValueError(f"rank {rank} outside [0, {self.num_trainers})")
        g = copy.copy(self)
        g.rank = rank
        g._client = None
        return g

    # ---- data access --------------------------------------------------
    @property
    def client(self) -> KVClient:
        """This handle's own (cache-less) KVStore client."""
        if self._client is None:
            self._client = self.store.client(self.machine)
        return self._client

    def new_client(self) -> KVClient:
        """A fresh client for a loader/pipeline to own (the pipeline may
        attach a per-trainer cache to it; handing out fresh clients keeps
        ``g.ndata`` pulls cache-free and loader clients independent)."""
        return self.store.client(self.machine)

    @property
    def ndata(self) -> _DataView:
        return _DataView(self, "node")

    @property
    def edata(self) -> _DataView:
        return _DataView(self, "edge")

    # ---- id spaces ----------------------------------------------------
    def to_new_nids(self, nids_old: np.ndarray) -> np.ndarray:
        """OLD (dataset) node ids -> NEW (partition-relabeled) ids."""
        return self.book.old2new_node[np.asarray(nids_old, dtype=np.int64)]

    @property
    def train_nids(self) -> np.ndarray:
        """The dataset's training vertices in the NEW id space."""
        return self.to_new_nids(self.ds.train_nids)

    @property
    def val_nids(self) -> np.ndarray:
        return self.to_new_nids(self.ds.val_nids)

    @property
    def test_nids(self) -> np.ndarray:
        return self.to_new_nids(self.ds.test_nids)

    def edge_endpoints(self) -> tuple:
        """(src, dst) NEW node ids indexed by NEW edge id (host-resident,
        computed once per world)."""
        if "sd" not in self._endpoints:
            self._endpoints["sd"] = edge_endpoints(self.book, self.ds.graph)
        return self._endpoints["sd"]

    # ---- splits (§5.6.1) ----------------------------------------------
    def node_splits(self, nids: Optional[np.ndarray] = None, *,
                    use_level2: bool = True,
                    seed: Optional[int] = None) -> List[np.ndarray]:
        """All trainers' seed sets: §5.6.1's equal-count contiguous-range
        split of ``nids`` (default: the training vertices)."""
        nids = self.train_nids if nids is None else np.asarray(nids)
        return split_training_set(self.hp, nids, use_level2=use_level2,
                                  seed=self.seed if seed is None else seed)

    def node_split(self, nids: Optional[np.ndarray] = None, *,
                   use_level2: bool = True,
                   seed: Optional[int] = None) -> np.ndarray:
        """This trainer's seed set (DGL's ``node_split`` analogue)."""
        return self.node_splits(nids, use_level2=use_level2,
                                seed=seed)[self.rank]

    def edge_splits(self) -> List[np.ndarray]:
        """All trainers' positive-edge pools: each machine's owned edge
        range (edges live with their dst vertex) cut into contiguous
        per-trainer chunks, equalized to the min chunk size ACROSS machines
        so every trainer schedules the same batch count (sync SGD)."""
        book, T = self.book, self.trainers_per_machine
        spans = [(int(book.edge_offsets[m]), int(book.edge_offsets[m + 1]))
                 for m in range(self.num_machines)]
        per = min((ehi - elo) // T for elo, ehi in spans)
        out: List[np.ndarray] = []
        for elo, ehi in spans:
            chunk = (ehi - elo) // T
            for t in range(T):
                out.append(np.arange(elo + t * chunk, elo + t * chunk + per,
                                     dtype=np.int64))
        return out

    def edge_split(self) -> np.ndarray:
        """This trainer's owned positive-edge pool."""
        return self.edge_splits()[self.rank]

    def locality_report(self, per_trainer_ids: List[np.ndarray]) -> dict:
        """Seed/endpoint locality of per-trainer id sets (§5.3)."""
        return locality_report(self.hp, per_trainer_ids)

    # ---- per-trainer hot-vertex cache (DESIGN.md §5) -------------------
    def feature_cache(self, config: Optional[CacheConfig]
                      ) -> Optional[FeatureCache]:
        """One trainer's hot-vertex cache over remote feature rows,
        registered for every feature tensor and (optionally) pre-warmed
        from the machine partition's halo access counts — the partition
        book's static prediction of which remote rows the sampler will
        keep pulling (§5.3's locality argument, attacked from the other
        side). Returns None when ``config`` is None (cache disabled)."""
        if config is None:
            return None
        cache = FeatureCache(config, self.store)
        names = ([f"{self.feat_name}:{nt}" for nt in self.schema.ntypes]
                 if self.hetero else [self.feat_name])
        for name in names:
            cache.register(self.store, name)
        # NOTE: the loader's pipeline owns the client<->cache binding;
        # warm() pulls with _bypass_cache and needs no attach
        if config.prewarm:
            client = self.new_client()
            gids, counts = halo_access_counts(self.partitions[self.machine])
            if self.hetero:
                types, tids = self.typed.nid2typed(gids)
                for t, nt in enumerate(self.schema.ntypes):
                    m = types == t
                    if m.any():
                        cache.warm(client, f"{self.feat_name}:{nt}",
                                   tids[m], counts[m])
            else:
                cache.warm(client, self.feat_name, gids, counts)
        return cache

    def __repr__(self) -> str:
        return (f"DistGraph({self.ds.name!r}, rank={self.rank}/"
                f"{self.num_trainers}, machine={self.machine}, "
                f"hetero={self.hetero})")
