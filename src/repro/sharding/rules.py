"""Sharding rules: logical-axis -> mesh-axis mapping and parameter
PartitionSpec derivation.

The production mesh is ("data", "model") per pod, with an optional leading
"pod" axis (see launch/mesh.py). The batch dimension shards over
("pod","data"); Megatron-style tensor parallelism shards attention heads /
FFN columns over "model"; configs with ``fsdp=True`` additionally shard the
other weight dim over "data" (ZeRO-3 / weight-gathered FSDP, which GSPMD
realizes as per-layer all-gathers).

Parameter specs are derived *by path name* from the param pytree, so model
code stays free of sharding concerns; activation constraint points call
``maybe_constrain`` which is a no-op outside a mesh context (CPU smoke
tests).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class AxisRules:
    batch_axes: Tuple[str, ...] = ("data",)   # ("pod","data") multi-pod
    model_axis: str = "model"
    fsdp_axis: Optional[str] = "data"
    model_axis_size: int = 16                 # for divisibility checks
    seq_shard_activations: bool = True        # Megatron sequence parallelism
    # pure_fsdp: ZeRO-3 data parallelism — batch over ALL mesh axes, weights
    # sharded on one dim and gathered per layer, no tensor parallelism.
    # (§Perf: for train_4k this removes the per-token TP/SP collectives.)
    pure_fsdp: bool = False
    # axes params shard over in pure_fsdp mode (defaults to batch_axes);
    # multi-pod uses all three axes for params while batch spans (pod,data)
    fsdp_param_axes: Optional[Tuple[str, ...]] = None


_RULES = AxisRules()


def set_rules(rules: AxisRules) -> None:
    global _RULES
    _RULES = rules


def current_rules() -> AxisRules:
    return _RULES


def batch_spec(*trailing) -> P:
    """PartitionSpec with the batch dim sharded over the batch axes."""
    return P(_RULES.batch_axes, *trailing)


def _active_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def maybe_constrain(x, spec: P):
    """with_sharding_constraint if a mesh is active, else identity."""
    if _active_mesh() is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# parameter specs by path
# ---------------------------------------------------------------------------

# (regex on the flattened param path, base rank, spec factory)
AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _axis_size(ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= AXIS_SIZES.get(a, 1)
        return n
    return AXIS_SIZES.get(ax, 1)


def _spec_for(path: str, shape: tuple, fsdp: bool, rules: AxisRules) -> P:
    if rules.pure_fsdp:
        return _spec_pure_fsdp(shape, rules)
    m = rules.model_axis
    f = rules.fsdp_axis if fsdp else None
    ndim = len(shape)

    def pad(spec_tail):
        """Left-pad with None for stacked/scanned leading dims, then drop
        any mesh axis that doesn't divide its dimension (pjit input
        shardings must divide evenly)."""
        tail = list(spec_tail)
        if len(tail) > ndim:
            tail = tail[-ndim:]
        full = [None] * (ndim - len(tail)) + tail
        out = []
        for dim, ax in zip(shape, full):
            if ax is None:
                out.append(None)
            else:
                out.append(ax if dim % _axis_size(ax) == 0 else None)
        return P(*out)

    if re.search(r"experts_gate|experts_up|experts_down", path):
        # (L, E, a, b): experts over model when divisible, else the
        # per-expert ff dim; the other big dim gets fsdp
        e = shape[-3]
        ff_axis = -1 if "down" not in path else -2
        spec = [None] * ndim
        if e % AXIS_SIZES[m] == 0:
            spec[-3] = m
            if f and shape[ff_axis] % _axis_size(f) == 0:
                spec[ff_axis] = f
        elif shape[ff_axis] % AXIS_SIZES[m] == 0:
            spec[ff_axis] = m
        return P(*spec)

    # order matters: first match wins
    table = [
        (r"embed",               (m, None)),          # (vocab, d)
        (r"lm_head",             (None, m)),          # (d, vocab)
        (r"router",              (None, None)),
        (r"\bwq\b|\bwk\b|\bwv\b|wqkv", (f, m)),
        (r"\bwo\b",              (m, f)),
        (r"w_gateup",            (f, None, m)),
        (r"w_gate|w_up",         (f, m)),
        (r"w_down",              (m, f)),
        (r"in_proj",             (f, m)),
        (r"out_proj",            (m, f)),
        (r"bc_proj",             (f, None)),
        (r"conv_bc",             None,),
        (r"conv_w",              (None, m)),
        (r"conv_b$",             (m,)),
        (r"(\b|_)b(q|k|v|o)?\b|bias|norm|scale|a_log|\bD\b|dt_bias", None),
    ]
    for pat, tail in [(t[0], t[1] if len(t) > 1 else None) for t in table]:
        if re.search(pat, path):
            if tail is None:
                return P()
            return pad(tail)
    return P()   # default: replicated


def _spec_pure_fsdp(shape: tuple, rules: AxisRules) -> P:
    """ZeRO-3: shard the first dividing dim (skipping the scan-stack dim
    for ndim>=3) over the fsdp param axes; everything else replicated."""
    axes = rules.fsdp_param_axes or rules.batch_axes
    total = _axis_size(axes)
    ndim = len(shape)
    if ndim < 2:
        return P()
    start = 1 if ndim >= 3 else 0
    spec = [None] * ndim
    for i in range(start, ndim):
        if shape[i] % total == 0:
            spec[i] = axes
            break
    return P(*spec)


def param_pspecs(params, fsdp: bool = False,
                 rules: Optional[AxisRules] = None):
    """Mirror ``params`` with a PartitionSpec per leaf, derived from paths."""
    rules = rules or _RULES
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        p = "/".join(str(k) for k in path).lower()
        specs.append(_spec_for(p, tuple(getattr(leaf, "shape", ())), fsdp,
                               rules))
    return jax.tree_util.tree_unflatten(treedef, specs)
