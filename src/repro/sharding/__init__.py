from .rules import (AxisRules, current_rules, maybe_constrain, param_pspecs,
                    set_rules, batch_spec)

__all__ = ["AxisRules", "current_rules", "maybe_constrain", "param_pspecs",
           "set_rules", "batch_spec"]
