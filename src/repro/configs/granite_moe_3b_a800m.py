"""Granite-3.0 MoE 3B-a800m: 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family]."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="granite-moe-3b-a800m", arch_type="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    head_dim=64, d_ff=512, vocab_size=49155,
    num_experts=40, experts_per_tok=8, moe_d_ff=512,
    rope_theta=1e4, tie_embeddings=True,
    citation="hf:ibm-granite/granite-3.0 MoE family; 32L d=1536 24H kv=8 "
             "expert_ff=512 vocab=49155, 40 experts top-8 (assignment "
             "header says 40e; bracket cites the 1b/32e card — we follow "
             "the structured field)",
)
