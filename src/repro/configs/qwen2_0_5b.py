"""Qwen2-0.5B: dense GQA decoder with QKV bias, tied embeddings [arXiv:2407.10671]."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="qwen2-0.5b", arch_type="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    head_dim=64, d_ff=4864, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
    citation="arXiv:2407.10671 (Qwen2); 24L d=896 14H kv=2 ff=4864 "
             "vocab=151936, QKV bias",
)
