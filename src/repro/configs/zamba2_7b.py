"""Zamba2-7B: Mamba2 backbone with shared attention blocks [arXiv:2411.15242]."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="zamba2-7b", arch_type="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    head_dim=112, d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    hybrid_attn_every=6,             # shared attn+MLP block every 6 mamba layers
    rope_theta=1e4, fsdp=True,
    citation="arXiv:2411.15242 (Zamba2); 81L d=3584 32H kv=32 ff=14336 "
             "vocab=32000 ssm_state=64",
)
