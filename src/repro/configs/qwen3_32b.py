"""Qwen3-32B: dense GQA decoder with per-head q/k RMSNorm [hf:Qwen/Qwen3-8B family]."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="qwen3-32b", arch_type="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=25600, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, fsdp=True,
    citation="hf:Qwen/Qwen3-8B family card; 64L d=5120 64H kv=8 ff=25600 "
             "vocab=151936, qk_norm",
)
