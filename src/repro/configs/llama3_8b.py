"""Llama-3-8B: dense GQA decoder, 128k vocab [arXiv:2407.21783]."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="llama3-8b", arch_type="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=128256,
    rope_theta=5e5, fsdp=True,
    citation="arXiv:2407.21783 (Llama 3); 32L d=4096 32H kv=8 ff=14336 "
             "vocab=128256",
)
