"""Qwen3-MoE 235B-A22B: 128 experts top-8, GQA kv=4, qk_norm
[hf:Qwen/Qwen3-30B-A3B family]."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="qwen3-moe-235b-a22b", arch_type="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    head_dim=128, d_ff=1536, vocab_size=151936,
    num_experts=128, experts_per_tok=8, moe_d_ff=1536,
    qk_norm=True, rope_theta=1e6, fsdp=True,
    citation="hf:Qwen/Qwen3-30B-A3B family card; 94L d=4096 64H kv=4 "
             "expert_ff=1536 vocab=151936, 128 experts top-8, qk_norm",
)
