"""The paper's RGCN benchmark config (§6: 2 layers, hidden 1024,
fanout 25/15)."""
from ..models.gnn.models import GNNConfig

CONFIG = GNNConfig(arch="rgcn", in_dim=128, hidden_dim=1024, num_classes=16,
                   fanouts=[25, 15], batch_size=1000, num_rels=4)
