"""Named architecture configs (assigned pool + the paper's own GNNs).

Each ``<id>.py`` module defines ``CONFIG`` with the exact assigned
hyper-parameters (citation in ``CONFIG.citation``). ``smoke_variant``
produces the reduced config (≤2 layers, d_model ≤ 512, ≤4 experts) used by
the per-arch CPU smoke tests; the full configs are only ever lowered
abstractly by the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib

from ..models.lm.config import LMConfig

ARCH_IDS = [
    "zamba2-7b", "qwen3-32b", "llama3-8b", "whisper-base", "mamba2-2.7b",
    "granite-moe-3b-a800m", "qwen2-0.5b", "qwen3-moe-235b-a22b",
    "pixtral-12b", "qwen3-8b",
]

GNN_ARCHS = ["graphsage", "gat", "rgcn"]          # the paper's own models


def get_config(arch_id: str) -> LMConfig:
    mod = importlib.import_module(
        f".{arch_id.replace('-', '_').replace('.', '_')}", __package__)
    return mod.CONFIG


def smoke_variant(cfg: LMConfig) -> LMConfig:
    """Reduced same-family config for CPU smoke tests."""
    d = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    kv = min(cfg.num_kv_heads, max(1, heads // 2)) if heads else 0
    upd = dict(
        num_layers=2, d_model=d, num_heads=heads, num_kv_heads=kv,
        head_dim=64 if heads else None,
        d_ff=min(cfg.d_ff, 512), vocab_size=min(cfg.vocab_size, 503),
        attn_chunk=16, remat=False, dtype="float32", fsdp=False,
        sliding_window=None,
    )
    if cfg.num_experts:
        upd.update(num_experts=4, experts_per_tok=2,
                   moe_d_ff=min(cfg.moe_d_ff, 64))
    if cfg.ssm_state:
        upd.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
    if cfg.hybrid_attn_every:
        upd.update(num_layers=3, hybrid_attn_every=2)
    if cfg.encdec:
        upd.update(num_encoder_layers=2, encoder_seq=24)
    if cfg.num_image_tokens:
        upd.update(num_image_tokens=8)
    return dataclasses.replace(cfg, **upd)


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
