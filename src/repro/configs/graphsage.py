"""The paper's GraphSAGE benchmark config (§6: 3 layers, hidden 256,
fanout 15/10/5, 2 heads n/a)."""
from ..models.gnn.models import GNNConfig

CONFIG = GNNConfig(arch="graphsage", in_dim=100, hidden_dim=256,
                   num_classes=16, fanouts=[15, 10, 5], batch_size=1000)
