"""Whisper-base transformer backbone [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB per the harness carve-out:
input_specs() provides precomputed frame embeddings (B, 1500, 512)."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="whisper-base", arch_type="audio", encdec=True,
    num_layers=6, num_encoder_layers=6, encoder_seq=1500,
    d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    rope_theta=1e4, remat=False,
    citation="arXiv:2212.04356 (Whisper); base: 6L enc + 6L dec d=512 8H "
             "ff=2048 vocab=51865; conv frontend stubbed",
)
