"""The paper's GAT benchmark config (§6: 3 layers, hidden 256, 2 heads)."""
from ..models.gnn.models import GNNConfig

CONFIG = GNNConfig(arch="gat", in_dim=100, hidden_dim=256, num_classes=16,
                   fanouts=[15, 10, 5], batch_size=1000, num_heads=2)
