"""Mamba2-2.7B: pure SSD state-space model, attention-free [arXiv:2405.21060]."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="mamba2-2.7b", arch_type="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    tie_embeddings=True, fsdp=True,
    citation="arXiv:2405.21060 (Mamba2/SSD); 64L d=2560 attn-free "
             "vocab=50280 ssm_state=128",
)
