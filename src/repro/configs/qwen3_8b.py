"""Qwen3-8B: dense GQA decoder with qk_norm [hf:Qwen/Qwen3-8B]."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="qwen3-8b", arch_type="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=12288, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, fsdp=True,
    citation="hf:Qwen/Qwen3-8B; 36L d=4096 32H kv=8 ff=12288 vocab=151936, "
             "qk_norm",
)
