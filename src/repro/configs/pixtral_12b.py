"""Pixtral-12B: mistral-nemo decoder consuming Pixtral-ViT patch embeddings
[hf:mistralai/Pixtral-12B-2409].

The ViT vision encoder + projector is a STUB per the harness carve-out:
input_specs() provides precomputed patch embeddings (B, 1024, 5120)."""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="pixtral-12b", arch_type="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=131072,
    num_image_tokens=1024, rope_theta=1e6, fsdp=True,
    citation="hf:mistralai/Pixtral-12B-2409; 40L d=5120 32H kv=8 ff=14336 "
             "vocab=131072; ViT frontend stubbed (patch embeddings input)",
)
