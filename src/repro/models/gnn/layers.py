"""GNN layers over padded MFG blocks (message passing per Eq. (1)).

Each layer consumes ``h_src`` (cap_src, d_in) — features of the block's
input nodes, dst nodes in the prefix — and produces ``h_dst``
(cap_dst, d_out). Aggregations run through the kernels package (Pallas on
TPU, jnp oracle elsewhere); padded edges are masked out of every reduction.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ...kernels import (fused_edge_softmax_aggregate, fused_gather_aggregate,
                        segment_sum)


def _degrees(edge_dst, edge_mask, num_dst):
    ones = edge_mask.astype(jnp.float32)[:, None]
    deg = segment_sum(ones, edge_dst, edge_mask, num_dst)[:, 0]
    return jnp.maximum(deg, 1.0)


def sage_layer(params, h_src: jnp.ndarray, block: dict, num_dst: int,
               activation=jax.nn.relu, impl: str = "auto") -> jnp.ndarray:
    """GraphSAGE mean aggregator: act(W_self h_v + W_neigh mean_u h_u)."""
    edge_src, edge_dst = block["edge_src"], block["edge_dst"]
    edge_mask = block["edge_mask"]
    # fused gather->aggregate: the (E, d_in) message array never
    # materializes on the pallas path (ref path = the old two-step jaxpr)
    agg = fused_gather_aggregate(h_src, edge_src, edge_dst, edge_mask,
                                 num_dst, impl=impl)
    agg = agg / _degrees(edge_dst, edge_mask, num_dst)[:, None]
    h_self = h_src[:num_dst]
    out = h_self @ params["w_self"] + agg @ params["w_neigh"] + params["b"]
    return activation(out) if activation is not None else out


def gat_layer(params, h_src: jnp.ndarray, block: dict, num_dst: int,
              activation=jax.nn.elu, impl: str = "auto",
              negative_slope: float = 0.2) -> jnp.ndarray:
    """GAT layer, multi-head concat. params: w (d_in, H, d_h), a_l/a_r (H, d_h)."""
    edge_src, edge_dst = block["edge_src"], block["edge_dst"]
    edge_mask = block["edge_mask"]
    w, a_l, a_r = params["w"], params["a_l"], params["a_r"]
    h_proj = jnp.einsum("nd,dhf->nhf", h_src, w)            # (cap_src, H, d_h)
    el = jnp.einsum("nhf,hf->nh", h_proj, a_l)              # (cap_src, H)
    er = jnp.einsum("nhf,hf->nh", h_proj[:num_dst], a_r)    # (cap_dst, H)
    scores = el[edge_src] + er[edge_dst]                    # (E, H)
    scores = jax.nn.leaky_relu(scores, negative_slope)
    # fused softmax -> weighted gather -> aggregate (attention tail)
    out = fused_edge_softmax_aggregate(h_proj, scores, edge_src, edge_dst,
                                       edge_mask, num_dst, impl=impl)
    out = out + params["b"]
    return activation(out) if activation is not None else out


def rgcn_layer(params, h_src: jnp.ndarray, block: dict, num_dst: int,
               num_rels: int, activation=jax.nn.relu,
               impl: str = "auto", rel_offsets=None) -> jnp.ndarray:
    """RGCN: h_v = act(W_0 h_v + sum_r (1/c_{v,r}) sum_{u in N_r(v)} W_r h_u).

    params: w_rel (R, d_in, d_out), w_self (d_in, d_out), b (d_out,).
    Relations are looped (R is small and static). Two block layouts:

    * typed (relation-major, ``rel_offsets`` a static (R+1,) tuple from the
      sampler's per-relation capacities): relation r's edges occupy the
      static slot range ``[rel_offsets[r], rel_offsets[r+1])``, so each
      relation's masked segment-sum runs over only its own slots — the
      edge axis per relation shrinks from sum(f_r) to f_r per dst;
    * untyped (legacy): one fused edge axis, each relation re-scans it with
      its own ``edge_types == r`` mask.
    """
    edge_src, edge_dst = block["edge_src"], block["edge_dst"]
    edge_mask, edge_types = block["edge_mask"], block["edge_types"]
    out = h_src[:num_dst] @ params["w_self"] + params["b"]
    for r in range(num_rels):
        if rel_offsets is not None:
            lo, hi = int(rel_offsets[r]), int(rel_offsets[r + 1])
            if hi == lo:          # relation not sampled at this layer
                continue
            es, ed, em = edge_src[lo:hi], edge_dst[lo:hi], edge_mask[lo:hi]
        else:
            es, ed = edge_src, edge_dst
            em = edge_mask & (edge_types == r)
        proj = h_src @ params["w_rel"][r]                   # (cap_src, d_out)
        agg = fused_gather_aggregate(proj, es, ed, em, num_dst, impl=impl)
        agg = agg / _degrees(ed, em, num_dst)[:, None]
        out = out + agg
    return activation(out) if activation is not None else out
