"""GNN models on padded MFG mini-batches: GraphSAGE, GAT, RGCN (the paper's
three benchmark models, §6), with node-classification and link-prediction
heads.

Models are functional: ``init(rng) -> params`` and
``apply(params, batch) -> logits``. ``batch`` is the device dict produced by
the pipeline's device-prefetch stage:

    {"input_feats": (cap_src_0, F), "blocks": [block dicts...],
     "labels": (B,), "seed_mask": (B,)}

The static per-layer dst capacities come from the sampler's ``capacities``
(batch_size, fanouts) — the same numbers the padding used.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ...core.sampler.mfg import Fanout, capacities, relation_capacities
from .layers import gat_layer, rgcn_layer, sage_layer


def _glorot(rng, shape):
    fan_in, fan_out = shape[0], shape[-1]
    lim = np.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(rng, shape, jnp.float32, -lim, lim)


@dataclasses.dataclass
class GNNConfig:
    arch: str                       # graphsage | gat | rgcn
    in_dim: int
    hidden_dim: int
    num_classes: int
    fanouts: Sequence[Fanout]       # input-layer first; int or {etype: f}
    batch_size: int
    num_heads: int = 2              # GAT (paper: 2 heads)
    num_rels: int = 1               # RGCN
    impl: str = "auto"              # kernel dispatch

    @property
    def num_layers(self) -> int:
        return len(self.fanouts)

    @property
    def typed(self) -> bool:
        """Any layer with per-relation fanouts => relation-major blocks."""
        return any(isinstance(f, Mapping) for f in self.fanouts)

    def dst_caps(self) -> List[int]:
        """Static dst-node capacity per layer (input-layer first)."""
        caps = capacities(self.batch_size, self.fanouts)
        dst = [c[0] for c in caps[1:]] + [self.batch_size]
        return dst

    def layer_rel_offsets(self, etype_id=None) -> List[Optional[tuple]]:
        """Static per-layer relation slot offsets (input-layer first);
        None entries for untyped layers. Mapping keys are relation IDs by
        default; pass a schema's ``etype_id`` for name keys. These are the
        SAME numbers the sampler pads with — model and sampler must agree,
        which is why both derive them from (batch_size, fanouts)."""
        offs = relation_capacities(self.batch_size, self.fanouts,
                                   self.num_rels, etype_id=etype_id)
        return [None if o is None else tuple(int(x) for x in o)
                for o in offs]


def init_gnn(cfg: GNNConfig, rng: jax.Array) -> dict:
    keys = jax.random.split(rng, cfg.num_layers * 4 + 1)
    layers = []
    d_in = cfg.in_dim
    for l in range(cfg.num_layers):
        last = l == cfg.num_layers - 1
        d_out = cfg.num_classes if last else cfg.hidden_dim
        k = keys[4 * l: 4 * l + 4]
        if cfg.arch == "graphsage":
            layers.append({
                "w_self": _glorot(k[0], (d_in, d_out)),
                "w_neigh": _glorot(k[1], (d_in, d_out)),
                "b": jnp.zeros((d_out,)),
            })
            d_in = d_out
        elif cfg.arch == "gat":
            d_h = max(d_out // cfg.num_heads, 1)
            layers.append({
                "w": _glorot(k[0], (d_in, cfg.num_heads, d_h)),
                "a_l": _glorot(k[1], (cfg.num_heads, d_h)),
                "a_r": _glorot(k[2], (cfg.num_heads, d_h)),
                "b": jnp.zeros((cfg.num_heads * d_h,)),
            })
            d_in = cfg.num_heads * d_h
        elif cfg.arch == "rgcn":
            layers.append({
                "w_rel": _glorot(k[0], (cfg.num_rels, d_in, d_out)) /
                         np.sqrt(cfg.num_rels),
                "w_self": _glorot(k[1], (d_in, d_out)),
                "b": jnp.zeros((d_out,)),
            })
            d_in = d_out
        else:
            raise ValueError(cfg.arch)
    params = {"layers": layers}
    if cfg.arch == "gat" and d_in != cfg.num_classes:
        params["head"] = _glorot(keys[-1], (d_in, cfg.num_classes))
    return params


def apply_gnn_layer(cfg: GNNConfig, params: dict, layer: int,
                    h: jnp.ndarray, block: dict, num_dst: int,
                    rel_offsets=None) -> jnp.ndarray:
    """One layer of the forward pass: (cap_src, d_in) -> (num_dst, d_out).

    This is the EXACT per-layer computation ``apply_gnn`` runs — the
    offline layer-wise inference pass (``repro.api.offline_embeddings``,
    DESIGN.md §11) calls it with full-neighbor blocks of arbitrary dst
    capacity, which is why ``num_dst``/``rel_offsets`` are arguments
    rather than derived from ``cfg.batch_size`` here.
    """
    p = params["layers"][layer]
    last = layer == cfg.num_layers - 1
    act = None if last and cfg.arch != "gat" else (
        jax.nn.elu if cfg.arch == "gat" else jax.nn.relu)
    if cfg.arch == "graphsage":
        return sage_layer(p, h, block, num_dst, activation=act,
                          impl=cfg.impl)
    if cfg.arch == "gat":
        return gat_layer(p, h, block, num_dst,
                         activation=None if last else jax.nn.elu,
                         impl=cfg.impl)
    if cfg.arch == "rgcn":
        return rgcn_layer(p, h, block, num_dst, cfg.num_rels,
                          activation=act, impl=cfg.impl,
                          rel_offsets=rel_offsets)
    raise ValueError(cfg.arch)


def apply_gnn(cfg: GNNConfig, params: dict, batch: dict,
              etype_id=None) -> jnp.ndarray:
    """Forward pass -> (batch_size, num_classes) logits.

    Relation slot offsets are static (derived from cfg, not from the batch)
    so typed blocks never leak shape information into the traced arrays.
    """
    h = batch["input_feats"]
    dst_caps = cfg.dst_caps()
    rel_offs = cfg.layer_rel_offsets(etype_id) if cfg.typed else (
        [None] * cfg.num_layers)
    for l, block in enumerate(batch["blocks"]):
        h = apply_gnn_layer(cfg, params, l, h, block, dst_caps[l],
                            rel_offsets=rel_offs[l])
    if "head" in params:
        h = h @ params["head"]
    return h


# ---------------------------------------------------------------------------
# heads / losses
# ---------------------------------------------------------------------------

def nc_loss(logits: jnp.ndarray, labels: jnp.ndarray,
            seed_mask: jnp.ndarray) -> jnp.ndarray:
    """Masked cross-entropy over real (non-padded) seeds."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    m = seed_mask.astype(jnp.float32)
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)


def nc_accuracy(logits: jnp.ndarray, labels: jnp.ndarray,
                seed_mask: jnp.ndarray) -> jnp.ndarray:
    pred = logits.argmax(axis=-1)
    m = seed_mask.astype(jnp.float32)
    return ((pred == labels) * m).sum() / jnp.maximum(m.sum(), 1.0)


LP_SCORE_FNS = ("dot", "distmult")


def init_lp_head(score_fn: str, num_rels: int, emb_dim: int) -> dict:
    """Scoring-head parameters. ``dot`` is parameter-free; ``distmult``
    owns one diagonal relation embedding per relation, initialized to ones
    so training starts exactly at the dot-product score and learns
    per-relation feature scales from there."""
    if score_fn == "dot":
        return {}
    if score_fn == "distmult":
        return {"rel_emb": jnp.ones((num_rels, emb_dim), dtype=jnp.float32)}
    raise ValueError(f"unknown score_fn {score_fn!r}; have {LP_SCORE_FNS}")


def lp_pair_scores(h: jnp.ndarray, u_idx: jnp.ndarray, v_idx: jnp.ndarray,
                   head: Optional[dict] = None, score_fn: str = "dot",
                   etypes: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Edge scores from node embeddings.

    h: (N, d); u_idx: (B,); v_idx: (B,) -> (B,) scores, or (B, K) ->
    (B, K) scores (negatives). ``distmult`` scores
    ``<h_u, diag(r_e), h_v>`` with ``r_e = rel_emb[etypes]`` — per-edge
    relation lookup, so mixed-relation batches stay static-shape too.
    """
    hu = h[u_idx]
    if score_fn == "distmult":
        hu = hu * head["rel_emb"][etypes]
    elif score_fn != "dot":
        raise ValueError(f"unknown score_fn {score_fn!r}; have {LP_SCORE_FNS}")
    hv = h[v_idx]
    if hv.ndim == hu.ndim + 1:
        return jnp.einsum("pd,pkd->pk", hu, hv)
    return jnp.einsum("pd,pd->p", hu, hv)


def lp_loss_from_scores(pos: jnp.ndarray, neg: jnp.ndarray,
                        pair_mask: jnp.ndarray) -> jnp.ndarray:
    """BCE over (B,) positive and (B, K) negative scores, masked to live
    positive slots."""
    m = pair_mask.astype(jnp.float32)
    pos_l = jax.nn.softplus(-pos) * m
    neg_l = (jax.nn.softplus(neg) * m[:, None]).mean(axis=1)
    return (pos_l + neg_l).sum() / jnp.maximum(m.sum(), 1.0)


def lp_loss(h: jnp.ndarray, pos_u: jnp.ndarray, pos_v: jnp.ndarray,
            neg_v: jnp.ndarray, pair_mask: jnp.ndarray) -> jnp.ndarray:
    """Link-prediction BCE: dot-product scores, uniform negatives.

    h: (N, d) output embeddings; pos_u/pos_v: (P,) indices into h;
    neg_v: (P, K) negatives per positive pair.
    """
    pos = lp_pair_scores(h, pos_u, pos_v)
    neg = lp_pair_scores(h, pos_u, neg_v)
    return lp_loss_from_scores(pos, neg, pair_mask)


def lp_ranks(pos: jnp.ndarray, neg: jnp.ndarray) -> jnp.ndarray:
    """Pessimistic rank of each positive among its 1+K candidates: ties
    count against the positive, so the rank is deterministic and exactly
    reproducible by the dense NumPy oracle (tested bitwise)."""
    return (1 + (neg >= pos[:, None]).sum(axis=-1)).astype(jnp.int32)


def lp_metrics(ranks: jnp.ndarray, pair_mask: jnp.ndarray,
               ks: Sequence[int] = (1, 3, 10)) -> dict:
    """MRR and Hits@k over live positive slots."""
    m = pair_mask.astype(jnp.float32)
    n = jnp.maximum(m.sum(), 1.0)
    out = {"mrr": (m / ranks).sum() / n}
    for k in ks:
        out[f"hits@{k}"] = ((ranks <= k) * m).sum() / n
    return out
