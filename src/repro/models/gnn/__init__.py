from .models import (GNNConfig, apply_gnn, init_gnn, lp_loss, nc_accuracy,
                     nc_loss)
from .layers import gat_layer, rgcn_layer, sage_layer

__all__ = ["GNNConfig", "apply_gnn", "init_gnn", "lp_loss", "nc_accuracy",
           "nc_loss", "gat_layer", "rgcn_layer", "sage_layer"]
