from .models import (GNNConfig, LP_SCORE_FNS, apply_gnn, apply_gnn_layer, init_gnn,
                     init_lp_head, lp_loss, lp_loss_from_scores, lp_metrics,
                     lp_pair_scores, lp_ranks, nc_accuracy, nc_loss)
from .layers import gat_layer, rgcn_layer, sage_layer

__all__ = ["GNNConfig", "LP_SCORE_FNS", "apply_gnn", "apply_gnn_layer", "init_gnn",
           "init_lp_head", "lp_loss", "lp_loss_from_scores", "lp_metrics",
           "lp_pair_scores", "lp_ranks", "nc_accuracy", "nc_loss",
           "gat_layer", "rgcn_layer", "sage_layer"]
