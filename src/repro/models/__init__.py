from . import gnn, lm  # noqa: F401
