"""Serving path: cache init, prefill, and single-token decode for every
architecture family.

Caches are ring buffers of length ``cache_len`` (== sliding window for
windowed configs, == max_seq for full attention). SSM/hybrid archs carry
O(1) recurrent state instead of (or in addition to) KV rings — that is why
they run the long_500k shape natively.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import LMConfig
from .layers import (attention, cache_update, decode_attention, mlp_block,
                     project_kv, project_q, rmsnorm)
from .moe import moe_block
from .ssm import mamba2_block


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, cache_len: int,
               encoder_seq: Optional[int] = None) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    kv, hd = cfg.num_kv_heads, cfg.hd
    at = cfg.arch_type
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if at in ("dense", "moe", "vlm"):
        shp = (cfg.num_layers, batch, cache_len, kv, hd)
        cache["k"] = jnp.zeros(shp, dtype)
        cache["v"] = jnp.zeros(shp, dtype)
    elif at == "ssm":
        cache["ssm"] = jnp.zeros((cfg.num_layers, batch, cfg.ssm_heads,
                                  cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        cache["conv"] = jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv - 1,
                                   cfg.ssm_d_inner + 2 * cfg.ssm_state), dtype)
    elif at == "hybrid":
        ke = cfg.hybrid_attn_every
        ns = cfg.num_layers // ke
        nt = cfg.num_layers - ns * ke
        conv_c = cfg.ssm_d_inner + 2 * cfg.ssm_state
        cache["ssm"] = jnp.zeros((ns, ke, batch, cfg.ssm_heads,
                                  cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        cache["conv"] = jnp.zeros((ns, ke, batch, cfg.ssm_conv - 1, conv_c),
                                  dtype)
        cache["k"] = jnp.zeros((ns, batch, cache_len, kv, hd), dtype)
        cache["v"] = jnp.zeros((ns, batch, cache_len, kv, hd), dtype)
        if nt:
            cache["tail_ssm"] = jnp.zeros(
                (nt, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32)
            cache["tail_conv"] = jnp.zeros((nt, batch, cfg.ssm_conv - 1,
                                            conv_c), dtype)
    elif at == "audio":
        enc_s = encoder_seq or cfg.encoder_seq
        shp = (cfg.num_layers, batch, cache_len, kv, hd)
        cache["k"] = jnp.zeros(shp, dtype)
        cache["v"] = jnp.zeros(shp, dtype)
        cache["xk"] = jnp.zeros((cfg.num_layers, batch, enc_s, kv, hd), dtype)
        cache["xv"] = jnp.zeros((cfg.num_layers, batch, enc_s, kv, hd), dtype)
    else:
        raise ValueError(at)
    return cache


def _ring_fill(k_seq: jnp.ndarray, cache_len: int) -> jnp.ndarray:
    """(B, S, KV, hd) per-position k/v -> ring cache (B, W, KV, hd)."""
    b, s = k_seq.shape[:2]
    w = cache_len
    if s <= w:
        pad = jnp.zeros((b, w - s) + k_seq.shape[2:], k_seq.dtype)
        return jnp.concatenate([k_seq, pad], axis=1)
    # keep last w positions, scatter to slot = pos % w
    tail = k_seq[:, s - w:]                       # positions s-w .. s-1
    slots = (jnp.arange(s - w, s)) % w
    out = jnp.zeros((b, w) + k_seq.shape[2:], k_seq.dtype)
    return out.at[:, slots].set(tail)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill(cfg: LMConfig, params: dict, tokens: jnp.ndarray,
            cache_len: int, *, image_embeds=None, encoder_embeds=None,
            window: Optional[int] = None) -> tuple[jnp.ndarray, dict]:
    """Run the full prompt, build the serve cache.
    Returns (last-position logits (B, V), cache)."""
    window = window if window is not None else cfg.sliding_window
    x = params["embed"][tokens]
    if cfg.arch_type == "vlm":
        x = jnp.concatenate([image_embeds.astype(x.dtype), x], axis=1)
    b, s, d = x.shape
    positions = jnp.arange(s)
    at = cfg.arch_type
    cache = init_cache(cfg, b, cache_len,
                       encoder_seq=None if encoder_embeds is None
                       else encoder_embeds.shape[1])

    if at in ("dense", "moe", "vlm"):
        def body(h, bp):
            hn = rmsnorm(h, bp["ln1"], cfg.norm_eps)
            q = project_q(bp["attn"], hn, cfg, positions)
            k, v = project_kv(bp["attn"], hn, cfg, positions)
            o = attention(q, k, v, causal=True, window=window,
                          chunk=cfg.attn_chunk)
            h = h + o.reshape(b, s, -1) @ bp["attn"]["wo"]
            if "moe" in bp:
                ff, _ = moe_block(bp["moe"], rmsnorm(h, bp["ln2"], cfg.norm_eps), cfg)
            else:
                ff = mlp_block(bp["mlp"], rmsnorm(h, bp["ln2"], cfg.norm_eps))
            return h + ff, (_ring_fill(k, cache_len), _ring_fill(v, cache_len))
        x, (kc, vc) = jax.lax.scan(body, x, params["blocks"])
        cache["k"], cache["v"] = kc, vc

    elif at == "ssm":
        def body(h, bp):
            out, S, conv = mamba2_block(bp["mamba"],
                                        rmsnorm(h, bp["ln1"], cfg.norm_eps), cfg)
            return h + out, (S, conv)
        x, (ss, cs) = jax.lax.scan(body, x, params["blocks"])
        cache["ssm"], cache["conv"] = ss, cs

    elif at == "hybrid":
        shared = params["shared"]

        def inner(h, bp):
            out, S, conv = mamba2_block(bp["mamba"],
                                        rmsnorm(h, bp["ln1"], cfg.norm_eps), cfg)
            return h + out, (S, conv)

        def super_body(h, sbp):
            h, (S, conv) = jax.lax.scan(inner, h, sbp)
            hn = rmsnorm(h, shared["ln_a"], cfg.norm_eps)
            q = project_q(shared["attn"], hn, cfg, positions)
            k, v = project_kv(shared["attn"], hn, cfg, positions)
            o = attention(q, k, v, causal=True, window=window,
                          chunk=cfg.attn_chunk)
            h = h + o.reshape(b, s, -1) @ shared["attn"]["wo"]
            h = h + mlp_block(shared["mlp"],
                              rmsnorm(h, shared["ln_m"], cfg.norm_eps))
            return h, (S, conv, _ring_fill(k, cache_len),
                       _ring_fill(v, cache_len))
        x, (ss, cs, kc, vc) = jax.lax.scan(super_body, x, params["blocks"])
        cache["ssm"], cache["conv"] = ss, cs
        cache["k"], cache["v"] = kc, vc
        if "tail_blocks" in params:
            x, (ts, tc) = jax.lax.scan(inner, x, params["tail_blocks"])
            cache["tail_ssm"], cache["tail_conv"] = ts, tc

    elif at == "audio":
        enc = encoder_embeds.astype(x.dtype)
        enc_pos = jnp.arange(enc.shape[1])

        def enc_body(h, bp):
            hn = rmsnorm(h, bp["ln1"], cfg.norm_eps)
            q = project_q(bp["attn"], hn, cfg, enc_pos)
            k, v = project_kv(bp["attn"], hn, cfg, enc_pos)
            o = attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
            h = h + o.reshape(h.shape[0], h.shape[1], -1) @ bp["attn"]["wo"]
            h = h + mlp_block(bp["mlp"], rmsnorm(h, bp["ln2"], cfg.norm_eps),
                              kind="gelu")
            return h, None
        enc, _ = jax.lax.scan(enc_body, enc, params["enc_blocks"])
        enc = rmsnorm(enc, params["enc_norm"], cfg.norm_eps)

        def dec_body(h, bp):
            hn = rmsnorm(h, bp["ln1"], cfg.norm_eps)
            q = project_q(bp["attn"], hn, cfg, positions)
            k, v = project_kv(bp["attn"], hn, cfg, positions)
            o = attention(q, k, v, causal=True, window=window,
                          chunk=cfg.attn_chunk)
            h = h + o.reshape(b, s, -1) @ bp["attn"]["wo"]
            hx = rmsnorm(h, bp["ln_x"], cfg.norm_eps)
            qx = project_q(bp["xattn"], hx, cfg, positions, use_rope=False)
            xk, xv = project_kv(bp["xattn"], enc, cfg, enc_pos, use_rope=False)
            ox = attention(qx, xk, xv, causal=False, chunk=cfg.attn_chunk)
            h = h + ox.reshape(b, s, -1) @ bp["xattn"]["wo"]
            h = h + mlp_block(bp["mlp"], rmsnorm(h, bp["ln2"], cfg.norm_eps),
                              kind="gelu")
            return h, (_ring_fill(k, cache_len), _ring_fill(v, cache_len),
                       xk, xv)
        x, (kc, vc, xk, xv) = jax.lax.scan(dec_body, x, params["blocks"])
        cache["k"], cache["v"] = kc, vc
        cache["xk"], cache["xv"] = xk, xv
    else:
        raise ValueError(at)

    cache["pos"] = jnp.asarray(s, jnp.int32)
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head)[:, 0], cache


# ---------------------------------------------------------------------------
# decode (one token)
# ---------------------------------------------------------------------------

def decode_step(cfg: LMConfig, params: dict, cache: dict,
                tokens: jnp.ndarray, *, window: Optional[int] = None
                ) -> tuple[jnp.ndarray, dict]:
    """tokens: (B, 1) the token generated at position cache['pos'].
    Returns (logits (B, V) for the next position, updated cache)."""
    window = window if window is not None else cfg.sliding_window
    pos = cache["pos"]
    x = params["embed"][tokens]                     # (B, 1, d)
    b = x.shape[0]
    positions = jnp.full((1,), pos)
    at = cfg.arch_type
    new_cache = dict(cache)

    def attn_decode(ap, h, kc, vc):
        hn = h
        q = project_q(ap, hn, cfg, positions)
        k, v = project_kv(ap, hn, cfg, positions)
        kc, vc = cache_update(kc, vc, k, v, pos)
        o = decode_attention(q, kc, vc, pos, window=window)
        return o.reshape(b, 1, -1) @ ap["wo"], kc, vc

    if at in ("dense", "moe", "vlm"):
        def body(h, xs):
            bp, kc, vc = xs
            o, kc, vc = attn_decode(bp["attn"],
                                    rmsnorm(h, bp["ln1"], cfg.norm_eps),
                                    kc, vc)
            h = h + o
            if "moe" in bp:
                ff, _ = moe_block(bp["moe"],
                                  rmsnorm(h, bp["ln2"], cfg.norm_eps), cfg)
            else:
                ff = mlp_block(bp["mlp"], rmsnorm(h, bp["ln2"], cfg.norm_eps))
            return h + ff, (kc, vc)
        x, (kc, vc) = jax.lax.scan(body, x,
                                   (params["blocks"], cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = kc, vc

    elif at == "ssm":
        def body(h, xs):
            bp, S, conv = xs
            out, S, conv = mamba2_block(bp["mamba"],
                                        rmsnorm(h, bp["ln1"], cfg.norm_eps),
                                        cfg, ssm_state=S, conv_state=conv,
                                        decode=True)
            return h + out, (S, conv)
        x, (ss, cs) = jax.lax.scan(body, x, (params["blocks"], cache["ssm"],
                                             cache["conv"]))
        new_cache["ssm"], new_cache["conv"] = ss, cs

    elif at == "hybrid":
        shared = params["shared"]

        def inner(h, xs):
            bp, S, conv = xs
            out, S, conv = mamba2_block(bp["mamba"],
                                        rmsnorm(h, bp["ln1"], cfg.norm_eps),
                                        cfg, ssm_state=S, conv_state=conv,
                                        decode=True)
            return h + out, (S, conv)

        def super_body(h, xs):
            sbp, S, conv, kc, vc = xs
            h, (S, conv) = jax.lax.scan(inner, h, (sbp, S, conv))
            o, kc, vc = attn_decode(shared["attn"],
                                    rmsnorm(h, shared["ln_a"], cfg.norm_eps),
                                    kc, vc)
            h = h + o
            h = h + mlp_block(shared["mlp"],
                              rmsnorm(h, shared["ln_m"], cfg.norm_eps))
            return h, (S, conv, kc, vc)
        x, (ss, cs, kc, vc) = jax.lax.scan(
            super_body, x, (params["blocks"], cache["ssm"], cache["conv"],
                            cache["k"], cache["v"]))
        new_cache.update(ssm=ss, conv=cs, k=kc, v=vc)
        if "tail_blocks" in params:
            x, (ts, tc) = jax.lax.scan(
                inner, x, (params["tail_blocks"], cache["tail_ssm"],
                           cache["tail_conv"]))
            new_cache["tail_ssm"], new_cache["tail_conv"] = ts, tc

    elif at == "audio":
        def body(h, xs):
            bp, kc, vc, xk, xv = xs
            o, kc, vc = attn_decode(bp["attn"],
                                    rmsnorm(h, bp["ln1"], cfg.norm_eps),
                                    kc, vc)
            h = h + o
            hx = rmsnorm(h, bp["ln_x"], cfg.norm_eps)
            qx = project_q(bp["xattn"], hx, cfg, positions, use_rope=False)
            sc = attention(qx, xk, xv, causal=False, chunk=1)
            h = h + sc.reshape(b, 1, -1) @ bp["xattn"]["wo"]
            h = h + mlp_block(bp["mlp"], rmsnorm(h, bp["ln2"], cfg.norm_eps),
                              kind="gelu")
            return h, (kc, vc)
        x, (kc, vc) = jax.lax.scan(body, x, (params["blocks"], cache["k"],
                                             cache["v"], cache["xk"],
                                             cache["xv"]))
        new_cache["k"], new_cache["v"] = kc, vc
    else:
        raise ValueError(at)

    new_cache["pos"] = pos + 1
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head)[:, 0], new_cache
