"""Model zoo: parameter init + forward/prefill/decode for every assigned
architecture family.

Layer stacks are ``lax.scan``-ed over stacked parameters (HLO size is
depth-independent — both a compile-feasibility requirement on this box and
the production-sane choice). Hybrid (Zamba2-style) models scan over
"super-blocks" of ``hybrid_attn_every`` Mamba2 layers followed by one
*shared-weight* attention+MLP block (shared = the same parameters at every
site, as in Zamba).

Caches (serve path) are ring buffers of length ``cache_len`` (== window for
sliding-window configs); see layers.decode_attention for slot semantics.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...sharding import batch_spec, maybe_constrain
from jax.sharding import PartitionSpec as P
from .config import LMConfig
from .layers import (attn_block, attention, cache_update, decode_attention,
                     mlp_block, project_kv, project_q, rmsnorm)
from .moe import moe_block
from .ssm import mamba2_block

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale or 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _attn_params(cfg: LMConfig, key, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * hd), dtype),
        "wk": _dense_init(ks[1], (d, kv * hd), dtype),
        "wv": _dense_init(ks[2], (d, kv * hd), dtype),
        "wo": _dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _mlp_params(cfg: LMConfig, key, dtype, kind="swiglu"):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        # gate|up fused on a leading size-2 axis: one matmul from the shared
        # input -> one dX in backward instead of two partial dXs that GSPMD
        # must all-reduce separately (§Perf iteration: -1.07GB f32/layer);
        # slicing stays shard-local because ff (not 2ff) carries "model"
        return {"w_gateup": _dense_init(ks[0], (d, 2, f), dtype),
                "w_down": _dense_init(ks[2], (f, d), dtype)}
    return {"w_up": _dense_init(ks[0], (d, f), dtype),
            "b_up": jnp.zeros((f,), dtype),
            "w_down": _dense_init(ks[1], (f, d), dtype),
            "b_down": jnp.zeros((d,), dtype)}


def _moe_params(cfg: LMConfig, key, dtype):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    return {"router": _dense_init(ks[0], (d, e), jnp.float32),
            "experts_gate": _dense_init(ks[1], (e, d, f), dtype),
            "experts_up": _dense_init(ks[2], (e, d, f), dtype),
            "experts_down": _dense_init(ks[3], (e, f, d), dtype)}


def _mamba_params(cfg: LMConfig, key, dtype):
    d, di, n, h = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 5)
    cs = 1.0 / np.sqrt(cfg.ssm_conv)
    return {
        # z|x inner projection: channel-sharded (tensor parallel)
        "in_proj": _dense_init(ks[0], (d, 2 * di), dtype),
        # B|C|dt projection: small, replicated (see ssm.py TP notes)
        "bc_proj": _dense_init(ks[1], (d, 2 * n + h), dtype),
        "conv_w": _dense_init(ks[2], (cfg.ssm_conv, di), dtype, scale=cs),
        "conv_b": jnp.zeros((di,), dtype),
        "conv_bc_w": _dense_init(ks[3], (cfg.ssm_conv, 2 * n), dtype,
                                 scale=cs),
        "conv_bc_b": jnp.zeros((2 * n,), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),     # A = -exp(0) = -1
        "D": jnp.ones((h,), dtype),
        "out_proj": _dense_init(ks[4], (di, d), dtype),
    }


def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: LMConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.padded_vocab
    params = {"embed": _dense_init(keys[0], (v, d), dtype, scale=0.02 * np.sqrt(d)),
              "final_norm": jnp.ones((d,), dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(keys[1], (d, v), dtype)

    at = cfg.arch_type
    if at in ("dense", "vlm", "moe"):
        def one(k):
            k1, k2 = jax.random.split(k)
            blk = {"ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype),
                   "attn": _attn_params(cfg, k1, dtype)}
            if at == "moe":
                blk["moe"] = _moe_params(cfg, k2, dtype)
            else:
                blk["mlp"] = _mlp_params(cfg, k2, dtype)
            return blk
        params["blocks"] = _stack_init(one, keys[2], cfg.num_layers)

    elif at == "ssm":
        def one(k):
            return {"ln1": jnp.ones((d,), dtype),
                    "mamba": _mamba_params(cfg, k, dtype)}
        params["blocks"] = _stack_init(one, keys[2], cfg.num_layers)

    elif at == "hybrid":
        k_every = cfg.hybrid_attn_every
        n_super = cfg.num_layers // k_every
        n_tail = cfg.num_layers - n_super * k_every

        def one(k):
            return {"ln1": jnp.ones((d,), dtype),
                    "mamba": _mamba_params(cfg, k, dtype)}
        def super_init(k):
            return _stack_init(one, k, k_every)
        params["blocks"] = _stack_init(super_init, keys[2], n_super)
        if n_tail:
            params["tail_blocks"] = _stack_init(one, keys[3], n_tail)
        k1, k2 = jax.random.split(keys[4])
        params["shared"] = {
            "ln_a": jnp.ones((d,), dtype), "ln_m": jnp.ones((d,), dtype),
            "attn": _attn_params(cfg, k1, dtype),
            "mlp": _mlp_params(cfg, k2, dtype),
        }

    elif at == "audio":   # whisper backbone: encoder + causal decoder
        def enc_one(k):
            k1, k2 = jax.random.split(k)
            return {"ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype),
                    "attn": _attn_params(cfg, k1, dtype),
                    "mlp": _mlp_params(cfg, k2, dtype, kind="gelu")}
        def dec_one(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"ln1": jnp.ones((d,), dtype),
                    "ln_x": jnp.ones((d,), dtype),
                    "ln2": jnp.ones((d,), dtype),
                    "attn": _attn_params(cfg, k1, dtype),
                    "xattn": _attn_params(cfg, k2, dtype),
                    "mlp": _mlp_params(cfg, k3, dtype, kind="gelu")}
        params["enc_blocks"] = _stack_init(enc_one, keys[2],
                                           cfg.num_encoder_layers)
        params["enc_norm"] = jnp.ones((d,), dtype)
        params["blocks"] = _stack_init(dec_one, keys[3], cfg.num_layers)
    else:
        raise ValueError(at)
    return params


def abstract_params(cfg: LMConfig) -> dict:
    """Shape/dtype skeleton without allocating (for the dry-run)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


# ---------------------------------------------------------------------------
# forward (training / scoring)
# ---------------------------------------------------------------------------

def _constrain_act(x):
    """Block-boundary activation sharding.

    Megatron sequence parallelism: the (B, S, D) residual stream is sharded
    over "model" on the *sequence* dim between blocks (norm/residual are
    elementwise), so per-layer remat residuals shrink by the model-axis
    size. GSPMD inserts the all-gather before each block's first matmul and
    the reduce-scatter after its last — measured 52.6 -> ~4 GB/device on
    the llama3-8b train step. Falls back to replicated when S doesn't
    divide (e.g. whisper's 1500-frame encoder, single-token decode).
    """
    from ...sharding import current_rules
    r = current_rules()
    if (r.seq_shard_activations and x.ndim >= 3
            and x.shape[1] % r.model_axis_size == 0):
        return maybe_constrain(x, P(r.batch_axes, r.model_axis, None))
    return maybe_constrain(x, batch_spec(None, None))


def _dense_block(cfg: LMConfig, bp: dict, x, positions, window):
    # norm outputs are pinned to the sequence-parallel spec so the SP->full
    # gather crosses in bf16 (GSPMD otherwise placed it around the f32
    # rmsnorm intermediate: a 2x-bytes f32 boundary, §Perf iteration 2);
    # sub-block outputs are constrained before the residual add likewise
    o = attn_block(bp["attn"],
                   _constrain_act(rmsnorm(x, bp["ln1"], cfg.norm_eps)), cfg,
                   positions=positions, window=window)
    h = x + _constrain_act(o)
    hn = _constrain_act(rmsnorm(h, bp["ln2"], cfg.norm_eps))
    if "moe" in bp:
        ff, aux = moe_block(bp["moe"], hn, cfg)
    else:
        ff = mlp_block(bp["mlp"], hn, kind="swiglu")
        aux = jnp.zeros((), jnp.float32)
    return _constrain_act(h + _constrain_act(ff)), aux


def _mamba_layer(cfg: LMConfig, bp: dict, x):
    out, _, _ = mamba2_block(bp["mamba"],
                             rmsnorm(x, bp["ln1"], cfg.norm_eps), cfg)
    return _constrain_act(x + out)


def _shared_attn_block(cfg: LMConfig, sp: dict, x, positions, window):
    h = x + attn_block(sp["attn"], rmsnorm(x, sp["ln_a"], cfg.norm_eps), cfg,
                       positions=positions, window=window)
    ff = mlp_block(sp["mlp"], rmsnorm(h, sp["ln_m"], cfg.norm_eps))
    return _constrain_act(h + ff)


def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def forward(cfg: LMConfig, params: dict, tokens: jnp.ndarray, *,
            image_embeds: Optional[jnp.ndarray] = None,
            encoder_embeds: Optional[jnp.ndarray] = None,
            window: Optional[int] = None,
            return_hidden: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S) -> (logits (B, S_total, V), aux_loss).

    ``return_hidden=True`` skips the LM-head matmul and returns the final
    normed hidden states — the train loss projects chunk-by-chunk so the
    (B, S, 150k-vocab) logits tensor never materializes in full.

    vlm: image_embeds (B, n_img, d) are prepended (logits cover the full
    sequence; the loss masks image positions). audio: encoder_embeds
    (B, S_enc, d) go through the encoder stack, decoder cross-attends.
    """
    window = window if window is not None else cfg.sliding_window
    x = params["embed"][tokens]
    if cfg.arch_type == "vlm":
        assert image_embeds is not None
        x = jnp.concatenate([image_embeds.astype(x.dtype), x], axis=1)
    x = _constrain_act(x)
    b, s, d = x.shape
    positions = jnp.arange(s)

    aux_total = jnp.zeros((), jnp.float32)
    at = cfg.arch_type
    if at in ("dense", "vlm", "moe"):
        def body(carry, bp):
            h, aux = carry
            h2, a = _maybe_remat(cfg, functools.partial(
                _dense_block, cfg))(bp, h, positions, window)
            return (h2, aux + a), None
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                         params["blocks"])

    elif at == "ssm":
        def body(h, bp):
            return _maybe_remat(cfg, functools.partial(
                _mamba_layer, cfg))(bp, h), None
        x, _ = jax.lax.scan(body, x, params["blocks"])

    elif at == "hybrid":
        shared = params["shared"]

        def super_body(h, sbp):
            def inner(hh, bp):
                return _maybe_remat(cfg, functools.partial(
                    _mamba_layer, cfg))(bp, hh), None
            h, _ = jax.lax.scan(inner, h, sbp)
            h = _maybe_remat(cfg, functools.partial(
                _shared_attn_block, cfg))(shared, h, positions, window)
            return h, None
        x, _ = jax.lax.scan(super_body, x, params["blocks"])
        if "tail_blocks" in params:
            def tail(h, bp):
                return _mamba_layer(cfg, bp, h), None
            x, _ = jax.lax.scan(tail, x, params["tail_blocks"])

    elif at == "audio":
        assert encoder_embeds is not None
        enc = encoder_embeds.astype(x.dtype)
        enc_pos = jnp.arange(enc.shape[1])

        def enc_body(h, bp):
            h2 = h + attn_block(bp["attn"],
                                rmsnorm(h, bp["ln1"], cfg.norm_eps), cfg,
                                positions=enc_pos, causal=False)
            h2 = h2 + mlp_block(bp["mlp"],
                                rmsnorm(h2, bp["ln2"], cfg.norm_eps),
                                kind="gelu")
            return _constrain_act(h2), None
        enc, _ = jax.lax.scan(enc_body, _constrain_act(enc),
                              params["enc_blocks"])
        enc = rmsnorm(enc, params["enc_norm"], cfg.norm_eps)

        def dec_body(h, bp):
            h = h + attn_block(bp["attn"],
                               rmsnorm(h, bp["ln1"], cfg.norm_eps), cfg,
                               positions=positions, window=window)
            h = h + attn_block(bp["xattn"],
                               rmsnorm(h, bp["ln_x"], cfg.norm_eps), cfg,
                               positions=positions, context=enc,
                               context_positions=enc_pos)
            h = h + mlp_block(bp["mlp"], rmsnorm(h, bp["ln2"], cfg.norm_eps),
                              kind="gelu")
            return _constrain_act(h), None
        x, _ = jax.lax.scan(jax.checkpoint(dec_body) if cfg.remat else dec_body,
                            x, params["blocks"])
    else:
        raise ValueError(at)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    aux = aux_total / max(cfg.num_layers, 1)
    if return_hidden:
        return x, aux
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, aux
