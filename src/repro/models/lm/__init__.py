from .config import LMConfig
from .model import abstract_params, forward, init_params
from .decode import decode_step, init_cache, prefill
from .steps import (init_train_state, lm_loss, make_decode_step,
                    make_prefill_step, make_train_step)

__all__ = [
    "LMConfig", "abstract_params", "forward", "init_params", "decode_step",
    "init_cache", "prefill", "init_train_state", "lm_loss",
    "make_decode_step", "make_prefill_step", "make_train_step",
]
