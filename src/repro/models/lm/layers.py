"""Transformer building blocks: RMSNorm, RoPE, GQA attention (query-chunked
"flash-style" for train/prefill; ring-buffer cache for decode), SwiGLU /
GeLU MLP.

All attention paths support:
  * grouped-query attention (num_kv_heads < num_heads), computed grouped —
    no materialized KV repeat;
  * optional per-head q/k RMSNorm (qwen3) and QKV bias (qwen2);
  * optional sliding-window masking (the sub-quadratic variant dense archs
    use for the long_500k shape);
  * query chunking via lax.scan so the score matrix never exceeds
    (B, H, chunk, S_kv) — required to lower prefill_32k without a
    quadratic-in-sequence buffer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ...sharding import maybe_constrain
from jax.sharding import PartitionSpec as P


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _gqa_scores(q, k):
    """q: (B,Sq,H,D), k: (B,Sk,KV,D) -> (B,KV,G,Sq,Sk), G = H // KV."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / jnp.sqrt(d).astype(q.dtype)


def _gqa_combine(probs, v):
    """probs: (B,KV,G,Sq,Sk), v: (B,Sk,KV,D) -> (B,Sq,H,D)."""
    b, kv, g, sq, sk = probs.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, kv * g, out.shape[-1])


def _head_spec():
    """(B, S, H, D) activations with heads sharded Megatron-style."""
    from ...sharding import current_rules
    r = current_rules()
    return P(r.batch_axes, None, r.model_axis, None)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: Optional[int] = None,
              q_offset: int = 0, chunk: int = 1024) -> jnp.ndarray:
    """Query-chunked masked attention.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D). ``q_offset`` is the absolute
    position of q[0] relative to k[0] (prefill: 0; other uses may differ).

    Tensor-parallel mapping: KV heads are expanded to the full H and the
    head axis is explicitly sharded over "model" (Megatron attention) — the
    reshape from the flat (H·hd) projection otherwise blocks GSPMD
    propagation and replicates the O(chunk·S_kv) score matrix on every
    model rank (measured: 19.5 GB/device for a 14-head 4k-seq train step;
    sharded: /mesh_model). The KV expansion is a (B,S,H,D) bf16 buffer —
    three orders of magnitude smaller than the scores it shards.
    """
    from ...sharding import current_rules, maybe_constrain
    r = current_rules()
    b, sq, h, d = q.shape
    kv = k.shape[2]
    sk = k.shape[1]
    g = h // kv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    head_axis = None if r.pure_fsdp else r.model_axis
    hspec = P(r.batch_axes, None, head_axis, None)
    q = maybe_constrain(q, hspec)
    k = maybe_constrain(k, hspec)
    v = maybe_constrain(v, hspec)
    chunk = min(chunk, sq)
    pad = (-sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // chunk
    qs = q.reshape(b, nq, chunk, h, d).transpose(1, 0, 2, 3, 4)

    kpos = jnp.arange(sk)
    sspec = P(r.batch_axes, head_axis, None, None)

    def one_chunk(ci, qc):
        # qc: (B, chunk, H, D)
        scores = jnp.einsum("bqhd,bshd->bhqs", qc, k) / jnp.sqrt(d)
        scores = maybe_constrain(scores.astype(jnp.float32), sspec)
        qpos = q_offset + ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, sk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v)     # (B,chunk,H,D)
        return maybe_constrain(out, hspec)

    # checkpoint per chunk: the (B,H,chunk,Sk) score/prob buffers are
    # recomputed in each chunk's backward instead of being stacked as scan
    # residuals (which would reconstitute the full O(S^2) matrix)
    out = jax.lax.map(lambda args: jax.checkpoint(one_chunk)(*args),
                      (jnp.arange(nq), qs))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nq * chunk, h, d)
    return out[:, :sq]


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray,
                     window: Optional[int] = None) -> jnp.ndarray:
    """Single-token attention over a (ring-buffer) cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, W, KV, D). Slot i of a ring
    buffer holds absolute position  pos - ((pos - i) mod W); slots with a
    negative implied position are unwritten and masked. For full
    (non-windowed) caches W == max_seq and the same formula masks exactly
    the > pos tail.
    """
    w = k_cache.shape[1]
    slots = jnp.arange(w)
    slot_pos = pos - ((pos - slots) % w)
    valid = slot_pos >= 0
    if window is not None:
        valid &= slot_pos > pos - window
    scores = _gqa_scores(q, k_cache).astype(jnp.float32)   # (B,KV,G,1,W)
    scores = jnp.where(valid[None, None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _gqa_combine(probs, v_cache)                    # (B,1,H,D)


def cache_update(k_cache, v_cache, k_new, v_new, pos):
    """Write one token's k/v into ring slot pos % W. k_new: (B,1,KV,D)."""
    w = k_cache.shape[1]
    slot = pos % w
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, slot, axis=1)
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# attention block (projections + norms + rope)
# ---------------------------------------------------------------------------

def project_q(p: dict, x: jnp.ndarray, cfg, positions, use_rope=True):
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.hd
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(1, 1, h, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
    return q


def project_kv(p: dict, x: jnp.ndarray, cfg, positions, use_rope=True):
    b, s, _ = x.shape
    kv, hd = cfg.num_kv_heads, cfg.hd
    k = (x @ p["wk"]).reshape(b, s, kv, hd)
    v = (x @ p["wv"]).reshape(b, s, kv, hd)
    if cfg.qkv_bias:
        k = k + p["bk"].reshape(1, 1, kv, hd)
        v = v + p["bv"].reshape(1, 1, kv, hd)
    if cfg.qk_norm:
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        k = rope(k, positions, cfg.rope_theta)
    return k, v


def attn_project_qkv(p: dict, x: jnp.ndarray, cfg, positions) -> tuple:
    """x: (B,S,d) -> roped q (B,S,H,hd), k,v (B,S,KV,hd)."""
    q = project_q(p, x, cfg, positions)
    k, v = project_kv(p, x, cfg, positions)
    return q, k, v


def attn_block(p: dict, x: jnp.ndarray, cfg, *, positions,
               window=None, causal=True, context=None,
               context_positions=None) -> jnp.ndarray:
    """Full attention sub-block (pre-norm residual handled by caller).
    ``context`` switches to cross-attention (k/v projected from context,
    no rope — encoder output carries its own positional content)."""
    if context is None:
        q, k, v = attn_project_qkv(p, x, cfg, positions)
    else:
        q = project_q(p, x, cfg, positions, use_rope=False)
        k, v = project_kv(p, context, cfg, context_positions, use_rope=False)
        causal = False
    o = attention(q, k, v, causal=causal, window=window, chunk=cfg.attn_chunk)
    o = o.reshape(x.shape[0], x.shape[1], -1)
    return o @ p["wo"]


def mlp_block(p: dict, x: jnp.ndarray, kind: str = "swiglu") -> jnp.ndarray:
    if kind == "swiglu":
        gu = jnp.einsum("...d,dgf->...gf", x, p["w_gateup"])
        return (jax.nn.silu(gu[..., 0, :]) * gu[..., 1, :]) @ p["w_down"]
    if kind == "gelu":
        return jax.nn.gelu(x @ p["w_up"] + p["b_up"]) @ p["w_down"] + p["b_down"]
    raise ValueError(kind)
