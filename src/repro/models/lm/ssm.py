"""Mamba2 SSD (state-space duality) layer [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: the sequence is split into
chunks of length Q; within a chunk the quadratic "attention-like" form runs
on the MXU, across chunks a linear recurrence carries the (H, P, N) state.
We scan over chunks (lax.scan) with a per-chunk checkpoint so activation
memory is O(Q^2·H/tp) instead of O(L·Q·H) — that is what lets
long-sequence shapes lower.

Decode is the O(1) recurrent form: S <- exp(dt·A)·S + dt·B⊗x, y = C·S.

Tensor-parallel mapping (the Mamba analogue of Megatron attention): SSD
heads shard over "model". The z/x inner projection and its depthwise conv
are channel-sharded; the small B/C/dt projection is kept *separate* and
replicated — folding it into one matmul (as the single-GPU reference does)
would make B/C slices cross shard boundaries and force GSPMD gathers of
the whole conv output.

Scalar-identity A per head, B/C shared across heads (single group), exactly
Mamba2's default.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ...sharding import current_rules, maybe_constrain


def _head_constrain(x):
    """(..., H, ...) head-sharded activations (heads on axis -2 or -3)."""
    r = current_rules()
    h_ax = None if r.pure_fsdp else r.model_axis
    if x.ndim == 4:      # (b, l, h, p)
        return maybe_constrain(x, P(r.batch_axes, None, h_ax, None))
    if x.ndim == 3:      # (b, l, h) or (b, h, p)
        return maybe_constrain(x, P(r.batch_axes, None, h_ax))
    return x


def _channel_constrain(x):
    r = current_rules()
    if x.ndim == 3:      # (b, l, c)
        return maybe_constrain(
            x, P(r.batch_axes, None,
                 None if r.pure_fsdp else r.model_axis))
    return x


def _depthwise_causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                           ) -> jnp.ndarray:
    """x: (B, L, C); w: (K, C); causal depthwise conv + silu."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, a_log, B, C, D, chunk: int,
                init_state: Optional[jnp.ndarray] = None):
    """Chunked SSD scan.

    x:  (b, l, h, p)   inner activations, heads h, head dim p
    dt: (b, l, h)      positive step sizes (softplus already applied)
    a_log: (h,)        A = -exp(a_log) (negative decay rate per head)
    B, C: (b, l, n)    input/output projections (shared across heads)
    D:  (h,)           skip connection
    Returns (y: (b,l,h,p), final_state: (b,h,p,n)).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    lp = x.shape[1]
    nc = lp // chunk
    A = -jnp.exp(a_log.astype(jnp.float32))                    # (h,)

    xc = x.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)
    Bc = B.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    Cc = C.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)

    r = current_rules()
    lmat_spec = P(r.batch_axes, None, None, r.model_axis)

    def step(S, inputs):
        xq, dtq, Bq, Cq = inputs          # (b,q,h,p), (b,q,h), (b,q,n) x2
        da = dtq.astype(jnp.float32) * A                        # (b,q,h) <0
        cs = jnp.cumsum(da, axis=1)                             # (b,q,h)
        # intra-chunk quadratic form — (b,t,s,h) sharded over heads
        seg = cs[:, :, None, :] - cs[:, None, :, :]             # (b,t,s,h)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        Lmat = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        Lmat = maybe_constrain(Lmat, lmat_spec)
        G = jnp.einsum("btn,bsn->bts", Cq, Bq)                  # (b,t,s)
        xdt = _head_constrain(xq * dtq[..., None])              # (b,q,h,p)
        y = jnp.einsum("bts,btsh,bshp->bthp",
                       G.astype(jnp.float32), Lmat,
                       xdt.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        y = y + jnp.einsum("btn,bhpn,bth->bthp",
                           Cq.astype(jnp.float32), S, jnp.exp(cs))
        # new state
        decay_to_end = jnp.exp(cs[:, -1:, :] - cs)              # (b,q,h)
        S_new = (jnp.exp(cs[:, -1, :])[:, :, None, None] * S
                 + jnp.einsum("bqn,bqhp,bqh->bhpn",
                              Bq.astype(jnp.float32),
                              xdt.astype(jnp.float32), decay_to_end))
        return S_new, _head_constrain(y.astype(x.dtype))

    S0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state)
    # checkpoint per chunk: the O(Q^2·H) decay/score buffers are recomputed
    # in each chunk's backward instead of being stacked as scan residuals
    # (without this an 81-layer hybrid train step peaks at ~140 GB/device)
    S_final, ys = jax.lax.scan(jax.checkpoint(step), S0, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, lp, h, p)[:, :l]
    y = y + x[:, :l] * D[None, None, :, None]
    return y, S_final


def ssd_decode_step(S, x, dt, a_log, B, C, D):
    """One-token recurrence. x: (b,h,p); dt: (b,h); B,C: (b,n).
    Returns (y: (b,h,p), S_new: (b,h,p,n))."""
    A = -jnp.exp(a_log.astype(jnp.float32))
    a = jnp.exp(dt.astype(jnp.float32) * A)                     # (b,h)
    dBx = jnp.einsum("bn,bhp,bh->bhpn", B.astype(jnp.float32),
                     x.astype(jnp.float32), dt.astype(jnp.float32))
    S_new = a[:, :, None, None] * S + dBx
    y = jnp.einsum("bn,bhpn->bhp", C.astype(jnp.float32), S_new)
    y = y.astype(x.dtype) + x * D[None, :, None]
    return y, S_new


# ---------------------------------------------------------------------------
# full mamba2 block (projections + conv + gate)
# ---------------------------------------------------------------------------

def mamba2_block(p: dict, x: jnp.ndarray, cfg,
                 ssm_state: Optional[jnp.ndarray] = None,
                 conv_state: Optional[jnp.ndarray] = None,
                 decode: bool = False):
    """x: (B, L, d) (L==1 with decode=True).

    params: in_proj (d, 2*di) [z | x, channel-sharded], bc_proj
    (d, 2n + h) [B | C | dt, replicated], conv_w (K, di), conv_b (di,),
    conv_bc_w (K, 2n), conv_bc_b (2n,), dt_bias (h,), a_log (h,), D (h,),
    out_proj (di, d).
    Returns (out, new_ssm_state, new_conv_state); conv state layout is
    (b, K-1, di + 2n) — x channels then B|C.
    """
    b, l, d = x.shape
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_head_dim
    zx = x @ p["in_proj"]                                       # (b,l,2di)
    zx = _channel_constrain(zx)
    z, xi_raw = zx[..., :di], zx[..., di:]
    bcdt = x @ p["bc_proj"]                                     # (b,l,2n+h)
    bc_raw = bcdt[..., :2 * n]
    dt_raw = bcdt[..., 2 * n:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])                 # (b,l,h)
    dt = _head_constrain(dt)

    if decode:
        k = cfg.ssm_conv
        hist_x = jnp.concatenate([conv_state[..., :di], xi_raw], axis=1)
        hist_bc = jnp.concatenate([conv_state[..., di:], bc_raw], axis=1)
        conv_x = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", hist_x, p["conv_w"]) + p["conv_b"])
        conv_bc = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", hist_bc, p["conv_bc_w"]) + p["conv_bc_b"])
        new_conv_state = jnp.concatenate([hist_x[:, 1:], hist_bc[:, 1:]],
                                         axis=-1)
        xi = conv_x.reshape(b, h, pdim)
        Bv, Cv = conv_bc[:, :n], conv_bc[:, n:]
        y, new_S = ssd_decode_step(ssm_state, xi, dt[:, 0], p["a_log"],
                                   Bv, Cv, p["D"])
        y = y.reshape(b, 1, di)
        out = (y * jax.nn.silu(z)) @ p["out_proj"]
        return out, new_S, new_conv_state

    conv_x = _depthwise_causal_conv(xi_raw, p["conv_w"], p["conv_b"])
    conv_bc = _depthwise_causal_conv(bc_raw, p["conv_bc_w"], p["conv_bc_b"])
    xi = _head_constrain(conv_x.reshape(b, l, h, pdim))
    Bv, Cv = conv_bc[..., :n], conv_bc[..., n:]
    y, S_final = ssd_chunked(xi, dt, p["a_log"], Bv, Cv, p["D"],
                             cfg.ssm_chunk, init_state=ssm_state)
    y = y.reshape(b, l, di)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    km1 = cfg.ssm_conv - 1
    raw = jnp.concatenate([xi_raw, bc_raw], axis=-1)
    new_conv_state = jnp.pad(raw, ((0, 0), (km1, 0), (0, 0)))[:, -km1:, :]
    return out, S_final, new_conv_state
