"""Unified architecture config for the assigned-architecture zoo.

One dataclass covers dense GQA decoders, MoE, Mamba2 (SSD), hybrid
(Zamba2-style shared attention), encoder-decoder audio backbones (Whisper)
and VLM decoders (Pixtral). Every named config in ``repro.configs`` is an
instance of this.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                 # 0 for attention-free (pure SSM)
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None          # default d_model // num_heads
    qk_norm: bool = False                   # qwen3
    qkv_bias: bool = False                  # qwen2
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # attention variants
    sliding_window: Optional[int] = None    # set => banded attention
    attn_chunk: int = 1024                  # query-chunked (flash-style) attn

    # MoE
    num_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: int = 0                       # per-expert hidden
    router_aux_coef: float = 0.01
    moe_dispatch: str = "allgather"         # "allgather" | "a2a" (§Perf)
    moe_capacity_factor: float = 2.0

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (Zamba2): shared attention block applied every k core layers
    hybrid_attn_every: int = 0

    # encoder-decoder (Whisper backbone; conv/mel frontend is a stub)
    encdec: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500                 # whisper frame count

    # VLM (Pixtral): patch embeddings prepended (ViT frontend is a stub)
    num_image_tokens: int = 0

    dtype: str = "bfloat16"
    remat: bool = True
    # sharding: shard big replicated weight dims over "data" too (FSDP/ZeRO-3)
    fsdp: bool = False

    citation: str = ""

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def uses_attention(self) -> bool:
        return self.num_heads > 0 and self.arch_type != "ssm"

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        d = self.d_model
        n = 0
        n += self.padded_vocab * d          # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * d      # lm head
        per_layer = 0
        if self.arch_type in ("dense", "moe", "vlm", "audio", "hybrid"):
            hd, h, kv = self.hd, self.num_heads, self.num_kv_heads
            attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
            mlp = 3 * d * self.d_ff if self.d_ff else 0
            if self.arch_type == "moe":
                mlp = 3 * d * self.moe_d_ff * self.num_experts + d * self.num_experts
            if self.arch_type == "hybrid":
                # ssm core layers + shared attn block counted once
                ssm = self._ssm_params()
                n += self.num_layers * (ssm + 2 * d)
                n += attn + 3 * d * self.d_ff + 2 * d   # shared block
                n += 2 * d                               # final norm
                return n
            per_layer = attn + mlp + 2 * d
            layers = self.num_layers
            if self.encdec:
                # encoder layers + decoder cross-attn
                enc = attn + 3 * d * self.d_ff + 2 * d
                per_layer += attn + d                   # cross attn + norm
                n += self.num_encoder_layers * enc
            n += layers * per_layer + 2 * d
        elif self.arch_type == "ssm":
            n += self.num_layers * (self._ssm_params() + 2 * d) + 2 * d
        return n

    def _ssm_params(self) -> int:
        d, di, ns = self.d_model, self.ssm_d_inner, self.ssm_state
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * ns + h)
        conv = (di + 2 * ns) * self.ssm_conv
        out_proj = di * d
        return in_proj + conv + out_proj + 2 * h

    def active_param_count(self) -> int:
        """N_active for MoE MODEL_FLOPS."""
        if self.arch_type != "moe":
            return self.param_count()
        d = self.d_model
        hd, h, kv = self.hd, self.num_heads, self.num_kv_heads
        attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
        mlp = 3 * d * self.moe_d_ff * self.experts_per_tok
        n = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return n + self.num_layers * (attn + mlp + 2 * d)
