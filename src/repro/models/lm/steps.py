"""Train / serve step functions for the LM zoo (what the launcher lowers).

``make_train_step`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with next-token cross-entropy (+ MoE aux loss), global-norm clipping and
AdamW. ``batch`` carries "tokens" (B, S) plus per-family extras
("image_embeds" for vlm, "encoder_embeds" for audio) and a "loss_mask".

``make_prefill_step`` / ``make_decode_step`` wrap decode.prefill /
decode.decode_step. These are the objects the multi-pod dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ...optim import adamw_init, adamw_update, clip_by_global_norm
from ...sharding import current_rules, maybe_constrain
from .config import LMConfig
from .decode import decode_step, init_cache, prefill
from .model import forward, init_params


def _chunked_ce(hidden: jnp.ndarray, head: jnp.ndarray, tgt: jnp.ndarray,
                mask: jnp.ndarray, chunk: int = 512) -> jnp.ndarray:
    """Softmax cross-entropy fused with the head projection, scanned over
    sequence chunks: the (B, S, vocab) logits tensor never materializes —
    only a (B, chunk, vocab/model_shards) f32 slice per step. Each chunk is
    checkpointed so its logits are recomputed (not stored) for backward."""
    rules = current_rules()
    b, s, d = hidden.shape
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        tgt = jnp.pad(tgt, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // chunk
    hs = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ts = tgt.reshape(b, nc, chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    vocab_axis = None if rules.pure_fsdp else rules.model_axis

    @jax.checkpoint
    def one(hc, tc, mc):
        logits = (hc @ head).astype(jnp.float32)
        logits = maybe_constrain(
            logits, P(rules.batch_axes, None, vocab_axis))
        lse = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return ((lse - tl) * mc).sum()

    per_chunk = jax.lax.map(lambda args: one(*args), (hs, ts, ms))
    return per_chunk.sum()


def lm_loss(cfg: LMConfig, params: dict, batch: dict
            ) -> tuple[jnp.ndarray, dict]:
    tokens = batch["tokens"]
    hidden, aux = forward(
        cfg, params, tokens,
        image_embeds=batch.get("image_embeds"),
        encoder_embeds=batch.get("encoder_embeds"),
        return_hidden=True)
    # hidden covers [image prefix +] tokens; next-token prediction on text
    n_img = cfg.num_image_tokens if cfg.arch_type == "vlm" else 0
    pred_h = hidden[:, n_img:-1]
    tgt = tokens[:, 1:]
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(tgt, jnp.float32) if mask is None \
        else mask[:, 1:].astype(jnp.float32)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    total = _chunked_ce(pred_h, head, tgt, mask)
    ce = total / jnp.maximum(mask.sum(), 1.0)
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux,
                  "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0))}


def make_train_step(cfg: LMConfig, lr: float = 3e-4, clip: float = 1.0,
                    weight_decay: float = 0.1, microbatches: int = 1):
    """``microbatches > 1`` scans over batch slices accumulating gradients
    (identical math for mean-reduced losses): activation memory scales with
    tokens per microbatch — the fit lever for the biggest train configs."""
    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch), has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def body(acc, b):
                (l, met), g = grad_fn(params, b)
                acc_g, acc_l = acc
                return (jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc_g, g),
                    acc_l + l), met
            zero = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params),
                    jnp.zeros((), jnp.float32))
            (gsum, lsum), mets = jax.lax.scan(body, zero, mb)
            grads = jax.tree.map(
                lambda g, p: (g / microbatches).astype(p.dtype), gsum, params)
            loss = lsum / microbatches
            metrics = jax.tree.map(lambda m: m.mean(axis=0), mets)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr,
                                         weight_decay=weight_decay)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics
    return train_step


def make_prefill_step(cfg: LMConfig, cache_len: int):
    def prefill_step(params, batch):
        return prefill(cfg, params, batch["tokens"], cache_len,
                       image_embeds=batch.get("image_embeds"),
                       encoder_embeds=batch.get("encoder_embeds"))
    return prefill_step


def make_decode_step(cfg: LMConfig):
    def serve_step(params, cache, tokens):
        return decode_step(cfg, params, cache, tokens)
    return serve_step


def init_train_state(cfg: LMConfig, seed: int = 0):
    params = init_params(cfg, jax.random.key(seed))
    return params, adamw_init(params)
