"""Mixture-of-Experts FFN.

Two implementations:

* ``_moe_local`` — single-device math: top-k routing -> flatten (T·k)
  assignments -> argsort by expert -> ``jax.lax.ragged_dot`` grouped matmul
  -> unsort -> weighted combine. No (T, E, C) one-hot dispatch tensor is
  ever materialized. Used on hosts without a mesh (CPU smoke tests) and as
  the correctness oracle.

* ``_moe_sharded`` — the distributed version under ``shard_map``. GSPMD
  cannot partition ``ragged_dot`` (auto-sharding replicates a (T·k, E, ·)
  intermediate — measured multi-TB per device at our shapes), so the
  expert dimension is sharded over "model" *explicitly*:

      all_gather tokens over "model"  (undo sequence sharding)
      -> each rank routes all its data-shard's tokens, keeps only the
         (token, k-slot) assignments owned by its local experts
         [owner-compute: experts are the owners, tokens come to them]
      -> capacity-bounded sort-compaction -> local ragged_dot (static
         shapes, no GSPMD involvement)
      -> scatter back, weight, psum_scatter over "model"

  This is the **allgather-EP baseline** (communication = one all-gather +
  one reduce-scatter of activations per MoE layer); the §Perf pass
  evaluates all-to-all dispatch against it. Per-expert capacity is
  ``cf · T·k / E`` (overflow tokens dropped, standard practice; cf=2).

Experts whose count doesn't divide the model axis (granite's 40) are padded
with never-routed dummy experts up to the next multiple.

Aux load-balance loss follows Switch/GShard: E · Σ_e f_e · p_e.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ...sharding import current_rules
from ...sharding.rules import AXIS_SIZES, _active_mesh

CAPACITY_FACTOR = 2.0


def _route(xt, router, k):
    """xt: (T, d) -> (top_p (T,k) f32-normalized, top_i (T,k), probs)."""
    logits = (xt @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_i, probs


def _aux_loss(probs, top_i, e):
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=(0, 1))
    mean_prob = probs.mean(axis=0)
    return e * jnp.sum(frac_tokens * mean_prob)


def _moe_local(p: dict, x: jnp.ndarray, cfg):
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_tok
    xt = x.reshape(b * s, d)
    t = xt.shape[0]
    top_p, top_i, probs = _route(xt, p["router"], k)
    top_p = top_p.astype(x.dtype)

    flat_expert = top_i.reshape(-1)
    order = jnp.argsort(flat_expert)
    xs = xt[order // k]
    group_sizes = jnp.bincount(flat_expert, length=e).astype(jnp.int32)

    h = jax.nn.silu(jax.lax.ragged_dot(xs, p["experts_gate"], group_sizes))
    h = h * jax.lax.ragged_dot(xs, p["experts_up"], group_sizes)
    y = jax.lax.ragged_dot(h, p["experts_down"], group_sizes)

    y_unsorted = jnp.zeros_like(y).at[order].set(y)
    out = jnp.einsum("tkd,tk->td", y_unsorted.reshape(t, k, d), top_p)
    return out.reshape(b, s, d), _aux_loss(probs, top_i, e)


def _local_expert_ffn(xs, gate, up, down, group_sizes):
    h = jax.nn.silu(jax.lax.ragged_dot(xs, gate, group_sizes))
    h = h * jax.lax.ragged_dot(xs, up, group_sizes)
    return jax.lax.ragged_dot(h, down, group_sizes)


def _moe_sharded(p: dict, x: jnp.ndarray, cfg, mesh):
    rules = current_rules()
    ba = rules.batch_axes
    m = rules.model_axis
    msize = AXIS_SIZES[m]
    e, k = cfg.num_experts, cfg.experts_per_tok
    e_pad = -(-e // msize) * msize
    e_loc = e_pad // msize
    b, s, d = x.shape
    seq_sharded = s % msize == 0
    ba_size = _ba_size(ba)
    batch_sharded = b % ba_size == 0
    b_loc = b // ba_size if batch_sharded else b
    t = b_loc * s                       # tokens per data shard (post-gather)
    # capacity per expert is relative to the REAL expert count: padded dummy
    # experts are never routed to, so the live experts carry T·k/e each
    cap = int(cfg.moe_capacity_factor * t * k / e) + 1
    l_static = cap * e_loc

    def pad_e(w):
        return jnp.pad(w, ((0, e_pad - e),) + ((0, 0),) * (w.ndim - 1))

    gate, up, down = (pad_e(p["experts_gate"]), pad_e(p["experts_up"]),
                      pad_e(p["experts_down"]))

    x_spec = P(ba if batch_sharded else None,
               m if seq_sharded else None, None)
    w_spec = P(m, None, None)

    def body(xb, router, gate_l, up_l, down_l):
        # xb: (b_loc, s_loc, d) — gather the full data-shard token set
        if seq_sharded:
            xg = jax.lax.all_gather(xb, m, axis=1, tiled=True)
        else:
            xg = xb
        bl, sl, _ = xg.shape
        xt = xg.reshape(bl * sl, d)
        tl = xt.shape[0]
        top_p, top_i, probs = _route(xt, router, k)
        top_p = top_p.astype(xb.dtype)

        r = jax.lax.axis_index(m)
        lo = r * e_loc
        flat_expert = top_i.reshape(-1)                       # (T*k,)
        local_id = flat_expert - lo
        is_local = (local_id >= 0) & (local_id < e_loc)
        # capacity-slot packing: token j of local expert i goes to slot
        # i*cap + (its rank within expert i); overflow beyond cap dropped.
        # Fixed slots turn the expert FFN into ONE dense batched einsum —
        # no ragged_dot (XLA lowers ragged_dot densely over the expert dim
        # on some backends: measured (E_loc, L, d) f32 buffers, 38 GB/block).
        key = jnp.where(is_local, local_id, e_loc)
        order = jnp.argsort(key)                              # (T*k,)
        sorted_key = key[order]
        gsz = jnp.bincount(sorted_key, length=e_loc + 1)[:e_loc]
        starts = jnp.cumsum(gsz) - gsz
        pos_in_group = jnp.arange(tl * k) - starts[
            jnp.clip(sorted_key, 0, e_loc - 1)]
        keep = (sorted_key < e_loc) & (pos_in_group < cap)
        slot = jnp.where(keep, sorted_key * cap + pos_in_group, l_static)
        token_of_row = (order // k).astype(jnp.int32)         # (T*k,)
        # slot -> source token (sentinel tl for empty slots), THEN gather
        # just the L kept rows — gathering xt[token_of_row] first would
        # materialize a (T*k, d) buffer (k× the token set, f32 in backward)
        slot_token = jnp.full((l_static + 1,), tl, jnp.int32).at[slot].set(
            token_of_row, mode="drop")[:l_static]             # (L,)
        slot_valid = slot_token < tl
        xs = jnp.where(slot_valid[:, None],
                       xt[jnp.minimum(slot_token, tl - 1)], 0)
        xs = xs.reshape(e_loc, cap, d)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, gate_l))
        h = h * jnp.einsum("ecd,edf->ecf", xs, up_l)
        y = jnp.einsum("ecf,efd->ecd", h, down_l)             # (E_loc,cap,d)
        y = y.reshape(l_static, d)

        # weight each slot by its router prob and scatter-add straight into
        # (T, d) — a (T*k, d) scatter buffer would be k× larger
        w_rows = top_p.reshape(-1)[order]                     # (T*k,)
        slot_w = jnp.zeros((l_static + 1,), w_rows.dtype).at[slot].set(
            w_rows, mode="drop")[:l_static]
        out = jnp.zeros((tl, d), y.dtype).at[
            jnp.where(slot_valid, slot_token, tl)].add(
            y * slot_w[:, None], mode="drop")
        out = out.reshape(bl, sl, d)
        if seq_sharded:
            out = jax.lax.psum_scatter(out, m, scatter_dimension=1,
                                       tiled=True)
        else:
            out = jax.lax.psum(out, m)
        aux = _aux_loss(probs, top_i, e)
        if batch_sharded:
            aux = jax.lax.pmean(aux, ba)
        return out, aux

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, p["router"], gate, up, down)
    return out, aux


def _slot_pack(xt, assign_key, n_groups, cap, tl, k, top_p):
    """Shared slot-packing: sort rows by ``assign_key`` (values >= n_groups
    are dropped), keep <= cap per group at fixed slots group*cap + rank.

    Returns (slot_token (n_groups*cap,), slot_w, slot_key) where slot_token
    is the source token (sentinel tl for empty slots), slot_w the router
    weight and slot_key the original assign value per slot."""
    l_static = n_groups * cap
    order = jnp.argsort(assign_key)
    sorted_key = assign_key[order]
    gsz = jnp.bincount(sorted_key, length=n_groups + 1)[:n_groups]
    starts = jnp.cumsum(gsz) - gsz
    pos = jnp.arange(sorted_key.shape[0]) - starts[
        jnp.clip(sorted_key, 0, n_groups - 1)]
    keep = (sorted_key < n_groups) & (pos < cap)
    slot = jnp.where(keep, sorted_key * cap + pos, l_static)
    token_of_row = (order // k).astype(jnp.int32)
    slot_token = jnp.full((l_static + 1,), tl, jnp.int32).at[slot].set(
        token_of_row, mode="drop")[:l_static]
    w_rows = top_p.reshape(-1)[order]
    slot_w = jnp.zeros((l_static + 1,), w_rows.dtype).at[slot].set(
        w_rows, mode="drop")[:l_static]
    return slot, order, slot_token, slot_w


def _moe_sharded_a2a(p: dict, x: jnp.ndarray, cfg, mesh):
    """All-to-all expert dispatch (§Perf beyond-paper optimization).

    Unlike the allgather baseline — which replicates every data-shard's
    full token set across the model axis (all_gather (T,d)) and reduces
    contributions back (psum_scatter (T,d)) — each rank here routes only
    its OWN T/msize tokens and ships exactly the rows bound for each expert
    owner: 2 all-to-alls of (msize, C2, d) with C2 ≈ cf·T·k/msize².
    Per-layer bytes drop from (1+1)·T·d to 2·cf·(k/msize)·T·d — a
    (msize/(cf·k))× collective reduction when k < msize.

    Tokens keep their expert id through the wire so the receiver re-packs
    per local expert; both capacity stages drop overflow (standard).
    """
    rules = current_rules()
    ba = rules.batch_axes
    m = rules.model_axis
    msize = AXIS_SIZES[m]
    e, k = cfg.num_experts, cfg.experts_per_tok
    cf = cfg.moe_capacity_factor
    e_pad = -(-e // msize) * msize
    e_loc = e_pad // msize
    b, s, d = x.shape
    ba_size = _ba_size(ba)
    b_loc = b // ba_size
    s_loc = s // msize
    t_loc = b_loc * s_loc                       # tokens per DEVICE
    # per-(src,dst-rank) wire capacity and per-expert compute capacity.
    # Both scale with the REAL expert count e: padded dummy experts receive
    # no tokens, so a rank owning e_loc experts sees ~t_loc·k·e_loc/e rows
    # and each live expert ~cf·T·k/e.
    c2 = int(cf * t_loc * k * e_loc / e) + 1
    cap = int(cf * t_loc * k * msize / e) + 1   # rows/expert at receiver

    def pad_e(w):
        return jnp.pad(w, ((0, e_pad - e),) + ((0, 0),) * (w.ndim - 1))

    gate, up, down = (pad_e(p["experts_gate"]), pad_e(p["experts_up"]),
                      pad_e(p["experts_down"]))
    x_spec = P(ba, m, None)
    w_spec = P(m, None, None)

    def body(xb, router, gate_l, up_l, down_l):
        bl, sl, _ = xb.shape
        xt = xb.reshape(bl * sl, d)
        tl = xt.shape[0]
        top_p, top_i, probs = _route(xt, router, k)
        top_p = top_p.astype(xb.dtype)

        flat_expert = top_i.reshape(-1)                     # (tl*k,)
        dest = flat_expert // e_loc                         # owner rank
        slot, order, slot_token, slot_w = _slot_pack(
            xt, dest, msize, c2, tl, k, top_p)
        l1 = msize * c2
        valid1 = slot_token < tl
        send_x = jnp.where(valid1[:, None],
                           xt[jnp.minimum(slot_token, tl - 1)], 0)
        send_eid = jnp.full((l1 + 1,), e_pad, jnp.int32).at[slot].set(
            flat_expert[order].astype(jnp.int32), mode="drop")[:l1]

        # ship rows + expert ids to the owners
        recv_x = jax.lax.all_to_all(send_x.reshape(msize, c2, d), m,
                                    split_axis=0, concat_axis=0, tiled=False)
        recv_eid = jax.lax.all_to_all(send_eid.reshape(msize, c2), m,
                                      split_axis=0, concat_axis=0,
                                      tiled=False)
        recv_x = recv_x.reshape(msize * c2, d)
        recv_eid = recv_eid.reshape(msize * c2)

        # receiver-side per-expert packing (local expert ids)
        r = jax.lax.axis_index(m)
        local_id = recv_eid - r * e_loc
        is_local = (local_id >= 0) & (local_id < e_loc) & (recv_eid < e_pad)
        key2 = jnp.where(is_local, local_id, e_loc)
        order2 = jnp.argsort(key2)
        sorted2 = key2[order2]
        gsz2 = jnp.bincount(sorted2, length=e_loc + 1)[:e_loc]
        starts2 = jnp.cumsum(gsz2) - gsz2
        pos2 = jnp.arange(sorted2.shape[0]) - starts2[
            jnp.clip(sorted2, 0, e_loc - 1)]
        keep2 = (sorted2 < e_loc) & (pos2 < cap)
        slot2 = jnp.where(keep2, sorted2 * cap + pos2, e_loc * cap)
        row2 = order2.astype(jnp.int32)
        slot2_row = jnp.full((e_loc * cap + 1,), msize * c2,
                             jnp.int32).at[slot2].set(row2, mode="drop")[:-1]
        v2 = slot2_row < msize * c2
        xs = jnp.where(v2[:, None],
                       recv_x[jnp.minimum(slot2_row, msize * c2 - 1)], 0)
        xs = xs.reshape(e_loc, cap, d)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, gate_l))
        h = h * jnp.einsum("ecd,edf->ecf", xs, up_l)
        y = jnp.einsum("ecf,efd->ecd", h, down_l).reshape(e_loc * cap, d)

        # scatter back to wire layout, return all_to_all, combine at sender
        y_wire = jnp.zeros((msize * c2, d), y.dtype).at[slot2_row].add(
            jnp.where(v2[:, None], y, 0), mode="drop")
        back = jax.lax.all_to_all(y_wire.reshape(msize, c2, d), m,
                                  split_axis=0, concat_axis=0, tiled=False)
        back = back.reshape(msize * c2, d)
        out = jnp.zeros((tl, d), y.dtype).at[
            jnp.where(valid1, slot_token, tl)].add(
            back * slot_w[:, None], mode="drop")
        out = out.reshape(bl, sl, d)
        aux = jax.lax.pmean(_aux_loss(probs, top_i, e), ba)
        aux = jax.lax.pmean(aux, m)
        return out, aux

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, p["router"], gate, up, down)
    return out, aux


def _ba_size(ba) -> int:
    n = 1
    for a in ba:
        n *= AXIS_SIZES.get(a, 1)
    return n


def moe_block(p: dict, x: jnp.ndarray, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss). params: router (d, E),
    experts_gate/experts_up (E, d, ff), experts_down (E, ff, d)."""
    mesh = _active_mesh()
    if mesh is None or getattr(mesh, "empty", True):
        return _moe_local(p, x, cfg)
    if current_rules().pure_fsdp:
        # ZeRO-3 mode has no model axis for experts; let GSPMD handle the
        # local formulation (experiment scope: dense archs — see §Perf)
        return _moe_local(p, x, cfg)
    if (cfg.moe_dispatch == "a2a"
            and x.shape[1] % AXIS_SIZES[current_rules().model_axis] == 0
            and x.shape[0] % _ba_size(current_rules().batch_axes) == 0):
        return _moe_sharded_a2a(p, x, cfg, mesh)
    return _moe_sharded(p, x, cfg, mesh)
