"""Named synthetic datasets, scaled to this machine.

Mirrors the paper's Table 1 roles:
  * ``product-sim``  — medium power-law graph (ogbn-products stand-in)
  * ``amazon-sim``   — denser medium graph (Amazon stand-in)
  * ``papers-sim``   — the "large" graph for scalability runs (scaled down
                       to host memory; structure/degree-skew preserved)
  * ``mag-sim``      — heterogeneous (typed edges) graph for RGCN
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .csr import CSRGraph
from .hetero import HeteroSchema
from .generate import (community_labels_and_features, mag_graph,
                       planted_partition_graph, random_features, rmat_graph,
                       train_val_test_split)


@dataclasses.dataclass
class GraphDataset:
    name: str
    graph: CSRGraph
    feats: np.ndarray              # (n, d) node features
    labels: np.ndarray             # (n,) int64
    split_mask: np.ndarray         # (n,) int8: 1 train / 2 val / 3 test
    num_classes: int
    schema: Optional[HeteroSchema] = None   # set => first-class heterograph

    @property
    def train_nids(self) -> np.ndarray:
        return np.nonzero(self.split_mask == 1)[0].astype(np.int64)

    @property
    def val_nids(self) -> np.ndarray:
        return np.nonzero(self.split_mask == 2)[0].astype(np.int64)

    @property
    def test_nids(self) -> np.ndarray:
        return np.nonzero(self.split_mask == 3)[0].astype(np.int64)


_REGISTRY = {}


def register(name):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_dataset(name: str, **kw) -> GraphDataset:
    if name not in _REGISTRY:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kw)


def list_datasets():
    return sorted(_REGISTRY)


def _make(name, g, num_classes, feat_dim, seed, train_frac=0.1):
    labels, feats = community_labels_and_features(g, num_classes, feat_dim, seed=seed)
    mask = train_val_test_split(g.num_nodes, train_frac=train_frac, seed=seed)
    return GraphDataset(name=name, graph=g, feats=feats, labels=labels,
                        split_mask=mask, num_classes=num_classes)


@register("product-sim")
def product_sim(scale: int = 14, seed: int = 0) -> GraphDataset:
    g = rmat_graph(scale, edge_factor=12, seed=seed)
    return _make("product-sim", g, num_classes=16, feat_dim=100, seed=seed)


@register("amazon-sim")
def amazon_sim(scale: int = 13, seed: int = 1) -> GraphDataset:
    g = rmat_graph(scale, edge_factor=32, seed=seed)
    return _make("amazon-sim", g, num_classes=16, feat_dim=200, seed=seed,
                 train_frac=0.5)


@register("papers-sim")
def papers_sim(scale: int = 16, seed: int = 2) -> GraphDataset:
    g = rmat_graph(scale, edge_factor=10, seed=seed)
    return _make("papers-sim", g, num_classes=32, feat_dim=128, seed=seed,
                 train_frac=0.01)


@register("mag-sim")
def mag_sim(scale: int = 14, seed: int = 3, num_etypes: int = 4) -> GraphDataset:
    g = rmat_graph(scale, edge_factor=12, seed=seed, num_etypes=num_etypes,
                   num_ntypes=3)
    return _make("mag-sim", g, num_classes=16, feat_dim=128, seed=seed,
                 train_frac=0.01)


@register("mag-hetero")
def mag_hetero(scale: int = 12, seed: int = 5) -> GraphDataset:
    """First-class heterograph (schema attached): 3 ntypes / 4 etypes,
    labels + train/val/test split on papers only (the MAG-LSC task)."""
    g, schema = mag_graph(scale, seed=seed)
    labels, feats = community_labels_and_features(g, 16, 64, seed=seed)
    mask = train_val_test_split(g.num_nodes, train_frac=0.1, seed=seed)
    papers = g.ntypes == schema.ntype_id("paper")
    mask[~papers] = 0              # only papers carry the prediction task
    return GraphDataset(name="mag-hetero", graph=g, feats=feats,
                        labels=labels, split_mask=mask, num_classes=16,
                        schema=schema)


@register("cluster-sim")
def cluster_sim(num_nodes: int = 20000, num_blocks: int = 64, seed: int = 4) -> GraphDataset:
    g = planted_partition_graph(num_nodes, num_blocks, seed=seed)
    return _make("cluster-sim", g, num_classes=16, feat_dim=64, seed=seed)
