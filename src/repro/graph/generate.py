"""Synthetic graph generators.

The paper evaluates on power-law natural graphs (ogbn-products, Amazon,
ogbn-papers100M, MAG-LSC). Those datasets are not available offline, so the
benchmark harness uses two families of synthetic graphs whose properties
drive the same system behaviours:

* ``rmat`` — recursive-matrix power-law graphs (degree skew => imbalanced
  mini-batches, hub HALO explosion), the stress case for multi-constraint
  balancing and the async pipeline.
* ``planted`` — planted-partition (stochastic block) graphs with strong
  community structure, the best case for min-edge-cut partitioning (METIS
  locality wins show up clearly, mirroring Fig. 14's partition bars).
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, from_edges, to_undirected
from .hetero import HeteroSchema, fused_from_typed


def rmat_graph(scale: int, edge_factor: int = 16, *,
               a: float = 0.57, b: float = 0.19, c: float = 0.19,
               seed: int = 0, undirected: bool = True,
               num_etypes: int = 1, num_ntypes: int = 1) -> CSRGraph:
    """R-MAT generator: 2**scale nodes, edge_factor * n edges."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for level in range(scale):
        r = rng.random(m)
        # quadrant probabilities a, b, c, d
        go_right = r >= a + b          # dst high bit
        go_down = ((r >= a) & (r < a + b)) | (r >= a + b + c)  # src high bit
        src |= go_down.astype(np.int64) << level
        dst |= go_right.astype(np.int64) << level
    # permute node ids so degree isn't correlated with id
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    # drop self loops, dedup
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src * n + dst
    _, uniq = np.unique(key, return_index=True)
    src, dst = src[uniq], dst[uniq]
    etypes = None
    if num_etypes > 1:
        etypes = rng.integers(0, num_etypes, size=len(src)).astype(np.int32)
    ntypes = None
    if num_ntypes > 1:
        ntypes = rng.integers(0, num_ntypes, size=n).astype(np.int32)
    g = from_edges(src, dst, n, etypes=etypes, ntypes=ntypes,
                   num_etypes=num_etypes, num_ntypes=num_ntypes)
    return to_undirected(g) if undirected else g


def planted_partition_graph(num_nodes: int, num_blocks: int, *,
                            p_in: float = 12.0, p_out: float = 1.0,
                            seed: int = 0,
                            num_etypes: int = 1) -> CSRGraph:
    """Stochastic block model, expected degree p_in within / p_out across.

    p_in / p_out are *expected per-node edge counts* to make scaling
    intuitive (not probabilities).
    """
    rng = np.random.default_rng(seed)
    blocks = rng.integers(0, num_blocks, size=num_nodes).astype(np.int64)
    # within-block edges
    m_in = int(num_nodes * p_in / 2)
    m_out = int(num_nodes * p_out / 2)
    # sample pairs within the same block: pick a node, pick another from its block
    order = np.argsort(blocks, kind="stable")
    sorted_nodes = order
    block_start = np.searchsorted(blocks[order], np.arange(num_blocks))
    block_end = np.searchsorted(blocks[order], np.arange(num_blocks), side="right")
    u = rng.integers(0, num_nodes, size=m_in)
    bu = blocks[u]
    lo, hi = block_start[bu], block_end[bu]
    v = sorted_nodes[lo + (rng.random(m_in) * (hi - lo)).astype(np.int64)]
    src_in, dst_in = u, v
    src_out = rng.integers(0, num_nodes, size=m_out)
    dst_out = rng.integers(0, num_nodes, size=m_out)
    src = np.concatenate([src_in, src_out])
    dst = np.concatenate([dst_in, dst_out])
    keep = src != dst
    src, dst = src[keep], dst[keep]
    etypes = None
    if num_etypes > 1:
        etypes = rng.integers(0, num_etypes, size=len(src)).astype(np.int32)
    g = from_edges(src, dst, num_nodes, etypes=etypes, num_etypes=num_etypes)
    return to_undirected(g)


def _powerlaw_targets(rng: np.random.Generator, num_edges: int,
                      num_targets: int, alpha: float = 0.8) -> np.ndarray:
    """Draw ``num_edges`` endpoints over [0, num_targets) with a Zipf-ish
    skew (hub targets), the degree profile of citation/authorship graphs."""
    u = rng.random(num_edges)
    ranks = (num_targets * u ** (1.0 / (1.0 - alpha))).astype(np.int64)
    ranks = np.minimum(ranks, num_targets - 1)
    # permute so hub ids aren't correlated with id order
    perm = rng.permutation(num_targets)
    return perm[ranks]


def mag_graph(scale: int = 12, *, authors_per_paper: float = 3.0,
              cites_per_paper: float = 8.0, inst_frac: float = 0.02,
              author_frac: float = 1.5, seed: int = 0
              ) -> tuple[CSRGraph, HeteroSchema]:
    """Synthetic OGBN-MAG-like heterograph: 3 node types, 4 relations.

        paper       --cites-->      paper    (power-law in-degree)
        author      --writes-->     paper
        paper       --rev_writes--> author   (reverse of writes: lets the
                                             sampler expand author frontiers)
        institution --employs-->    author   (institution features reach
                                             papers via author hops)

    2**scale papers; authors ~ ``author_frac``×papers, institutions
    ~ ``inst_frac``×papers. Edges point *toward* the prediction targets
    (message-passing direction): the trainer samples in-neighbors of paper
    seeds, so every relation's src type can enter a paper-rooted MFG,
    mirroring the paper's MAG-LSC workload where labels live on papers only.
    """
    rng = np.random.default_rng(seed)
    n_paper = 1 << scale
    n_author = int(n_paper * author_frac)
    n_inst = max(int(n_paper * inst_frac), 4)

    # cites: paper -> paper, power-law cited-degree, no self-cites
    m_cite = int(n_paper * cites_per_paper)
    cite_src = rng.integers(0, n_paper, size=m_cite)
    cite_dst = _powerlaw_targets(rng, m_cite, n_paper)
    keep = cite_src != cite_dst
    cite_src, cite_dst = cite_src[keep], cite_dst[keep]

    # writes: author -> paper (each paper gets ~authors_per_paper authors,
    # authors have power-law productivity)
    m_wr = int(n_paper * authors_per_paper)
    wr_author = _powerlaw_targets(rng, m_wr, n_author)
    wr_paper = rng.integers(0, n_paper, size=m_wr)

    # employs: institution -> author (hub institutions, one each per author)
    emp_author = np.arange(n_author, dtype=np.int64)
    emp_inst = _powerlaw_targets(rng, n_author, n_inst)

    g, schema = fused_from_typed(
        {"paper": n_paper, "author": n_author, "institution": n_inst},
        [(("paper", "cites", "paper"), cite_src, cite_dst),
         (("author", "writes", "paper"), wr_author, wr_paper),
         (("paper", "rev_writes", "author"), wr_paper, wr_author),
         (("institution", "employs", "author"), emp_inst, emp_author)],
    )
    return g, schema


def random_features(num_nodes: int, dim: int, seed: int = 0,
                    dtype=np.float32) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((num_nodes, dim)).astype(dtype)


def community_labels_and_features(g: CSRGraph, num_classes: int, dim: int, *,
                                  seed: int = 0, noise: float = 1.0):
    """Learnable synthetic node-classification task.

    Labels come from spectral-ish communities (here: label propagation from
    random seeds over the real graph structure), features are a noisy
    class-conditioned Gaussian mixture *plus* neighbor mixing, so that a GNN
    that actually aggregates neighbors beats an MLP — which makes the
    convergence benchmarks (Fig. 2/13 analogues) meaningful.
    """
    rng = np.random.default_rng(seed)
    n = g.num_nodes
    labels = rng.integers(0, num_classes, size=n).astype(np.int64)
    # few rounds of majority propagation to create clustered labels
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(g.indptr))
    dst = g.indices
    for _ in range(3):
        onehot = np.zeros((n, num_classes), dtype=np.float32)
        onehot[np.arange(n), labels] = 1.0
        agg = np.zeros((n, num_classes), dtype=np.float32)
        np.add.at(agg, dst, onehot[src])
        agg += onehot * 0.5 + rng.random((n, num_classes)) * 0.1
        labels = agg.argmax(axis=1).astype(np.int64)
    centers = rng.standard_normal((num_classes, dim)).astype(np.float32)
    feats = centers[labels] + noise * rng.standard_normal((n, dim)).astype(np.float32)
    # one hop of smoothing: makes the signal partially *structural*
    deg = np.maximum(np.diff(g.indptr), 1).astype(np.float32)
    smooth = np.zeros_like(feats)
    np.add.at(smooth, dst, feats[src])
    feats = 0.5 * feats + 0.5 * smooth / deg[:, None]
    return labels, feats


def train_val_test_split(num_nodes: int, *, train_frac: float = 0.1,
                         val_frac: float = 0.05, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_nodes)
    n_tr = int(num_nodes * train_frac)
    n_va = int(num_nodes * val_frac)
    mask = np.zeros(num_nodes, dtype=np.int8)  # 0 none, 1 train, 2 val, 3 test
    mask[perm[:n_tr]] = 1
    mask[perm[n_tr:n_tr + n_va]] = 2
    mask[perm[n_tr + n_va:n_tr + n_va + n_tr]] = 3
    return mask
