"""Compressed-sparse-row graph container.

This is the substrate DistDGLv2 samples from: the *structure* lives in host
memory as NumPy arrays (the paper keeps it in CPU memory), while mini-batch
tensors are the only thing shipped to the accelerator.

Supports optional edge types (for RGCN-style heterogeneous relations) and
optional node types. The fused single-ID-space layout is deliberate:
full heterographs are a *view* over it (``graph.hetero.HeteroCSRGraph``),
and per-type node/edge ID spaces appear only at the KVStore boundary via
the partition book's per-type policies (see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    """Directed graph in CSR form (out-neighbors), host-resident.

    indptr:  (n+1,) int64 — row offsets
    indices: (nnz,) int32/int64 — destination node of each out-edge
    edge_ids:(nnz,) int64 — global edge IDs (identity if None at build)
    etypes:  (nnz,) int32 or None — edge type per edge (RGCN)
    ntypes:  (n,)  int32 or None — node type per node (hetero balancing)
    """

    indptr: np.ndarray
    indices: np.ndarray
    edge_ids: np.ndarray
    etypes: Optional[np.ndarray] = None
    ntypes: Optional[np.ndarray] = None
    num_etypes: int = 1
    num_ntypes: int = 1

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    def out_degree(self, u: Optional[np.ndarray] = None) -> np.ndarray:
        deg = np.diff(self.indptr)
        return deg if u is None else deg[u]

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u] : self.indptr[u + 1]]

    def edge_range(self, u: int) -> tuple[int, int]:
        return int(self.indptr[u]), int(self.indptr[u + 1])

    # ------------------------------------------------------------------
    def reverse(self) -> "CSRGraph":
        """Transpose (in-neighbor CSR), preserving edge ids/types."""
        src = np.repeat(np.arange(self.num_nodes, dtype=self.indices.dtype),
                        np.diff(self.indptr))
        return from_edges(self.indices, src, self.num_nodes,
                          edge_ids=self.edge_ids, etypes=self.etypes,
                          ntypes=self.ntypes, num_etypes=self.num_etypes,
                          num_ntypes=self.num_ntypes)

    def subgraph(self, nodes: np.ndarray) -> tuple["CSRGraph", np.ndarray]:
        """Node-induced subgraph with relabeled IDs.

        Returns (sub, orig_edge_positions). ``nodes`` defines the new ID
        order: new id i == old id nodes[i].
        """
        nodes = np.asarray(nodes)
        n = self.num_nodes
        mapping = np.full(n, -1, dtype=np.int64)
        mapping[nodes] = np.arange(len(nodes), dtype=np.int64)
        # Gather all out edges of `nodes`, keep those landing inside.
        counts = np.diff(self.indptr)[nodes]
        starts = self.indptr[nodes]
        pos = _expand_ranges(starts, counts)
        dst = self.indices[pos]
        keep = mapping[dst] >= 0
        pos = pos[keep]
        dst_new = mapping[dst[keep]]
        src_new = np.repeat(np.arange(len(nodes), dtype=np.int64), counts)[keep]
        sub = from_edges(
            src_new, dst_new, len(nodes),
            edge_ids=self.edge_ids[pos],
            etypes=None if self.etypes is None else self.etypes[pos],
            ntypes=None if self.ntypes is None else self.ntypes[nodes],
            num_etypes=self.num_etypes, num_ntypes=self.num_ntypes,
        )
        return sub, pos


def _expand_ranges(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Vectorized concatenation of [s, s+c) ranges."""
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    ends = np.cumsum(counts)
    return np.repeat(starts, counts) + (np.arange(total) - np.repeat(ends - counts, counts))


def from_edges(src: np.ndarray, dst: np.ndarray, num_nodes: int, *,
               edge_ids: Optional[np.ndarray] = None,
               etypes: Optional[np.ndarray] = None,
               ntypes: Optional[np.ndarray] = None,
               num_etypes: int = 1, num_ntypes: int = 1,
               sort: bool = True) -> CSRGraph:
    """Build a CSRGraph from a COO edge list (src -> dst)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    m = len(src)
    if edge_ids is None:
        edge_ids = np.arange(m, dtype=np.int64)
    else:
        edge_ids = np.asarray(edge_ids, dtype=np.int64)
    if sort:
        order = np.argsort(src, kind="stable")
        src, dst, edge_ids = src[order], dst[order], edge_ids[order]
        if etypes is not None:
            etypes = np.asarray(etypes)[order]
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSRGraph(indptr=indptr, indices=dst.astype(np.int64),
                    edge_ids=edge_ids,
                    etypes=None if etypes is None else etypes.astype(np.int32),
                    ntypes=None if ntypes is None else np.asarray(ntypes, dtype=np.int32),
                    num_etypes=num_etypes, num_ntypes=num_ntypes)


def to_coo(g: CSRGraph) -> tuple[np.ndarray, np.ndarray]:
    src = np.repeat(np.arange(g.num_nodes, dtype=np.int64), np.diff(g.indptr))
    return src, g.indices.astype(np.int64)


def to_undirected(g: CSRGraph) -> CSRGraph:
    """Symmetrize; edge ids are reassigned, types follow the first
    occurrence. Parallel duplicates (when both (u,v) and (v,u) existed)
    are collapsed — samplers assume simple adjacency lists."""
    src, dst = to_coo(g)
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    et = None if g.etypes is None else np.concatenate([g.etypes, g.etypes])
    key = s2 * g.num_nodes + d2
    _, first = np.unique(key, return_index=True)
    s2, d2 = s2[first], d2[first]
    et = None if et is None else et[first]
    return from_edges(s2, d2, g.num_nodes, etypes=et, ntypes=g.ntypes,
                      num_etypes=g.num_etypes, num_ntypes=g.num_ntypes)
