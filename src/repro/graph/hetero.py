"""First-class heterogeneous graph schema and typed view (DistDGL's
heterograph API, adapted to the fused-ID storage this repro uses).

The storage substrate stays a single fused :class:`~repro.graph.csr.CSRGraph`
— one node-ID space, one CSR, per-edge ``etypes`` and per-node ``ntypes``
arrays — because that is what the partitioner, KVStore relabeling and
samplers operate on. What this module adds on top:

* :class:`HeteroSchema` — the *names*: node types and canonical edge types
  ``(src_ntype, relation, dst_ntype)``. Every typed component (partition
  policies, KVStore tensors, per-relation fanouts, RGCN weights) is keyed by
  this schema, so the homogeneous path is literally the degenerate
  single-ntype/single-etype schema.
* :class:`HeteroCSRGraph` — a view over the fused graph exposing
  per-relation adjacency (lazily materialized sub-CSRs) and per-type node
  sets, plus schema validation (every typed edge must connect the node types
  its canonical type declares).

See DESIGN.md §3 for how typed IDs map onto the fused ID space after
partitioning.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from .csr import CSRGraph


CanonicalEtype = Tuple[str, str, str]     # (src_ntype, relation, dst_ntype)
EtypeKey = Union[int, str, CanonicalEtype]


@dataclasses.dataclass(frozen=True)
class HeteroSchema:
    """Node types + canonical edge types of a heterogeneous graph.

    Type IDs are positions in these tuples; the fused graph's ``ntypes`` /
    ``etypes`` arrays hold those IDs. Relation names must be unique (DGL
    allows ambiguous short names; we don't — it keeps KVStore tensor names
    and fanout dicts unambiguous).
    """

    ntypes: Tuple[str, ...]
    canonical_etypes: Tuple[CanonicalEtype, ...]

    def __post_init__(self):
        rels = [c[1] for c in self.canonical_etypes]
        if len(set(rels)) != len(rels):
            raise ValueError(f"duplicate relation names: {rels}")
        for s, r, d in self.canonical_etypes:
            if s not in self.ntypes or d not in self.ntypes:
                raise ValueError(f"canonical etype ({s},{r},{d}) references "
                                 f"unknown ntype (have {self.ntypes})")

    @property
    def num_ntypes(self) -> int:
        return len(self.ntypes)

    @property
    def num_etypes(self) -> int:
        return len(self.canonical_etypes)

    @property
    def etypes(self) -> Tuple[str, ...]:
        return tuple(c[1] for c in self.canonical_etypes)

    def ntype_id(self, name: str) -> int:
        return self.ntypes.index(name)

    def etype_id(self, key: EtypeKey) -> int:
        """Accepts an int ID, a relation name, or a canonical triple."""
        if isinstance(key, int):
            if not 0 <= key < self.num_etypes:
                raise KeyError(key)
            return key
        if isinstance(key, tuple):
            return self.canonical_etypes.index(key)
        return self.etypes.index(key)

    def src_ntype_id(self, et: int) -> int:
        return self.ntype_id(self.canonical_etypes[et][0])

    def dst_ntype_id(self, et: int) -> int:
        return self.ntype_id(self.canonical_etypes[et][2])

    def normalize_fanout(self, fanout: Union[int, Mapping[EtypeKey, int]]
                         ) -> np.ndarray:
        """One layer's fanout -> dense (num_etypes,) int array.

        An int applies to every relation (DGL's semantics); a mapping gives
        per-relation fanouts, missing relations get 0 (not sampled).
        """
        out = np.zeros(self.num_etypes, dtype=np.int64)
        if isinstance(fanout, (int, np.integer)):
            out[:] = int(fanout)
        else:
            for k, v in fanout.items():
                out[self.etype_id(k)] = int(v)
        return out

    @staticmethod
    def homogeneous() -> "HeteroSchema":
        """The degenerate schema every untyped graph implicitly has."""
        return HeteroSchema(ntypes=("_N",),
                            canonical_etypes=(("_N", "_E", "_N"),))


class HeteroCSRGraph:
    """Typed view over a fused CSRGraph (storage is shared, never copied).

    ``g`` keeps the out-neighbor CSR exactly as before; this view adds
    per-relation adjacency (``relation_coo``/``relation_csr``, lazily built
    and cached) and per-ntype node sets. All IDs remain fused global IDs —
    type-local IDs only appear at the KVStore boundary (see
    ``core.partition.book.build_typed_partition``).
    """

    def __init__(self, g: CSRGraph, schema: HeteroSchema,
                 validate: bool = True):
        if g.num_etypes != schema.num_etypes:
            raise ValueError(f"graph has {g.num_etypes} etypes, schema "
                             f"{schema.num_etypes}")
        if g.num_ntypes != schema.num_ntypes:
            raise ValueError(f"graph has {g.num_ntypes} ntypes, schema "
                             f"{schema.num_ntypes}")
        self.g = g
        self.schema = schema
        self._rel_cache: Dict[int, tuple] = {}
        if validate and schema.num_etypes > 1:
            self._validate()

    # -- delegation ----------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return self.g.num_nodes

    @property
    def num_edges(self) -> int:
        return self.g.num_edges

    def ntype_of(self) -> np.ndarray:
        """(n,) int32 node-type IDs (zeros for an untyped substrate)."""
        if self.g.ntypes is None:
            return np.zeros(self.g.num_nodes, dtype=np.int32)
        return self.g.ntypes

    def etype_of(self) -> np.ndarray:
        if self.g.etypes is None:
            return np.zeros(self.g.num_edges, dtype=np.int32)
        return self.g.etypes

    # -- typed accessors -----------------------------------------------
    def nodes_of_type(self, ntype: Union[int, str]) -> np.ndarray:
        t = (ntype if isinstance(ntype, (int, np.integer))
             else self.schema.ntype_id(ntype))
        return np.nonzero(self.ntype_of() == t)[0].astype(np.int64)

    def num_nodes_of_type(self, ntype: Union[int, str]) -> int:
        return len(self.nodes_of_type(ntype))

    def relation_coo(self, etype: EtypeKey
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, edge_positions) of one relation, fused IDs.

        ``edge_positions`` indexes the fused CSR's edge axis (for edge_ids /
        feature lookups).
        """
        et = self.schema.etype_id(etype)
        if et not in self._rel_cache:
            g = self.g
            if g.etypes is None:           # degenerate: the whole graph
                pos = np.arange(g.num_edges, dtype=np.int64)
            else:
                pos = np.nonzero(g.etypes == et)[0].astype(np.int64)
            src_all = np.repeat(np.arange(g.num_nodes, dtype=np.int64),
                                np.diff(g.indptr))
            self._rel_cache[et] = (src_all[pos], g.indices[pos].astype(np.int64),
                                   pos)
        return self._rel_cache[et]

    def relation_csr(self, etype: EtypeKey
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-relation out-CSR (indptr, indices, edge_positions) over the
        full fused node space — rows of non-src-typed nodes are empty."""
        src, dst, pos = self.relation_coo(etype)
        indptr = np.zeros(self.num_nodes + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        np.cumsum(indptr, out=indptr)
        # relation_coo preserves fused-CSR order, which is sorted by src
        return indptr, dst, pos

    def num_rel_edges(self, etype: EtypeKey) -> int:
        return len(self.relation_coo(etype)[0])

    def type_counts(self) -> dict:
        nt = self.ntype_of()
        et = self.etype_of()
        return {
            "nodes": {self.schema.ntypes[t]: int((nt == t).sum())
                      for t in range(self.schema.num_ntypes)},
            "edges": {self.schema.etypes[r]: int((et == r).sum())
                      for r in range(self.schema.num_etypes)},
        }

    # -- validation ----------------------------------------------------
    def _validate(self) -> None:
        nt = self.ntype_of()
        for et in range(self.schema.num_etypes):
            src, dst, _ = self.relation_coo(et)
            s_t = self.schema.src_ntype_id(et)
            d_t = self.schema.dst_ntype_id(et)
            bad_s = np.nonzero(nt[src] != s_t)[0]
            bad_d = np.nonzero(nt[dst] != d_t)[0]
            if len(bad_s) or len(bad_d):
                c = self.schema.canonical_etypes[et]
                raise ValueError(
                    f"relation {c}: {len(bad_s)} edges with wrong src ntype, "
                    f"{len(bad_d)} with wrong dst ntype")

    @staticmethod
    def wrap(g: CSRGraph, schema: Optional[HeteroSchema] = None,
             validate: bool = True) -> "HeteroCSRGraph":
        """Wrap any CSRGraph; untyped graphs get the degenerate schema."""
        if schema is None:
            if g.num_etypes == 1 and g.num_ntypes == 1:
                schema = HeteroSchema.homogeneous()
            else:
                # unnamed types: synthesize positional names. The canonical
                # src/dst ntypes are unknown for a bare typed array, so every
                # relation is declared n0->n0 and validation is skipped —
                # the positional schema names types, it claims no structure.
                schema = HeteroSchema(
                    ntypes=tuple(f"n{t}" for t in range(g.num_ntypes)),
                    canonical_etypes=tuple(("n0", f"e{r}", "n0")
                                           for r in range(g.num_etypes)))
                validate = False
        return HeteroCSRGraph(g, schema, validate=validate)


def fused_from_typed(node_counts: Mapping[str, int],
                     typed_edges: Sequence[tuple[CanonicalEtype,
                                                 np.ndarray, np.ndarray]],
                     ) -> tuple[CSRGraph, HeteroSchema]:
    """Build a fused CSRGraph + schema from per-type node counts and
    per-relation COO edge lists with *type-local* endpoints.

    Node types are laid out contiguously in declaration order (paper IDs
    first, then authors, ...): fused_id = type_offset[ntype] + local_id.
    This is the constructor the synthetic MAG generator uses.
    """
    from .csr import from_edges
    ntypes = tuple(node_counts.keys())
    offsets = {}
    off = 0
    for nt in ntypes:
        offsets[nt] = off
        off += int(node_counts[nt])
    n = off
    ntype_arr = np.zeros(n, dtype=np.int32)
    for t, nt in enumerate(ntypes):
        lo = offsets[nt]
        ntype_arr[lo:lo + node_counts[nt]] = t

    canon = tuple(c for c, _, _ in typed_edges)
    schema = HeteroSchema(ntypes=ntypes, canonical_etypes=canon)
    srcs, dsts, ets = [], [], []
    for r, ((s_nt, _rel, d_nt), src_local, dst_local) in enumerate(typed_edges):
        srcs.append(np.asarray(src_local, dtype=np.int64) + offsets[s_nt])
        dsts.append(np.asarray(dst_local, dtype=np.int64) + offsets[d_nt])
        ets.append(np.full(len(src_local), r, dtype=np.int32))
    src = np.concatenate(srcs) if srcs else np.empty(0, np.int64)
    dst = np.concatenate(dsts) if dsts else np.empty(0, np.int64)
    et = np.concatenate(ets) if ets else np.empty(0, np.int32)
    g = from_edges(src, dst, n, etypes=et, ntypes=ntype_arr,
                   num_etypes=len(canon), num_ntypes=len(ntypes))
    return g, schema
