from .csr import CSRGraph, from_edges, to_coo, to_undirected
from .hetero import HeteroCSRGraph, HeteroSchema, fused_from_typed
from .generate import (mag_graph, planted_partition_graph, random_features,
                       rmat_graph, train_val_test_split)
from .datasets import GraphDataset, get_dataset, list_datasets

__all__ = [
    "CSRGraph", "from_edges", "to_coo", "to_undirected",
    "HeteroCSRGraph", "HeteroSchema", "fused_from_typed", "mag_graph",
    "planted_partition_graph", "random_features", "rmat_graph",
    "train_val_test_split", "GraphDataset", "get_dataset", "list_datasets",
]
