from .csr import CSRGraph, from_edges, to_coo, to_undirected
from .generate import (planted_partition_graph, random_features, rmat_graph,
                       train_val_test_split)
from .datasets import GraphDataset, get_dataset, list_datasets

__all__ = [
    "CSRGraph", "from_edges", "to_coo", "to_undirected",
    "planted_partition_graph", "random_features", "rmat_graph",
    "train_val_test_split", "GraphDataset", "get_dataset", "list_datasets",
]
