from .multilevel import (balance_report, edge_cut, make_constraints,
                         partition_graph, random_partition)
from .book import GraphPartition, PartitionBook, build_partitions, halo_stats
from .hierarchical import (HierarchicalPartition, hierarchical_partition,
                           locality_report, split_training_set)

__all__ = [
    "balance_report", "edge_cut", "make_constraints", "partition_graph",
    "random_partition", "GraphPartition", "PartitionBook", "build_partitions",
    "halo_stats", "HierarchicalPartition", "hierarchical_partition",
    "locality_report", "split_training_set",
]
