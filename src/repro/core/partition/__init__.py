from .multilevel import (balance_report, edge_cut, make_constraints,
                         partition_graph, random_partition)
from .book import (GraphPartition, PartitionBook, TypedPartitionData,
                   build_partitions, build_typed_partition, halo_stats)
from .hierarchical import (HierarchicalPartition, hierarchical_partition,
                           locality_report, split_training_set)

__all__ = [
    "balance_report", "edge_cut", "make_constraints", "partition_graph",
    "random_partition", "GraphPartition", "PartitionBook",
    "TypedPartitionData", "build_partitions", "build_typed_partition",
    "halo_stats", "HierarchicalPartition", "hierarchical_partition",
    "locality_report", "split_training_set",
]
