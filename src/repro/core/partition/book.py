"""Partition book + physical graph partitions (§5.3).

Implements the paper's partition-data layout:

* vertex/edge **ID relabeling** so every partition's core vertices and edges
  occupy a contiguous range of the new global ID space — global→partition is
  a binary search over a (k+1) offsets array, global→local a subtraction;
* **edge assignment** to the partition of the *destination* vertex
  (owner-compute: the owner of a target vertex can sample its in-neighbors
  locally without talking to other samplers);
* **HALO vertices**: source endpoints of assigned edges that are core in
  another partition are duplicated into the local node space (structure
  only — features are never duplicated, exactly as in the paper).

Each physical partition stores an in-neighbor CSR over its local ID space
(core rows only; sampling dispatches frontier nodes to their owners, so halo
rows are never expanded locally).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ...graph.csr import CSRGraph, to_coo
from ...graph.hetero import HeteroSchema


@dataclasses.dataclass
class GraphPartition:
    """One machine's physical partition (local in-CSR, core rows)."""
    part_id: int
    indptr: np.ndarray        # (n_core + 1,)
    indices: np.ndarray       # (m_local,) LOCAL src ids (core then halo space)
    edge_ids: np.ndarray      # (m_local,) NEW global edge ids
    etypes: Optional[np.ndarray]
    local2global: np.ndarray  # (n_local,) NEW global node ids; [:n_core] core
    n_core: int
    _rel_views: Dict[int, "GraphPartition"] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def n_local(self) -> int:
        return len(self.local2global)

    @property
    def n_halo(self) -> int:
        return self.n_local - self.n_core

    @property
    def num_local_edges(self) -> int:
        return len(self.indices)

    def relation_view(self, etype: int) -> "GraphPartition":
        """This partition restricted to one relation's edges.

        Same core rows and local node space (``local2global`` is shared,
        not copied); only the adjacency is filtered, so per-relation
        sampling reuses ``sample_local`` unchanged. The view is built
        lazily once and cached. An untyped partition *is* its own
        relation-0 view — that identity is what keeps the degenerate
        homogeneous schema byte-identical to the legacy path.
        """
        if self.etypes is None:
            if etype != 0:
                raise KeyError(f"untyped partition has no relation {etype}")
            return self
        if etype not in self._rel_views:
            keep = np.nonzero(self.etypes == etype)[0]
            rows = np.repeat(np.arange(self.n_core, dtype=np.int64),
                             np.diff(self.indptr))[keep]
            indptr = np.zeros(self.n_core + 1, dtype=np.int64)
            np.add.at(indptr, rows + 1, 1)
            np.cumsum(indptr, out=indptr)
            self._rel_views[etype] = GraphPartition(
                part_id=self.part_id, indptr=indptr,
                indices=self.indices[keep], edge_ids=self.edge_ids[keep],
                etypes=None, local2global=self.local2global,
                n_core=self.n_core)
        return self._rel_views[etype]


@dataclasses.dataclass
class PartitionBook:
    """Global metadata shared by every machine (tiny)."""
    num_parts: int
    node_offsets: np.ndarray   # (k+1,) new-global node-ID range per partition
    edge_offsets: np.ndarray   # (k+1,)
    new2old_node: np.ndarray   # (n,) permutation
    old2new_node: np.ndarray
    new2old_edge: np.ndarray
    old2new_edge: np.ndarray

    @property
    def num_nodes(self) -> int:
        return int(self.node_offsets[-1])

    def nid2part(self, nids: np.ndarray) -> np.ndarray:
        """Binary search in the small offsets array (paper's lookup)."""
        return (np.searchsorted(self.node_offsets, nids, side="right") - 1).astype(np.int32)

    def nid2local(self, nids: np.ndarray, parts: Optional[np.ndarray] = None) -> np.ndarray:
        if parts is None:
            parts = self.nid2part(nids)
        return nids - self.node_offsets[parts]

    def eid2part(self, eids: np.ndarray) -> np.ndarray:
        return (np.searchsorted(self.edge_offsets, eids, side="right") - 1).astype(np.int32)

    def part_core_range(self, p: int) -> tuple[int, int]:
        return int(self.node_offsets[p]), int(self.node_offsets[p + 1])


def build_partitions(g: CSRGraph, parts: np.ndarray
                     ) -> tuple[PartitionBook, List[GraphPartition]]:
    """Relabel IDs and materialize per-partition physical subgraphs."""
    n = g.num_nodes
    k = int(parts.max()) + 1 if len(parts) else 1
    parts = parts.astype(np.int64)

    # ---- node relabel: order by (partition, old id) ----
    new2old_node = np.argsort(parts, kind="stable").astype(np.int64)
    old2new_node = np.empty(n, dtype=np.int64)
    old2new_node[new2old_node] = np.arange(n, dtype=np.int64)
    counts = np.bincount(parts, minlength=k)
    node_offsets = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(counts, out=node_offsets[1:])

    # ---- edge assignment to partition(dst), relabel ----
    src_old, dst_old = to_coo(g)
    src = old2new_node[src_old]
    dst = old2new_node[dst_old]
    eparts = parts[dst_old]
    # new edge id order: (partition, dst, original)
    order = np.lexsort((np.arange(len(src)), dst, eparts))
    new2old_edge = order.astype(np.int64)
    old2new_edge = np.empty(len(src), dtype=np.int64)
    old2new_edge[order] = np.arange(len(src), dtype=np.int64)
    ecounts = np.bincount(eparts, minlength=k)
    edge_offsets = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(ecounts, out=edge_offsets[1:])

    book = PartitionBook(num_parts=k, node_offsets=node_offsets,
                         edge_offsets=edge_offsets,
                         new2old_node=new2old_node, old2new_node=old2new_node,
                         new2old_edge=new2old_edge, old2new_edge=old2new_edge)

    src_sorted = src[order]
    dst_sorted = dst[order]
    et_sorted = None if g.etypes is None else g.etypes[new2old_edge]

    partitions = []
    for p in range(k):
        elo, ehi = int(edge_offsets[p]), int(edge_offsets[p + 1])
        nlo, nhi = int(node_offsets[p]), int(node_offsets[p + 1])
        n_core = nhi - nlo
        e_src = src_sorted[elo:ehi]          # global new ids
        e_dst = dst_sorted[elo:ehi]          # all inside [nlo, nhi)
        # halo: srcs outside the core range
        outside = (e_src < nlo) | (e_src >= nhi)
        halo_g = np.unique(e_src[outside])
        local2global = np.concatenate(
            [np.arange(nlo, nhi, dtype=np.int64), halo_g])
        # map global src -> local id
        src_local = np.where(~outside, e_src - nlo, 0)
        if len(halo_g):
            src_local = np.where(
                outside, n_core + np.searchsorted(halo_g, e_src), src_local)
        dst_local = e_dst - nlo
        # in-CSR rows over core nodes (edges already sorted by dst)
        indptr = np.zeros(n_core + 1, dtype=np.int64)
        np.add.at(indptr, dst_local + 1, 1)
        np.cumsum(indptr, out=indptr)
        partitions.append(GraphPartition(
            part_id=p, indptr=indptr, indices=src_local.astype(np.int64),
            edge_ids=np.arange(elo, ehi, dtype=np.int64),
            etypes=None if et_sorted is None else et_sorted[elo:ehi],
            local2global=local2global, n_core=n_core))
    return book, partitions


# ---------------------------------------------------------------------------
# typed (heterograph) partition data: per-ntype node policies and per-etype
# edge policies over TYPE-LOCAL id spaces (§5.4's "separate policies per
# node/edge type", delivered — see DESIGN.md §3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TypedPartitionData:
    """Typed ID spaces layered on a relabeled partition book.

    After ``build_partitions`` the fused node IDs are partition-contiguous.
    For each node type t we define a *type-local* ID space by ranking type-t
    nodes in fused-ID order — which makes every partition's type-t nodes a
    contiguous type-local range, i.e. each per-ntype KVStore policy is again
    binary-search + subtraction (same scheme as the fused policies, one
    offsets array per type). Edge types get the same treatment over the
    fused edge-ID order.

    Maps (all in the NEW/fused id spaces):
      ntype_of_node (n,)  — node type per fused node id
      node_type_local (n,) — type-local id of each fused node
      type2node[t]        — fused ids of type t, in type-local order
      (and the edge-side equivalents)
    """
    schema: HeteroSchema
    ntype_of_node: np.ndarray
    node_type_local: np.ndarray
    type2node: List[np.ndarray]
    etype_of_edge: np.ndarray
    edge_type_local: np.ndarray
    type2edge: List[np.ndarray]
    node_policies: "Dict[str, object]"   # "node:<ntype>" -> PartitionPolicy
    edge_policies: "Dict[str, object]"   # "edge:<rel>"   -> PartitionPolicy

    def node_policy_name(self, ntype: str) -> str:
        return f"node:{ntype}"

    def edge_policy_name(self, rel: str) -> str:
        return f"edge:{rel}"

    def policies(self) -> "Dict[str, object]":
        return {**self.node_policies, **self.edge_policies}

    def nid2typed(self, nids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """fused node ids -> (ntype ids, type-local ids)."""
        nids = np.asarray(nids, dtype=np.int64)
        return self.ntype_of_node[nids], self.node_type_local[nids]

    def typed2nid(self, ntype: int, tids: np.ndarray) -> np.ndarray:
        return self.type2node[ntype][np.asarray(tids, dtype=np.int64)]


def _typed_axis(type_of: np.ndarray, num_types: int, part_of: np.ndarray,
                num_parts: int, names: List[str], prefix: str):
    """Shared node/edge construction for ``build_typed_partition``."""
    from ..kvstore.store import PartitionPolicy
    n = len(type_of)
    type_local = np.zeros(n, dtype=np.int64)
    type2id: List[np.ndarray] = []
    policies = {}
    for t in range(num_types):
        sel = np.nonzero(type_of == t)[0].astype(np.int64)   # fused-id order
        type_local[sel] = np.arange(len(sel), dtype=np.int64)
        type2id.append(sel)
        counts = np.bincount(part_of[sel], minlength=num_parts)
        offs = np.zeros(num_parts + 1, dtype=np.int64)
        np.cumsum(counts, out=offs[1:])
        policies[f"{prefix}:{names[t]}"] = PartitionPolicy(
            f"{prefix}:{names[t]}", offs)
    return type_local, type2id, policies


def build_typed_partition(book: PartitionBook, schema: HeteroSchema,
                          ntypes_new: Optional[np.ndarray],
                          etypes_new: Optional[np.ndarray]
                          ) -> TypedPartitionData:
    """Construct per-type policies + id maps for a partitioned heterograph.

    ``ntypes_new``/``etypes_new`` are the type arrays in the NEW (relabeled)
    id orders, e.g. ``g.ntypes[book.new2old_node]`` — None means untyped
    (all type 0), which yields policies identical to the fused ones: the
    degenerate schema costs nothing.
    """
    n = book.num_nodes
    m = int(book.edge_offsets[-1])
    nt = (np.zeros(n, dtype=np.int32) if ntypes_new is None
          else np.asarray(ntypes_new, dtype=np.int32))
    et = (np.zeros(m, dtype=np.int32) if etypes_new is None
          else np.asarray(etypes_new, dtype=np.int32))
    assert len(nt) == n and len(et) == m, (len(nt), n, len(et), m)

    node_part = book.nid2part(np.arange(n, dtype=np.int64))
    edge_part = book.eid2part(np.arange(m, dtype=np.int64))
    node_type_local, type2node, node_policies = _typed_axis(
        nt, schema.num_ntypes, node_part, book.num_parts,
        list(schema.ntypes), "node")
    edge_type_local, type2edge, edge_policies = _typed_axis(
        et, schema.num_etypes, edge_part, book.num_parts,
        list(schema.etypes), "edge")
    return TypedPartitionData(
        schema=schema, ntype_of_node=nt, node_type_local=node_type_local,
        type2node=type2node, etype_of_edge=et,
        edge_type_local=edge_type_local, type2edge=type2edge,
        node_policies=node_policies, edge_policies=edge_policies)


def halo_stats(partitions: List[GraphPartition]) -> dict:
    n_core = sum(p.n_core for p in partitions)
    n_halo = sum(p.n_halo for p in partitions)
    return {"core": n_core, "halo": n_halo,
            "halo_ratio": n_halo / max(n_core, 1)}
