"""Partition book + physical graph partitions (§5.3).

Implements the paper's partition-data layout:

* vertex/edge **ID relabeling** so every partition's core vertices and edges
  occupy a contiguous range of the new global ID space — global→partition is
  a binary search over a (k+1) offsets array, global→local a subtraction;
* **edge assignment** to the partition of the *destination* vertex
  (owner-compute: the owner of a target vertex can sample its in-neighbors
  locally without talking to other samplers);
* **HALO vertices**: source endpoints of assigned edges that are core in
  another partition are duplicated into the local node space (structure
  only — features are never duplicated, exactly as in the paper).

Each physical partition stores an in-neighbor CSR over its local ID space
(core rows only; sampling dispatches frontier nodes to their owners, so halo
rows are never expanded locally).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from ...graph.csr import CSRGraph, to_coo


@dataclasses.dataclass
class GraphPartition:
    """One machine's physical partition (local in-CSR, core rows)."""
    part_id: int
    indptr: np.ndarray        # (n_core + 1,)
    indices: np.ndarray       # (m_local,) LOCAL src ids (core then halo space)
    edge_ids: np.ndarray      # (m_local,) NEW global edge ids
    etypes: Optional[np.ndarray]
    local2global: np.ndarray  # (n_local,) NEW global node ids; [:n_core] core
    n_core: int

    @property
    def n_local(self) -> int:
        return len(self.local2global)

    @property
    def n_halo(self) -> int:
        return self.n_local - self.n_core

    @property
    def num_local_edges(self) -> int:
        return len(self.indices)


@dataclasses.dataclass
class PartitionBook:
    """Global metadata shared by every machine (tiny)."""
    num_parts: int
    node_offsets: np.ndarray   # (k+1,) new-global node-ID range per partition
    edge_offsets: np.ndarray   # (k+1,)
    new2old_node: np.ndarray   # (n,) permutation
    old2new_node: np.ndarray
    new2old_edge: np.ndarray
    old2new_edge: np.ndarray

    @property
    def num_nodes(self) -> int:
        return int(self.node_offsets[-1])

    def nid2part(self, nids: np.ndarray) -> np.ndarray:
        """Binary search in the small offsets array (paper's lookup)."""
        return (np.searchsorted(self.node_offsets, nids, side="right") - 1).astype(np.int32)

    def nid2local(self, nids: np.ndarray, parts: Optional[np.ndarray] = None) -> np.ndarray:
        if parts is None:
            parts = self.nid2part(nids)
        return nids - self.node_offsets[parts]

    def eid2part(self, eids: np.ndarray) -> np.ndarray:
        return (np.searchsorted(self.edge_offsets, eids, side="right") - 1).astype(np.int32)

    def part_core_range(self, p: int) -> tuple[int, int]:
        return int(self.node_offsets[p]), int(self.node_offsets[p + 1])


def build_partitions(g: CSRGraph, parts: np.ndarray
                     ) -> tuple[PartitionBook, List[GraphPartition]]:
    """Relabel IDs and materialize per-partition physical subgraphs."""
    n = g.num_nodes
    k = int(parts.max()) + 1 if len(parts) else 1
    parts = parts.astype(np.int64)

    # ---- node relabel: order by (partition, old id) ----
    new2old_node = np.argsort(parts, kind="stable").astype(np.int64)
    old2new_node = np.empty(n, dtype=np.int64)
    old2new_node[new2old_node] = np.arange(n, dtype=np.int64)
    counts = np.bincount(parts, minlength=k)
    node_offsets = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(counts, out=node_offsets[1:])

    # ---- edge assignment to partition(dst), relabel ----
    src_old, dst_old = to_coo(g)
    src = old2new_node[src_old]
    dst = old2new_node[dst_old]
    eparts = parts[dst_old]
    # new edge id order: (partition, dst, original)
    order = np.lexsort((np.arange(len(src)), dst, eparts))
    new2old_edge = order.astype(np.int64)
    old2new_edge = np.empty(len(src), dtype=np.int64)
    old2new_edge[order] = np.arange(len(src), dtype=np.int64)
    ecounts = np.bincount(eparts, minlength=k)
    edge_offsets = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(ecounts, out=edge_offsets[1:])

    book = PartitionBook(num_parts=k, node_offsets=node_offsets,
                         edge_offsets=edge_offsets,
                         new2old_node=new2old_node, old2new_node=old2new_node,
                         new2old_edge=new2old_edge, old2new_edge=old2new_edge)

    src_sorted = src[order]
    dst_sorted = dst[order]
    et_sorted = None if g.etypes is None else g.etypes[new2old_edge]

    partitions = []
    for p in range(k):
        elo, ehi = int(edge_offsets[p]), int(edge_offsets[p + 1])
        nlo, nhi = int(node_offsets[p]), int(node_offsets[p + 1])
        n_core = nhi - nlo
        e_src = src_sorted[elo:ehi]          # global new ids
        e_dst = dst_sorted[elo:ehi]          # all inside [nlo, nhi)
        # halo: srcs outside the core range
        outside = (e_src < nlo) | (e_src >= nhi)
        halo_g = np.unique(e_src[outside])
        local2global = np.concatenate(
            [np.arange(nlo, nhi, dtype=np.int64), halo_g])
        # map global src -> local id
        src_local = np.where(~outside, e_src - nlo, 0)
        if len(halo_g):
            src_local = np.where(
                outside, n_core + np.searchsorted(halo_g, e_src), src_local)
        dst_local = e_dst - nlo
        # in-CSR rows over core nodes (edges already sorted by dst)
        indptr = np.zeros(n_core + 1, dtype=np.int64)
        np.add.at(indptr, dst_local + 1, 1)
        np.cumsum(indptr, out=indptr)
        partitions.append(GraphPartition(
            part_id=p, indptr=indptr, indices=src_local.astype(np.int64),
            edge_ids=np.arange(elo, ehi, dtype=np.int64),
            etypes=None if et_sorted is None else et_sorted[elo:ehi],
            local2global=local2global, n_core=n_core))
    return book, partitions


def halo_stats(partitions: List[GraphPartition]) -> dict:
    n_core = sum(p.n_core for p in partitions)
    n_halo = sum(p.n_halo for p in partitions)
    return {"core": n_core, "halo": n_halo,
            "halo_ratio": n_halo / max(n_core, 1)}
