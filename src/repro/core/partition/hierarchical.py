"""Hierarchical (2-level) partitioning (§5.3, Fig. 6) and the training-set
split algorithm (§5.6.1, Fig. 9).

Level 1: machines (physical subgraphs with HALO, via ``build_partitions``).
Level 2: trainers within a machine. The paper does NOT build physical
subgraphs at this level — trainers share the machine's partition and use a
*node split* so each trainer's seeds are topologically clustered (better
intra-batch locality => fewer unique input nodes per mini-batch, Fig. 14's
"2-level partition" bar). We realize level 2 by running the same multilevel
partitioner on the machine-local core subgraph.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from ...graph.csr import CSRGraph
from .book import GraphPartition, PartitionBook, build_partitions
from .multilevel import make_constraints, partition_graph, random_partition


@dataclasses.dataclass
class HierarchicalPartition:
    book: PartitionBook
    partitions: List[GraphPartition]
    machine_of_node: np.ndarray       # (n,) in NEW global id space
    trainer_of_node: np.ndarray       # (n,) trainer index WITHIN its machine
    trainers_per_machine: int

    @property
    def num_machines(self) -> int:
        return self.book.num_parts

    @property
    def num_trainers(self) -> int:
        return self.num_machines * self.trainers_per_machine

    def global_trainer(self, machine: int, local_trainer: int) -> int:
        return machine * self.trainers_per_machine + local_trainer


def hierarchical_partition(g: CSRGraph, num_machines: int,
                           trainers_per_machine: int, *,
                           split_mask: Optional[np.ndarray] = None,
                           method: str = "metis", seed: int = 0,
                           eps: float = 0.08) -> HierarchicalPartition:
    """Partition ``g`` for ``num_machines`` × ``trainers_per_machine``.

    method: "metis" (multilevel multi-constraint, the paper) or "random"
    (the Euler baseline).
    """
    vw = make_constraints(g, split_mask)
    if method == "metis":
        parts = partition_graph(g, num_machines, vwgts=vw, seed=seed, eps=eps)
    elif method == "random":
        parts = random_partition(g, num_machines, seed=seed)
    else:
        raise ValueError(f"unknown partition method {method!r}")

    book, partitions = build_partitions(g, parts)

    n = g.num_nodes
    machine_of_node = book.nid2part(np.arange(n, dtype=np.int64))
    trainer_of_node = np.zeros(n, dtype=np.int32)
    if trainers_per_machine > 1:
        split_new = None if split_mask is None else split_mask[book.new2old_node]
        for p, gp in enumerate(partitions):
            lo, hi = book.part_core_range(p)
            core_old = book.new2old_node[lo:hi]
            sub, _ = g.subgraph(core_old)
            sub_mask = None if split_new is None else split_new[lo:hi]
            sub_vw = make_constraints(sub, sub_mask)
            if method == "metis":
                sub_parts = partition_graph(sub, trainers_per_machine,
                                            vwgts=sub_vw, seed=seed + 1 + p,
                                            eps=eps)
            else:
                sub_parts = random_partition(sub, trainers_per_machine,
                                             seed=seed + 1 + p)
            trainer_of_node[lo:hi] = sub_parts
    return HierarchicalPartition(book=book, partitions=partitions,
                                 machine_of_node=machine_of_node,
                                 trainer_of_node=trainer_of_node,
                                 trainers_per_machine=trainers_per_machine)


def split_training_set(hp: HierarchicalPartition, train_nids_new: np.ndarray,
                       *, use_level2: bool = True,
                       seed: int = 0) -> List[np.ndarray]:
    """§5.6.1's split algorithm, returning one seed array per trainer.

    The paper splits the training IDs into equal contiguous ranges and
    assigns each range to the machine whose partition overlaps it most
    (possible because relabeling made partitions contiguous). Every trainer
    then gets exactly the same number of seeds — the synchronous-SGD
    requirement — while nearly all seeds stay machine-local.
    """
    t = hp.num_trainers
    train_sorted = np.sort(np.asarray(train_nids_new, dtype=np.int64))
    total = len(train_sorted)
    per = total // t
    if per == 0:
        raise ValueError(f"fewer training points ({total}) than trainers ({t})")
    train_sorted = train_sorted[: per * t]          # equal counts (drop tail)
    ranges = train_sorted.reshape(t, per)

    # assign each contiguous range to the machine with the largest overlap
    machine_budget = {m: hp.trainers_per_machine for m in range(hp.num_machines)}
    assignment: List[Optional[np.ndarray]] = [None] * t
    order = []
    for r in range(t):
        mids = hp.machine_of_node[ranges[r]]
        best = np.bincount(mids, minlength=hp.num_machines)
        order.append((r, best))
    # greedy: process ranges by how peaked their overlap is
    order.sort(key=lambda x: -x[1].max())
    slots: List[List[np.ndarray]] = [[] for _ in range(hp.num_machines)]
    unplaced = []
    for r, counts in order:
        placed = False
        for m in np.argsort(-counts):
            if machine_budget[int(m)] > 0:
                slots[int(m)].append(ranges[r])
                machine_budget[int(m)] -= 1
                placed = True
                break
        if not placed:
            unplaced.append(ranges[r])
    assert not unplaced

    out: List[np.ndarray] = []
    rng = np.random.default_rng(seed)
    for m in range(hp.num_machines):
        chunks = slots[m]
        if use_level2 and hp.trainers_per_machine > 1:
            # distribute this machine's seeds across its trainers by the
            # level-2 (intra-machine) partition for intra-batch locality,
            # re-balancing to equal counts.
            allseeds = np.concatenate(chunks)
            t2 = hp.trainer_of_node[allseeds]
            buckets = [allseeds[t2 == j] for j in range(hp.trainers_per_machine)]
            # equalize: move overflow to underfull buckets
            target = len(allseeds) // hp.trainers_per_machine
            overflow = []
            for j in range(hp.trainers_per_machine):
                if len(buckets[j]) > target:
                    overflow.append(buckets[j][target:])
                    buckets[j] = buckets[j][:target]
            extra = (np.concatenate(overflow) if overflow
                     else np.empty(0, dtype=np.int64))
            ptr = 0
            for j in range(hp.trainers_per_machine):
                need = target - len(buckets[j])
                if need > 0:
                    buckets[j] = np.concatenate([buckets[j], extra[ptr:ptr + need]])
                    ptr += need
            out.extend(buckets)
        else:
            for c in chunks:
                out.append(c.copy())
    # every trainer: identical count (sync SGD), shuffled order
    counts = {len(s) for s in out}
    m = min(counts)
    out = [rng.permutation(s[:m]) for s in out]
    return out


def locality_report(hp: HierarchicalPartition,
                    trainer_seeds: List[np.ndarray]) -> dict:
    """Fraction of each trainer's seeds that are machine-local."""
    fracs = []
    for ti, seeds in enumerate(trainer_seeds):
        m = ti // hp.trainers_per_machine
        fracs.append(float((hp.machine_of_node[seeds] == m).mean()))
    return {"per_trainer_local_frac": fracs,
            "mean_local_frac": float(np.mean(fracs))}
