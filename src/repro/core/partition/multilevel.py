"""Multilevel multi-constraint min-edge-cut graph partitioner (§5.3.1–5.3.2).

A NumPy reimplementation of the METIS recipe the paper uses, including the
paper's power-law extensions:

* **coarsening** by heavy-edge matching (HEM);
* **degree-capped edge retention**: on each coarser graph, every coarse
  vertex keeps only its highest-weight edges so that its degree is (at most)
  the average degree of its constituent vertices — the paper's fix for
  power-law graphs whose coarse graphs otherwise densify ("we extended METIS
  to only retain a subset of the edges in each successive graph");
* a **single initial partitioning** (greedy region growing) and a **single
  refinement pass per level** (the paper reduces METIS' defaults of 5 / 10
  to 1 / 1 for power-law graphs at a 2–10% edge-cut cost);
* **multi-constraint balancing** [Karypis & Kumar 1998]: vertex weights are
  (n, ncon) — e.g. [ones, degree, is_train, is_val, is_test, ntype
  indicators] — and every move/assignment must keep every constraint within
  (1 + eps) of its per-partition average. This is §5.3.2's balancing of
  train/val/test vertices, edges, and per-type counts.

The partitioner is model-agnostic and runs once per graph (preprocessing),
matching the paper's amortization argument.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ...graph.csr import CSRGraph, to_coo


@dataclasses.dataclass
class _Level:
    indptr: np.ndarray
    indices: np.ndarray
    ewgts: np.ndarray
    vwgts: np.ndarray      # (n, ncon)
    cmap: Optional[np.ndarray]  # fine -> coarse map that produced THIS level


def _symmetrize(indptr, indices, ewgts, n):
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    dst = indices.astype(np.int64)
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    w = np.concatenate([ewgts, ewgts])
    return _build_csr(s, d, w, n, combine=True)


def _build_csr(src, dst, w, n, combine=False):
    if combine and len(src):
        key = src * n + dst
        order = np.argsort(key, kind="stable")
        key, src, dst, w = key[order], src[order], dst[order], w[order]
        uniq_mask = np.empty(len(key), dtype=bool)
        uniq_mask[0] = True
        np.not_equal(key[1:], key[:-1], out=uniq_mask[1:])
        group = np.cumsum(uniq_mask) - 1
        wsum = np.zeros(int(group[-1]) + 1, dtype=w.dtype)
        np.add.at(wsum, group, w)
        src, dst, w = src[uniq_mask], dst[uniq_mask], wsum
    else:
        order = np.argsort(src, kind="stable")
        src, dst, w = src[order], dst[order], w[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, dst.astype(np.int64), w


def _heavy_edge_matching(indptr, indices, ewgts, rng):
    """Greedy heavy-edge matching. Returns match[v] = partner (or v)."""
    n = len(indptr) - 1
    match = np.full(n, -1, dtype=np.int64)
    order = rng.permutation(n)
    for v in order:
        if match[v] >= 0:
            continue
        lo, hi = indptr[v], indptr[v + 1]
        nbrs = indices[lo:hi]
        if len(nbrs) == 0:
            match[v] = v
            continue
        w = ewgts[lo:hi]
        free = match[nbrs] < 0
        free &= nbrs != v
        if not free.any():
            match[v] = v
            continue
        cand_w = np.where(free, w, -1)
        u = nbrs[int(np.argmax(cand_w))]
        match[v] = u
        match[u] = v
    return match


def _coarsen(level: _Level, rng, degree_cap: bool) -> Optional[_Level]:
    n = len(level.indptr) - 1
    match = _heavy_edge_matching(level.indptr, level.indices, level.ewgts, rng)
    # assign coarse ids: representative = min(v, match[v])
    rep = np.minimum(np.arange(n), match)
    uniq, cmap = np.unique(rep, return_inverse=True)
    nc = len(uniq)
    if nc > 0.95 * n:   # matching stalled (e.g. star graphs) — stop coarsening
        return None
    # coarse vertex weights
    ncon = level.vwgts.shape[1]
    cvw = np.zeros((nc, ncon), dtype=level.vwgts.dtype)
    np.add.at(cvw, cmap, level.vwgts)
    # coarse edges
    src, _ = _fine_coo(level)
    csrc = cmap[src]
    cdst = cmap[level.indices]
    keep = csrc != cdst
    ci, cx, cw = _build_csr(csrc[keep], cdst[keep], level.ewgts[keep], nc,
                            combine=True)
    if degree_cap:
        ci, cx, cw = _cap_degrees(ci, cx, cw, level, cmap, nc)
    return _Level(indptr=ci, indices=cx, ewgts=cw, vwgts=cvw, cmap=cmap)


def _fine_coo(level: _Level):
    n = len(level.indptr) - 1
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(level.indptr))
    return src, level.indices


def _cap_degrees(indptr, indices, ewgts, fine: _Level, cmap, nc):
    """Paper's power-law fix: cap each coarse vertex's degree at the average
    degree of its constituents, keeping the highest-weight edges."""
    fine_deg = np.diff(fine.indptr).astype(np.float64)
    csize = np.zeros(nc, dtype=np.int64)
    np.add.at(csize, cmap, 1)
    cdegsum = np.zeros(nc, dtype=np.float64)
    np.add.at(cdegsum, cmap, fine_deg)
    cap = np.maximum(1, np.ceil(cdegsum / np.maximum(csize, 1))).astype(np.int64)

    deg = np.diff(indptr)
    if (deg <= cap).all():
        return indptr, indices, ewgts
    keep = np.ones(len(indices), dtype=bool)
    for v in np.nonzero(deg > cap)[0]:
        lo, hi = indptr[v], indptr[v + 1]
        w = ewgts[lo:hi]
        # keep the cap[v] highest-weight edges
        drop = np.argsort(w, kind="stable")[: (hi - lo) - cap[v]]
        keep[lo + drop] = False
    s = np.repeat(np.arange(nc, dtype=np.int64), deg)[keep]
    return _build_csr(s, indices[keep], ewgts[keep], nc)


def _balance_caps(vwgts, k, eps):
    totals = vwgts.sum(axis=0).astype(np.float64)
    return (1.0 + eps) * totals / k + vwgts.max(axis=0)   # slack for granularity


def _initial_partition(level: _Level, k, eps, rng):
    """Greedy region growing: k BFS fronts grown by connection strength,
    constrained by the primary weight; leftovers go to the lightest part."""
    n = len(level.indptr) - 1
    parts = np.full(n, -1, dtype=np.int32)
    caps = _balance_caps(level.vwgts, k, eps)
    loads = np.zeros((k, level.vwgts.shape[1]), dtype=np.float64)
    seeds = rng.choice(n, size=min(k, n), replace=False)
    from heapq import heappush, heappop
    heaps = [[] for _ in range(k)]
    counter = 0
    for p, s in enumerate(seeds):
        heappush(heaps[p], (0.0, counter, int(s)))
        counter += 1
    active = list(range(min(k, n)))
    while active:
        # grow the currently lightest active part (primary constraint)
        p = min(active, key=lambda q: loads[q, 0])
        placed = False
        while heaps[p]:
            _, _, v = heappop(heaps[p])
            if parts[v] >= 0:
                continue
            if ((loads[p] + level.vwgts[v]) > caps).any():
                continue
            parts[v] = p
            loads[p] += level.vwgts[v]
            lo, hi = level.indptr[v], level.indptr[v + 1]
            for u, w in zip(level.indices[lo:hi], level.ewgts[lo:hi]):
                if parts[u] < 0:
                    heappush(heaps[p], (-float(w), counter, int(u)))
                    counter += 1
            placed = True
            break
        if not placed:
            active.remove(p)
    # assign untouched vertices (disconnected or capacity-skipped):
    # lightest part that still fits every constraint, falling back to the
    # overall-lightest only when nothing fits (rebalance repairs later)
    for v in np.nonzero(parts < 0)[0]:
        score = loads[:, 0] + loads.sum(axis=1)
        fits = ((loads + level.vwgts[v]) <= caps).all(axis=1)
        if fits.any():
            score = np.where(fits, score, np.inf)
        p = int(np.argmin(score))
        parts[v] = p
        loads[p] += level.vwgts[v]
    return parts


def _rebalance(level: _Level, parts, k, eps, max_passes=4):
    """Drain overloaded partitions until every constraint is within the
    ``_balance_caps`` envelope ``(1+eps)·total/k + max_vwgt``.

    Refinement alone never repairs imbalance (it only refuses to worsen
    it), and both the coarse-level granularity and the initial
    partition's forced placements can overflow the caps. Each level runs
    this after refinement, so successively finer granularity shaves the
    overflow down to the finest level's vertex weights. Vertices leave an
    overloaded part least-attached-first (minimum same-part edge weight),
    landing on the feasible part they connect to most — the smallest cut
    damage that restores balance.
    """
    caps = _balance_caps(level.vwgts, k, eps)
    loads = np.zeros((k, level.vwgts.shape[1]), dtype=np.float64)
    np.add.at(loads, parts, level.vwgts)
    src, _ = _fine_coo(level)
    for _ in range(max_passes):
        over = np.nonzero((loads > caps + 1e-9).any(axis=1))[0]
        if not len(over):
            break
        moved = 0
        # same-part connectivity: how embedded each vertex is where it sits
        own_w = np.zeros(len(parts), dtype=np.float64)
        same = parts[src] == parts[level.indices]
        np.add.at(own_w, src[same], level.ewgts[same])
        for p in over:
            verts = np.nonzero(parts == p)[0]
            for v in verts[np.argsort(own_w[verts], kind="stable")]:
                if (loads[p] <= caps + 1e-9).all():
                    break
                lo, hi = level.indptr[v], level.indptr[v + 1]
                conn = np.zeros(k, dtype=np.float64)
                np.add.at(conn, parts[level.indices[lo:hi]],
                          level.ewgts[lo:hi])
                feasible = ((loads + level.vwgts[v]) <= caps).all(axis=1)
                feasible[p] = False
                if not feasible.any():
                    continue
                conn = np.where(feasible, conn, -np.inf)
                best = int(np.argmax(conn))
                parts[v] = best
                loads[p] -= level.vwgts[v]
                loads[best] += level.vwgts[v]
                moved += 1
        if moved == 0:
            break
    # best-effort phase: strict feasibility can dead-end — e.g. two parts
    # over the COUNT cap while two others are over the DEGREE cap, so no
    # single receiver is feasible and the tied maximum never strictly
    # drops. Descend a potential Φ = Σ_{p,c} excess(p,c)² instead (excess
    # in units of the cap): any move that strictly shrinks TOTAL excess is
    # taken, which walks through tied-maximum plateaus and trades hub
    # vertices one way for light vertices the other.
    def _phi_part(load):
        ex = np.maximum(load / caps - 1.0, 0.0)
        return float((ex * ex).sum())

    # bounded move count: each iteration re-derives candidates with
    # per-part argsorts, so an O(n) bound would be O(n² log n) at the
    # finest level; the residual past a few hundred single-row moves is
    # within the property-tested 2·vmax slack anyway
    for _ in range(min(4 * len(parts), 512)):
        over = np.nonzero((loads > caps + 1e-9).any(axis=1))[0]
        if not len(over):
            break
        best_move, best_dphi = None, -1e-12
        for p in over:
            verts = np.nonzero(parts == p)[0]
            # candidates: the heaviest vertices on each violated
            # constraint (hubs shift load fastest) + a light-vertex tail
            # (fine-grained count adjustment)
            cand: list = []
            for c in np.nonzero(loads[p] > caps + 1e-9)[0]:
                w = level.vwgts[verts, c]
                cand.extend(verts[np.argsort(-w, kind="stable")[:8]])
            cand.extend(verts[np.argsort(
                level.vwgts[verts].sum(axis=1), kind="stable")[:32]])
            phi_p = _phi_part(loads[p])
            for v in dict.fromkeys(int(x) for x in cand):
                d_p = _phi_part(loads[p] - level.vwgts[v]) - phi_p
                for q in range(k):
                    if q == p:
                        continue
                    d_q = (_phi_part(loads[q] + level.vwgts[v])
                           - _phi_part(loads[q]))
                    if d_p + d_q < best_dphi:
                        best_move, best_dphi = (v, p, q), d_p + d_q
        if best_move is None:
            break
        v, p, q = best_move
        parts[v] = q
        loads[p] -= level.vwgts[v]
        loads[q] += level.vwgts[v]
    return parts


def _refine(level: _Level, parts, k, eps, passes=1):
    """Greedy boundary (KL/FM-style) refinement, multi-constraint safe.

    The paper runs a single refinement iteration per level for power-law
    graphs; ``passes=1`` mirrors that.
    """
    n = len(level.indptr) - 1
    caps = _balance_caps(level.vwgts, k, eps)
    loads = np.zeros((k, level.vwgts.shape[1]), dtype=np.float64)
    np.add.at(loads, parts, level.vwgts)
    src, dst = _fine_coo(level)
    for _ in range(passes):
        # boundary vertices: any edge crossing partitions
        cross = parts[src] != parts[dst]
        boundary = np.unique(src[cross])
        moved = 0
        for v in boundary:
            lo, hi = level.indptr[v], level.indptr[v + 1]
            nbr_p = parts[level.indices[lo:hi]]
            w = level.ewgts[lo:hi]
            own = parts[v]
            conn = np.zeros(k, dtype=np.float64)
            np.add.at(conn, nbr_p, w)
            gain = conn - conn[own]
            gain[own] = -np.inf
            # forbid moves that break any balance constraint
            feasible = ((loads + level.vwgts[v]) <= caps).all(axis=1)
            gain[~feasible] = -np.inf
            best = int(np.argmax(gain))
            if gain[best] > 0:
                parts[v] = best
                loads[own] -= level.vwgts[v]
                loads[best] += level.vwgts[v]
                moved += 1
        if moved == 0:
            break
    return parts


def partition_graph(g: CSRGraph, k: int, *,
                    vwgts: Optional[np.ndarray] = None,
                    eps: float = 0.08, seed: int = 0,
                    coarsen_to: Optional[int] = None,
                    degree_cap: bool = True,
                    refine_passes: int = 1) -> np.ndarray:
    """k-way multi-constraint partition. Returns parts: (n,) int32.

    ``vwgts`` (n, ncon) are the balance constraints; defaults to
    [ones, out_degree] (vertex + edge balance).
    """
    n = g.num_nodes
    if k <= 1 or n <= k:
        return (np.arange(n) % max(k, 1)).astype(np.int32)
    if vwgts is None:
        vwgts = np.stack([np.ones(n), np.diff(g.indptr)], axis=1).astype(np.float64)
    vwgts = np.asarray(vwgts, dtype=np.float64)
    if vwgts.ndim == 1:
        vwgts = vwgts[:, None]
    rng = np.random.default_rng(seed)
    if coarsen_to is None:
        coarsen_to = max(32 * k, 256)

    src, dst = to_coo(g)
    keep = src != dst
    indptr, indices, ewgts = _symmetrize(
        *_build_csr(src[keep], dst[keep], np.ones(keep.sum(), dtype=np.float64),
                    n, combine=True), n)
    levels = [_Level(indptr, indices, ewgts, vwgts, cmap=None)]
    while len(levels[-1].indptr) - 1 > coarsen_to:
        nxt = _coarsen(levels[-1], rng, degree_cap)
        if nxt is None:
            break
        levels.append(nxt)

    parts = _initial_partition(levels[-1], k, eps, rng)
    parts = _refine(levels[-1], parts, k, eps, passes=max(refine_passes, 2))
    parts = _rebalance(levels[-1], parts, k, eps)
    for fine, coarse in zip(levels[-2::-1], levels[:0:-1]):
        parts = parts[coarse.cmap]
        parts = _refine(fine, parts, k, eps, passes=refine_passes)
        parts = _rebalance(fine, parts, k, eps)
    return parts.astype(np.int32)


def random_partition(g: CSRGraph, k: int, seed: int = 0) -> np.ndarray:
    """Euler-style random partitioning (the paper's baseline contrast)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, k, size=g.num_nodes).astype(np.int32)


def edge_cut(g: CSRGraph, parts: np.ndarray) -> float:
    """Fraction of (directed) edges crossing partitions."""
    src, dst = to_coo(g)
    if len(src) == 0:
        return 0.0
    return float((parts[src] != parts[dst]).mean())


def balance_report(g: CSRGraph, parts: np.ndarray, vwgts: np.ndarray) -> np.ndarray:
    """Max-over-partitions imbalance factor per constraint:
    max_p load[p, c] / (total[c] / k). 1.0 == perfectly balanced."""
    k = int(parts.max()) + 1
    vwgts = np.asarray(vwgts, dtype=np.float64)
    if vwgts.ndim == 1:
        vwgts = vwgts[:, None]
    loads = np.zeros((k, vwgts.shape[1]))
    np.add.at(loads, parts, vwgts)
    ideal = vwgts.sum(axis=0) / k
    with np.errstate(divide="ignore", invalid="ignore"):
        return np.where(ideal > 0, loads.max(axis=0) / ideal, 1.0)


def make_constraints(g: CSRGraph, split_mask: Optional[np.ndarray] = None) -> np.ndarray:
    """§5.3.2's constraint matrix: vertices, edges, train/val/test counts,
    and per-ntype vertex counts for heterographs."""
    n = g.num_nodes
    cols = [np.ones(n), np.diff(g.indptr).astype(np.float64)]
    if split_mask is not None:
        for s in (1, 2, 3):
            cols.append((split_mask == s).astype(np.float64))
    if g.ntypes is not None and g.num_ntypes > 1:
        for t in range(g.num_ntypes):
            cols.append((g.ntypes == t).astype(np.float64))
    return np.stack(cols, axis=1)
