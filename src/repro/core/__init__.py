"""DistDGLv2's core contribution, reimplemented for JAX/TPU clusters:
hierarchical multi-constraint partitioning, distributed KVStore, distributed
owner-compute neighbor sampling, and the asynchronous mini-batch pipeline.
"""
from . import kvstore, partition, pipeline, sampler  # noqa: F401
