"""Distributed sparse (learnable) embeddings (§3.1 "sparse parameters",
§5.4, Fig. 4's "sparse emb update" arrow).

Embedding rows live in the KVStore next to the features; a mini-batch pulls
only the rows it touches, and the trainer pushes *row-sparse gradients*
back, where the owning server applies a row-wise Adam update. Dense model
parameters never flow through here — they take the all-reduce path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ...kernels import sparse_adam_apply
from .store import DistKVStore, KVClient


@dataclasses.dataclass
class SparseAdamConfig:
    lr: float = 1e-2
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8


class DistEmbedding:
    """num x dim learnable table, sharded by a node partition policy.

    Part of the public ``repro.api`` surface (DESIGN.md §8's
    ``dgl.distributed.DistEmbedding`` analogue): the table registers
    *mutable* (version-tracked), so it is also reachable as a writable
    ``DistTensor`` through ``DistGraph.ndata`` — row writes bump versions
    and invalidate trainer caches, exactly like ``push_grad``'s updates.
    """

    def __init__(self, store: DistKVStore, name: str, num: int, dim: int,
                 policy_name: str, *, seed: int = 0,
                 optim: Optional[SparseAdamConfig] = None,
                 dtype=np.float32, impl: str = "auto"):
        pol = store.policies[policy_name]
        assert pol.total == num, (pol.total, num)
        self.store = store
        self.name = name
        self.num = num
        self.dim = dim
        self.policy_name = policy_name
        self.optim = optim or SparseAdamConfig()
        # sparse-Adam implementation at the owners: "ref" = in-place NumPy,
        # "pallas" = the fused gather->update->scatter kernel ("auto" picks
        # pallas on TPU).  Both are bitwise-identical to the dense oracle.
        self.impl = impl
        rng = np.random.default_rng(seed)
        scale = 1.0 / np.sqrt(dim)
        # mutable=True: rows change under sparse-Adam pushes, so trainer
        # caches must version-check them (immutable features skip this)
        store.init_data(name, (dim,), dtype, policy_name,
                        init=lambda s: rng.standard_normal(s) * scale,
                        mutable=True)
        store.init_data(name + "__m", (dim,), np.float32, policy_name)
        store.init_data(name + "__v", (dim,), np.float32, policy_name)
        store.init_data(name + "__t", (), np.int64, policy_name)

    def __len__(self) -> int:
        return self.num

    @property
    def shape(self) -> tuple:
        return (self.num, self.dim)

    def pull(self, client: KVClient, ids: np.ndarray) -> np.ndarray:
        return client.pull(self.name, ids)

    def push_grad(self, client: KVClient, ids: np.ndarray, grad: np.ndarray) -> None:
        """Row-sparse Adam applied at the owners.

        Duplicate IDs within a batch are first coalesced (summed) so each
        row gets a single update — matching how DGL's sparse optimizer
        behaves under synchronous training.
        """
        # the optimizer-state writes below bypass KVClient.push, so run
        # its pre-write guard for every tensor this method mutates
        for suffix in ("", "__m", "__v", "__t"):
            self.store.check_writable(self.name + suffix)
        ids = np.asarray(ids, dtype=np.int64)
        uniq, inv = np.unique(ids, return_inverse=True)
        g = np.zeros((len(uniq), grad.shape[1]), dtype=np.float32)
        np.add.at(g, inv, grad.astype(np.float32))

        store, cfg = self.store, self.optim
        pol = store.policy_for(self.name)
        parts = pol.part_of(uniq)
        local = pol.local_of(uniq, parts)
        for p in range(store.num_parts):
            m = parts == p
            if not m.any():
                continue
            srv = store.servers[p]
            rows = local[m]
            gm = g[m]
            # charge the gradient shipment to EVERY copy holder BEFORE the
            # owner applies it — same ordering as KVClient.push: a
            # transient-fault retry (client._charge_remote) must never
            # re-run an Adam step. A holder inside a down window gets its
            # charge skipped (deferred replica write, DESIGN.md §12); the
            # update only fails when no copy holder accepted it.
            nbytes = gm.nbytes
            holders = (store.replicas_of(p) if hasattr(store, "replicas_of")
                       else (p,))
            machine = getattr(client, "machine", p)
            delivered = 0
            last = None
            for h in holders:
                if h == machine:
                    store.transport.charge_local(nbytes)
                    delivered += 1
                elif hasattr(client, "_charge_remote"):
                    try:
                        client._charge_remote(nbytes, op="push", dst=h)
                        delivered += 1
                    except Exception as e:
                        if len(holders) == 1:
                            raise
                        last = e
                        store.transport.note_deferred_replica_write()
                else:
                    store.transport.charge_remote(nbytes, op="push")
                    delivered += 1
            if delivered == 0:
                raise last
            t = srv.local_view(self.name + "__t")
            mm = srv.local_view(self.name + "__m")
            vv = srv.local_view(self.name + "__v")
            w = srv.local_view(self.name)
            # fused gather -> Adam -> scatter on the owner's local views
            # (kernels.sparse_adam; bitwise contract with the old inline
            # NumPy update either impl)
            sparse_adam_apply(w, mm, vv, rows, gm, t, beta1=cfg.beta1,
                              beta2=cfg.beta2, lr=cfg.lr, eps=cfg.eps,
                              impl=self.impl)
            # synchronous replication: copy the post-Adam rows (weights AND
            # optimizer state) to every replica, so a failover read of any
            # tensor in the family is byte-identical to the primary
            store.copy_rows_to_replicas(self.name, p, rows)
            store.copy_rows_to_replicas(self.name + "__m", p, rows)
            store.copy_rows_to_replicas(self.name + "__v", p, rows)
            # __t is a per-row step counter with scalar rows
            store.copy_rows_to_replicas(self.name + "__t", p, rows)
        # AFTER the owners applied the update: bump versions + drop own
        # cached copies (the shared writer protocol)
        client.notify_write(self.name, uniq)
