from .transport import NetworkModel, Transport
from .store import DistKVStore, KVClient, KVServer, PartitionPolicy
from .embedding import DistEmbedding, SparseAdamConfig

__all__ = [
    "NetworkModel", "Transport", "DistKVStore", "KVClient", "KVServer",
    "PartitionPolicy", "DistEmbedding", "SparseAdamConfig",
]
