from .transport import NetworkModel, PeerHealth, Transport
from .store import DistKVStore, KVClient, KVServer, PartitionPolicy
from .embedding import DistEmbedding, SparseAdamConfig
from .cache import CacheConfig, FeatureCache, halo_access_counts
from .faults import (FaultInjector, OwnerDownError, OwnerDownWindow,
                     OwnerUnavailable, RPCRetriesExhausted, TrainerDeath,
                     TransientRPCError)

__all__ = [
    "NetworkModel", "PeerHealth", "Transport", "DistKVStore", "KVClient",
    "KVServer", "PartitionPolicy", "DistEmbedding", "SparseAdamConfig",
    "CacheConfig", "FeatureCache", "halo_access_counts",
    "FaultInjector", "TransientRPCError", "RPCRetriesExhausted",
    "TrainerDeath", "OwnerDownError", "OwnerDownWindow", "OwnerUnavailable",
]
