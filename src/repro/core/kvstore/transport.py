"""Transport layer between KVStore clients and servers.

On a real cluster the local partition is reached through shared memory
(zero-copy — §5.4 "uses shared memory to access data in the local KVStore
server to minimize data copy") and remote partitions over TCP. This
container is one host, so *correctness* is exact (separate per-partition
arrays, all remote accesses go through ``remote_fetch``/``remote_apply``)
while the *network cost* is modeled: every remote byte is charged to a
latency+bandwidth accountant that benchmarks read out, and can optionally
really sleep to make pipeline-overlap benchmarks honest in wall-clock.

Availability plumbing (DESIGN.md §12): charges may carry a destination
server id (``dst``), and the transport keeps a :class:`PeerHealth`
circuit breaker per destination — consecutive failures open the breaker,
an open breaker half-opens after a cooldown on the *simulated* clock, and
a successful probe closes it. The breaker never blocks a charge by
itself (replication r=1 must behave exactly as before); it informs the
client's *routing*: the replicated read path orders candidates
available-first and skips open destinations instead of burning the whole
retry budget on a dead server.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional

from .faults import OwnerDownError, TransientRPCError


@dataclasses.dataclass
class NetworkModel:
    """Cost model: t = latency + bytes / bandwidth (per request)."""
    latency_s: float = 100e-6           # ~100us RPC latency
    bandwidth_Bps: float = 12.5e9       # 100 Gbps, the paper's cluster
    sleep: bool = False                 # really sleep (for wall-clock benches)

    def cost(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bandwidth_Bps


class PeerHealth:
    """Per-destination circuit breaker (DESIGN.md §12).

    States per peer: **closed** (healthy — all traffic allowed), **open**
    (``failure_threshold`` consecutive failures — presumed dead), and
    **half-open** (``open_window_s`` of simulated time elapsed since the
    breaker opened — one probe is allowed; success closes it, failure
    reopens it and restarts the cooldown). Time comes from a caller-
    supplied clock so the state machine is driven by the *simulated*
    clock, keeping chaos tests deterministic.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"

    def __init__(self, clock, *, failure_threshold: int = 3,
                 open_window_s: float = 0.1):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        self._clock = clock
        self.failure_threshold = int(failure_threshold)
        self.open_window_s = float(open_window_s)
        self._lock = threading.Lock()
        self._consecutive: Dict[int, int] = {}
        self._opened_at: Dict[int, float] = {}
        self.breaker_opens = 0

    def state(self, dst: int) -> str:
        dst = int(dst)
        with self._lock:
            if dst not in self._opened_at:
                return self.CLOSED
            if self._clock() - self._opened_at[dst] >= self.open_window_s:
                return self.HALF_OPEN
            return self.OPEN

    def available(self, dst: int) -> bool:
        """True when traffic to ``dst`` is worth attempting (closed or
        half-open — a half-open peer gets its probe)."""
        return self.state(dst) != self.OPEN

    def record_success(self, dst: int) -> None:
        dst = int(dst)
        with self._lock:
            self._consecutive[dst] = 0
            self._opened_at.pop(dst, None)

    def record_failure(self, dst: int) -> None:
        dst = int(dst)
        with self._lock:
            was_open = dst in self._opened_at
            if was_open:
                # a failed half-open probe reopens and restarts the cooldown
                self._opened_at[dst] = self._clock()
                return
            n = self._consecutive.get(dst, 0) + 1
            self._consecutive[dst] = n
            if n >= self.failure_threshold:
                self._opened_at[dst] = self._clock()
                self.breaker_opens += 1

    def stats(self) -> dict:
        with self._lock:
            return {"breaker_opens": self.breaker_opens,
                    "open_peers": sorted(self._opened_at)}


class Transport:
    def __init__(self, model: NetworkModel | None = None,
                 fault_injector=None):
        self.model = model or NetworkModel()
        # optional FaultInjector (kvstore.faults): charge_remote raises
        # TransientRPCError on its deterministic schedule. None (default)
        # keeps the fault check off the hot path entirely.
        self.fault_injector = fault_injector
        self._lock = threading.Lock()
        self.remote_bytes = 0
        self.remote_requests = 0
        self.local_bytes = 0
        self.simulated_time_s = 0.0
        # transient-fault accounting (kvstore.faults): injected failures
        # and the retries/backoffs clients paid recovering from them
        self.rpc_failures = 0
        self.rpc_retries = 0
        # hot-vertex cache accounting (kvstore.cache): bytes a remote fetch
        # WOULD have moved but a trainer-side cache hit absorbed — the
        # paper-style traffic-reduction numerator for benchmarks
        self.cache_hits = 0
        self.cache_misses = 0
        self.saved_remote_bytes = 0
        # availability accounting (DESIGN.md §12)
        self.owner_down_failures = 0    # charges refused by a down window
        self.failovers = 0              # reads served by a non-primary copy
        self.hedged_reads = 0           # hedge timers that fired
        self.hedge_wins = 0             # hedged replica attempt succeeded
        self.deferred_replica_writes = 0  # write charges skipped: dst down
        self.degraded_pulls = 0         # rows served stale/zero-filled
        self.health = PeerHealth(lambda: self.simulated_time_s)

    def charge_cache_hit(self, nbytes: int, rows: int = 1) -> None:
        with self._lock:
            self.cache_hits += rows
            self.saved_remote_bytes += nbytes

    def charge_cache_miss(self, rows: int = 1) -> None:
        with self._lock:
            self.cache_misses += rows

    def charge_remote(self, nbytes: int, op: str = "data",
                      dst: Optional[int] = None) -> None:
        inj = self.fault_injector
        if (inj is not None and dst is not None
                and inj.owner_is_down(dst, op)):
            # the destination server is inside a sustained down window:
            # the request times out after one round trip, no bytes move
            with self._lock:
                self.rpc_failures += 1
                self.owner_down_failures += 1
                self.simulated_time_s += self.model.latency_s
            if dst is not None:
                self.health.record_failure(dst)
            if self.model.sleep:
                time.sleep(self.model.latency_s)
            raise OwnerDownError(
                f"server {dst} is down (injected outage) on {op!r} RPC "
                f"({nbytes}B)")
        if inj is not None and inj.rpc_should_fail(op):
            # a failed RPC still burned a round trip before the error came
            # back; the payload bytes never moved
            with self._lock:
                self.rpc_failures += 1
                self.simulated_time_s += self.model.latency_s
            if dst is not None:
                self.health.record_failure(dst)
            if self.model.sleep:
                time.sleep(self.model.latency_s)
            raise TransientRPCError(
                f"injected transient failure on {op!r} RPC ({nbytes}B)")
        t = self.model.cost(nbytes)
        with self._lock:
            self.remote_bytes += nbytes
            self.remote_requests += 1
            self.simulated_time_s += t
        if dst is not None:
            self.health.record_success(dst)
        if self.model.sleep:
            time.sleep(t)

    def charge_retry_backoff(self, delay_s: float) -> None:
        """One retry's backoff wait, charged to the simulated clock (and
        really slept when the model sleeps — wall-clock benches stay
        honest about recovery cost)."""
        with self._lock:
            self.rpc_retries += 1
            self.simulated_time_s += delay_s
        if self.model.sleep:
            time.sleep(delay_s)

    def charge_hedge_delay(self, delay_s: float) -> None:
        """The hedge timer firing: the primary read is ``delay_s`` late,
        so a replica attempt is launched (DESIGN.md §12)."""
        with self._lock:
            self.hedged_reads += 1
            self.simulated_time_s += delay_s
        if self.model.sleep:
            time.sleep(delay_s)

    def charge_local(self, nbytes: int) -> None:
        with self._lock:
            self.local_bytes += nbytes

    # -- availability accounting hooks (DESIGN.md §12) --------------------
    def note_failover(self) -> None:
        with self._lock:
            self.failovers += 1

    def note_hedge_win(self) -> None:
        with self._lock:
            self.hedge_wins += 1

    def note_deferred_replica_write(self) -> None:
        with self._lock:
            self.deferred_replica_writes += 1

    def note_degraded(self, rows: int = 1) -> None:
        with self._lock:
            self.degraded_pulls += rows

    def stats(self) -> dict:
        with self._lock:
            looked_up = self.cache_hits + self.cache_misses
            return {
                "remote_bytes": self.remote_bytes,
                "remote_requests": self.remote_requests,
                "local_bytes": self.local_bytes,
                "simulated_network_s": self.simulated_time_s,
                "rpc_failures": self.rpc_failures,
                "rpc_retries": self.rpc_retries,
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "cache_hit_rate": self.cache_hits / max(looked_up, 1),
                "saved_remote_bytes": self.saved_remote_bytes,
                # conservative in-run estimate (DESIGN.md §5): the
                # denominator is ALL remote traffic — sampling RPCs and
                # pushes included — so this understates the pull-only
                # reduction; the table2 ablation's on/off comparison is
                # the controlled number
                "remote_traffic_reduction": self.saved_remote_bytes / max(
                    self.saved_remote_bytes + self.remote_bytes, 1),
                # availability accounting (DESIGN.md §12)
                "owner_down_failures": self.owner_down_failures,
                "failovers": self.failovers,
                "hedged_reads": self.hedged_reads,
                "hedge_wins": self.hedge_wins,
                "deferred_replica_writes": self.deferred_replica_writes,
                "degraded_pulls": self.degraded_pulls,
                "breaker_opens": self.health.breaker_opens,
            }

    def reset(self) -> None:
        with self._lock:
            self.remote_bytes = 0
            self.remote_requests = 0
            self.local_bytes = 0
            self.simulated_time_s = 0.0
            self.rpc_failures = 0
            self.rpc_retries = 0
            self.cache_hits = 0
            self.cache_misses = 0
            self.saved_remote_bytes = 0
            self.owner_down_failures = 0
            self.failovers = 0
            self.hedged_reads = 0
            self.hedge_wins = 0
            self.deferred_replica_writes = 0
            self.degraded_pulls = 0
        self.health = PeerHealth(lambda: self.simulated_time_s)
