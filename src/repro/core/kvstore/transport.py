"""Transport layer between KVStore clients and servers.

On a real cluster the local partition is reached through shared memory
(zero-copy — §5.4 "uses shared memory to access data in the local KVStore
server to minimize data copy") and remote partitions over TCP. This
container is one host, so *correctness* is exact (separate per-partition
arrays, all remote accesses go through ``remote_fetch``/``remote_apply``)
while the *network cost* is modeled: every remote byte is charged to a
latency+bandwidth accountant that benchmarks read out, and can optionally
really sleep to make pipeline-overlap benchmarks honest in wall-clock.
"""
from __future__ import annotations

import dataclasses
import threading
import time

from .faults import TransientRPCError


@dataclasses.dataclass
class NetworkModel:
    """Cost model: t = latency + bytes / bandwidth (per request)."""
    latency_s: float = 100e-6           # ~100us RPC latency
    bandwidth_Bps: float = 12.5e9       # 100 Gbps, the paper's cluster
    sleep: bool = False                 # really sleep (for wall-clock benches)

    def cost(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bandwidth_Bps


class Transport:
    def __init__(self, model: NetworkModel | None = None,
                 fault_injector=None):
        self.model = model or NetworkModel()
        # optional FaultInjector (kvstore.faults): charge_remote raises
        # TransientRPCError on its deterministic schedule. None (default)
        # keeps the fault check off the hot path entirely.
        self.fault_injector = fault_injector
        self._lock = threading.Lock()
        self.remote_bytes = 0
        self.remote_requests = 0
        self.local_bytes = 0
        self.simulated_time_s = 0.0
        # transient-fault accounting (kvstore.faults): injected failures
        # and the retries/backoffs clients paid recovering from them
        self.rpc_failures = 0
        self.rpc_retries = 0
        # hot-vertex cache accounting (kvstore.cache): bytes a remote fetch
        # WOULD have moved but a trainer-side cache hit absorbed — the
        # paper-style traffic-reduction numerator for benchmarks
        self.cache_hits = 0
        self.cache_misses = 0
        self.saved_remote_bytes = 0

    def charge_cache_hit(self, nbytes: int, rows: int = 1) -> None:
        with self._lock:
            self.cache_hits += rows
            self.saved_remote_bytes += nbytes

    def charge_cache_miss(self, rows: int = 1) -> None:
        with self._lock:
            self.cache_misses += rows

    def charge_remote(self, nbytes: int, op: str = "data") -> None:
        inj = self.fault_injector
        if inj is not None and inj.rpc_should_fail(op):
            # a failed RPC still burned a round trip before the error came
            # back; the payload bytes never moved
            with self._lock:
                self.rpc_failures += 1
                self.simulated_time_s += self.model.latency_s
            if self.model.sleep:
                time.sleep(self.model.latency_s)
            raise TransientRPCError(
                f"injected transient failure on {op!r} RPC ({nbytes}B)")
        t = self.model.cost(nbytes)
        with self._lock:
            self.remote_bytes += nbytes
            self.remote_requests += 1
            self.simulated_time_s += t
        if self.model.sleep:
            time.sleep(t)

    def charge_retry_backoff(self, delay_s: float) -> None:
        """One retry's backoff wait, charged to the simulated clock (and
        really slept when the model sleeps — wall-clock benches stay
        honest about recovery cost)."""
        with self._lock:
            self.rpc_retries += 1
            self.simulated_time_s += delay_s
        if self.model.sleep:
            time.sleep(delay_s)

    def charge_local(self, nbytes: int) -> None:
        with self._lock:
            self.local_bytes += nbytes

    def stats(self) -> dict:
        with self._lock:
            looked_up = self.cache_hits + self.cache_misses
            return {
                "remote_bytes": self.remote_bytes,
                "remote_requests": self.remote_requests,
                "local_bytes": self.local_bytes,
                "simulated_network_s": self.simulated_time_s,
                "rpc_failures": self.rpc_failures,
                "rpc_retries": self.rpc_retries,
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "cache_hit_rate": self.cache_hits / max(looked_up, 1),
                "saved_remote_bytes": self.saved_remote_bytes,
                # conservative in-run estimate (DESIGN.md §5): the
                # denominator is ALL remote traffic — sampling RPCs and
                # pushes included — so this understates the pull-only
                # reduction; the table2 ablation's on/off comparison is
                # the controlled number
                "remote_traffic_reduction": self.saved_remote_bytes / max(
                    self.saved_remote_bytes + self.remote_bytes, 1),
            }

    def reset(self) -> None:
        with self._lock:
            self.remote_bytes = 0
            self.remote_requests = 0
            self.local_bytes = 0
            self.simulated_time_s = 0.0
            self.rpc_failures = 0
            self.rpc_retries = 0
            self.cache_hits = 0
            self.cache_misses = 0
            self.saved_remote_bytes = 0
