"""Hot-vertex feature cache on the KVStore read path (ROADMAP "caching").

DistDGLv2 attacks remote feature pulls with min-edge-cut partitioning and
the async pipeline; the next lever — caching frequently accessed *remote*
rows on the trainer — is standard in the distributed-GNN literature
(Vatter et al., arXiv:2305.13854) and directly targets the remote-pull
breakdown of DistDGL's Table 4 (arXiv:2010.05337). This module provides a
per-trainer :class:`FeatureCache` that any :class:`~.store.KVClient` can
consult:

* **scope** — only rows owned by a *remote* partition are ever cached; the
  local partition is shared memory already (caching it would only copy);
* **admission** — pre-warm from the partition book's halo access counts
  (:func:`halo_access_counts`: a halo vertex's local in-edge count is a
  static prediction of its pull frequency), then online frequency — a row
  is admitted once it has been pulled ``admit_after`` times;
* **eviction** — CLOCK (second chance, O(1) amortized) or strict LRU under
  a per-trainer byte budget shared by all registered tensors;
* **consistency** — mutable tables (``DistEmbedding`` rows updated by
  sparse-Adam pushes) carry per-row version counters in the
  ``DistKVStore``; a cached row whose stored version no longer matches is
  a miss and is refreshed, so the cache **never serves stale data**.
  Immutable feature tensors skip version bookkeeping entirely (no counter
  reads on the hot path). See DESIGN.md §5 for the full contract.

The cache-on read path is numerically byte-identical to cache-off (guarded
by the golden-hash tests): a hit returns exactly the bytes the owning
server would have sent.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Per-trainer cache policy knobs (wired through ``TrainJobConfig`` and
    ``launch/train.py --cache-budget-mb / --cache-policy``)."""
    budget_bytes: int = 64 * 1024 * 1024
    policy: str = "clock"          # "clock" | "lru"
    admit_after: int = 1           # admit a row on its admit_after-th miss
    prewarm: bool = True           # pre-warm from halo access counts
    prewarm_frac: float = 1.0      # fraction of the budget prewarm may fill
    # only pre-pull halo rows this many local edges reference: a count-1
    # row may never be sampled at all (fanout subsampling), so paying its
    # pull up front is a pure byte loss; multiply-referenced rows are
    # near-certain repeat pulls and amortize immediately
    prewarm_min_count: int = 2

    @staticmethod
    def from_mb(budget_mb: float, policy: str = "clock",
                **kw) -> "CacheConfig":
        return CacheConfig(budget_bytes=int(budget_mb * 1024 * 1024),
                           policy=policy, **kw)

    def __post_init__(self):
        if self.policy not in ("clock", "lru"):
            raise ValueError(f"unknown cache policy {self.policy!r}")
        if self.budget_bytes <= 0:
            raise ValueError("cache budget must be positive")


def halo_access_counts(partition) -> Tuple[np.ndarray, np.ndarray]:
    """Static pull-frequency prediction from one machine's partition.

    A partition's halo vertices are exactly the remote endpoints its local
    edges reference; each halo vertex's local in-edge count is how many
    edge slots can demand its features, i.e. the partition book's access
    count for that remote vertex. Returns ``(gids, counts)`` sorted by
    count descending (ties broken by gid for determinism).
    """
    n_core = partition.n_core
    halo_local = partition.indices[partition.indices >= n_core] - n_core
    counts = np.bincount(halo_local, minlength=partition.n_halo)
    gids = partition.local2global[n_core:]
    order = np.lexsort((gids, -counts))
    return gids[order], counts[order]


class _TensorCache:
    """One tensor's slab: a growable row array + gid->slot map.

    ``slot_of`` is an ``OrderedDict`` so the LRU policy is O(1)
    (``move_to_end`` on hit, first entry is the victim); CLOCK ignores the
    order and uses the ``ref`` second-chance bits instead.
    """

    def __init__(self, name: str, row_shape: tuple, dtype, row_nbytes: int,
                 mutable: bool, policy: str):
        self.name = name
        self.row_shape = tuple(row_shape)
        self.dtype = np.dtype(dtype)
        self.row_nbytes = row_nbytes
        self.mutable = mutable
        self.policy = policy
        self.rows = np.empty((0,) + self.row_shape, dtype=self.dtype)
        self.slot_gid = np.empty(0, dtype=np.int64)      # slot -> gid
        self.ref = np.empty(0, dtype=bool)               # CLOCK ref bits
        self.version = np.empty(0, dtype=np.int64)       # mutable only
        self.slot_of: "OrderedDict[int, int]" = OrderedDict()
        self.free: List[int] = []
        self.hand = 0
        self.freq: Dict[int, int] = {}

    @property
    def num_rows(self) -> int:
        return len(self.slot_of)

    def _grow(self, min_slots: int, max_slots: int) -> None:
        cur = len(self.slot_gid)
        new = min(max(2 * cur, min_slots, 64), max_slots)
        if new <= cur:
            return
        rows = np.empty((new,) + self.row_shape, dtype=self.dtype)
        rows[:cur] = self.rows
        self.rows = rows
        self.slot_gid = np.concatenate(
            [self.slot_gid, np.full(new - cur, -1, dtype=np.int64)])
        self.ref = np.concatenate([self.ref, np.zeros(new - cur, dtype=bool)])
        self.version = np.concatenate(
            [self.version, np.zeros(new - cur, dtype=np.int64)])
        self.free.extend(range(cur, new))

    def evict_one(self) -> bool:
        """Free one slot per the eviction policy. False if nothing cached."""
        if not self.slot_of:
            return False
        if self.policy == "lru":
            gid, slot = self.slot_of.popitem(last=False)
        else:   # CLOCK: advance the hand, clearing second-chance bits
            n = len(self.slot_gid)
            while True:
                self.hand %= n
                s = self.hand
                self.hand += 1
                if self.slot_gid[s] < 0:
                    continue
                if self.ref[s]:
                    self.ref[s] = False
                    continue
                slot, gid = s, int(self.slot_gid[s])
                del self.slot_of[gid]
                break
        self.slot_gid[slot] = -1
        self.ref[slot] = False
        self.free.append(slot)
        return True

    def invalidate(self, gid: int) -> bool:
        slot = self.slot_of.pop(gid, None)
        if slot is None:
            return False
        self.slot_gid[slot] = -1
        self.ref[slot] = False
        self.free.append(slot)
        return True


class FeatureCache:
    """Per-trainer hot-vertex cache over remote KVStore rows.

    One instance per trainer (attach with ``KVClient.attach_cache``); the
    sampling thread's CPU-prefetch pulls and the training thread's
    embedding pulls may interleave, so all public methods lock.

    ``lookup`` / ``insert`` are the two halves of the read path: the
    client looks up remote ids, fetches the misses from the owning
    servers, and inserts what came back (admission permitting). ``warm``
    force-inserts pre-pulled rows, bypassing frequency admission.
    """

    def __init__(self, config: CacheConfig, store=None):
        self.config = config
        self.store = store          # version authority for mutable tensors
        self._tensors: Dict[str, _TensorCache] = {}
        self._lock = threading.RLock()
        self.used_bytes = 0
        self.hits = 0
        self.misses = 0
        self.stale_hits = 0         # version-mismatched entries refreshed
        self.degraded_hits = 0      # stale rows served by lookup_stale
        self.evictions = 0
        self.rejected = 0           # admission-declined inserts

    # -- registration ---------------------------------------------------
    def register(self, store, name: str) -> None:
        """Register one KVStore tensor (idempotent). Row shape/dtype come
        from the store; mutability from the store's version table."""
        with self._lock:
            if name in self._tensors:
                return
            self.store = store
            sample = store.servers[0].local_view(name)
            row_shape = sample.shape[1:]
            row_nbytes = int(sample.dtype.itemsize
                             * int(np.prod(row_shape, initial=1)))
            if row_nbytes > self.config.budget_bytes:
                raise ValueError(
                    f"cache budget {self.config.budget_bytes}B below one "
                    f"{name!r} row ({row_nbytes}B)")
            self._tensors[name] = _TensorCache(
                name, row_shape, sample.dtype, row_nbytes,
                mutable=store.is_mutable(name), policy=self.config.policy)
            store.note_cache_registration(name, self)

    def has(self, name: str) -> bool:
        return name in self._tensors

    # -- read path ------------------------------------------------------
    def lookup(self, name: str, gids: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray]:
        """(hit_mask, rows[hits]) for remote ``gids``; counts frequency on
        every access. Mutable tensors: a version-mismatched entry is
        invalidated and reported as a miss (never stale data)."""
        tc = self._tensors[name]
        gids = np.asarray(gids, dtype=np.int64)
        with self._lock:
            slots = np.fromiter((tc.slot_of.get(int(g), -1) for g in gids),
                                dtype=np.int64, count=len(gids))
            hit = slots >= 0
            if tc.mutable and hit.any():
                cur = self.store.versions_of(name, gids[hit])
                fresh = tc.version[slots[hit]] == cur
                if not fresh.all():
                    for g in gids[hit][~fresh]:
                        if tc.invalidate(int(g)):
                            self.used_bytes -= tc.row_nbytes
                            self.stale_hits += 1
                    idx = np.nonzero(hit)[0][~fresh]
                    hit[idx] = False
                    slots[idx] = -1
            n_hit = int(hit.sum())
            rows = tc.rows[slots[hit]].copy() if n_hit else \
                np.empty((0,) + tc.row_shape, dtype=tc.dtype)
            # touch: CLOCK second-chance bit / LRU recency
            if n_hit:
                tc.ref[slots[hit]] = True
                if tc.policy == "lru":
                    for g in gids[hit]:
                        tc.slot_of.move_to_end(int(g))
            # admission frequency only matters past the first miss; with
            # admit_after <= 1 (the default) skip the bookkeeping — on a
            # billion-scale graph the dict would otherwise accumulate one
            # entry per ever-missed remote vertex
            if self.config.admit_after > 1:
                for g in gids[~hit]:
                    g = int(g)
                    tc.freq[g] = tc.freq.get(g, 0) + 1
                # bound the counter dict to a few multiples of the slot
                # count — admission bookkeeping must not dwarf the row
                # budget it guards; losing partial counts only delays
                # admission, never breaks correctness
                cap = max(4 * (self.config.budget_bytes // tc.row_nbytes),
                          4096)
                if len(tc.freq) > cap:
                    tc.freq = {g: c for g, c in tc.freq.items()
                               if c >= self.config.admit_after}
                    if len(tc.freq) > cap:
                        tc.freq.clear()
            self.hits += n_hit
            self.misses += len(gids) - n_hit
            return hit, rows

    def lookup_stale(self, name: str, gids: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """(hit_mask, rows[hits]) with version checks SKIPPED — the
        degraded-serving salvage path (DESIGN.md §12), used only when
        every copy of the owner is unreachable. A possibly-stale row beats
        a zero-filled one: the bytes were valid when cached (bounded
        staleness — at most the writes since this entry was inserted).
        Accounted separately (``degraded_hits``) and touches neither the
        hit/miss counters nor recency/frequency state, so degraded reads
        never perturb the normal cache policy."""
        tc = self._tensors[name]
        gids = np.asarray(gids, dtype=np.int64)
        with self._lock:
            slots = np.fromiter((tc.slot_of.get(int(g), -1) for g in gids),
                                dtype=np.int64, count=len(gids))
            hit = slots >= 0
            n_hit = int(hit.sum())
            rows = tc.rows[slots[hit]].copy() if n_hit else \
                np.empty((0,) + tc.row_shape, dtype=tc.dtype)
            self.degraded_hits += n_hit
            return hit, rows

    def insert(self, name: str, gids: np.ndarray, rows: np.ndarray,
               force: bool = False,
               versions: Optional[np.ndarray] = None) -> int:
        """Admit fetched remote rows; returns how many were admitted.

        Regular inserts respect frequency admission (``admit_after``
        misses recorded by ``lookup``); ``force=True`` (pre-warm) bypasses
        it. For mutable tensors ``versions`` is the caller's snapshot taken
        *before* the fetch — entries whose store version moved since are
        skipped (the rows might predate a concurrent push). ``None`` falls
        back to a snapshot taken now, which is only safe when no writer
        can run concurrently with the caller's fetch."""
        tc = self._tensors[name]
        gids = np.asarray(gids, dtype=np.int64)
        ok = np.ones(len(gids), dtype=bool)
        if tc.mutable:
            cur = self.store.versions_of(name, gids)
            if versions is None:
                versions = cur
            else:
                ok = versions == cur
        admitted = 0
        with self._lock:
            max_slots = self.config.budget_bytes // tc.row_nbytes
            for i, g in enumerate(gids):
                g = int(g)
                if not ok[i]:
                    continue
                if g in tc.slot_of:       # refresh in place (post-invalidate
                    s = tc.slot_of[g]     # re-pull lands here)
                    tc.rows[s] = rows[i]
                    if tc.mutable:
                        tc.version[s] = versions[i]
                    continue
                if (not force and self.config.admit_after > 1
                        and tc.freq.get(g, 0) < self.config.admit_after):
                    self.rejected += 1
                    continue
                if not self._make_room(tc, max_slots):
                    self.rejected += 1
                    continue
                s = tc.free.pop()
                tc.rows[s] = rows[i]
                tc.slot_gid[s] = g
                tc.ref[s] = False
                if tc.mutable:
                    tc.version[s] = versions[i]
                tc.slot_of[g] = s
                self.used_bytes += tc.row_nbytes
                admitted += 1
        return admitted

    def _make_room(self, tc: _TensorCache, max_slots: int) -> bool:
        """Ensure ``tc`` has a free slot within the global byte budget.

        Budget pressure evicts from whichever tensor holds the most bytes
        (possibly ``tc`` itself) — always self-evicting would freeze any
        tensor registered after the budget filled at ~one row while
        earlier tensors kept cold rows forever."""
        if tc.num_rows >= max_slots:
            if not tc.evict_one():
                return False
            self.used_bytes -= tc.row_nbytes
            self.evictions += 1
        while self.used_bytes + tc.row_nbytes > self.config.budget_bytes:
            victim = max((t for t in self._tensors.values() if t.num_rows),
                         key=lambda t: t.num_rows * t.row_nbytes,
                         default=None)
            if victim is None or not victim.evict_one():
                return False
            self.used_bytes -= victim.row_nbytes
            self.evictions += 1
        if not tc.free:
            tc._grow(tc.num_rows + 1, max_slots)
        return bool(tc.free)

    # -- invalidation ---------------------------------------------------
    def drop(self, name: str) -> None:
        """Flush every entry of one tensor (bulk rewrites — checkpoint
        restore — where even immutable bytes change)."""
        if name not in self._tensors:
            return
        tc = self._tensors[name]
        with self._lock:
            for gid in list(tc.slot_of):
                if tc.invalidate(gid):
                    self.used_bytes -= tc.row_nbytes

    def invalidate(self, name: str, gids: np.ndarray) -> None:
        """Drop entries eagerly (e.g. the pushing trainer's own cache);
        version checks already protect correctness without this."""
        if name not in self._tensors:
            return
        tc = self._tensors[name]
        with self._lock:
            for g in np.asarray(gids, dtype=np.int64):
                if tc.invalidate(int(g)):
                    self.used_bytes -= tc.row_nbytes

    # -- checkpoint (DESIGN.md §10) --------------------------------------
    def state_dict(self) -> Dict[str, dict]:
        """Snapshot every registered tensor's cached rows.

        Per tensor: ``gids`` in recency order (oldest first — restoring
        inserts in that order, so the LRU/CLOCK recency structure
        survives), the matching ``rows``, and (mutable tensors only) the
        per-row ``versions`` the entries were stamped with. Restoring is
        only byte-safe together with the store's version tables from the
        SAME checkpoint — ``repro.checkpoint`` saves/loads the pair."""
        with self._lock:
            out: Dict[str, dict] = {}
            for name, tc in self._tensors.items():
                n = len(tc.slot_of)
                gids = np.fromiter(tc.slot_of.keys(), np.int64, count=n)
                slots = np.fromiter(tc.slot_of.values(), np.int64, count=n)
                out[name] = {
                    "gids": gids,
                    "rows": tc.rows[slots].copy(),
                    "versions": (tc.version[slots].copy()
                                 if tc.mutable else None),
                }
            return out

    def load_state_dict(self, state: Dict[str, dict]) -> int:
        """Restore a :meth:`state_dict` snapshot; returns rows admitted.

        Existing entries for the snapshot's tensors are dropped first
        (they predate or postdate the checkpoint — either way they are
        not the checkpoint's). Entries whose saved version no longer
        matches the store's current table are refused by ``insert``'s
        version check, so a snapshot restored against a *different*
        store state degrades to a cold cache instead of serving stale
        bytes."""
        admitted = 0
        for name, s in state.items():
            if name not in self._tensors:
                continue   # tensor not registered in this cache instance
            self.drop(name)
            admitted += self.insert(name, s["gids"], s["rows"], force=True,
                                    versions=s["versions"])
        return admitted

    # -- pre-warm -------------------------------------------------------
    def warm(self, client, name: str, gids: np.ndarray,
             counts: Optional[np.ndarray] = None) -> int:
        """Pre-fill from predicted-hot remote rows (one batched pull, the
        only time the cache itself creates traffic). ``gids``/``counts``
        come from :func:`halo_access_counts`; rows are admitted hottest
        first until ``prewarm_frac`` of the budget is full."""
        self.register(client.store, name)
        tc = self._tensors[name]
        gids = np.asarray(gids, dtype=np.int64)
        if counts is not None:
            counts = np.asarray(counts)
            keep = counts >= self.config.prewarm_min_count
            gids, counts = gids[keep], counts[keep]
            order = np.lexsort((gids, -counts))
            gids = gids[order]
        # prewarm_frac bounds the CUMULATIVE bytes all warms may occupy
        # (per-ntype warms share it), and pulling rows insert() can't
        # retain would charge the transport for bytes that are
        # immediately discarded — so cap by what's still unused
        budget = (min(int(self.config.budget_bytes * self.config.prewarm_frac),
                      self.config.budget_bytes) - self.used_bytes)
        k = min(len(gids), max(budget // tc.row_nbytes, 0))
        if k == 0:
            return 0
        # version snapshot BEFORE the fetch (same ordering as KVClient.pull):
        # otherwise a push landing mid-warm could get its pre-push rows
        # stamped with the post-push version and served as fresh forever
        pre_versions = client.store.versions_of(name, gids[:k])
        rows = client.pull(name, gids[:k], _bypass_cache=True)
        return self.insert(name, gids[:k], rows, force=True,
                           versions=pre_versions)

    def reset_stats(self) -> None:
        """Zero the hit/miss/eviction counters WITHOUT touching cached
        rows. A long-lived cache is shared across serving requests (and
        possibly across an eval loader and an `InferenceServer` at once —
        every public method locks, so concurrent clients are safe); the
        serving benchmark brackets a measurement window with this to read
        warm-vs-cold hit rates off one instance instead of rebuilding it."""
        with self._lock:
            self.hits = self.misses = self.stale_hits = 0
            self.degraded_hits = self.evictions = self.rejected = 0

    # -- reporting ------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / max(total, 1),
                "stale_hits": self.stale_hits,
                "degraded_hits": self.degraded_hits,
                "evictions": self.evictions,
                "rejected": self.rejected,
                "used_bytes": self.used_bytes,
                "budget_bytes": self.config.budget_bytes,
                "rows": {n: t.num_rows for n, t in self._tensors.items()},
            }
