"""Failure injection for elastic-recovery testing (DESIGN.md §10).

Production traffic implies machines dying mid-epoch; this repo's unfair
advantage is that every sampling-front draw is counter-keyed on
``(seed, epoch, batch_index, stream)`` (DESIGN.md §7), so a replacement
trainer can re-derive *exactly* the batches a dead one would have produced.
:class:`FaultInjector` is the other half of that story: a **seeded,
deterministic failure schedule** that the chaos suite and the launcher's
``--inject-fault`` flag use to make "a machine died" a reproducible event.

Two failure families:

* **trainer death** — ``kill_at=(epoch, batch_index)`` raises
  :class:`TrainerDeath` from the trainer loop the moment it is about to
  consume that batch (i.e. the batch is never trained). One-shot: after
  firing, the injector disarms itself so a recovered run that replays
  through the same coordinate does not die again.
* **transient RPC errors** — ``rpc_failure_rate`` makes
  ``Transport.charge_remote`` raise :class:`TransientRPCError` on a
  deterministic counter-keyed schedule (per-call draw from
  ``SeedSequence((seed, call_counter))``, same construction as the
  sampler's per-batch RNG). ``KVClient`` retries these with exponential
  backoff charged to the simulated clock, so injected transients change
  accounting but **never bytes** — golden hashes are pinned by tests.

``ops`` scopes injection to transport operation tags: feature/embedding
traffic is ``"pull"``/``"push"`` (the retried paths); sampler dispatch
charges under the default ``"data"`` tag and is only faulted when a test
asks for it explicitly (the mid-stream pipeline-failure tests do).
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence, Tuple

import numpy as np

_MASK32 = 0xFFFFFFFF


class TransientRPCError(RuntimeError):
    """A remote call failed but may succeed on retry (network blip)."""


class RPCRetriesExhausted(RuntimeError):
    """A remote call kept failing past the retry budget — fatal."""


class TrainerDeath(RuntimeError):
    """An injected trainer loss at coordinate ``(epoch, batch_index)``.

    The batch at the death coordinate was NOT trained; recovery restores
    the latest checkpoint and replays forward through it.
    """

    def __init__(self, epoch: int, batch_index: int):
        super().__init__(f"trainer killed at epoch {epoch}, "
                         f"batch {batch_index} (injected fault)")
        self.epoch = int(epoch)
        self.batch_index = int(batch_index)


class FaultInjector:
    """Seeded deterministic failure schedule.

    Thread-safe: the RPC draw counter is shared by every thread that
    charges the transport (CPU-prefetch stages, embedding pushes). The
    schedule is a pure function of ``(seed, call order)`` — two runs that
    issue the same calls in the same order see identical faults, which is
    what lets CI pin a fault schedule.
    """

    def __init__(self, seed: int = 0, *,
                 kill_at: Optional[Tuple[int, int]] = None,
                 rpc_failure_rate: float = 0.0,
                 ops: Sequence[str] = ("pull", "push"),
                 max_rpc_failures: Optional[int] = None):
        if not (0.0 <= rpc_failure_rate <= 1.0):
            raise ValueError(f"rpc_failure_rate must be in [0, 1], "
                             f"got {rpc_failure_rate}")
        self.seed = int(seed)
        self.kill_at = None if kill_at is None else (int(kill_at[0]),
                                                     int(kill_at[1]))
        self.rpc_failure_rate = float(rpc_failure_rate)
        self.ops = tuple(ops)
        # cap on TOTAL injected RPC faults (None = unlimited): lets a test
        # inject "the first k calls fail" without rate-1.0 starving retries
        self.max_rpc_failures = max_rpc_failures
        self._lock = threading.Lock()
        self._rpc_calls = 0
        self.rpc_faults_injected = 0
        self.death_fired = False

    # -- transient RPC faults -------------------------------------------
    def rpc_should_fail(self, op: str = "data") -> bool:
        """Deterministic per-call draw; counts every matching call."""
        if self.rpc_failure_rate <= 0.0 or op not in self.ops:
            return False
        with self._lock:
            n = self._rpc_calls
            self._rpc_calls += 1
            if (self.max_rpc_failures is not None
                    and self.rpc_faults_injected >= self.max_rpc_failures):
                return False
            # counter-keyed, like prng.batch_rng: reproducible per call index
            rng = np.random.default_rng(np.random.SeedSequence(
                (self.seed & _MASK32, n & _MASK32)))
            fail = bool(rng.random() < self.rpc_failure_rate)
            if fail:
                self.rpc_faults_injected += 1
            return fail

    # -- trainer death ---------------------------------------------------
    def check_death(self, epoch: int, batch_index: int) -> None:
        """Raise :class:`TrainerDeath` at the scheduled coordinate (once)."""
        if self.kill_at is None or self.death_fired:
            return
        if (int(epoch), int(batch_index)) == self.kill_at:
            self.death_fired = True
            raise TrainerDeath(epoch, batch_index)

    def stats(self) -> dict:
        with self._lock:
            return {"rpc_calls_seen": self._rpc_calls,
                    "rpc_faults_injected": self.rpc_faults_injected,
                    "death_fired": self.death_fired,
                    "kill_at": self.kill_at}
