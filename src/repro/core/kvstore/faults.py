"""Failure injection for elastic-recovery testing (DESIGN.md §10).

Production traffic implies machines dying mid-epoch; this repo's unfair
advantage is that every sampling-front draw is counter-keyed on
``(seed, epoch, batch_index, stream)`` (DESIGN.md §7), so a replacement
trainer can re-derive *exactly* the batches a dead one would have produced.
:class:`FaultInjector` is the other half of that story: a **seeded,
deterministic failure schedule** that the chaos suite and the launcher's
``--inject-fault`` flag use to make "a machine died" a reproducible event.

Two failure families:

* **trainer death** — ``kill_at=(epoch, batch_index)`` raises
  :class:`TrainerDeath` from the trainer loop the moment it is about to
  consume that batch (i.e. the batch is never trained). One-shot: after
  firing, the injector disarms itself so a recovered run that replays
  through the same coordinate does not die again.
* **transient RPC errors** — ``rpc_failure_rate`` makes
  ``Transport.charge_remote`` raise :class:`TransientRPCError` on a
  deterministic counter-keyed schedule (per-call draw from
  ``SeedSequence((seed, call_counter))``, same construction as the
  sampler's per-batch RNG). ``KVClient`` retries these with exponential
  backoff charged to the simulated clock, so injected transients change
  accounting but **never bytes** — golden hashes are pinned by tests.

``ops`` scopes injection to transport operation tags: feature/embedding
traffic is ``"pull"``/``"push"`` (the retried paths); sampler dispatch
charges under the default ``"data"`` tag and is only faulted when a test
asks for it explicitly (the mid-stream pipeline-failure tests do).

A third family (DESIGN.md §12) models a **server dying**, not a blip:

* **sustained owner-down windows** — :class:`OwnerDownWindow` marks one
  KVStore owner unreachable for a contiguous window, in *call-index*
  coordinates (the n-th..m-th RPC addressed to that owner) or
  *epoch:batch* coordinates (the trainer's batch clock, updated through
  :meth:`FaultInjector.check_death`). Every charge addressed to a down
  owner raises :class:`OwnerDownError`; the replicated read path fails
  over to a live replica (byte-identical rows), and when EVERY copy of
  an owner is unreachable the client surfaces
  :class:`OwnerUnavailable` — which degraded-mode serving converts into
  a flagged stale-cache/zero-fill response instead of a failure.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

_MASK32 = 0xFFFFFFFF


class TransientRPCError(RuntimeError):
    """A remote call failed but may succeed on retry (network blip)."""


class OwnerDownError(TransientRPCError):
    """A remote call failed because its destination server is inside a
    sustained down window (DESIGN.md §12). Subclasses
    :class:`TransientRPCError` so unreplicated retry loops treat it like
    any failure; the health-routed read path recognizes it and fails
    over instead of burning the retry budget."""


class RPCRetriesExhausted(RuntimeError):
    """A remote call kept failing past the retry budget — fatal."""


class OwnerUnavailable(RuntimeError):
    """EVERY replica of an owner is unreachable (DESIGN.md §12).

    Raised by the replicated read path after failover exhausted all copy
    holders, or by an unreplicated read whose owner is inside a sustained
    down window. Training treats it as fatal (no copy of the bytes
    exists); degraded-mode serving catches it and falls back to stale
    cached rows / zero-fill with the response flagged ``degraded``.
    """


Coordinate = Union[int, Tuple[int, int]]


@dataclasses.dataclass(frozen=True)
class OwnerDownWindow:
    """A sustained outage of one KVStore owner (DESIGN.md §12).

    ``owner`` is the partition/machine id whose server is unreachable for
    ``start <= x < end``, where ``x`` is either

    * ``unit="calls"`` — the per-owner RPC call index (the n-th charge
      addressed to that owner), so the window is a pure function of call
      order and needs no trainer wiring; or
    * ``unit="batch"`` — the trainer's ``(epoch, batch_index)`` clock,
      compared lexicographically and advanced as a side effect of
      :meth:`FaultInjector.check_death` (which every injected trainer
      already calls once per batch).
    """

    owner: int
    start: Coordinate
    end: Coordinate
    unit: str = "calls"

    def __post_init__(self):
        if self.unit not in ("calls", "batch"):
            raise ValueError(f"unit must be 'calls' or 'batch', "
                             f"got {self.unit!r}")
        if self.unit == "batch":
            for name in ("start", "end"):
                v = getattr(self, name)
                if not (isinstance(v, tuple) and len(v) == 2):
                    raise ValueError(f"batch-unit window needs "
                                     f"(epoch, batch) {name}, got {v!r}")
        if not (self.start < self.end):  # lexicographic for tuples
            raise ValueError(f"empty window: start {self.start!r} "
                             f">= end {self.end!r}")

    def contains(self, x: Coordinate) -> bool:
        return self.start <= x < self.end


class TrainerDeath(RuntimeError):
    """An injected trainer loss at coordinate ``(epoch, batch_index)``.

    The batch at the death coordinate was NOT trained; recovery restores
    the latest checkpoint and replays forward through it.
    """

    def __init__(self, epoch: int, batch_index: int):
        super().__init__(f"trainer killed at epoch {epoch}, "
                         f"batch {batch_index} (injected fault)")
        self.epoch = int(epoch)
        self.batch_index = int(batch_index)


class FaultInjector:
    """Seeded deterministic failure schedule.

    Thread-safe: the RPC draw counter is shared by every thread that
    charges the transport (CPU-prefetch stages, embedding pushes). The
    schedule is a pure function of ``(seed, call order)`` — two runs that
    issue the same calls in the same order see identical faults, which is
    what lets CI pin a fault schedule.
    """

    def __init__(self, seed: int = 0, *,
                 kill_at: Optional[Tuple[int, int]] = None,
                 rpc_failure_rate: float = 0.0,
                 ops: Sequence[str] = ("pull", "push"),
                 max_rpc_failures: Optional[int] = None,
                 owner_down: Sequence[OwnerDownWindow] = ()):
        if not (0.0 <= rpc_failure_rate <= 1.0):
            raise ValueError(f"rpc_failure_rate must be in [0, 1], "
                             f"got {rpc_failure_rate}")
        self.seed = int(seed)
        self.kill_at = None if kill_at is None else (int(kill_at[0]),
                                                     int(kill_at[1]))
        self.rpc_failure_rate = float(rpc_failure_rate)
        self.ops = tuple(ops)
        # cap on TOTAL injected RPC faults (None = unlimited): lets a test
        # inject "the first k calls fail" without rate-1.0 starving retries
        self.max_rpc_failures = max_rpc_failures
        self.owner_down = tuple(owner_down)
        self._lock = threading.Lock()
        self._rpc_calls = 0
        self.rpc_faults_injected = 0
        self.death_fired = False
        # per-owner RPC call counters for unit="calls" windows
        self._owner_calls: Dict[int, int] = {}
        # trainer batch clock for unit="batch" windows, advanced by
        # check_death; (-1, -1) = before the first batch
        self._coord: Tuple[int, int] = (-1, -1)
        self.owner_down_hits = 0

    # -- transient RPC faults -------------------------------------------
    def rpc_should_fail(self, op: str = "data") -> bool:
        """Deterministic per-call draw; counts every matching call."""
        if self.rpc_failure_rate <= 0.0 or op not in self.ops:
            return False
        with self._lock:
            n = self._rpc_calls
            self._rpc_calls += 1
            if (self.max_rpc_failures is not None
                    and self.rpc_faults_injected >= self.max_rpc_failures):
                return False
            # counter-keyed, like prng.batch_rng: reproducible per call index
            rng = np.random.default_rng(np.random.SeedSequence(
                (self.seed & _MASK32, n & _MASK32)))
            fail = bool(rng.random() < self.rpc_failure_rate)
            if fail:
                self.rpc_faults_injected += 1
            return fail

    # -- sustained owner-down windows -------------------------------------
    def owner_is_down(self, owner: int, op: str = "data") -> bool:
        """True if ``owner`` is inside a down window for this call.

        Counts one per-owner call per invocation (unit="calls" windows are
        a pure function of per-owner call order); batch-unit windows
        compare against the clock advanced by :meth:`check_death`.
        Scoped to ``ops`` like the transient schedule, so sampler dispatch
        (op="data") is untouched unless a test opts in.
        """
        if not self.owner_down or op not in self.ops:
            return False
        owner = int(owner)
        with self._lock:
            n = self._owner_calls.get(owner, 0)
            self._owner_calls[owner] = n + 1
            coord = self._coord
            down = any(
                w.owner == owner and w.contains(n if w.unit == "calls"
                                                else coord)
                for w in self.owner_down)
            if down:
                self.owner_down_hits += 1
            return down

    # -- trainer death ---------------------------------------------------
    def check_death(self, epoch: int, batch_index: int) -> None:
        """Raise :class:`TrainerDeath` at the scheduled coordinate (once).

        Also advances the batch clock used by batch-unit owner-down
        windows — the trainer calls this once per batch whenever an
        injector is attached, so the clock needs no extra wiring.
        """
        with self._lock:
            self._coord = (int(epoch), int(batch_index))
        if self.kill_at is None or self.death_fired:
            return
        if (int(epoch), int(batch_index)) == self.kill_at:
            self.death_fired = True
            raise TrainerDeath(epoch, batch_index)

    def stats(self) -> dict:
        with self._lock:
            return {"rpc_calls_seen": self._rpc_calls,
                    "rpc_faults_injected": self.rpc_faults_injected,
                    "death_fired": self.death_fired,
                    "kill_at": self.kill_at,
                    "owner_down_windows": len(self.owner_down),
                    "owner_down_hits": self.owner_down_hits}
