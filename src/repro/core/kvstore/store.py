"""Distributed in-memory key-value store for vertex/edge data (§5.4).

One ``KVServer`` per machine holds the rows whose global IDs fall in that
machine's partition range (per a ``PartitionPolicy`` — vertex data and edge
data are partitioned differently, and heterographs can register separate
policies per node/edge type). ``KVClient`` is what a trainer uses: ``pull``
gathers rows by global ID (local rows via the shared-memory fast path,
remote rows through the transport), ``push`` scatters values or gradient
updates back to the owning servers.

Replication (DESIGN.md §12): with ``replication=r`` every partition's
shard also lives on its ``r-1`` ring successors. Writes are synchronous —
every copy holder is charged and every copy array mutated before the
write returns — so a failover read from any replica is **byte-identical**
to the primary read, and the store-global version counters stay the
single invalidation authority no matter which copy served a row. Reads
are health-routed: the transport's :class:`~.transport.PeerHealth`
breaker orders candidates available-first, an optional hedge delay races
a replica against a slow primary, and only when EVERY copy is
unreachable does the client surface :class:`~.faults.OwnerUnavailable`.
"""
from __future__ import annotations

import dataclasses
import threading
import weakref
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .faults import (OwnerDownError, OwnerUnavailable, RPCRetriesExhausted,
                     TransientRPCError)
from .transport import Transport

_MASK32 = 0xFFFFFFFF

# transient-RPC retry budget (DESIGN.md §10): 8 attempts with doubling
# backoff spans ~256x the base latency — a schedule that fails past it is
# treated as a dead peer, not a blip, and surfaces RPCRetriesExhausted
MAX_RPC_RETRIES = 8


@dataclasses.dataclass(frozen=True)
class PartitionPolicy:
    """Maps a global ID to (partition, local offset) via contiguous ranges.

    Built from the partition book's node/edge offsets, which is exactly the
    paper's scheme (binary search + subtraction).
    """
    name: str
    offsets: np.ndarray   # (k+1,)

    @property
    def num_parts(self) -> int:
        return len(self.offsets) - 1

    @property
    def total(self) -> int:
        return int(self.offsets[-1])

    def part_of(self, ids: np.ndarray) -> np.ndarray:
        return (np.searchsorted(self.offsets, ids, side="right") - 1).astype(np.int32)

    def local_of(self, ids: np.ndarray, parts: Optional[np.ndarray] = None) -> np.ndarray:
        if parts is None:
            parts = self.part_of(ids)
        return ids - self.offsets[parts]

    def part_size(self, p: int) -> int:
        return int(self.offsets[p + 1] - self.offsets[p])


class KVServer:
    """Holds the local shard of every registered tensor."""

    def __init__(self, part_id: int):
        self.part_id = part_id
        self._data: Dict[str, np.ndarray] = {}
        # replica shards this server holds FOR OTHER partitions, keyed by
        # (tensor name, primary part id) — full copies of the primary
        # shard, kept byte-identical by synchronous writes (DESIGN.md §12)
        self._replicas: Dict[Tuple[str, int], np.ndarray] = {}

    def init_data(self, name: str, shape_suffix: tuple, dtype, policy: PartitionPolicy,
                  init: Optional[Callable[[tuple], np.ndarray]] = None,
                  rows: Optional[np.ndarray] = None) -> None:
        n_local = policy.part_size(self.part_id)
        shape = (n_local,) + tuple(shape_suffix)
        if rows is not None:
            assert rows.shape == shape, (rows.shape, shape)
            # explicit copy: the server must own its shard (ascontiguousarray
            # would alias the caller's buffer for contiguous slices)
            self._data[name] = np.array(rows, dtype=dtype, copy=True)
        elif init is not None:
            self._data[name] = np.asarray(init(shape), dtype=dtype)
        else:
            self._data[name] = np.zeros(shape, dtype=dtype)

    def local_view(self, name: str) -> np.ndarray:
        """Shared-memory fast path: the trainer reads this array directly."""
        return self._data[name]

    def fetch(self, name: str, local_ids: np.ndarray) -> np.ndarray:
        return self._data[name][local_ids]

    def apply(self, name: str, local_ids: np.ndarray, values: np.ndarray,
              reduce: str = "assign") -> None:
        if reduce == "assign":
            self._data[name][local_ids] = values
        elif reduce == "sum":
            np.add.at(self._data[name], local_ids, values)
        else:
            raise ValueError(reduce)

    # -- replica shards held for other partitions (DESIGN.md §12) --------
    def init_replica(self, name: str, primary_part: int,
                     rows: np.ndarray) -> None:
        self._replicas[(name, int(primary_part))] = np.array(rows, copy=True)

    def replica_view(self, name: str, primary_part: int) -> np.ndarray:
        return self._replicas[(name, int(primary_part))]

    def fetch_replica(self, name: str, primary_part: int,
                      local_ids: np.ndarray) -> np.ndarray:
        return self._replicas[(name, int(primary_part))][local_ids]


class DistKVStore:
    """The full store: all servers + a per-machine client view.

    In production each machine would construct only its server and a client;
    here the object graph holds all of them (one host), but clients only
    touch remote servers through ``transport``-charged calls.
    """

    def __init__(self, policies: Dict[str, PartitionPolicy],
                 transport: Optional[Transport] = None,
                 replication: int = 1,
                 max_rpc_retries: int = MAX_RPC_RETRIES,
                 hedge_delay_s: Optional[float] = None,
                 jitter_seed: int = 0):
        self.policies = dict(policies)
        num_parts = next(iter(self.policies.values())).num_parts
        for pol in self.policies.values():
            assert pol.num_parts == num_parts
        self.servers = [KVServer(p) for p in range(num_parts)]
        self.transport = transport or Transport()
        if replication < 1:
            raise ValueError(f"replication must be >= 1, got {replication}")
        # clamp: r copies need r distinct machines; a 1-machine smoke run
        # with --replication 2 degrades to r=1 instead of crashing
        self.replication = min(int(replication), num_parts)
        if max_rpc_retries < 1:
            raise ValueError(f"max_rpc_retries must be >= 1, "
                             f"got {max_rpc_retries}")
        self.max_rpc_retries = int(max_rpc_retries)
        self.hedge_delay_s = hedge_delay_s
        self.jitter_seed = int(jitter_seed)
        # ring placement: partition p's copies live on machines
        # p, p+1, ..., p+r-1 (mod k) — every machine holds r shards and
        # every shard has r holders, no placement table to persist
        self._replica_map: Tuple[Tuple[int, ...], ...] = tuple(
            tuple((p + i) % num_parts for i in range(self.replication))
            for p in range(num_parts))
        self._meta: Dict[str, tuple] = {}   # name -> (policy_name, dtype)
        # per-row version counters for MUTABLE tensors only — the
        # invalidation authority for trainer-side feature caches (in a real
        # deployment this metadata rides the push acks / an invalidation
        # broadcast; see DESIGN.md §5). Immutable tensors have no entry and
        # pay zero version overhead.
        self._versions: Dict[str, np.ndarray] = {}
        self._version_lock = threading.Lock()
        # tensors ANY trainer cache has registered (cache registration is
        # global metadata, like the policies): writes to a cached tensor
        # without a version table are refused up front — no client can see
        # the other trainers' caches to invalidate them. The weak set of
        # live caches exists for BULK rewrites (checkpoint restore), which
        # legitimately replace even immutable bytes and must flush them.
        self._cached_names: set = set()
        self._cache_refs: "weakref.WeakSet" = weakref.WeakSet()

    @property
    def num_parts(self) -> int:
        return len(self.servers)

    def init_data(self, name: str, shape_suffix: tuple, dtype, policy_name: str,
                  init: Optional[Callable[[tuple], np.ndarray]] = None,
                  full_array: Optional[np.ndarray] = None,
                  mutable: bool = False) -> None:
        pol = self.policies[policy_name]
        self._meta[name] = (policy_name, np.dtype(dtype))
        if mutable:
            self._versions[name] = np.zeros(pol.total, dtype=np.int64)
        for server in self.servers:
            rows = None
            if full_array is not None:
                lo, hi = int(pol.offsets[server.part_id]), int(pol.offsets[server.part_id + 1])
                rows = full_array[lo:hi]
            server.init_data(name, shape_suffix, dtype, pol, init=init, rows=rows)
        # seed the replica copies from the freshly-initialized primaries
        if self.replication > 1:
            for p in range(self.num_parts):
                src = self.servers[p].local_view(name)
                for h in self.replicas_of(p)[1:]:
                    self.servers[h].init_replica(name, p, src)

    # -- replication (DESIGN.md §12) --------------------------------------
    def replicas_of(self, p: int) -> Tuple[int, ...]:
        """Copy holders of partition ``p``, primary first."""
        return self._replica_map[p]

    def apply_update(self, name: str, p: int, local_ids: np.ndarray,
                     values: np.ndarray, reduce: str = "assign") -> None:
        """Apply one delivered write to EVERY copy of partition ``p``.

        The primary takes the real reduction; replicas then copy the
        primary's updated rows, so all copies are byte-identical even for
        ``sum`` reductions with duplicate ids. Copies of a holder inside a
        down window are updated too — this models the write-ahead log the
        holder replays on return; availability is what the down window
        takes away, not durability (the charge was already skipped and
        counted as a deferred replica write by the caller)."""
        self.servers[p].apply(name, local_ids, values, reduce=reduce)
        self.copy_rows_to_replicas(name, p, local_ids)

    def copy_rows_to_replicas(self, name: str, p: int,
                              local_ids: np.ndarray) -> None:
        """Propagate the primary's current bytes for ``local_ids`` to every
        replica copy of partition ``p`` (no-op at r=1)."""
        if self.replication == 1:
            return
        rows = self.servers[p].local_view(name)[local_ids]
        for h in self.replicas_of(p)[1:]:
            self.servers[h].replica_view(name, p)[local_ids] = rows

    def sync_replicas(self) -> None:
        """Bulk re-copy every primary shard to its replicas — the
        checkpoint-restore path, which rewrites primaries in place and
        must bring all copies back to byte-identity."""
        if self.replication == 1:
            return
        for name in self._meta:
            for p in range(self.num_parts):
                src = self.servers[p].local_view(name)
                for h in self.replicas_of(p)[1:]:
                    self.servers[h].replica_view(name, p)[...] = src

    # -- row versioning (cache invalidation authority) ------------------
    def is_mutable(self, name: str) -> bool:
        return name in self._versions

    def note_cache_registration(self, name: str, cache=None) -> None:
        """Called by FeatureCache.register; see check_writable."""
        self._cached_names.add(name)
        if cache is not None:
            self._cache_refs.add(cache)

    def invalidate_caches(self, name: str) -> None:
        """Flush every live trainer cache's entries for ``name`` — the
        bulk-rewrite path (checkpoint restore), where even immutable
        tensors' bytes legitimately change."""
        for cache in list(self._cache_refs):
            cache.drop(name)

    def check_writable(self, name: str) -> None:
        """Refuse writes that would strand stale rows in SOME trainer's
        cache: a cached tensor with no version table has no invalidation
        channel. Runs BEFORE any server mutation."""
        if name in self._cached_names and not self.is_mutable(name):
            raise ValueError(
                f"write to {name!r}, which is cached by a trainer but has "
                f"no version table — register it with "
                f"init_data(..., mutable=True)")

    def versions_of(self, name: str, ids: np.ndarray) -> Optional[np.ndarray]:
        """Current version counter per row, or None for immutable tensors."""
        vers = self._versions.get(name)
        if vers is None:
            return None
        with self._version_lock:
            return vers[np.asarray(ids, dtype=np.int64)].copy()

    def bump_versions(self, name: str, ids: np.ndarray) -> None:
        """Called by writers AFTER applying an update, so a concurrent
        reader can at worst stamp fresh data with a stale version (an
        unnecessary refresh later) — never stale data with a fresh one."""
        vers = self._versions.get(name)
        if vers is None:
            return
        with self._version_lock:
            np.add.at(vers, np.asarray(ids, dtype=np.int64), 1)

    # -- checkpoint access (repro.checkpoint save/load_kvstore) ----------
    def mutable_names(self) -> List[str]:
        """Tensors with a version table, in registration order."""
        return list(self._versions)

    def version_table(self, name: str) -> np.ndarray:
        """A consistent snapshot of one tensor's full version table."""
        with self._version_lock:
            return self._versions[name].copy()

    def set_versions(self, name: str, values: np.ndarray) -> None:
        """Restore a tensor's exact version counters (checkpoint load):
        cache entries saved against these versions validate again, instead
        of the blanket bump a version-less restore must fall back to."""
        vers = self._versions[name]
        values = np.asarray(values, dtype=np.int64)
        assert values.shape == vers.shape, (name, values.shape, vers.shape)
        with self._version_lock:
            vers[...] = values

    def client(self, machine: int) -> "KVClient":
        return KVClient(self, machine)

    def policy_for(self, name: str) -> PartitionPolicy:
        return self.policies[self._meta[name][0]]

    # -- metadata introspection (the repro.api DistTensor façade reads
    #    these instead of poking _meta / server shards directly) ----------
    def has_tensor(self, name: str) -> bool:
        return name in self._meta

    def tensor_names(self) -> List[str]:
        """Registered tensor names, in registration order."""
        return list(self._meta)

    def policy_name_of(self, name: str) -> str:
        return self._meta[name][0]

    def dtype_of(self, name: str) -> np.dtype:
        return self._meta[name][1]

    def row_shape(self, name: str) -> tuple:
        """Per-row feature shape (without the leading id axis)."""
        return tuple(self.servers[0].local_view(name).shape[1:])

    def gather_all(self, name: str) -> np.ndarray:
        """Debug/checkpoint helper: reassemble the full tensor."""
        return np.concatenate([s.local_view(name) for s in self.servers], axis=0)


class KVClient:
    def __init__(self, store: DistKVStore, machine: int, cache=None):
        self.store = store
        self.machine = machine
        self.cache = cache          # Optional[FeatureCache], per trainer
        self.max_rpc_retries = store.max_rpc_retries
        self.hedge_delay_s = store.hedge_delay_s
        # partitions with a copy (primary OR replica) on this machine —
        # served via shared memory; degenerates to {machine} at r=1
        self._local_parts = frozenset(
            p for p in range(store.num_parts)
            if machine in store.replicas_of(p))
        self._local_parts_arr = np.fromiter(sorted(self._local_parts),
                                            dtype=np.int32)
        # backoff-jitter draws are counter-keyed like every other RNG in
        # the repo (seed, machine, draw index) — deterministic per client,
        # desynchronized across clients (DESIGN.md §12)
        self._jitter_lock = threading.Lock()
        self._jitter_calls = 0

    def attach_cache(self, cache) -> "KVClient":
        """Attach a per-trainer hot-vertex cache (see kvstore.cache); only
        tensors registered with the cache take the cached read path."""
        self.cache = cache
        return self

    def _jittered(self, delay_s: float) -> float:
        """Scale one backoff wait by a seeded factor in [0.5, 1.5) so
        synchronized retry storms desynchronize; affects the simulated
        clock only, never retry counts or bytes."""
        with self._jitter_lock:
            n = self._jitter_calls
            self._jitter_calls += 1
        rng = np.random.default_rng(np.random.SeedSequence(
            (self.store.jitter_seed & _MASK32, self.machine & _MASK32,
             n & _MASK32)))
        return delay_s * (0.5 + rng.random())

    def _charge_remote(self, nbytes: int, op: str,
                       dst: Optional[int] = None) -> None:
        """Charge one remote RPC to a single destination, absorbing
        injected transient failures with jittered exponential backoff
        (DESIGN.md §10).

        Every data-plane RPC this client issues routes through here or
        :meth:`_remote_read`, and the charge always runs BEFORE the
        corresponding server mutation (see ``push``) — so a retried call
        never re-applies a ``sum`` reduction, and injected transients
        change accounting but not one byte of training state."""
        transport = self.store.transport
        delay = transport.model.latency_s
        last: Optional[TransientRPCError] = None
        for _ in range(self.max_rpc_retries):
            try:
                transport.charge_remote(nbytes, op=op, dst=dst)
                return
            except TransientRPCError as e:
                last = e
                transport.charge_retry_backoff(self._jittered(delay))
                delay *= 2
        if isinstance(last, OwnerDownError):
            raise OwnerUnavailable(
                f"server {dst} is inside a sustained outage and partition "
                f"has no other copy ({op!r} RPC, {nbytes}B)") from last
        raise RPCRetriesExhausted(
            f"{op!r} RPC ({nbytes}B) failed {self.max_rpc_retries} times — "
            f"treating the peer as dead") from last

    def _remote_read(self, nbytes: int, owner: int, op: str = "pull") -> int:
        """Charge one read addressed to ``owner``, failing over across its
        copy holders (DESIGN.md §12). Returns the server id that served it.

        Routing: candidates are the owner's copy holders primary-first,
        reordered available-first by the transport's health breaker — a
        known-dead primary costs zero attempts. If a hedge delay is
        configured, a first round races the candidates: one attempt at the
        best candidate, and only when that attempt comes back failed (the
        simulated transport surfaces failure after one round trip — a
        successful read never hedges) the hedge timer is charged and the
        next candidate tried, first success winning. After that, the
        retry budget is split evenly across candidates with jittered
        doubling backoff. Only when every copy holder is exhausted does
        the read fail — as :class:`OwnerUnavailable` if the final error
        was a down window, else :class:`RPCRetriesExhausted`."""
        store = self.store
        transport = store.transport
        cands = store.replicas_of(owner)
        if len(cands) == 1:
            self._charge_remote(nbytes, op=op, dst=owner)
            return owner
        health = transport.health
        order = ([c for c in cands if health.available(c)]
                 + [c for c in cands if not health.available(c)])
        last: Optional[TransientRPCError] = None
        if self.hedge_delay_s is not None:
            for i, c in enumerate(order):
                try:
                    transport.charge_remote(nbytes, op=op, dst=c)
                    if i > 0:
                        transport.note_hedge_win()
                    if c != owner:
                        transport.note_failover()
                    return c
                except TransientRPCError as e:
                    last = e
                    if i == 0:
                        transport.charge_hedge_delay(self.hedge_delay_s)
        budget = max(1, self.max_rpc_retries // len(order))
        for c in order:
            delay = transport.model.latency_s
            for _ in range(budget):
                try:
                    transport.charge_remote(nbytes, op=op, dst=c)
                    if c != owner:
                        transport.note_failover()
                    return c
                except TransientRPCError as e:
                    last = e
                    transport.charge_retry_backoff(self._jittered(delay))
                    delay *= 2
        if isinstance(last, OwnerDownError):
            raise OwnerUnavailable(
                f"all {len(order)} copies of partition {owner} unreachable "
                f"({op!r} RPC, {nbytes}B)") from last
        raise RPCRetriesExhausted(
            f"{op!r} RPC ({nbytes}B) to partition {owner} failed on all "
            f"{len(order)} copies — treating the owner as dead") from last

    def pull(self, name: str, ids: np.ndarray, *,
             _bypass_cache: bool = False) -> np.ndarray:
        """Gather rows by global ID. Local rows: direct view indexing
        (shared memory). Remote rows: cache hits served trainer-side
        (saved bytes credited to the transport accountant), misses via one
        batched transport-charged fetch per owning server."""
        store = self.store
        pol = store.policy_for(name)
        ids = np.asarray(ids, dtype=np.int64)
        parts = pol.part_of(ids)
        local_ids = pol.local_of(ids, parts)
        sample = store.servers[self.machine].local_view(name)
        out = np.empty((len(ids),) + sample.shape[1:], dtype=sample.dtype)
        itemrow = sample.dtype.itemsize * int(np.prod(sample.shape[1:], initial=1))

        cache = None if _bypass_cache else self.cache
        if cache is not None and not cache.has(name):
            cache = None
        # rows with ANY copy on this machine (primary or replica shard)
        # take the shared-memory path; at r=1 this is parts == machine
        is_local = np.isin(parts, self._local_parts_arr)
        fetch = np.ones(len(ids), dtype=bool)
        if cache is not None:
            rem_idx = np.nonzero(~is_local)[0]
            if len(rem_idx):
                hit, rows = cache.lookup(name, ids[rem_idx])
                if hit.any():
                    out[rem_idx[hit]] = rows
                    fetch[rem_idx[hit]] = False
                    store.transport.charge_cache_hit(
                        int(hit.sum()) * itemrow, int(hit.sum()))
                store.transport.charge_cache_miss(int((~hit).sum()))
        # version snapshot BEFORE fetching, so a concurrent push can never
        # stamp stale rows with a fresh version (see bump_versions)
        pre_versions = (store.versions_of(name, ids)
                        if cache is not None else None)
        for p in range(store.num_parts):
            m = (parts == p) & fetch
            if not m.any():
                continue
            nbytes = int(m.sum()) * itemrow
            if p in self._local_parts:
                src = (store.servers[self.machine].local_view(name)
                       if p == self.machine else
                       store.servers[self.machine].replica_view(name, p))
                out[m] = src[local_ids[m]]
                store.transport.charge_local(nbytes)
                continue
            served_by = self._remote_read(nbytes, p, op="pull")
            rows = (store.servers[p].fetch(name, local_ids[m])
                    if served_by == p else
                    store.servers[served_by].fetch_replica(
                        name, p, local_ids[m]))
            out[m] = rows
            if cache is not None:
                cache.insert(name, ids[m], rows,
                             versions=None if pre_versions is None
                             else pre_versions[m])
        return out

    def push(self, name: str, ids: np.ndarray, values: np.ndarray,
             reduce: str = "sum") -> None:
        store = self.store
        store.check_writable(name)   # before any server mutation
        pol = store.policy_for(name)
        ids = np.asarray(ids, dtype=np.int64)
        parts = pol.part_of(ids)
        local_ids = pol.local_of(ids, parts)
        itemrow = values.dtype.itemsize * int(np.prod(values.shape[1:], initial=1))
        transport = store.transport
        for p in range(store.num_parts):
            m = parts == p
            if not m.any():
                continue
            nbytes = int(m.sum()) * itemrow
            # charge EVERY copy holder (and absorb transient faults)
            # BEFORE the apply: each copy mutates exactly once per
            # delivered request, so a retried charge can never
            # double-apply a "sum" reduction. Synchronous replication:
            # a holder inside a down window gets its charge skipped and
            # counted as deferred (its copy is still brought up to date —
            # the replayed write-ahead log, see apply_update); the write
            # only fails when NO copy holder accepted it.
            holders = store.replicas_of(p)
            delivered = 0
            last: Optional[Exception] = None
            for h in holders:
                if h == self.machine:
                    transport.charge_local(nbytes)
                    delivered += 1
                    continue
                try:
                    self._charge_remote(nbytes, op="push", dst=h)
                    delivered += 1
                except (OwnerUnavailable, RPCRetriesExhausted) as e:
                    if len(holders) == 1:
                        raise
                    last = e
                    transport.note_deferred_replica_write()
            if delivered == 0:
                raise OwnerUnavailable(
                    f"push to partition {p} failed on all {len(holders)} "
                    f"copy holders") from last
            store.apply_update(name, p, local_ids[m], values[m],
                               reduce=reduce)
        self.notify_write(name, ids)

    def notify_write(self, name: str, ids: np.ndarray) -> None:
        """Post-write protocol shared by every writer (``push``,
        ``DistEmbedding.push_grad``, ...): bump the rows' version counters
        so OTHER trainers' caches refuse their copies, and eagerly drop
        this client's own entries. (``DistKVStore.check_writable`` — run
        before the write — is what refuses cached-but-unversioned
        tensors.)"""
        self.store.bump_versions(name, ids)   # no-op for immutable tensors
        if self.cache is not None and self.cache.has(name):
            self.cache.invalidate(name, ids)

    def local_fraction(self, name: str, ids: np.ndarray) -> float:
        pol = self.store.policy_for(name)
        parts = pol.part_of(np.asarray(ids, dtype=np.int64))
        return float((parts == self.machine).mean()) if len(ids) else 1.0

    # -- heterograph path ----------------------------------------------
    def pull_typed(self, name_prefix: str, fused_ids: np.ndarray,
                   typed, ntypes: Optional[np.ndarray] = None) -> np.ndarray:
        """Gather rows for a mixed-type fused-ID set, routing every node
        type through its own policy (§5.4's per-type registration).

        ``typed`` is a ``core.partition.book.TypedPartitionData``; node type
        t's rows live in tensor ``f"{name_prefix}:{ntype_name}"`` indexed by
        *type-local* IDs under policy ``node:<ntype>``. Rows come back in
        ``fused_ids`` order in one contiguous buffer (the paper's CPU
        prefetch contract) — all per-type tensors must share dtype and
        feature shape. ``ntypes`` (if given) is the caller's precomputed
        node type per id — the sampler's typed frontier bookkeeping — which
        skips the type lookup here.
        """
        fused_ids = np.asarray(fused_ids, dtype=np.int64)
        if ntypes is None:
            types, tids = typed.nid2typed(fused_ids)
        else:
            types = ntypes
            tids = typed.node_type_local[fused_ids]
        out: Optional[np.ndarray] = None
        for t, ntname in enumerate(typed.schema.ntypes):
            m = types == t
            if not m.any():
                continue
            rows = self.pull(f"{name_prefix}:{ntname}", tids[m])
            if out is None:
                out = np.empty((len(fused_ids),) + rows.shape[1:],
                               dtype=rows.dtype)
            out[m] = rows
        if out is None:   # empty id set: use any registered type for shape
            sample = self.store.servers[self.machine].local_view(
                f"{name_prefix}:{typed.schema.ntypes[0]}")
            out = np.empty((0,) + sample.shape[1:], dtype=sample.dtype)
        return out

    # -- degraded-mode reads (DESIGN.md §12) ------------------------------
    def pull_degraded(self, name: str, ids: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Best-effort gather for serving: rows whose owner has NO
        reachable copy (:class:`OwnerUnavailable` — a sustained outage,
        not a blip) come from the stale cache (version checks skipped —
        bounded staleness, the rows were valid when cached) or zero-fill,
        instead of raising. Failure is isolated per owner, so one dead
        owner never poisons rows healthy owners can serve. Plain retry
        exhaustion still raises — the data exists, the network is just
        misbehaving, and fabricating bytes would mask it.

        Returns ``(rows, fresh)`` where ``fresh[i]`` is False for every
        row that was salvaged; training paths must keep using ``pull``,
        which refuses to fabricate bytes."""
        store = self.store
        pol = store.policy_for(name)
        ids = np.asarray(ids, dtype=np.int64)
        parts = pol.part_of(ids)
        sample = store.servers[self.machine].local_view(name)
        out = np.zeros((len(ids),) + sample.shape[1:], dtype=sample.dtype)
        fresh = np.ones(len(ids), dtype=bool)
        for p in np.unique(parts):
            m = parts == p
            try:
                out[m] = self.pull(name, ids[m])
            except OwnerUnavailable:
                fresh[m] = False
                idx = np.nonzero(m)[0]
                if self.cache is not None and self.cache.has(name):
                    hit, rows = self.cache.lookup_stale(name, ids[m])
                    if hit.any():
                        out[idx[hit]] = rows
                store.transport.note_degraded(int(m.sum()))
        return out, fresh

    def pull_typed_degraded(self, name_prefix: str, fused_ids: np.ndarray,
                            typed, ntypes: Optional[np.ndarray] = None
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """Typed counterpart of :meth:`pull_degraded` — per-type routing
        like :meth:`pull_typed`, salvage masks merged across types."""
        fused_ids = np.asarray(fused_ids, dtype=np.int64)
        if ntypes is None:
            types, tids = typed.nid2typed(fused_ids)
        else:
            types = ntypes
            tids = typed.node_type_local[fused_ids]
        out: Optional[np.ndarray] = None
        fresh = np.ones(len(fused_ids), dtype=bool)
        for t, ntname in enumerate(typed.schema.ntypes):
            m = types == t
            if not m.any():
                continue
            rows, f = self.pull_degraded(f"{name_prefix}:{ntname}", tids[m])
            if out is None:
                out = np.empty((len(fused_ids),) + rows.shape[1:],
                               dtype=rows.dtype)
            out[m] = rows
            fresh[m] = f
        if out is None:
            sample = self.store.servers[self.machine].local_view(
                f"{name_prefix}:{typed.schema.ntypes[0]}")
            out = np.empty((0,) + sample.shape[1:], dtype=sample.dtype)
        return out, fresh
