"""Distributed in-memory key-value store for vertex/edge data (§5.4).

One ``KVServer`` per machine holds the rows whose global IDs fall in that
machine's partition range (per a ``PartitionPolicy`` — vertex data and edge
data are partitioned differently, and heterographs can register separate
policies per node/edge type). ``KVClient`` is what a trainer uses: ``pull``
gathers rows by global ID (local rows via the shared-memory fast path,
remote rows through the transport), ``push`` scatters values or gradient
updates back to the owning servers.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from .transport import Transport


@dataclasses.dataclass(frozen=True)
class PartitionPolicy:
    """Maps a global ID to (partition, local offset) via contiguous ranges.

    Built from the partition book's node/edge offsets, which is exactly the
    paper's scheme (binary search + subtraction).
    """
    name: str
    offsets: np.ndarray   # (k+1,)

    @property
    def num_parts(self) -> int:
        return len(self.offsets) - 1

    @property
    def total(self) -> int:
        return int(self.offsets[-1])

    def part_of(self, ids: np.ndarray) -> np.ndarray:
        return (np.searchsorted(self.offsets, ids, side="right") - 1).astype(np.int32)

    def local_of(self, ids: np.ndarray, parts: Optional[np.ndarray] = None) -> np.ndarray:
        if parts is None:
            parts = self.part_of(ids)
        return ids - self.offsets[parts]

    def part_size(self, p: int) -> int:
        return int(self.offsets[p + 1] - self.offsets[p])


class KVServer:
    """Holds the local shard of every registered tensor."""

    def __init__(self, part_id: int):
        self.part_id = part_id
        self._data: Dict[str, np.ndarray] = {}

    def init_data(self, name: str, shape_suffix: tuple, dtype, policy: PartitionPolicy,
                  init: Optional[Callable[[tuple], np.ndarray]] = None,
                  rows: Optional[np.ndarray] = None) -> None:
        n_local = policy.part_size(self.part_id)
        shape = (n_local,) + tuple(shape_suffix)
        if rows is not None:
            assert rows.shape == shape, (rows.shape, shape)
            # explicit copy: the server must own its shard (ascontiguousarray
            # would alias the caller's buffer for contiguous slices)
            self._data[name] = np.array(rows, dtype=dtype, copy=True)
        elif init is not None:
            self._data[name] = np.asarray(init(shape), dtype=dtype)
        else:
            self._data[name] = np.zeros(shape, dtype=dtype)

    def local_view(self, name: str) -> np.ndarray:
        """Shared-memory fast path: the trainer reads this array directly."""
        return self._data[name]

    def fetch(self, name: str, local_ids: np.ndarray) -> np.ndarray:
        return self._data[name][local_ids]

    def apply(self, name: str, local_ids: np.ndarray, values: np.ndarray,
              reduce: str = "assign") -> None:
        if reduce == "assign":
            self._data[name][local_ids] = values
        elif reduce == "sum":
            np.add.at(self._data[name], local_ids, values)
        else:
            raise ValueError(reduce)


class DistKVStore:
    """The full store: all servers + a per-machine client view.

    In production each machine would construct only its server and a client;
    here the object graph holds all of them (one host), but clients only
    touch remote servers through ``transport``-charged calls.
    """

    def __init__(self, policies: Dict[str, PartitionPolicy],
                 transport: Optional[Transport] = None):
        self.policies = dict(policies)
        num_parts = next(iter(self.policies.values())).num_parts
        for pol in self.policies.values():
            assert pol.num_parts == num_parts
        self.servers = [KVServer(p) for p in range(num_parts)]
        self.transport = transport or Transport()
        self._meta: Dict[str, tuple] = {}   # name -> (policy_name, dtype)

    @property
    def num_parts(self) -> int:
        return len(self.servers)

    def init_data(self, name: str, shape_suffix: tuple, dtype, policy_name: str,
                  init: Optional[Callable[[tuple], np.ndarray]] = None,
                  full_array: Optional[np.ndarray] = None) -> None:
        pol = self.policies[policy_name]
        self._meta[name] = (policy_name, np.dtype(dtype))
        for server in self.servers:
            rows = None
            if full_array is not None:
                lo, hi = int(pol.offsets[server.part_id]), int(pol.offsets[server.part_id + 1])
                rows = full_array[lo:hi]
            server.init_data(name, shape_suffix, dtype, pol, init=init, rows=rows)

    def client(self, machine: int) -> "KVClient":
        return KVClient(self, machine)

    def policy_for(self, name: str) -> PartitionPolicy:
        return self.policies[self._meta[name][0]]

    def gather_all(self, name: str) -> np.ndarray:
        """Debug/checkpoint helper: reassemble the full tensor."""
        return np.concatenate([s.local_view(name) for s in self.servers], axis=0)


class KVClient:
    def __init__(self, store: DistKVStore, machine: int):
        self.store = store
        self.machine = machine

    def pull(self, name: str, ids: np.ndarray) -> np.ndarray:
        """Gather rows by global ID. Local rows: direct view indexing
        (shared memory). Remote rows: transport-charged server fetch."""
        store = self.store
        pol = store.policy_for(name)
        ids = np.asarray(ids, dtype=np.int64)
        parts = pol.part_of(ids)
        local_ids = pol.local_of(ids, parts)
        sample = store.servers[self.machine].local_view(name)
        out = np.empty((len(ids),) + sample.shape[1:], dtype=sample.dtype)
        itemrow = sample.dtype.itemsize * int(np.prod(sample.shape[1:], initial=1))
        for p in range(store.num_parts):
            m = parts == p
            if not m.any():
                continue
            rows = store.servers[p].fetch(name, local_ids[m])
            out[m] = rows
            nbytes = int(m.sum()) * itemrow
            if p == self.machine:
                store.transport.charge_local(nbytes)
            else:
                store.transport.charge_remote(nbytes)
        return out

    def push(self, name: str, ids: np.ndarray, values: np.ndarray,
             reduce: str = "sum") -> None:
        store = self.store
        pol = store.policy_for(name)
        ids = np.asarray(ids, dtype=np.int64)
        parts = pol.part_of(ids)
        local_ids = pol.local_of(ids, parts)
        itemrow = values.dtype.itemsize * int(np.prod(values.shape[1:], initial=1))
        for p in range(store.num_parts):
            m = parts == p
            if not m.any():
                continue
            store.servers[p].apply(name, local_ids[m], values[m], reduce=reduce)
            nbytes = int(m.sum()) * itemrow
            if p == self.machine:
                store.transport.charge_local(nbytes)
            else:
                store.transport.charge_remote(nbytes)

    def local_fraction(self, name: str, ids: np.ndarray) -> float:
        pol = self.store.policy_for(name)
        parts = pol.part_of(np.asarray(ids, dtype=np.int64))
        return float((parts == self.machine).mean()) if len(ids) else 1.0

    # -- heterograph path ----------------------------------------------
    def pull_typed(self, name_prefix: str, fused_ids: np.ndarray,
                   typed, ntypes: Optional[np.ndarray] = None) -> np.ndarray:
        """Gather rows for a mixed-type fused-ID set, routing every node
        type through its own policy (§5.4's per-type registration).

        ``typed`` is a ``core.partition.book.TypedPartitionData``; node type
        t's rows live in tensor ``f"{name_prefix}:{ntype_name}"`` indexed by
        *type-local* IDs under policy ``node:<ntype>``. Rows come back in
        ``fused_ids`` order in one contiguous buffer (the paper's CPU
        prefetch contract) — all per-type tensors must share dtype and
        feature shape. ``ntypes`` (if given) is the caller's precomputed
        node type per id — the sampler's typed frontier bookkeeping — which
        skips the type lookup here.
        """
        fused_ids = np.asarray(fused_ids, dtype=np.int64)
        if ntypes is None:
            types, tids = typed.nid2typed(fused_ids)
        else:
            types = ntypes
            tids = typed.node_type_local[fused_ids]
        out: Optional[np.ndarray] = None
        for t, ntname in enumerate(typed.schema.ntypes):
            m = types == t
            if not m.any():
                continue
            rows = self.pull(f"{name_prefix}:{ntname}", tids[m])
            if out is None:
                out = np.empty((len(fused_ids),) + rows.shape[1:],
                               dtype=rows.dtype)
            out[m] = rows
        if out is None:   # empty id set: use any registered type for shape
            sample = self.store.servers[self.machine].local_view(
                f"{name_prefix}:{typed.schema.ntypes[0]}")
            out = np.empty((0,) + sample.shape[1:], dtype=sample.dtype)
        return out
