from .async_pipeline import AsyncPipeline, Stage, StageStats
from .minibatch import MinibatchPipeline

__all__ = ["AsyncPipeline", "Stage", "StageStats", "MinibatchPipeline"]
