from .async_pipeline import AsyncPipeline, Stage, StageStats
from .minibatch import EdgeMinibatchPipeline, MinibatchPipeline

__all__ = ["AsyncPipeline", "Stage", "StageStats", "MinibatchPipeline",
           "EdgeMinibatchPipeline"]
