"""The 5-stage GNN mini-batch generation pipeline (§5.5, Fig. 7), built on
:class:`AsyncPipeline`:

  1. **batch scheduling** — permute the trainer's seed set each epoch, cut
     into fixed-size batches (runs in the feeder thread);
  2. **neighbor sampling** — multi-hop owner-compute sampling
     (``sample_workers`` pool threads sharing the stage queue — the
     paper's multiple sampling workers per trainer; batches come out in
     order and byte-identical for any pool size, DESIGN.md §7);
  3. **CPU prefetch** — pull input-node features (local shared-memory +
     remote KVStore) into one contiguous buffer (sampling thread);
  4. **device prefetch** — ship the padded arrays to the accelerator
     (depth 1: device memory is scarce);
  5. **subgraph compaction** — runs device-side in the *training thread*
     (the consumer), via ``to_block_device`` or fused into the jitted
     train step — matching the paper's CUDA-interference argument.

``non_stop=True`` keeps one pipeline alive across epochs (the paper's
"non-stop asynchronous pipeline" that removes per-epoch startup overhead —
the last bar of Fig. 14). ``sync=True`` gives the unpipelined baseline.
"""
from __future__ import annotations

import threading
from typing import Callable, Iterator, Optional

import numpy as np

from ...kernels.pack import device_stage
from ..kvstore.store import KVClient
from ..sampler.dispatch import DistributedSampler
from ..sampler.edge_batch import EdgeBatchSampler, EdgeMiniBatch
from ..sampler.mfg import MiniBatch
from ..sampler.prng import STREAM_SCHEDULE, batch_rng
from .async_pipeline import AsyncPipeline, Stage


def _host_blocks(mb) -> list:
    """A mini-batch's padded block arrays as a plain host tree (shared by
    the node and edge device-prefetch stages)."""
    return [dict(edge_src=b.edge_src, edge_dst=b.edge_dst,
                 edge_mask=b.edge_mask, edge_types=b.edge_types)
            for b in mb.blocks]


def _epoch_schedule(seeds: np.ndarray, labels: Optional[np.ndarray],
                    batch_size: int, rng: np.random.Generator, epoch: int,
                    drop_last: bool = True, shuffle: bool = True,
                    start_batch: int = 0):
    """Stage 1: uniform random batch schedule over this trainer's seed set
    (``shuffle=False``: fixed sequential batches — inference/eval order).

    ``start_batch`` fast-forwards the schedule for recovery replay
    (DESIGN.md §10): the permutation is drawn in full — identical rng
    consumption — and only the emission is skipped, so batch k's seed
    selection is byte-identical whether reached live or by fast-forward.
    """
    perm = (rng.permutation(len(seeds)) if shuffle
            else np.arange(len(seeds), dtype=np.int64))
    n_batches = len(seeds) // batch_size if drop_last else -(-len(seeds) // batch_size)
    for b in range(start_batch, n_batches):
        sel = perm[b * batch_size:(b + 1) * batch_size]
        yield (epoch, b, seeds[sel], None if labels is None else labels[sel])


class MinibatchPipeline:
    def __init__(self, sampler: DistributedSampler, kv_client: KVClient,
                 feat_name: str, seeds: np.ndarray,
                 labels: Optional[np.ndarray] = None, *,
                 batch_size: Optional[int] = None,
                 depths: dict | None = None,
                 sync: bool = False, non_stop: bool = True,
                 to_device: bool = True, packed: bool = True, seed: int = 0,
                 typed=None, cache=None, sample_workers: int = 1,
                 shuffle: bool = True):
        self.sampler = sampler
        self.kv_client = kv_client
        self.feat_name = feat_name
        # heterograph runs: TypedPartitionData — features are registered
        # per node type ("<feat_name>:<ntype>") and the prefetch stage
        # routes each type through its own policy
        self.typed = typed
        # per-trainer hot-vertex cache (kvstore.cache): the CPU-prefetch
        # stage's pulls consult it for remote rows; hits never touch the
        # transport. None = uncached (byte-identical batches either way).
        self.cache = cache
        if cache is not None:
            kv_client.attach_cache(cache)
        self.seeds = np.asarray(seeds, dtype=np.int64)
        self.labels = labels
        self.batch_size = batch_size or sampler.batch_size
        d = {"sample": 8, "cpu_prefetch": 4, "device_prefetch": 1}
        d.update(depths or {})
        self.depths = d
        self.sync = sync
        self.non_stop = non_stop
        self.to_device = to_device
        # packed=True: the device-prefetch stage flattens the whole batch
        # into one contiguous host buffer per dtype and issues a SINGLE
        # jax.device_put (DESIGN.md §9); False = legacy per-array puts
        self.packed = packed
        # counter-based schedule randomness (DESIGN.md §7): each epoch's
        # permutation derives from (seed, epoch) so schedules are replayable
        # and independent of how many epochs ran before
        self.seed = seed
        # sampling-stage worker pool size (§5.5's "multiple sampling
        # workers per trainer"); batches are byte-identical for any value
        self.sample_workers = max(int(sample_workers), 1)
        self.shuffle = shuffle
        self.batches_per_epoch = len(self.seeds) // self.batch_size
        self._pipe: Optional[AsyncPipeline] = None
        self._out_iter = None
        self._nonstop_epoch: Optional[int] = None
        # batches pulled off the non-stop stream within the current epoch:
        # the mid-epoch abandonment guard (see epoch()) keys on it
        self._epoch_pos = 0
        self._lock = threading.Lock()

    # ---- stages -------------------------------------------------------
    def _stage_sample(self, item) -> MiniBatch:
        epoch, b, seeds, labels = item
        return self.sampler.sample(seeds, labels=labels, batch_index=b,
                                   epoch=epoch)

    def _stage_cpu_prefetch(self, mb: MiniBatch) -> MiniBatch:
        # one contiguous buffer, exactly the paper's "collect data from both
        # local machines and remote machines ... store in contiguous memory"
        if self.typed is not None:
            # the sampler already typed the frontier (mb.input_ntypes)
            mb.input_feats = self.kv_client.pull_typed(
                self.feat_name, mb.input_gids, self.typed,
                ntypes=mb.input_ntypes)
        else:
            mb.input_feats = self.kv_client.pull(self.feat_name,
                                                 mb.input_gids)
        return mb

    def _stage_device_prefetch(self, mb: MiniBatch):
        if not self.to_device:
            return mb
        tree = dict(input_feats=mb.input_feats, seeds=mb.seeds,
                    seed_mask=mb.seed_mask, labels=mb.labels,
                    blocks=_host_blocks(mb))
        return mb, device_stage(tree, packed=self.packed)

    # ---- driving ------------------------------------------------------
    def _epoch_rng(self, epoch: int) -> np.random.Generator:
        return batch_rng(self.seed, epoch, 0, STREAM_SCHEDULE)

    def _schedule_source(self, epochs: Iterator[int], start_batch: int = 0):
        for e in epochs:
            yield from _epoch_schedule(self.seeds, self.labels,
                                       self.batch_size, self._epoch_rng(e), e,
                                       shuffle=self.shuffle,
                                       start_batch=start_batch)
            # fast-forward applies to the FIRST epoch of the stream only:
            # subsequent epochs replay from their own batch 0
            start_batch = 0

    def _build(self, epochs, start_batch: int = 0) -> AsyncPipeline:
        stages = [
            Stage("sample", self._stage_sample, depth=self.depths["sample"],
                  workers=self.sample_workers),
            Stage("cpu_prefetch", self._stage_cpu_prefetch,
                  depth=self.depths["cpu_prefetch"]),
            Stage("device_prefetch", self._stage_device_prefetch,
                  depth=self.depths["device_prefetch"]),
        ]
        return AsyncPipeline(self._schedule_source(epochs, start_batch),
                             stages, sync=self.sync, name="minibatch")

    def epoch(self, epoch: int, start_batch: int = 0):
        """Iterate one epoch's device-ready mini-batches.

        Non-stop mode keeps ONE pipeline alive across epochs: the internal
        epoch stream starts at the first requested epoch and advances by
        one per completed epoch, so callers MUST ask for consecutive
        epochs (e, e+1, e+2, ...) — the batches already in flight were
        scheduled under that assumption. A non-consecutive request raises
        instead of silently serving batches labeled (and permuted) for a
        different epoch. Abandoning an epoch iterator mid-epoch leaves the
        remaining batches in flight: a later ``epoch()`` call raises
        instead of serving another epoch's schedule under a stale label —
        ``stop()`` drains the in-flight work and rewinds (the loader
        façade in ``repro.api`` does exactly that on early ``close()``).

        ``start_batch=k`` is the recovery fast-forward (DESIGN.md §10):
        the epoch's full schedule is derived as usual — identical rng
        consumption — but emission begins at batch k, so a revived trainer
        resumes exactly at its death coordinate with byte-identical
        batches. Only valid on a fresh pipeline: batches already in
        flight were scheduled from batch 0."""
        if self.non_stop and not self.sync:
            with self._lock:
                if start_batch and self._pipe is not None:
                    raise ValueError(
                        "fast-forward (start_batch != 0) requires a fresh "
                        "pipeline — stop() before recovering")
                if (self._pipe is not None
                        and self._epoch_pos not in (0, self.batches_per_epoch)):
                    raise ValueError(
                        f"non-stop pipeline abandoned mid-epoch (batch "
                        f"{self._epoch_pos}/{self.batches_per_epoch} of epoch "
                        f"{self._nonstop_epoch - 1}) — stop() to drain and "
                        f"rewind before starting another epoch")
                if self._pipe is None:
                    self._nonstop_epoch = epoch

                    # infinite epoch stream; the pipeline never drains
                    def forever():
                        e = epoch
                        while True:
                            yield e
                            e += 1
                    self._pipe = self._build(forever(), start_batch)
                    self._out_iter = iter(self._pipe)
                elif epoch != self._nonstop_epoch:
                    raise ValueError(
                        f"non-stop pipeline serves consecutive epochs: "
                        f"expected epoch {self._nonstop_epoch}, got {epoch} "
                        f"(stop() the pipeline to rewind or skip)")
                self._nonstop_epoch = epoch + 1
                self._epoch_pos = start_batch
            for _ in range(self.batches_per_epoch - start_batch):
                item = next(self._out_iter)
                # count at pull time: once off the stream, the stream is
                # past it — a consumer that stops right after taking the
                # last batch has still cleanly finished the epoch
                self._epoch_pos += 1
                yield item
        else:
            pipe = self._build(iter([epoch]), start_batch)
            self._pipe = pipe
            yield from pipe

    def stop(self):
        if self._pipe is not None:
            self._pipe.stop()
            self._pipe = None
            self._out_iter = None
            self._nonstop_epoch = None
            self._epoch_pos = 0

    def stats_report(self) -> dict:
        return {} if self._pipe is None else self._pipe.stats_report()


class EdgeMinibatchPipeline(MinibatchPipeline):
    """The same 5-stage async pipeline driving *edge* mini-batches
    (link prediction): edge scheduling -> endpoint ego-network sampling ->
    CPU feature prefetch (cached KVStore pulls) -> device prefetch ->
    device-side compaction in the consumer.

    Only stages 1-2 change shape: the schedule permutes the trainer's owned
    positive edges (per relation on the typed path) instead of its seed
    nodes, and the sample stage wraps the node sampler through
    ``EdgeBatchSampler`` — the ``EdgeMiniBatch`` it emits duck-types the
    ``MiniBatch`` surface, so CPU/device prefetch (and the hot-vertex
    cache sitting under them) are inherited verbatim.
    """

    def __init__(self, edge_sampler: EdgeBatchSampler, kv_client: KVClient,
                 feat_name: str, **kw):
        self.edge_sampler = edge_sampler
        super().__init__(edge_sampler.node_sampler, kv_client, feat_name,
                         seeds=edge_sampler.owned_eids,
                         batch_size=edge_sampler.batch_edges, **kw)
        # per-etype pools drop their own tails, so the count is NOT
        # len(owned)//B on typed runs — ask the edge scheduler
        self.batches_per_epoch = edge_sampler.batches_per_epoch

    # ---- stages -------------------------------------------------------
    def _stage_sample(self, item) -> EdgeMiniBatch:
        epoch, b, etype, eids = item
        return self.edge_sampler.sample_edges(eids, etype=etype,
                                              batch_index=b, epoch=epoch)

    def _stage_device_prefetch(self, emb):
        if not self.to_device:
            return emb
        tree = dict(input_feats=emb.input_feats, seed_mask=emb.seed_mask,
                    pos_u=emb.pos_u, pos_v=emb.pos_v, neg_v=emb.neg_v,
                    pair_mask=emb.pair_mask, edge_etypes=emb.edge_etypes,
                    blocks=_host_blocks(emb))
        return emb, device_stage(tree, packed=self.packed)

    # ---- driving ------------------------------------------------------
    def _schedule_source(self, epochs, start_batch: int = 0):
        for e in epochs:
            yield from self.edge_sampler.schedule(self._epoch_rng(e), e,
                                                  start_batch=start_batch)
            start_batch = 0
