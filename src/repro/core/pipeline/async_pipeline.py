"""Generic multi-stage asynchronous pipeline with per-stage bounded queues
and per-stage worker pools (§5.5, Fig. 7).

Every stage runs in one or more threads and communicates through a bounded
queue whose depth encodes the paper's "different degrees of aggressiveness
in different stages": deep queues at the cheap front of the pipeline (batch
scheduling, sampling), shallow ones near the device (depth 1 for device
prefetch, because accelerator memory is scarce). A stage that is slower than
its consumers simply keeps its queue drained; a stage slower than its
*producers* exerts backpressure through the bounded queue — no global
barrier anywhere, which is how the pipeline hides both I/O latency and the
per-batch imbalance of GNN sampling.

``Stage(workers=N)`` runs N threads pulling from the stage's shared input
queue — the paper's *multiple sampling workers per trainer* (§5.5), which
keeps the pipeline fed when one stage's per-item latency (RPC round trips,
per-batch sampling skew) exceeds the consumer's step time. Items are tagged
with sequence numbers by the feeder and a reassembly buffer at the pooled
stage's output restores arrival order, so downstream consumers — and the
byte-identity guarantees of DESIGN.md §7 — are unaffected by pool size or
completion order. The reorder buffer is bounded by ``workers + depth``
in-flight items, so pooling never breaks backpressure.

``sync=True`` collapses the whole thing into an inline loop — the
no-pipelining baseline used for the Fig. 14 ablation.

Per-stage wall-time and occupancy counters feed the Table-2-style breakdown
benchmark; under pools the counters aggregate over all of a stage's
workers (guarded by a per-stage lock).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Iterable, Iterator, List, Optional

_SENTINEL = object()
_WORKER_DONE = object()   # one pool worker exited normally
_WORKER_ERR = object()    # a pool worker errored: end the stream now


@dataclasses.dataclass
class Stage:
    name: str
    fn: Callable[[Any], Any]
    depth: int = 2          # output queue bound (ahead-of-time aggressiveness)
    workers: int = 1        # >1: thread pool + in-order reassembly


@dataclasses.dataclass
class StageStats:
    items: int = 0
    busy_s: float = 0.0
    wait_in_s: float = 0.0     # starved (waiting for producer)
    wait_out_s: float = 0.0    # backpressured (waiting for consumer)

    def as_dict(self):
        return dataclasses.asdict(self)


class AsyncPipeline:
    """Drive ``source`` through ``stages``; iterate results.

    The source iterable runs in its own feeder thread so that *scheduling*
    (the first pipeline stage in Fig. 7) is also asynchronous. The feeder
    tags every item with a sequence number; pooled stages may complete
    items out of order but re-emit them in sequence order.
    """

    def __init__(self, source: Iterable[Any], stages: List[Stage], *,
                 sync: bool = False, name: str = "pipeline"):
        self.source = source
        self.stages = stages
        self.sync = sync
        self.name = name
        self.stats = {s.name: StageStats() for s in stages}
        self._stat_locks = {s.name: threading.Lock() for s in stages}
        self._threads: List[threading.Thread] = []
        self._queues: List[queue.Queue] = []
        self._aux_queues: List[queue.Queue] = []   # pool intermediate queues
        self._stop = threading.Event()
        self._started = False
        self._error: Optional[BaseException] = None
        # pooled-stage ordering state: the emitted frontier per stage (the
        # next seq its reassembler will release) and a condition workers
        # wait on so no worker runs fn() more than workers+depth items
        # ahead of the frontier — this is what bounds the reorder buffer
        self._order_cv = threading.Condition()
        self._emitted = {i: 0 for i, s in enumerate(stages) if s.workers > 1}
        # stages whose pool hit an error: siblings stop running fn()
        self._failed_stages: set = set()

    # ------------------------------------------------------------------
    def __iter__(self) -> Iterator[Any]:
        if self.sync:
            yield from self._run_sync()
            return
        self.start()
        out_q = self._queues[-1]
        while True:
            item = out_q.get()
            if item is _SENTINEL:
                if self._error is not None:
                    raise self._error
                return
            yield item[1]          # strip the sequence tag

    def _run_sync(self) -> Iterator[Any]:
        for item in self.source:
            for s in self.stages:
                st = self.stats[s.name]
                t0 = time.perf_counter()
                item = s.fn(item)
                st.busy_s += time.perf_counter() - t0
                st.items += 1
            yield item

    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._started:
            return
        self._started = True
        # queue[0] feeds stage 0; queue[i+1] is stage i's output
        self._queues = [queue.Queue(maxsize=max(self.stages[0].depth, 1))]
        for s in self.stages:
            self._queues.append(queue.Queue(maxsize=max(s.depth, 1)))

        def feeder():
            try:
                for seq, item in enumerate(self.source):
                    if self._stop.is_set():
                        break
                    if not self._put(self._queues[0], (seq, item)):
                        return   # stopped while backpressured
            except BaseException as e:   # propagate into the consumer
                self._error = e
            finally:
                self._put(self._queues[0], _SENTINEL)

        t = threading.Thread(target=feeder, name=f"{self.name}-feed", daemon=True)
        t.start()
        self._threads.append(t)

        for i, s in enumerate(self.stages):
            if s.workers <= 1:
                t = threading.Thread(target=self._stage_loop, args=(i, s),
                                     name=f"{self.name}-{s.name}", daemon=True)
                t.start()
                self._threads.append(t)
                continue
            # worker pool: N workers share the input queue and deposit
            # (seq, out) into an intermediate queue; one reassembler
            # restores sequence order on the stage's output queue. The
            # mid queue leaves headroom for every worker to park one
            # finished item without deadlocking the reorder flush.
            mid_q = queue.Queue(maxsize=max(s.depth, 1) + s.workers)
            self._aux_queues.append(mid_q)
            for w in range(s.workers):
                t = threading.Thread(
                    target=self._pool_worker, args=(i, s, mid_q),
                    name=f"{self.name}-{s.name}-w{w}", daemon=True)
                t.start()
                self._threads.append(t)
            t = threading.Thread(
                target=self._reassembler, args=(i, s, mid_q),
                name=f"{self.name}-{s.name}-order", daemon=True)
            t.start()
            self._threads.append(t)

    def _put(self, q: queue.Queue, item: Any) -> bool:
        """put() that cannot deadlock a shutdown: while running it blocks
        (bounded-queue backpressure), but it re-checks the stop flag so a
        producer stuck on a full queue wakes up once ``stop()`` is called.
        Returns False if the item was dropped because the pipeline stopped."""
        while not self._stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        try:                       # stopping: best-effort, never block
            q.put_nowait(item)
            return True
        except queue.Full:
            return False

    def _get(self, q: queue.Queue) -> Any:
        """get() that re-checks the stop flag: a worker that was mid-``fn``
        when ``stop()``'s pill/join window expired must not block forever on
        the abandoned (empty) input queue afterwards."""
        while True:
            try:
                return q.get(timeout=0.1)
            except queue.Empty:
                if self._stop.is_set():
                    return _SENTINEL

    def _stage_loop(self, i: int, s: Stage) -> None:
        # single-worker stage: sole writer of its stats, no lock needed
        in_q, out_q = self._queues[i], self._queues[i + 1]
        st = self.stats[s.name]
        while True:
            t0 = time.perf_counter()
            item = self._get(in_q)
            t1 = time.perf_counter()
            st.wait_in_s += t1 - t0
            if item is _SENTINEL or self._stop.is_set():
                self._put(out_q, _SENTINEL)
                return
            seq, payload = item
            try:
                out = s.fn(payload)
            except BaseException as e:
                self._error = e
                self._put(out_q, _SENTINEL)
                return
            t2 = time.perf_counter()
            st.busy_s += t2 - t1
            if not self._put(out_q, (seq, out)):
                return
            st.wait_out_s += time.perf_counter() - t2
            st.items += 1

    # ---- worker pools -------------------------------------------------
    def _pool_worker(self, i: int, s: Stage, mid_q: queue.Queue) -> None:
        """One of a pooled stage's N workers: pull from the shared input
        queue, run ``fn``, deposit the tagged result for reassembly. On
        the end-of-stream sentinel it re-posts the sentinel so sibling
        workers see it too (the sentinel is always the queue's last real
        item, so the re-post cannot block behind payload)."""
        in_q = self._queues[i]
        st, lock = self.stats[s.name], self._stat_locks[s.name]
        window = s.workers + max(s.depth, 1)
        while True:
            t0 = time.perf_counter()
            item = self._get(in_q)
            t1 = time.perf_counter()
            with lock:
                st.wait_in_s += t1 - t0
            if i in self._failed_stages:
                return   # a sibling errored: stop running fn (side effects)
            if item is _SENTINEL or self._stop.is_set():
                self._put(in_q, _SENTINEL)
                self._put(mid_q, _WORKER_DONE)
                return
            seq, payload = item
            # ordering window: never run fn more than workers+depth items
            # ahead of the emitted frontier, so one slow batch cannot let
            # the siblings cycle and grow the reorder buffer without
            # bound. The frontier item itself (seq == emitted) never
            # waits, so the window cannot deadlock.
            with self._order_cv:
                while (seq >= self._emitted[i] + window
                       and not self._stop.is_set()
                       and i not in self._failed_stages):
                    self._order_cv.wait(0.1)
            if self._stop.is_set() or i in self._failed_stages:
                return   # woken by shutdown/error, not by the frontier
            tw = time.perf_counter()
            with lock:
                st.wait_out_s += tw - t1     # window wait = backpressure
            t1 = tw
            try:
                out = s.fn(payload)
            except BaseException as e:
                self._error = e
                with self._order_cv:
                    self._failed_stages.add(i)
                    self._order_cv.notify_all()
                self._put(mid_q, _WORKER_ERR)
                return
            t2 = time.perf_counter()
            with lock:
                st.busy_s += t2 - t1
            if not self._put(mid_q, (seq, out)):
                return
            with lock:
                st.wait_out_s += time.perf_counter() - t2
                st.items += 1

    def _reassembler(self, i: int, s: Stage, mid_q: queue.Queue) -> None:
        """In-order reassembly for a pooled stage: buffer out-of-order
        completions, emit runs of consecutive sequence numbers, and
        advance the emitted frontier the workers' ordering window keys
        on. Every stage's input is a contiguous in-order sequence (the
        feeder numbers from 0 and upstream pools reorder before
        emitting), and the window keeps workers within ``workers +
        depth`` of the frontier, so the buffer is bounded by that too."""
        out_q = self._queues[i + 1]
        buf: dict = {}
        expected = 0
        done = 0

        def advance(to_seq):
            with self._order_cv:
                self._emitted[i] = to_seq
                self._order_cv.notify_all()

        while True:
            item = self._get(mid_q)
            if item is _WORKER_ERR or item is _SENTINEL or self._stop.is_set():
                self._put(out_q, _SENTINEL)
                return
            if item is _WORKER_DONE:
                done += 1
                if done == s.workers:
                    for seq in sorted(buf):     # gapless unless stopping
                        if not self._put(out_q, (seq, buf[seq])):
                            return
                    self._put(out_q, _SENTINEL)
                    return
                continue
            seq, out = item
            buf[seq] = out
            while expected in buf:
                if not self._put(out_q, (expected, buf.pop(expected))):
                    return
                expected += 1
                advance(expected)

    # ------------------------------------------------------------------
    def stop(self, timeout: float = 5.0) -> None:
        """Tear the pipeline down without leaking blocked threads.

        A single drain races the workers: a stage blocked on ``put()`` into
        a full queue can refill it right after the drain and then block
        again forever. Instead we repeatedly (a) drain every queue so
        blocked producers wake, (b) poison-pill every queue so blocked
        consumers wake, and (c) join the workers with a bounded timeout,
        until every thread has exited or ``timeout`` elapses."""
        self._stop.set()
        deadline = time.perf_counter() + timeout
        alive = [t for t in self._threads if t.is_alive()]
        while alive:
            for q in self._queues + self._aux_queues:
                try:
                    while True:
                        q.get_nowait()
                except queue.Empty:
                    pass
                try:
                    q.put_nowait(_SENTINEL)
                except queue.Full:
                    pass
            for t in alive:
                t.join(timeout=0.05)
            alive = [t for t in alive if t.is_alive()]
            if time.perf_counter() >= deadline:
                break   # daemon threads; don't hang the caller
        # leave queues drained (sentinels only) so a consumer mid-iteration
        # terminates instead of blocking on an abandoned queue
        self._threads = [t for t in self._threads if t.is_alive()]

    def stats_report(self) -> dict:
        out = {}
        for s in self.stages:
            d = self.stats[s.name].as_dict()
            d["workers"] = s.workers
            out[s.name] = d
        return out
