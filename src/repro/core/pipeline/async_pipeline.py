"""Generic multi-stage asynchronous pipeline with per-stage bounded queues
(§5.5, Fig. 7).

Every stage runs in its own thread and communicates through a bounded queue
whose depth encodes the paper's "different degrees of aggressiveness in
different stages": deep queues at the cheap front of the pipeline (batch
scheduling, sampling), shallow ones near the device (depth 1 for device
prefetch, because accelerator memory is scarce). A stage that is slower than
its consumers simply keeps its queue drained; a stage slower than its
*producers* exerts backpressure through the bounded queue — no global
barrier anywhere, which is how the pipeline hides both I/O latency and the
per-batch imbalance of GNN sampling.

``sync=True`` collapses the whole thing into an inline loop — the
no-pipelining baseline used for the Fig. 14 ablation.

Per-stage wall-time and occupancy counters feed the Table-2-style breakdown
benchmark.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Iterable, Iterator, List, Optional

_SENTINEL = object()


@dataclasses.dataclass
class Stage:
    name: str
    fn: Callable[[Any], Any]
    depth: int = 2          # output queue bound (ahead-of-time aggressiveness)


@dataclasses.dataclass
class StageStats:
    items: int = 0
    busy_s: float = 0.0
    wait_in_s: float = 0.0     # starved (waiting for producer)
    wait_out_s: float = 0.0    # backpressured (waiting for consumer)

    def as_dict(self):
        return dataclasses.asdict(self)


class AsyncPipeline:
    """Drive ``source`` through ``stages``; iterate results.

    The source iterable runs in its own feeder thread so that *scheduling*
    (the first pipeline stage in Fig. 7) is also asynchronous.
    """

    def __init__(self, source: Iterable[Any], stages: List[Stage], *,
                 sync: bool = False, name: str = "pipeline"):
        self.source = source
        self.stages = stages
        self.sync = sync
        self.name = name
        self.stats = {s.name: StageStats() for s in stages}
        self._threads: List[threading.Thread] = []
        self._queues: List[queue.Queue] = []
        self._stop = threading.Event()
        self._started = False
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def __iter__(self) -> Iterator[Any]:
        if self.sync:
            yield from self._run_sync()
            return
        self.start()
        out_q = self._queues[-1]
        while True:
            item = out_q.get()
            if item is _SENTINEL:
                if self._error is not None:
                    raise self._error
                return
            yield item

    def _run_sync(self) -> Iterator[Any]:
        for item in self.source:
            for s in self.stages:
                st = self.stats[s.name]
                t0 = time.perf_counter()
                item = s.fn(item)
                st.busy_s += time.perf_counter() - t0
                st.items += 1
            yield item

    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._started:
            return
        self._started = True
        # queue[0] feeds stage 0; queue[i+1] is stage i's output
        self._queues = [queue.Queue(maxsize=max(self.stages[0].depth, 1))]
        for s in self.stages:
            self._queues.append(queue.Queue(maxsize=max(s.depth, 1)))

        def feeder():
            try:
                for item in self.source:
                    if self._stop.is_set():
                        break
                    if not self._put(self._queues[0], item):
                        return   # stopped while backpressured
            except BaseException as e:   # propagate into the consumer
                self._error = e
            finally:
                self._put(self._queues[0], _SENTINEL)

        t = threading.Thread(target=feeder, name=f"{self.name}-feed", daemon=True)
        t.start()
        self._threads.append(t)

        for i, s in enumerate(self.stages):
            t = threading.Thread(target=self._stage_loop, args=(i, s),
                                 name=f"{self.name}-{s.name}", daemon=True)
            t.start()
            self._threads.append(t)

    def _put(self, q: queue.Queue, item: Any) -> bool:
        """put() that cannot deadlock a shutdown: while running it blocks
        (bounded-queue backpressure), but it re-checks the stop flag so a
        producer stuck on a full queue wakes up once ``stop()`` is called.
        Returns False if the item was dropped because the pipeline stopped."""
        while not self._stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        try:                       # stopping: best-effort, never block
            q.put_nowait(item)
            return True
        except queue.Full:
            return False

    def _get(self, q: queue.Queue) -> Any:
        """get() that re-checks the stop flag: a worker that was mid-``fn``
        when ``stop()``'s pill/join window expired must not block forever on
        the abandoned (empty) input queue afterwards."""
        while True:
            try:
                return q.get(timeout=0.1)
            except queue.Empty:
                if self._stop.is_set():
                    return _SENTINEL

    def _stage_loop(self, i: int, s: Stage) -> None:
        in_q, out_q = self._queues[i], self._queues[i + 1]
        st = self.stats[s.name]
        while True:
            t0 = time.perf_counter()
            item = self._get(in_q)
            t1 = time.perf_counter()
            st.wait_in_s += t1 - t0
            if item is _SENTINEL or self._stop.is_set():
                self._put(out_q, _SENTINEL)
                return
            try:
                out = s.fn(item)
            except BaseException as e:
                self._error = e
                self._put(out_q, _SENTINEL)
                return
            t2 = time.perf_counter()
            st.busy_s += t2 - t1
            if not self._put(out_q, out):
                return
            st.wait_out_s += time.perf_counter() - t2
            st.items += 1

    def stop(self, timeout: float = 5.0) -> None:
        """Tear the pipeline down without leaking blocked threads.

        A single drain races the workers: a stage blocked on ``put()`` into
        a full queue can refill it right after the drain and then block
        again forever. Instead we repeatedly (a) drain every queue so
        blocked producers wake, (b) poison-pill every queue so blocked
        consumers wake, and (c) join the workers with a bounded timeout,
        until every thread has exited or ``timeout`` elapses."""
        self._stop.set()
        deadline = time.perf_counter() + timeout
        alive = [t for t in self._threads if t.is_alive()]
        while alive:
            for q in self._queues:
                try:
                    while True:
                        q.get_nowait()
                except queue.Empty:
                    pass
                try:
                    q.put_nowait(_SENTINEL)
                except queue.Full:
                    pass
            for t in alive:
                t.join(timeout=0.05)
            alive = [t for t in alive if t.is_alive()]
            if time.perf_counter() >= deadline:
                break   # daemon threads; don't hang the caller
        # leave queues drained (sentinels only) so a consumer mid-iteration
        # terminates instead of blocking on an abandoned queue
        self._threads = [t for t in self._threads if t.is_alive()]

    def stats_report(self) -> dict:
        return {k: v.as_dict() for k, v in self.stats.items()}
