"""Generic multi-stage asynchronous pipeline with per-stage bounded queues
(§5.5, Fig. 7).

Every stage runs in its own thread and communicates through a bounded queue
whose depth encodes the paper's "different degrees of aggressiveness in
different stages": deep queues at the cheap front of the pipeline (batch
scheduling, sampling), shallow ones near the device (depth 1 for device
prefetch, because accelerator memory is scarce). A stage that is slower than
its consumers simply keeps its queue drained; a stage slower than its
*producers* exerts backpressure through the bounded queue — no global
barrier anywhere, which is how the pipeline hides both I/O latency and the
per-batch imbalance of GNN sampling.

``sync=True`` collapses the whole thing into an inline loop — the
no-pipelining baseline used for the Fig. 14 ablation.

Per-stage wall-time and occupancy counters feed the Table-2-style breakdown
benchmark.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Iterable, Iterator, List, Optional

_SENTINEL = object()


@dataclasses.dataclass
class Stage:
    name: str
    fn: Callable[[Any], Any]
    depth: int = 2          # output queue bound (ahead-of-time aggressiveness)


@dataclasses.dataclass
class StageStats:
    items: int = 0
    busy_s: float = 0.0
    wait_in_s: float = 0.0     # starved (waiting for producer)
    wait_out_s: float = 0.0    # backpressured (waiting for consumer)

    def as_dict(self):
        return dataclasses.asdict(self)


class AsyncPipeline:
    """Drive ``source`` through ``stages``; iterate results.

    The source iterable runs in its own feeder thread so that *scheduling*
    (the first pipeline stage in Fig. 7) is also asynchronous.
    """

    def __init__(self, source: Iterable[Any], stages: List[Stage], *,
                 sync: bool = False, name: str = "pipeline"):
        self.source = source
        self.stages = stages
        self.sync = sync
        self.name = name
        self.stats = {s.name: StageStats() for s in stages}
        self._threads: List[threading.Thread] = []
        self._queues: List[queue.Queue] = []
        self._stop = threading.Event()
        self._started = False
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def __iter__(self) -> Iterator[Any]:
        if self.sync:
            yield from self._run_sync()
            return
        self.start()
        out_q = self._queues[-1]
        while True:
            item = out_q.get()
            if item is _SENTINEL:
                if self._error is not None:
                    raise self._error
                return
            yield item

    def _run_sync(self) -> Iterator[Any]:
        for item in self.source:
            for s in self.stages:
                st = self.stats[s.name]
                t0 = time.perf_counter()
                item = s.fn(item)
                st.busy_s += time.perf_counter() - t0
                st.items += 1
            yield item

    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._started:
            return
        self._started = True
        # queue[0] feeds stage 0; queue[i+1] is stage i's output
        self._queues = [queue.Queue(maxsize=max(self.stages[0].depth, 1))]
        for s in self.stages:
            self._queues.append(queue.Queue(maxsize=max(s.depth, 1)))

        def feeder():
            try:
                for item in self.source:
                    if self._stop.is_set():
                        break
                    self._queues[0].put(item)
            except BaseException as e:   # propagate into the consumer
                self._error = e
            finally:
                self._queues[0].put(_SENTINEL)

        t = threading.Thread(target=feeder, name=f"{self.name}-feed", daemon=True)
        t.start()
        self._threads.append(t)

        for i, s in enumerate(self.stages):
            t = threading.Thread(target=self._stage_loop, args=(i, s),
                                 name=f"{self.name}-{s.name}", daemon=True)
            t.start()
            self._threads.append(t)

    def _stage_loop(self, i: int, s: Stage) -> None:
        in_q, out_q = self._queues[i], self._queues[i + 1]
        st = self.stats[s.name]
        while True:
            t0 = time.perf_counter()
            item = in_q.get()
            t1 = time.perf_counter()
            st.wait_in_s += t1 - t0
            if item is _SENTINEL or self._stop.is_set():
                out_q.put(_SENTINEL)
                return
            try:
                out = s.fn(item)
            except BaseException as e:
                self._error = e
                out_q.put(_SENTINEL)
                return
            t2 = time.perf_counter()
            st.busy_s += t2 - t1
            out_q.put(out)
            st.wait_out_s += time.perf_counter() - t2
            st.items += 1

    def stop(self) -> None:
        self._stop.set()
        # drain so producer threads blocked on put() can exit
        for q in self._queues:
            try:
                while True:
                    q.get_nowait()
            except queue.Empty:
                pass

    def stats_report(self) -> dict:
        return {k: v.as_dict() for k, v in self.stats.items()}
