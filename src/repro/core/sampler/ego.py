"""Ad-hoc ego-network sampling — the shared eval/serving protocol.

``NodeDataLoader(mode="eval")`` and :class:`repro.api.InferenceServer`
serve the SAME deterministic ego networks: sequential (unshuffled) chunks
of the requested node ids, each sampled at the ad-hoc epoch coordinate
``(epoch=-1, batch_index=chunk_position)`` (DESIGN.md §7) with features
pulled through the caller's KVStore client. Factoring the loop here is
what makes the serving-oracle contract (DESIGN.md §11) structural: the
server cannot drift from the eval loader because both run this function.

Determinism properties the serving tests pin:

* a chunk's bytes are a pure function of ``(sampler seed, chunk position,
  chunk contents, partitions)`` — not of call history (the coordinates are
  counter-keyed, not drawn from a shared mutable RNG);
* feature bytes are cache-invariant (a cache hit returns exactly the rows
  the owning server would have sent — DESIGN.md §5).

``full_neighbor_fanouts`` resolves DGL's ``fanout=-1`` ("all in-neighbors")
into a static per-layer bound so full-neighborhood sampling fits the §2
static-capacity contract: with ``fanout >= max in-degree`` every seed takes
the whole adjacency list deterministically (no subsampling draw) and the
padded capacities stay compile-time constants. This is what the offline
layer-wise inference pass (DESIGN.md §11) samples with.
"""
from __future__ import annotations

from typing import Iterator, Optional, Sequence

import numpy as np

from .dispatch import DistributedSampler
from .mfg import MiniBatch


def pull_batch_feats(client, feat_name: str, mb: MiniBatch,
                     typed=None) -> np.ndarray:
    """The eval/serving feature pull: one batched ``pull`` over the
    batch's input nodes (``pull_typed`` on the heterogeneous path, routed
    by the sampler's frontier type bookkeeping)."""
    if typed is not None:
        return client.pull_typed(feat_name, mb.input_gids, typed,
                                 ntypes=mb.input_ntypes)
    return client.pull(feat_name, mb.input_gids)


def sample_ego_networks(sampler: DistributedSampler, client, feat_name: str,
                        nids: np.ndarray, *,
                        labels: Optional[np.ndarray] = None,
                        typed=None, drop_last: bool = True,
                        start_batch_index: int = 0,
                        pull_feats: bool = True) -> Iterator[MiniBatch]:
    """Yield one featurized :class:`MiniBatch` per sequential chunk of
    ``nids`` — the deterministic ad-hoc protocol shared by
    ``NodeDataLoader(mode="eval")`` and the inference server.

    Chunk ``b`` (size ``sampler.batch_size``) is sampled at coordinate
    ``batch_index=start_batch_index + b`` on the ad-hoc epoch (-1), so a
    request covering the same ids produces byte-identical blocks whether
    it is served by a loader, a server tick, or a direct call here.
    ``drop_last=False`` additionally serves the ragged tail chunk (padded
    to capacity like any short batch) — the serving path, where every
    requested node must get a prediction; the eval loader keeps the
    historical ``drop_last=True`` full-chunks-only protocol.
    """
    nids = np.asarray(nids, dtype=np.int64)
    bs = sampler.batch_size
    n_full = len(nids) // bs
    n_chunks = n_full if drop_last else -(-len(nids) // bs)
    for b in range(n_chunks):
        chunk = nids[b * bs:(b + 1) * bs]
        lab = None if labels is None else labels[b * bs:(b + 1) * bs]
        mb = sampler.sample(chunk, labels=lab,
                            batch_index=start_batch_index + b)
        if pull_feats:
            mb.input_feats = pull_batch_feats(client, feat_name, mb,
                                              typed=typed)
        yield mb


def full_neighbor_fanouts(partitions, num_layers: int,
                          schema=None) -> list:
    """Static per-layer fanouts equivalent to DGL's ``fanout=-1``.

    Returns ``[D] * num_layers`` with ``D`` the max in-degree over every
    partition (per relation on the typed path: ``[{etype: D_r}] * L``).
    ``sample_local`` takes a seed's entire adjacency list whenever
    ``degree <= fanout``, so sampling with these fanouts is full-neighbor
    aggregation — deterministic, no RNG consumption — while the padded
    capacities derived from them stay static (§2).
    """
    def max_deg(gps) -> int:
        d = 0
        for gp in gps:
            if len(gp.indptr) > 1:
                d = max(d, int(np.max(np.diff(gp.indptr))))
        return max(d, 1)

    if schema is None:
        return [max_deg(partitions)] * num_layers
    per_rel = {schema.etypes[r]: max_deg([gp.relation_view(r)
                                          for gp in partitions])
               for r in range(schema.num_etypes)}
    return [dict(per_rel)] * num_layers
