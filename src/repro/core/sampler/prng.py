"""Counter-based per-batch RNG derivation (the sampling determinism
contract, DESIGN.md §7).

Every random draw on the sampling front — neighbor subsampling, negative
sampling, the epoch batch schedule — is made from a short-lived generator
derived from ``(root_seed, epoch, batch_index, stream)`` instead of a
shared mutated ``np.random.Generator``.  Two consequences:

* **worker-count invariance** — a batch's bytes depend only on its
  coordinates, never on which pool thread produced it or how many
  siblings ran before it, so ``--sample-workers {1, 2, 4}``, ``sync=True``
  and replay all yield byte-identical streams;
* **thread safety for free** — pool workers never contend on generator
  state; each ``sample()`` call owns its private generator.

The ``stream`` axis keeps co-seeded consumers (node sampler vs negative
sampler vs schedule) on provably disjoint key material even when callers
reuse a root seed.
"""
from __future__ import annotations

import threading

import numpy as np

_MASK32 = (1 << 32) - 1

# stream ids: one per independent consumer of a (seed, epoch, batch) cell
STREAM_SAMPLE = 0     # DistributedSampler neighbor draws
STREAM_NEG = 1        # NegativeSampler corrupted-destination draws
STREAM_SCHEDULE = 2   # per-epoch batch schedule permutations
STREAM_ADHOC = 3      # sequential sampler calls without batch coordinates
STREAM_NEG_ADHOC = 4  # sequential negative-sampler calls without coordinates


def batch_seed_sequence(root_seed: int, epoch: int, batch_index: int,
                        stream: int = STREAM_SAMPLE) -> np.random.SeedSequence:
    """The key cell for one (batch, consumer).  Negative coordinates (the
    ``-1`` "unscheduled" defaults) are folded into uint32 words, so every
    integer input is legal and the map stays injective per word."""
    return np.random.SeedSequence(
        (root_seed & _MASK32, epoch & _MASK32, batch_index & _MASK32,
         stream & _MASK32))


def batch_rng(root_seed: int, epoch: int, batch_index: int,
              stream: int = STREAM_SAMPLE) -> np.random.Generator:
    """A fresh private generator for one batch's draws."""
    return np.random.default_rng(
        batch_seed_sequence(root_seed, epoch, batch_index, stream))


class PerBatchRng:
    """The per-batch generator policy, shared by every sampling-front
    consumer: scheduled calls (``batch_index >= 0``) key on their batch
    coordinates in ``stream``; unscheduled calls (evaluation, direct test
    calls passing the ``-1`` default) key on a lock-guarded sequential
    counter in ``adhoc_stream`` — deterministic for a sequential caller,
    a fresh stream per call. Keeping the policy here (one place) is what
    keeps neighbor and negative draws on the same DESIGN.md §7 contract."""

    def __init__(self, root_seed: int, stream: int, adhoc_stream: int):
        self.root_seed = int(root_seed)
        self.stream = stream
        self.adhoc_stream = adhoc_stream
        self._lock = threading.Lock()
        self._adhoc_calls = 0

    def __call__(self, epoch: int, batch_index: int) -> np.random.Generator:
        if batch_index < 0:
            with self._lock:
                n = self._adhoc_calls
                self._adhoc_calls += 1
            return batch_rng(self.root_seed, epoch, n, self.adhoc_stream)
        return batch_rng(self.root_seed, epoch, batch_index, self.stream)
