"""Per-partition vertex-wise neighbor sampling (§5.5.1).

``sample_local`` is what a sampler *server* runs on its own physical
partition: given the seed vertices it owns (local core IDs), draw at most
``fanout`` in-neighbors per seed without replacement, returning global IDs.
The computation is per-vertex independent — the property the paper exploits
to decompose sampling across machines.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..partition.book import GraphPartition


def sample_local(gp: GraphPartition, local_seeds: np.ndarray, fanout: int,
                 rng: np.random.Generator,
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray, Optional[np.ndarray]]:
    """Sample in-neighbors of ``local_seeds`` (core-local IDs) on ``gp``.

    Returns (src_gids, seed_pos, edge_ids, etypes): one row per sampled
    edge; ``seed_pos`` indexes into ``local_seeds`` (the caller knows which
    global seed that is). fanout < 0 means "all neighbors".
    """
    indptr, indices = gp.indptr, gp.indices
    starts = indptr[local_seeds]
    degs = indptr[local_seeds + 1] - starts

    if fanout < 0:
        counts = degs
    else:
        counts = np.minimum(degs, fanout)
    total = int(counts.sum())
    if total == 0:
        z = np.empty(0, dtype=np.int64)
        return z, z.astype(np.int32), z, (None if gp.etypes is None else z.astype(np.int32))

    seed_pos = np.repeat(np.arange(len(local_seeds), dtype=np.int32), counts)
    # positions within each seed's adjacency list
    ends = np.cumsum(counts)
    offs = np.arange(total, dtype=np.int64) - np.repeat(ends - counts, counts)

    take_all = (fanout < 0) | (degs <= fanout) if fanout >= 0 else np.ones(len(degs), bool)
    pos = np.empty(total, dtype=np.int64)
    # full-neighborhood seeds: contiguous ranges (vectorized)
    full_rows = np.repeat(take_all, counts)
    pos[full_rows] = np.repeat(starts, counts)[full_rows] + offs[full_rows]
    # subsampled seeds: per-seed partial Fisher–Yates (without replacement)
    sub = np.nonzero(~take_all)[0]
    if len(sub):
        out_off = (ends - counts)
        for i in sub:
            d = int(degs[i])
            picks = rng.choice(d, size=fanout, replace=False)
            pos[out_off[i]: out_off[i] + fanout] = starts[i] + picks

    src_local = indices[pos]
    src_gids = gp.local2global[src_local]
    edge_ids = gp.edge_ids[pos]
    etypes = None if gp.etypes is None else gp.etypes[pos]
    return src_gids, seed_pos, edge_ids, etypes
