"""Per-partition vertex-wise neighbor sampling (§5.5.1).

``sample_local`` is what a sampler *server* runs on its own physical
partition: given the seed vertices it owns (local core IDs), draw at most
``fanout`` in-neighbors per seed without replacement, returning global IDs.
The computation is per-vertex independent — the property the paper exploits
to decompose sampling across machines.

The without-replacement subsample is fully vectorized: instead of a Python
loop calling ``rng.choice`` per seed, every candidate edge slot of every
subsampled seed gets one uniform random key and a single ``lexsort`` ranks
the keys within each seed's segment — the ``fanout`` smallest keys per seed
are the draw (a batched random-key selection, equivalent in distribution to
a per-seed partial Fisher–Yates). One RNG call, one sort, no per-seed
Python overhead — this is the kernel the sampler worker pool multiplies.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..partition.book import GraphPartition


def _subsample_positions(starts: np.ndarray, degs: np.ndarray, fanout: int,
                         rng: np.random.Generator) -> np.ndarray:
    """Vectorized without-replacement draw of ``fanout`` adjacency
    positions for every seed (all must have ``degs > fanout``).

    Returns ``len(starts) * fanout`` absolute positions, grouped by seed.
    Random-key selection: candidate ``j`` of seed ``i`` gets key ``u_ij``;
    the ``fanout`` smallest keys within each seed's segment are a uniform
    without-replacement sample of its adjacency list.
    """
    degs = degs.astype(np.int64)
    tot = int(degs.sum())
    ends = np.cumsum(degs)
    grp_start = ends - degs
    # candidate's offset within its seed's adjacency list — also, because
    # segments occupy the same index ranges after a stable per-segment
    # sort, the rank threshold mask for the sorted layout
    within = np.arange(tot, dtype=np.int64) - np.repeat(grp_start, degs)
    seed_rep = np.repeat(np.arange(len(degs), dtype=np.int64), degs)
    keys = rng.random(tot)
    order = np.lexsort((keys, seed_rep))      # segment-major, key-ascending
    sel = order[within < fanout]              # fanout smallest keys per seed
    return starts[seed_rep[sel]] + within[sel]


def _subsample_positions_loop(starts: np.ndarray, degs: np.ndarray,
                              fanout: int, rng: np.random.Generator
                              ) -> np.ndarray:
    """Pre-pool per-seed ``rng.choice`` loop. Kept as the reference for
    ``benchmarks/sampling_micro.py`` (vectorized-vs-loop row) and the
    distribution tests; not used on the hot path."""
    out = np.empty(len(starts) * fanout, dtype=np.int64)
    for i in range(len(starts)):
        picks = rng.choice(int(degs[i]), size=fanout, replace=False)
        out[i * fanout:(i + 1) * fanout] = starts[i] + picks
    return out


def sample_local(gp: GraphPartition, local_seeds: np.ndarray, fanout: int,
                 rng: np.random.Generator,
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray, Optional[np.ndarray]]:
    """Sample in-neighbors of ``local_seeds`` (core-local IDs) on ``gp``.

    Returns (src_gids, seed_pos, edge_ids, etypes): one row per sampled
    edge; ``seed_pos`` indexes into ``local_seeds`` (the caller knows which
    global seed that is). fanout < 0 means "all neighbors".
    """
    indptr, indices = gp.indptr, gp.indices
    starts = indptr[local_seeds]
    degs = indptr[local_seeds + 1] - starts

    if fanout < 0:
        take_all = np.ones(len(degs), dtype=bool)
        counts = degs
    else:
        take_all = degs <= fanout
        counts = np.minimum(degs, fanout)
    total = int(counts.sum())
    if total == 0:
        z = np.empty(0, dtype=np.int64)
        return z, z.astype(np.int32), z, (None if gp.etypes is None else z.astype(np.int32))

    seed_pos = np.repeat(np.arange(len(local_seeds), dtype=np.int32), counts)
    # positions within each seed's adjacency list
    ends = np.cumsum(counts)
    offs = np.arange(total, dtype=np.int64) - np.repeat(ends - counts, counts)

    pos = np.empty(total, dtype=np.int64)
    # full-neighborhood seeds: contiguous ranges (vectorized)
    full_rows = np.repeat(take_all, counts)
    pos[full_rows] = np.repeat(starts, counts)[full_rows] + offs[full_rows]
    # subsampled seeds: batched random-key selection (see module docstring)
    sub = np.nonzero(~take_all)[0]
    if len(sub):
        pos[~full_rows] = _subsample_positions(starts[sub], degs[sub],
                                               fanout, rng)

    src_local = indices[pos]
    src_gids = gp.local2global[src_local]
    edge_ids = gp.edge_ids[pos]
    etypes = None if gp.etypes is None else gp.etypes[pos]
    return src_gids, seed_pos, edge_ids, etypes
