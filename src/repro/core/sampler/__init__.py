from .mfg import (MFGBlock, MiniBatch, capacities, pad_block,
                  pad_typed_block, relation_capacities)
from .neighbor import sample_local
from .dispatch import DistributedSampler, SamplerStats
from .ego import (full_neighbor_fanouts, pull_batch_feats,
                  sample_ego_networks)
from .compaction import to_block_device, to_block_reference
from .edge_batch import (EdgeBatchSampler, EdgeMiniBatch, NegativeSampler,
                         edge_endpoints)
from .prng import batch_rng, batch_seed_sequence

__all__ = [
    "MFGBlock", "MiniBatch", "capacities", "pad_block", "pad_typed_block",
    "relation_capacities", "sample_local", "DistributedSampler",
    "SamplerStats", "to_block_device", "to_block_reference",
    "EdgeBatchSampler", "EdgeMiniBatch", "NegativeSampler", "edge_endpoints",
    "batch_rng", "batch_seed_sequence",
    "sample_ego_networks", "pull_batch_feats", "full_neighbor_fanouts",
]
