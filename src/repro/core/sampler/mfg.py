"""Padded message-flow-graph (MFG) mini-batches.

DGL mini-batches are ragged; XLA/TPU wants one compiled shape. Every layer's
block is padded to *static capacities* derived from (batch_size, fanouts):

    cap_dst[L-1] = batch_size
    cap_edge[l]  = cap_dst[l] * fanout[l]
    cap_src[l]   = cap_dst[l] + cap_edge[l]   (self nodes first, then newly
                                               discovered neighbors)
    cap_dst[l-1] = cap_src[l]

A layer's fanout is either an int (homogeneous) or a per-relation mapping
``{etype: fanout}``; for typed layers ``fanout[l]`` above is the *sum* over
relations, and the edge axis is laid out **relation-major**: relation r owns
the static slot range ``[rel_offsets[r], rel_offsets[r+1])`` with its own
padding, so typed models slice a relation's edges statically instead of
masking the whole axis (see DESIGN.md §2 for the capacity contract and §4
for the per-relation math).

The dst nodes of each block are a prefix of its src nodes (DGL's ``to_block``
invariant), so layer l+1 can slice its inputs from layer l's outputs.
Padding is masked out of aggregation; padded node slots repeat a valid ID so
feature gathers stay in-bounds. The harness reports padding waste — it is
part of the TPU-adaptation story (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import List, Mapping, Optional, Sequence, Union

import numpy as np

Fanout = Union[int, Mapping]    # one layer: int or {etype: fanout}


@dataclasses.dataclass
class MFGBlock:
    """One GNN layer's bipartite block (host arrays, padded).

    For typed blocks (built by ``pad_typed_block``) the edge axis is
    relation-major: ``rel_offsets`` (R+1,) gives each relation's static slot
    range, ``rel_counts`` (R,) its live edge count, and ``edge_types`` is
    filled with the relation ID across the whole segment — padding included —
    so it is a first-class axis (``edge_mask`` alone distinguishes padding).
    Untyped blocks leave ``rel_offsets``/``rel_counts`` as None.
    """
    src_gids: np.ndarray       # (cap_src,) int64 global node ids, dst prefix
    edge_src: np.ndarray       # (cap_edge,) int32 index into src_gids
    edge_dst: np.ndarray       # (cap_edge,) int32 index into dst prefix
    edge_mask: np.ndarray      # (cap_edge,) bool
    edge_types: np.ndarray     # (cap_edge,) int32
    num_src: int
    num_dst: int
    num_edges: int
    rel_offsets: Optional[np.ndarray] = None   # (R+1,) int64, static
    rel_counts: Optional[np.ndarray] = None    # (R,) int64, live edges

    @property
    def cap_src(self) -> int:
        return len(self.src_gids)

    @property
    def cap_edge(self) -> int:
        return len(self.edge_src)

    @property
    def num_rels(self) -> Optional[int]:
        return None if self.rel_offsets is None else len(self.rel_offsets) - 1

    def rel_slice(self, r: int) -> slice:
        """Static slot range of relation ``r`` on the edge axis."""
        assert self.rel_offsets is not None, "untyped block"
        return slice(int(self.rel_offsets[r]), int(self.rel_offsets[r + 1]))


@dataclasses.dataclass
class MiniBatch:
    """Blocks are input-layer first: blocks[0] consumes raw features."""
    blocks: List[MFGBlock]
    seeds: np.ndarray              # (batch,) target node gids (padded)
    seed_mask: np.ndarray          # (batch,) bool
    labels: Optional[np.ndarray]   # (batch,) int64
    input_gids: np.ndarray         # == blocks[0].src_gids
    input_feats: Optional[np.ndarray] = None   # filled by CPU prefetch stage
    input_ntypes: Optional[np.ndarray] = None  # (cap_src_0,) int32, typed runs
    batch_index: int = -1
    epoch: int = -1

    @property
    def num_input_nodes(self) -> int:
        return self.blocks[0].num_src

    def padding_waste(self) -> dict:
        """Fraction of padded slots (reported in benchmarks)."""
        e_cap = sum(b.cap_edge for b in self.blocks)
        e_use = sum(b.num_edges for b in self.blocks)
        s_cap = sum(b.cap_src for b in self.blocks)
        s_use = sum(b.num_src for b in self.blocks)
        return {"edge_fill": e_use / max(e_cap, 1),
                "node_fill": s_use / max(s_cap, 1)}


def _fanout_total(f: Fanout) -> int:
    if isinstance(f, (int, np.integer)):
        return int(f)
    return int(sum(int(v) for v in f.values()))


def capacities(batch_size: int, fanouts: Sequence[Fanout]
               ) -> list[tuple[int, int]]:
    """[(cap_src, cap_edge) per layer], input-layer first.

    Typed layers (dict fanouts) contribute the sum of their per-relation
    fanouts — the relation-major layout partitions exactly that budget.
    """
    caps = []
    cap_dst = batch_size
    for f in reversed(list(fanouts)):       # walk from target layer inward
        cap_edge = cap_dst * _fanout_total(f)
        cap_src = cap_dst + cap_edge
        caps.append((cap_src, cap_edge))
        cap_dst = cap_src
    return caps[::-1]


def relation_capacities(batch_size: int, fanouts: Sequence[Fanout],
                        num_etypes: int, etype_id=None
                        ) -> list[Optional[np.ndarray]]:
    """Per-layer relation slot offsets, input-layer first.

    Each typed layer gets an (R+1,) offsets array with
    ``offsets[r+1]-offsets[r] == cap_dst * fanout_r`` (relation r's static
    edge budget); layers with int fanouts get None (untyped layout).
    ``etype_id`` maps mapping keys to relation IDs (defaults to identity
    for int keys).
    """
    if etype_id is None:
        def etype_id(k):
            if not isinstance(k, (int, np.integer)):
                raise ValueError(
                    f"fanout key {k!r} is not a relation id; name-keyed "
                    f"fanouts need a resolver — pass the schema's etype_id")
            return int(k)
    per_layer: list[Optional[np.ndarray]] = []
    cap_dst = batch_size
    for f in reversed(list(fanouts)):
        if isinstance(f, (int, np.integer)):
            per_layer.append(None)
        else:
            rel_f = np.zeros(num_etypes, dtype=np.int64)
            for k, v in f.items():
                rel_f[etype_id(k)] = int(v)
            offs = np.zeros(num_etypes + 1, dtype=np.int64)
            np.cumsum(cap_dst * rel_f, out=offs[1:])
            per_layer.append(offs)
        cap_dst = cap_dst + cap_dst * _fanout_total(f)
    return per_layer[::-1]


def pad_block(src_gids: np.ndarray, edge_src: np.ndarray, edge_dst: np.ndarray,
              edge_types: Optional[np.ndarray], num_dst: int,
              cap_src: int, cap_edge: int) -> MFGBlock:
    n_src, n_edge = len(src_gids), len(edge_src)
    assert n_src <= cap_src, (n_src, cap_src)
    assert n_edge <= cap_edge, (n_edge, cap_edge)
    pad_gid = src_gids[0] if n_src else 0
    sg = np.full(cap_src, pad_gid, dtype=np.int64)
    sg[:n_src] = src_gids
    es = np.zeros(cap_edge, dtype=np.int32)
    ed = np.zeros(cap_edge, dtype=np.int32)
    em = np.zeros(cap_edge, dtype=bool)
    et = np.zeros(cap_edge, dtype=np.int32)
    es[:n_edge] = edge_src
    ed[:n_edge] = edge_dst
    em[:n_edge] = True
    if edge_types is not None:
        et[:n_edge] = edge_types
    return MFGBlock(src_gids=sg, edge_src=es, edge_dst=ed, edge_mask=em,
                    edge_types=et, num_src=n_src, num_dst=num_dst,
                    num_edges=n_edge)


def pad_typed_block(src_gids: np.ndarray,
                    rel_edge_src: Sequence[np.ndarray],
                    rel_edge_dst: Sequence[np.ndarray],
                    num_dst: int, cap_src: int,
                    rel_offsets: np.ndarray) -> MFGBlock:
    """Relation-major padded block: relation r's live edges go to the head
    of its slot range ``[rel_offsets[r], rel_offsets[r+1])``; the segment
    tail is padding (masked). ``edge_types`` is set to r across the entire
    segment so the type axis is meaningful on every slot."""
    n_src = len(src_gids)
    assert n_src <= cap_src, (n_src, cap_src)
    num_rels = len(rel_offsets) - 1
    assert len(rel_edge_src) == num_rels
    cap_edge = int(rel_offsets[-1])
    pad_gid = src_gids[0] if n_src else 0
    sg = np.full(cap_src, pad_gid, dtype=np.int64)
    sg[:n_src] = src_gids
    es = np.zeros(cap_edge, dtype=np.int32)
    ed = np.zeros(cap_edge, dtype=np.int32)
    em = np.zeros(cap_edge, dtype=bool)
    et = np.zeros(cap_edge, dtype=np.int32)
    counts = np.zeros(num_rels, dtype=np.int64)
    total = 0
    for r in range(num_rels):
        lo, hi = int(rel_offsets[r]), int(rel_offsets[r + 1])
        n_r = len(rel_edge_src[r])
        assert n_r <= hi - lo, (r, n_r, hi - lo)
        es[lo:lo + n_r] = rel_edge_src[r]
        ed[lo:lo + n_r] = rel_edge_dst[r]
        em[lo:lo + n_r] = True
        et[lo:hi] = r
        counts[r] = n_r
        total += n_r
    return MFGBlock(src_gids=sg, edge_src=es, edge_dst=ed, edge_mask=em,
                    edge_types=et, num_src=n_src, num_dst=num_dst,
                    num_edges=total, rel_offsets=np.asarray(rel_offsets,
                                                            dtype=np.int64),
                    rel_counts=counts)
