"""Padded message-flow-graph (MFG) mini-batches.

DGL mini-batches are ragged; XLA/TPU wants one compiled shape. Every layer's
block is padded to *static capacities* derived from (batch_size, fanouts):

    cap_dst[L-1] = batch_size
    cap_edge[l]  = cap_dst[l] * fanout[l]
    cap_src[l]   = cap_dst[l] + cap_edge[l]   (self nodes first, then newly
                                               discovered neighbors)
    cap_dst[l-1] = cap_src[l]

The dst nodes of each block are a prefix of its src nodes (DGL's ``to_block``
invariant), so layer l+1 can slice its inputs from layer l's outputs.
Padding is masked out of aggregation; padded node slots repeat a valid ID so
feature gathers stay in-bounds. The harness reports padding waste — it is
part of the TPU-adaptation story (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class MFGBlock:
    """One GNN layer's bipartite block (host arrays, padded)."""
    src_gids: np.ndarray       # (cap_src,) int64 global node ids, dst prefix
    edge_src: np.ndarray       # (cap_edge,) int32 index into src_gids
    edge_dst: np.ndarray       # (cap_edge,) int32 index into dst prefix
    edge_mask: np.ndarray      # (cap_edge,) bool
    edge_types: np.ndarray     # (cap_edge,) int32 (zeros if untyped)
    num_src: int
    num_dst: int
    num_edges: int

    @property
    def cap_src(self) -> int:
        return len(self.src_gids)

    @property
    def cap_edge(self) -> int:
        return len(self.edge_src)


@dataclasses.dataclass
class MiniBatch:
    """Blocks are input-layer first: blocks[0] consumes raw features."""
    blocks: List[MFGBlock]
    seeds: np.ndarray              # (batch,) target node gids (padded)
    seed_mask: np.ndarray          # (batch,) bool
    labels: Optional[np.ndarray]   # (batch,) int64
    input_gids: np.ndarray         # == blocks[0].src_gids
    input_feats: Optional[np.ndarray] = None   # filled by CPU prefetch stage
    batch_index: int = -1
    epoch: int = -1

    @property
    def num_input_nodes(self) -> int:
        return self.blocks[0].num_src

    def padding_waste(self) -> dict:
        """Fraction of padded slots (reported in benchmarks)."""
        e_cap = sum(b.cap_edge for b in self.blocks)
        e_use = sum(b.num_edges for b in self.blocks)
        s_cap = sum(b.cap_src for b in self.blocks)
        s_use = sum(b.num_src for b in self.blocks)
        return {"edge_fill": e_use / max(e_cap, 1),
                "node_fill": s_use / max(s_cap, 1)}


def capacities(batch_size: int, fanouts: Sequence[int]) -> list[tuple[int, int]]:
    """[(cap_src, cap_edge) per layer], input-layer first."""
    caps = []
    cap_dst = batch_size
    for f in reversed(list(fanouts)):       # walk from target layer inward
        cap_edge = cap_dst * f
        cap_src = cap_dst + cap_edge
        caps.append((cap_src, cap_edge))
        cap_dst = cap_src
    return caps[::-1]


def pad_block(src_gids: np.ndarray, edge_src: np.ndarray, edge_dst: np.ndarray,
              edge_types: Optional[np.ndarray], num_dst: int,
              cap_src: int, cap_edge: int) -> MFGBlock:
    n_src, n_edge = len(src_gids), len(edge_src)
    assert n_src <= cap_src, (n_src, cap_src)
    assert n_edge <= cap_edge, (n_edge, cap_edge)
    pad_gid = src_gids[0] if n_src else 0
    sg = np.full(cap_src, pad_gid, dtype=np.int64)
    sg[:n_src] = src_gids
    es = np.zeros(cap_edge, dtype=np.int32)
    ed = np.zeros(cap_edge, dtype=np.int32)
    em = np.zeros(cap_edge, dtype=bool)
    et = np.zeros(cap_edge, dtype=np.int32)
    es[:n_edge] = edge_src
    ed[:n_edge] = edge_dst
    em[:n_edge] = True
    if edge_types is not None:
        et[:n_edge] = edge_types
    return MFGBlock(src_gids=sg, edge_src=es, edge_dst=ed, edge_mask=em,
                    edge_types=et, num_src=n_src, num_dst=num_dst,
                    num_edges=n_edge)
