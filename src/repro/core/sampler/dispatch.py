"""Distributed multi-hop neighbor sampling with owner-compute dispatch
(§5.5.1) producing padded MFG mini-batches.

For every hop, frontier vertices are grouped by owning partition (binary
search in the partition book); each owner samples its vertices' in-neighbors
on its local physical partition (``sample_local``) and the trainer stitches
the per-partition results into one bipartite block. Seeds owned by the
trainer's own machine are sampled through the shared-memory path; seeds
owned elsewhere are counted as remote sampling requests (the transport is
charged for the request + response bytes).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from ..kvstore.transport import Transport
from ..partition.book import GraphPartition, PartitionBook
from .mfg import MFGBlock, MiniBatch, capacities, pad_block
from .neighbor import sample_local


def _unique_first_occurrence(ids: np.ndarray) -> np.ndarray:
    """Unique preserving first-occurrence order."""
    uniq, first = np.unique(ids, return_index=True)
    return ids[np.sort(first)]


@dataclasses.dataclass
class SamplerStats:
    batches: int = 0
    seeds_total: int = 0
    seeds_remote: int = 0
    edges_total: int = 0
    input_nodes_total: int = 0

    @property
    def remote_seed_frac(self) -> float:
        return self.seeds_remote / max(self.seeds_total, 1)


class DistributedSampler:
    """One trainer's sampler (runs in the sampling thread, §5.5).

    fanouts are input-layer first (the paper's "15, 10, 5"). ``machine`` is
    the trainer's home machine: its partition is accessed via shared memory,
    all other partitions through (simulated) RPC.
    """

    def __init__(self, book: PartitionBook, partitions: List[GraphPartition],
                 fanouts: Sequence[int], batch_size: int, machine: int = 0,
                 transport: Optional[Transport] = None, seed: int = 0):
        self.book = book
        self.partitions = partitions
        self.fanouts = list(fanouts)
        self.batch_size = batch_size
        self.machine = machine
        self.transport = transport
        self.caps = capacities(batch_size, self.fanouts)
        self.rng = np.random.default_rng(seed)
        self.stats = SamplerStats()

    # ------------------------------------------------------------------
    def sample(self, seeds: np.ndarray, labels: Optional[np.ndarray] = None,
               batch_index: int = -1, epoch: int = -1) -> MiniBatch:
        """Build the padded multi-layer MFG for ``seeds`` (global IDs)."""
        seeds = np.asarray(seeds, dtype=np.int64)
        n_seed = len(seeds)
        assert n_seed <= self.batch_size
        book = self.book

        cur = seeds
        blocks_rev: List[MFGBlock] = []
        for hop, fanout in enumerate(reversed(self.fanouts)):
            cap_src, cap_edge = self.caps[len(self.fanouts) - 1 - hop]
            parts = book.nid2part(cur)
            e_src_g: List[np.ndarray] = []
            e_dst_i: List[np.ndarray] = []
            e_type: List[np.ndarray] = []
            typed = False
            for p in np.unique(parts):
                sel = np.nonzero(parts == p)[0]
                local = book.nid2local(cur[sel], parts[sel])
                src_g, seed_pos, eids, etyp = sample_local(
                    self.partitions[int(p)], local, fanout, self.rng)
                e_src_g.append(src_g)
                e_dst_i.append(sel[seed_pos].astype(np.int32))
                if etyp is not None:
                    typed = True
                    e_type.append(etyp)
                # network accounting: remote sampling request/response
                self.stats.seeds_total += len(sel)
                if int(p) != self.machine:
                    self.stats.seeds_remote += len(sel)
                    if self.transport is not None:
                        req = len(sel) * 8
                        resp = len(src_g) * (8 + 8 + 4)
                        self.transport.charge_remote(req + resp)
            src_gids = (np.concatenate(e_src_g) if e_src_g
                        else np.empty(0, dtype=np.int64))
            dst_idx = (np.concatenate(e_dst_i) if e_dst_i
                       else np.empty(0, dtype=np.int32))
            etypes = np.concatenate(e_type) if typed else None

            # next-layer inputs: current seeds first (to_block prefix rule)
            uniq = _unique_first_occurrence(np.concatenate([cur, src_gids]))
            # host-side compaction of src indices (device version:
            # core.sampler.compaction, used by the GPU pipeline stage)
            order = np.argsort(uniq, kind="stable")
            pos_sorted = np.searchsorted(uniq[order], src_gids)
            src_idx = order[pos_sorted].astype(np.int32)

            blocks_rev.append(pad_block(
                uniq, src_idx, dst_idx, etypes, num_dst=len(cur),
                cap_src=cap_src, cap_edge=cap_edge))
            self.stats.edges_total += len(src_gids)
            cur = uniq

        self.stats.batches += 1
        self.stats.input_nodes_total += len(cur)

        blocks = blocks_rev[::-1]
        seed_pad = np.full(self.batch_size, seeds[0] if n_seed else 0,
                           dtype=np.int64)
        seed_pad[:n_seed] = seeds
        seed_mask = np.zeros(self.batch_size, dtype=bool)
        seed_mask[:n_seed] = True
        lab = None
        if labels is not None:
            lab = np.zeros(self.batch_size, dtype=np.int64)
            lab[:n_seed] = labels
        return MiniBatch(blocks=blocks, seeds=seed_pad, seed_mask=seed_mask,
                         labels=lab, input_gids=blocks[0].src_gids,
                         batch_index=batch_index, epoch=epoch)
