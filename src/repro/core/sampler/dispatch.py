"""Distributed multi-hop neighbor sampling with owner-compute dispatch
(§5.5.1) producing padded MFG mini-batches.

For every hop, frontier vertices are grouped by owning partition (binary
search in the partition book); each owner samples its vertices' in-neighbors
on its local physical partition (``sample_local``) and the trainer stitches
the per-partition results into one bipartite block. Seeds owned by the
trainer's own machine are sampled through the shared-memory path; seeds
owned elsewhere are counted as remote sampling requests (the transport is
charged for the request + response bytes, and for the request *count* —
the batched-RPC metric of §5.5).

Fanouts are per-layer and either an int (homogeneous) or a mapping
``{etype: fanout}`` (DGL-style per-relation fanouts). Typed layers sample
each relation independently on the owner's per-relation partition view and
lay the block's edge axis out relation-major (``MFGBlock.rel_offsets``);
the frontier stays one fused node set — exactly DistDGL's design, where
heterogeneity lives in the relation schema while storage stays fused. The
typed dispatch is **coalesced per owner**: each remote machine receives ONE
sampling request per layer carrying every relation's fanout (the paper
batches RPCs so the async pipeline's front is never starved by per-relation
round trips) — previously it was one request per relation × per owner.

Randomness is counter-based (DESIGN.md §7): every ``sample()`` call derives
a private generator from ``(seed, epoch, batch_index)``, so the sampler is
safe under the pipeline's multi-worker sampling pools and batches are
byte-identical for any worker count, in sync mode, and on replay. Calls
without batch coordinates (evaluation, ad-hoc tests) draw from a
deterministic sequential side stream.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import List, Mapping, Optional, Sequence

import numpy as np

from ...graph.hetero import HeteroSchema
from ..kvstore.transport import Transport
from ..partition.book import GraphPartition, PartitionBook
from .mfg import (Fanout, MFGBlock, MiniBatch, capacities, pad_block,
                  pad_typed_block, relation_capacities)
from .neighbor import sample_local
from .prng import STREAM_ADHOC, STREAM_SAMPLE, PerBatchRng


def _unique_first_occurrence(ids: np.ndarray) -> np.ndarray:
    """Unique preserving first-occurrence order."""
    uniq, first = np.unique(ids, return_index=True)
    return ids[np.sort(first)]


@dataclasses.dataclass
class SamplerStats:
    batches: int = 0
    seeds_total: int = 0
    seeds_remote: int = 0
    edges_total: int = 0
    input_nodes_total: int = 0
    # remote sampling request accounting (the coalescing win, §5.5):
    # owner_requests counts requests actually issued (one per remote owner
    # per layer); relation_requests counts what a per-relation dispatch
    # would have issued (one per remote owner per *relation* per layer)
    owner_requests: int = 0
    relation_requests: int = 0
    edges_per_etype: Optional[np.ndarray] = None   # typed runs only

    @property
    def remote_seed_frac(self) -> float:
        return self.seeds_remote / max(self.seeds_total, 1)

    @property
    def request_coalescing_factor(self) -> float:
        """How many per-relation requests each issued request replaced."""
        return self.relation_requests / max(self.owner_requests, 1)

    def as_dict(self) -> dict:
        """Flat report for loader/benchmark consumers (repro.api's
        ``stats_report`` surfaces this instead of the raw dataclass)."""
        return {"batches": self.batches,
                "seeds_total": self.seeds_total,
                "seeds_remote": self.seeds_remote,
                "remote_seed_frac": self.remote_seed_frac,
                "edges_total": self.edges_total,
                "input_nodes_total": self.input_nodes_total,
                "owner_requests": self.owner_requests,
                "relation_requests": self.relation_requests,
                "coalescing_factor": self.request_coalescing_factor}


class DistributedSampler:
    """One trainer's sampler (runs in the sampling worker pool, §5.5).

    fanouts are input-layer first (the paper's "15, 10, 5"); each entry is
    an int or a per-relation mapping ``{etype: fanout}`` (keys: relation
    ids, or names when ``schema`` is given). ``machine`` is the trainer's
    home machine: its partition is accessed via shared memory, all other
    partitions through (simulated) RPC. ``ntype_of_node`` (NEW-id space)
    enables typed frontier bookkeeping: each minibatch reports its input
    nodes' types so the CPU-prefetch stage can route per-ntype KVStore
    pulls.

    ``sample`` is thread-safe: randomness is derived per call (see
    ``prng.batch_rng``), stats updates are lock-guarded, and relation
    views are pre-built at construction so the pool workers only read.
    """

    def __init__(self, book: PartitionBook, partitions: List[GraphPartition],
                 fanouts: Sequence[Fanout], batch_size: int, machine: int = 0,
                 transport: Optional[Transport] = None, seed: int = 0,
                 schema: Optional[HeteroSchema] = None,
                 ntype_of_node: Optional[np.ndarray] = None):
        self.book = book
        self.partitions = partitions
        self.fanouts = list(fanouts)
        self.batch_size = batch_size
        self.machine = machine
        self.transport = transport
        self.schema = schema
        self.ntype_of_node = ntype_of_node
        self.typed = any(isinstance(f, Mapping) for f in self.fanouts)
        if self.typed and schema is None:
            raise ValueError("per-relation fanouts require a HeteroSchema")
        self.caps = capacities(batch_size, self.fanouts)
        if self.typed:
            self.rel_caps = relation_capacities(
                batch_size, self.fanouts, schema.num_etypes,
                etype_id=schema.etype_id)
            # relation views are lazily cached on the (shared) partitions;
            # build them now, single-threaded, so pool workers never race
            # the cache fill
            for gp in partitions:
                for r in range(schema.num_etypes):
                    gp.relation_view(r)
        else:
            self.rel_caps = [None] * len(self.fanouts)
        self.seed = seed
        self.stats = SamplerStats()
        self._stats_lock = threading.Lock()
        # the call's private generator policy (DESIGN.md §7)
        self._batch_rng = PerBatchRng(seed, STREAM_SAMPLE, STREAM_ADHOC)
        if self.typed:
            self.stats.edges_per_etype = np.zeros(schema.num_etypes,
                                                  dtype=np.int64)

    # ------------------------------------------------------------------
    def sample(self, seeds: np.ndarray, labels: Optional[np.ndarray] = None,
               batch_index: int = -1, epoch: int = -1) -> MiniBatch:
        """Build the padded multi-layer MFG for ``seeds`` (global IDs)."""
        seeds = np.asarray(seeds, dtype=np.int64)
        n_seed = len(seeds)
        assert n_seed <= self.batch_size
        rng = self._batch_rng(epoch, batch_index)

        cur = seeds
        blocks_rev: List[MFGBlock] = []
        edges_total = 0
        for hop in range(len(self.fanouts)):
            layer = len(self.fanouts) - 1 - hop
            fanout = self.fanouts[layer]
            cap_src, cap_edge = self.caps[layer]
            if isinstance(fanout, Mapping):
                block = self._sample_typed_layer(cur, fanout, cap_src,
                                                 self.rel_caps[layer], rng)
            else:
                block = self._sample_untyped_layer(cur, fanout, cap_src,
                                                   cap_edge, rng)
            blocks_rev.append(block)
            edges_total += block.num_edges
            cur = block.src_gids[:block.num_src]

        with self._stats_lock:
            self.stats.batches += 1
            self.stats.edges_total += edges_total
            self.stats.input_nodes_total += len(cur)

        blocks = blocks_rev[::-1]
        seed_pad = np.full(self.batch_size, seeds[0] if n_seed else 0,
                           dtype=np.int64)
        seed_pad[:n_seed] = seeds
        seed_mask = np.zeros(self.batch_size, dtype=bool)
        seed_mask[:n_seed] = True
        lab = None
        if labels is not None:
            lab = np.zeros(self.batch_size, dtype=np.int64)
            lab[:n_seed] = labels
        input_ntypes = None
        if self.ntype_of_node is not None:
            input_ntypes = self.ntype_of_node[blocks[0].src_gids].astype(
                np.int32)
        return MiniBatch(blocks=blocks, seeds=seed_pad, seed_mask=seed_mask,
                         labels=lab, input_gids=blocks[0].src_gids,
                         input_ntypes=input_ntypes,
                         batch_index=batch_index, epoch=epoch)

    # ------------------------------------------------------------------
    def _group_by_owner(self, cur: np.ndarray
                        ) -> List[tuple[int, np.ndarray, np.ndarray]]:
        """Partition-book lookup for one layer's frontier, computed once
        per layer (every relation reuses it): [(part, sel, local_ids)]."""
        parts = self.book.nid2part(cur)
        with self._stats_lock:
            self.stats.seeds_total += len(parts)
            self.stats.seeds_remote += int((parts != self.machine).sum())
        groups = []
        for p in np.unique(parts):
            sel = np.nonzero(parts == p)[0]
            local = self.book.nid2local(cur[sel], parts[sel])
            groups.append((int(p), sel, local))
        return groups

    def _charge_owner_request(self, num_seeds: int, resp_rows: int,
                              num_relations: int) -> None:
        """Account ONE coalesced sampling request to a remote owner:
        request = the seed list + one fanout word per relation; response =
        the sampled (src_gid, edge_id, etype) triples."""
        if self.transport is not None:
            req = num_seeds * 8 + num_relations * 4
            resp = resp_rows * (8 + 8 + 4)
            self.transport.charge_remote(req + resp)
        with self._stats_lock:
            self.stats.owner_requests += 1
            self.stats.relation_requests += num_relations

    def _dispatch(self, groups, fanout: int, rng: np.random.Generator,
                  view=None, collect_etypes: bool = False
                  ) -> tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
        """Owner-compute one (layer, relation): returns
        (src_gids, dst_idx, etypes) concatenated over partitions in
        partition order. ``view`` selects a per-relation partition view
        (None = the full partition); ``etypes`` is None unless requested
        and the partitions carry edge types."""
        e_src_g: List[np.ndarray] = []
        e_dst_i: List[np.ndarray] = []
        e_type: List[np.ndarray] = []
        typed = False
        for p, sel, local in groups:
            gp = self.partitions[p]
            if view is not None:
                gp = gp.relation_view(view)
            src_g, seed_pos, _eids, etyp = sample_local(gp, local, fanout, rng)
            e_src_g.append(src_g)
            e_dst_i.append(sel[seed_pos].astype(np.int32))
            if collect_etypes and etyp is not None:
                typed = True
                e_type.append(etyp)
            if p != self.machine:
                self._charge_owner_request(len(sel), len(src_g), 1)
        src_gids = (np.concatenate(e_src_g) if e_src_g
                    else np.empty(0, dtype=np.int64))
        dst_idx = (np.concatenate(e_dst_i) if e_dst_i
                   else np.empty(0, dtype=np.int32))
        etypes = np.concatenate(e_type) if typed else None
        return src_gids, dst_idx, etypes

    @staticmethod
    def _compact(cur: np.ndarray, src_gids: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
        """Next-layer inputs: current seeds first (to_block prefix rule),
        then newly discovered neighbors; returns (uniq, src_idx) with
        ``src_idx`` the compacted per-edge src index. Host-side version of
        core.sampler.compaction (the GPU pipeline stage)."""
        uniq = _unique_first_occurrence(np.concatenate([cur, src_gids]))
        order = np.argsort(uniq, kind="stable")
        pos_sorted = np.searchsorted(uniq[order], src_gids)
        src_idx = order[pos_sorted].astype(np.int32)
        return uniq, src_idx

    def _sample_untyped_layer(self, cur: np.ndarray, fanout: int,
                              cap_src: int, cap_edge: int,
                              rng: np.random.Generator) -> MFGBlock:
        """Legacy homogeneous layer (one sample_local call per owning
        partition, one flat edge list — guarded by the golden-hash test)."""
        groups = self._group_by_owner(cur)
        src_gids, dst_idx, etypes = self._dispatch(groups, fanout, rng,
                                                   collect_etypes=True)
        uniq, src_idx = self._compact(cur, src_gids)
        return pad_block(uniq, src_idx, dst_idx, etypes, num_dst=len(cur),
                         cap_src=cap_src, cap_edge=cap_edge)

    def _sample_typed_layer(self, cur: np.ndarray, fanout: Mapping,
                            cap_src: int, rel_offsets: np.ndarray,
                            rng: np.random.Generator) -> MFGBlock:
        """Per-relation layer with per-owner request coalescing: the loop
        is owner-major — each owner samples EVERY active relation on its
        relation views and is charged ONE request for the lot — while the
        assembled edge lists stay relation-major (each relation's edges
        concatenated over partitions in partition order), so the block
        layout is identical to the per-relation dispatch. The frontier
        (and to_block compaction) stays one fused node set."""
        schema = self.schema
        rel_fanout = schema.normalize_fanout(dict(fanout))
        groups = self._group_by_owner(cur)
        active = [r for r in range(schema.num_etypes) if rel_fanout[r] != 0]
        # per (relation, partition) results, assembled relation-major below
        parts_src: dict = {r: [] for r in active}
        parts_dst: dict = {r: [] for r in active}
        for p, sel, local in groups:
            gp = self.partitions[p]
            resp_rows = 0
            for r in active:
                src_g, seed_pos, _eids, _ = sample_local(
                    gp.relation_view(r), local, int(rel_fanout[r]), rng)
                parts_src[r].append(src_g)
                parts_dst[r].append(sel[seed_pos].astype(np.int32))
                resp_rows += len(src_g)
            if p != self.machine:
                self._charge_owner_request(len(sel), resp_rows, len(active))
        rel_src_g: List[np.ndarray] = []
        rel_dst_i: List[np.ndarray] = []
        per_etype = np.zeros(schema.num_etypes, dtype=np.int64)
        for r in range(schema.num_etypes):
            if r not in parts_src:
                rel_src_g.append(np.empty(0, dtype=np.int64))
                rel_dst_i.append(np.empty(0, dtype=np.int32))
                continue
            src_g = (np.concatenate(parts_src[r]) if parts_src[r]
                     else np.empty(0, dtype=np.int64))
            dst_i = (np.concatenate(parts_dst[r]) if parts_dst[r]
                     else np.empty(0, dtype=np.int32))
            rel_src_g.append(src_g)
            rel_dst_i.append(dst_i)
            per_etype[r] = len(src_g)
        with self._stats_lock:
            self.stats.edges_per_etype += per_etype
        all_src = (np.concatenate(rel_src_g) if rel_src_g
                   else np.empty(0, dtype=np.int64))
        uniq, src_idx = self._compact(cur, all_src)
        # split the compacted indices back per relation
        rel_src_idx: List[np.ndarray] = []
        off = 0
        for r in range(schema.num_etypes):
            n_r = len(rel_src_g[r])
            rel_src_idx.append(src_idx[off:off + n_r])
            off += n_r
        return pad_typed_block(uniq, rel_src_idx, rel_dst_i,
                               num_dst=len(cur), cap_src=cap_src,
                               rel_offsets=rel_offsets)
