"""Device-side subgraph compaction — the paper's ``to_block``-on-GPU
(§5.5.1: "after sampling a subgraph, we move the subgraph to GPU and perform
to_block on GPUs"), adapted to TPU constraints: everything is static-shape,
sort-based (no dynamic ``unique``), jittable, and runs in the training
thread stage of the pipeline.

Given padded seed gids and padded edge src gids, produce:
  * ``uniq``      (cap_src,) unique gids in first-occurrence order (seeds
                  first — the to_block dst-prefix invariant), padded;
  * ``n_uniq``    scalar count;
  * ``edge_src``  (cap_edge,) compacted src index per edge.

Algorithm: stable-sort by gid; flag group heads; each group's head priority
(= first occurrence position, with padding pushed to +inf) is ranked to
recover first-occurrence order; ranks are scattered back through the sort
permutation. O(N log N) sort + O(N) scans — MXU-free but VPU/sort friendly,
which is exactly why the paper moves it off the (busy) host CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# sentinel: max of the id dtype actually in use (int32 unless x64 enabled —
# node ids fit int32 at any scale this host reaches; a real deployment
# enables x64 and the same code uses the int64 max)
_ID_DTYPE = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
_BIG = int(jnp.iinfo(_ID_DTYPE).max)


def _propagate_group_head(values: jnp.ndarray, is_head: jnp.ndarray) -> jnp.ndarray:
    """For each position, the ``values`` entry at its group head.
    (last-set-value scan; groups are contiguous runs.)"""
    def combine(a, b):
        va, fa = a
        vb, fb = b
        return jnp.where(fb, vb, va), fa | fb
    out, _ = jax.lax.associative_scan(combine, (values, is_head))
    return out


@functools.partial(jax.jit, static_argnames=("cap_src",))
def to_block_device(seed_gids: jnp.ndarray, seed_mask: jnp.ndarray,
                    edge_gids: jnp.ndarray, edge_mask: jnp.ndarray,
                    cap_src: int):
    """Static-shape first-occurrence compaction. See module docstring."""
    seed_gids = seed_gids.astype(_ID_DTYPE)
    edge_gids = edge_gids.astype(_ID_DTYPE)
    n_seed = seed_gids.shape[0]
    ids = jnp.concatenate([
        jnp.where(seed_mask, seed_gids, _BIG),
        jnp.where(edge_mask, edge_gids, _BIG)])
    n = ids.shape[0]
    prio = jnp.where(ids == _BIG, _BIG, jnp.arange(n, dtype=_ID_DTYPE))

    order = jnp.argsort(ids, stable=True)
    sid = ids[order]
    sprio = prio[order]
    is_head = jnp.concatenate([jnp.ones((1,), bool), sid[1:] != sid[:-1]])
    group = jnp.cumsum(is_head) - 1                      # (n,) contiguous
    head_prio = _propagate_group_head(sprio, is_head)    # min prio per group

    # rank groups by head priority (== first occurrence order)
    gfp = jnp.full((n,), _BIG, dtype=_ID_DTYPE)
    gfp = gfp.at[jnp.where(is_head, group, n - 1)].min(
        jnp.where(is_head, head_prio, _BIG), mode="drop")
    ord2 = jnp.argsort(gfp)
    grank = jnp.zeros((n,), dtype=jnp.int32).at[ord2].set(
        jnp.arange(n, dtype=jnp.int32))

    new_idx_sorted = grank[group]
    new_idx = jnp.zeros((n,), jnp.int32).at[order].set(new_idx_sorted)
    edge_src = new_idx[n_seed:]

    # unique ids in rank order
    head_rank = jnp.where(is_head & (sprio != _BIG), grank[group], cap_src)
    uniq = jnp.zeros((cap_src,), _ID_DTYPE).at[head_rank].set(sid, mode="drop")
    n_uniq = jnp.sum(is_head & (head_prio != _BIG)).astype(jnp.int32)
    # padded uniq slots repeat slot 0 (valid gid) so feature gathers stay real
    uniq = jnp.where(jnp.arange(cap_src) < n_uniq, uniq, uniq[0])
    return uniq, n_uniq, edge_src


def to_block_reference(seed_gids: np.ndarray, seed_mask: np.ndarray,
                       edge_gids: np.ndarray, edge_mask: np.ndarray,
                       cap_src: int):
    """NumPy oracle (the host compaction the sampler uses)."""
    seeds = np.asarray(seed_gids)[np.asarray(seed_mask)]
    egs = np.asarray(edge_gids)[np.asarray(edge_mask)]
    allids = np.concatenate([seeds, egs])
    _, first = np.unique(allids, return_index=True)
    uniq = allids[np.sort(first)]
    n_uniq = len(uniq)
    lookup = {g: i for i, g in enumerate(uniq.tolist())}
    edge_src = np.zeros(len(edge_gids), dtype=np.int32)
    em = np.asarray(edge_mask)
    for i, (g, m) in enumerate(zip(np.asarray(edge_gids).tolist(), em.tolist())):
        if m:
            edge_src[i] = lookup[g]
    out = np.full(cap_src, uniq[0] if n_uniq else 0, dtype=np.int64)
    out[:n_uniq] = uniq[:cap_src]
    return out, n_uniq, edge_src
