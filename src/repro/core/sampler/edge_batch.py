"""Edge mini-batches for link prediction (§6: "for link prediction, we may
use all edges to train a model"), layered on the node sampler.

DistDGL's link-prediction workload trains on *edge* mini-batches: a batch of
positive edges, uniform negative endpoints, and the multi-hop ego-networks of
every endpoint gathered through the same distributed neighbor sampler the
node-classification path uses. This module adds exactly that layer without
duplicating any machinery:

* **positive-edge scheduling over owned edges** — each trainer draws its
  positive batches from the edge-ID range its machine owns (edges live with
  their destination vertex, so the owner can resolve both endpoints from
  host-resident arrays without RPC), mirroring §5.6.1's seed split;
* **per-etype edge batches on the typed path** — a schema'd run schedules
  each batch from a single relation (batch order shuffled across relations),
  so the scoring head can look up one relation embedding per batch and
  negatives can be drawn type-correctly from the relation's dst node type;
* **uniform negative sampling with static padded shapes** — ``num_negs``
  corrupted destinations per positive edge, always shaped ``(B, K)``;
  optionally re-drawn so no negative collides with a positive pair of the
  same batch ("exclusion");
* **:class:`EdgeMiniBatch`** — the endpoint seed set is laid out
  ``[u(B) | v(B) | neg(B*K)]`` and pushed through ``DistributedSampler`` as
  ONE padded node mini-batch, so the ego-networks of positive sources,
  positive destinations and negatives share the §2 MFG capacity formulas
  (DESIGN.md §6 has the slot math).

The class duck-types the ``MiniBatch`` surface the pipeline stages touch
(``input_gids`` / ``input_ntypes`` / ``input_feats``), which is what lets
``EdgeMinibatchPipeline`` reuse the 5-stage async pipeline unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence

import numpy as np

from ...graph.csr import CSRGraph, to_coo
from ...graph.hetero import HeteroSchema
from ..partition.book import PartitionBook
from .dispatch import DistributedSampler
from .mfg import MiniBatch
from .prng import STREAM_NEG, STREAM_NEG_ADHOC, PerBatchRng


def edge_endpoints(book: PartitionBook, g: CSRGraph
                   ) -> tuple[np.ndarray, np.ndarray]:
    """(src, dst) in the NEW node-ID space, indexed by NEW edge ID.

    Host-resident positive-edge lookup table: after relabeling, machine m's
    owned edges are exactly NEW edge IDs ``[edge_offsets[m],
    edge_offsets[m+1])``, so a trainer slices its schedule pool directly.
    """
    src_old, dst_old = to_coo(g)
    return (book.old2new_node[src_old[book.new2old_edge]],
            book.old2new_node[dst_old[book.new2old_edge]])


@dataclasses.dataclass(frozen=True)
class PairGraph:
    """The scoring-head view of one edge mini-batch — what DGL hands a
    link-prediction loop as the (positive+negative) *pair graph*. All
    index arrays point at the seed axis of the underlying node mini-batch
    (= the rows of the encoder's output embeddings); gid arrays carry the
    global ids the scheduler/negative-sampler actually drew."""
    pos_u: np.ndarray          # (B,) int32 seed-axis rows of positive srcs
    pos_v: np.ndarray          # (B,) int32 seed-axis rows of positive dsts
    neg_v: np.ndarray          # (B, K) int32 seed-axis rows of negatives
    pair_mask: np.ndarray      # (B,) bool — live positive edges
    pos_eids: np.ndarray       # (B,) int64 NEW edge ids (padded by repeat)
    pos_src: np.ndarray        # (B,) int64 gids
    pos_dst: np.ndarray        # (B,) int64 gids
    neg_dst: np.ndarray        # (B, K) int64 gids
    edge_etypes: np.ndarray    # (B,) int32 relation id per positive edge
    etype: int = -1            # single-relation batch id (-1 = untyped)

    @property
    def batch_edges(self) -> int:
        return len(self.pos_u)

    @property
    def num_negs(self) -> int:
        return int(self.neg_v.shape[1])


@dataclasses.dataclass
class EdgeMiniBatch:
    """One link-prediction batch: a node ``MiniBatch`` over the endpoint
    seed set plus the index arrays the scoring head consumes.

    ``pos_u``/``pos_v``/``neg_v`` index the *seed axis* of ``mb`` (and so
    the rows of the GNN's output embeddings): positives occupy rows
    ``[0, B)`` and ``[B, 2B)``; uniform negatives rows ``[2B, 2B+B*K)``,
    in-batch negatives point back into the ``v`` section. All shapes are
    static — ``pair_mask`` marks live positive slots.
    """
    mb: MiniBatch
    pos_u: np.ndarray          # (B,) int32 seed-axis rows of positive srcs
    pos_v: np.ndarray          # (B,) int32 seed-axis rows of positive dsts
    neg_v: np.ndarray          # (B, K) int32 seed-axis rows of negatives
    pair_mask: np.ndarray      # (B,) bool — live positive edges
    pos_eids: np.ndarray       # (B,) int64 NEW edge ids (padded by repeat)
    pos_src: np.ndarray        # (B,) int64 gids
    pos_dst: np.ndarray        # (B,) int64 gids
    neg_dst: np.ndarray        # (B, K) int64 gids
    edge_etypes: np.ndarray    # (B,) int32 relation id per positive edge
    etype: int = -1            # single-relation batch id (-1 = untyped)

    # -- MiniBatch duck-typing for the pipeline stages -------------------
    @property
    def blocks(self):
        return self.mb.blocks

    @property
    def seeds(self) -> np.ndarray:
        return self.mb.seeds

    @property
    def seed_mask(self) -> np.ndarray:
        return self.mb.seed_mask

    @property
    def input_gids(self) -> np.ndarray:
        return self.mb.input_gids

    @property
    def input_ntypes(self) -> Optional[np.ndarray]:
        return self.mb.input_ntypes

    @property
    def input_feats(self) -> Optional[np.ndarray]:
        return self.mb.input_feats

    @input_feats.setter
    def input_feats(self, value) -> None:
        self.mb.input_feats = value

    @property
    def batch_index(self) -> int:
        return self.mb.batch_index

    @property
    def epoch(self) -> int:
        return self.mb.epoch

    @property
    def batch_edges(self) -> int:
        return len(self.pos_u)

    @property
    def num_negs(self) -> int:
        return self.neg_v.shape[1]

    @property
    def pair_graph(self) -> PairGraph:
        """The scoring-head slice of this batch (what ``EdgeDataLoader``
        yields as the middle element of its DGL-style triple)."""
        return PairGraph(pos_u=self.pos_u, pos_v=self.pos_v,
                         neg_v=self.neg_v, pair_mask=self.pair_mask,
                         pos_eids=self.pos_eids, pos_src=self.pos_src,
                         pos_dst=self.pos_dst, neg_dst=self.neg_dst,
                         edge_etypes=self.edge_etypes, etype=self.etype)


class NegativeSampler:
    """Uniform corrupted-destination sampling with static ``(B, K)`` shapes.

    ``pools`` (typed path) restricts relation r's candidates to its dst
    node type's fused IDs — negatives are always type-correct, matching the
    schema the scorer assumes. ``exclude_batch_positives`` re-draws any
    negative that would collide with a positive pair *of the same batch*
    (the classic false-negative filter; collisions with graph edges outside
    the batch are allowed, as in DGL's uniform sampler), falling back to a
    deterministic linear probe so the guarantee is absolute, not
    probabilistic.

    Randomness is counter-based (DESIGN.md §7): each ``sample`` call draws
    from a private generator derived from ``(seed, epoch, batch_index)``,
    so negatives are reproducible per batch coordinate regardless of
    which sampling worker builds the batch or in what order.
    """

    def __init__(self, num_nodes: int, num_negs: int, *,
                 mode: str = "uniform", seed: int = 0,
                 pools: Optional[Sequence[np.ndarray]] = None,
                 exclude_batch_positives: bool = False,
                 max_resample: int = 8):
        if mode not in ("uniform", "in-batch"):
            raise ValueError(f"unknown negative mode {mode!r}")
        self.num_nodes = int(num_nodes)
        self.num_negs = int(num_negs)
        self.mode = mode
        self.pools = pools
        self.exclude = exclude_batch_positives
        self.max_resample = max_resample
        self.seed = int(seed)
        # the per-batch generator policy (DESIGN.md §7), shared with the
        # node sampler via prng.PerBatchRng — scheduled draws key on
        # (epoch, batch_index), unscheduled ones on a sequential counter
        self._batch_rng = PerBatchRng(self.seed, STREAM_NEG,
                                      STREAM_NEG_ADHOC)

    # ------------------------------------------------------------------
    def _pool(self, etype: int) -> Optional[np.ndarray]:
        if self.pools is None:
            return None
        return self.pools[etype]

    def _bad(self, pos_keys: np.ndarray, u: np.ndarray,
             neg: np.ndarray) -> np.ndarray:
        """(B, K) mask of proposals that equal a positive pair in-batch."""
        keys = u[:, None].astype(np.int64) * self.num_nodes + neg
        return np.isin(keys, pos_keys)

    def _saturated_rows(self, pos_keys: np.ndarray, pos_src: np.ndarray,
                        candidates: np.ndarray) -> np.ndarray:
        """(B,) mask of rows whose ENTIRE candidate set collides with a
        batch positive — exclusion is impossible there (think a 3-node
        graph whose every edge is in the batch), so those rows keep their
        uniform draw instead of probing forever. ``candidates`` is the
        (finite) candidate dst array: the pool for uniform mode, the
        batch's positive dsts for in-batch mode."""
        mat = np.isin(pos_src[:, None].astype(np.int64) * self.num_nodes
                      + candidates[None, :], pos_keys)
        return mat.all(axis=1)

    def sample(self, pos_src: np.ndarray, pos_dst: np.ndarray, etype: int,
               epoch: int = -1, batch_index: int = -1
               ) -> tuple[np.ndarray, Optional[np.ndarray]]:
        """Draw negatives for one batch of positive pairs.

        Returns ``(neg_dst, in_batch_idx)``: gids always; for in-batch mode
        additionally the (B, K) indices into the positive-dst section that
        produced them (None for uniform mode).
        """
        B, K = len(pos_src), self.num_negs
        rng = self._batch_rng(epoch, batch_index)
        pos_keys = (pos_src.astype(np.int64) * self.num_nodes + pos_dst)
        if self.mode == "in-batch":
            idx = rng.integers(0, B, size=(B, K))
            if self.exclude:
                ok = ~self._saturated_rows(pos_keys, pos_src, pos_dst)
                for _ in range(self.max_resample):
                    bad = self._bad(pos_keys, pos_src, pos_dst[idx]) & ok[:, None]
                    if not bad.any():
                        break
                    idx[bad] = rng.integers(0, B, size=int(bad.sum()))
                bad = self._bad(pos_keys, pos_src, pos_dst[idx]) & ok[:, None]
                while bad.any():        # deterministic probe, bounded by B
                    idx[bad] = (idx[bad] + 1) % B
                    bad = self._bad(pos_keys, pos_src, pos_dst[idx]) & ok[:, None]
            return pos_dst[idx], idx.astype(np.int32)

        pool = self._pool(etype)
        size = len(pool) if pool is not None else self.num_nodes

        def draw(n):
            picks = rng.integers(0, size, size=n)
            return pool[picks] if pool is not None else picks.astype(np.int64)

        neg = draw((B, K))
        if self.exclude:
            # a batch holds <= B distinct positives per src, so a row can
            # only saturate when the candidate pool itself is that small
            if size <= B:
                cand = pool if pool is not None else np.arange(
                    size, dtype=np.int64)
                ok = ~self._saturated_rows(pos_keys, pos_src, cand)
            else:
                ok = np.ones(B, dtype=bool)
            for _ in range(self.max_resample):
                bad = self._bad(pos_keys, pos_src, neg) & ok[:, None]
                if not bad.any():
                    break
                neg[bad] = draw(int(bad.sum()))
            bad = self._bad(pos_keys, pos_src, neg) & ok[:, None]
            if bad.any():               # deterministic probe over the pool
                probe = rng.integers(0, size, size=(B, K))
                while bad.any():
                    probe[bad] = (probe[bad] + 1) % size
                    neg[bad] = (pool[probe[bad]] if pool is not None
                                else probe[bad].astype(np.int64))
                    bad = self._bad(pos_keys, pos_src, neg) & ok[:, None]
        return neg, None


class EdgeBatchSampler:
    """Positive-edge scheduling + negative sampling + endpoint ego-networks.

    Wraps a ``DistributedSampler`` whose ``batch_size`` must equal
    :meth:`required_node_batch` — the static endpoint seed capacity
    (2B for in-batch negatives, 2B + B*K for uniform ones). The node
    sampler builds one padded multi-layer MFG over all endpoints; this
    class only decides *which* seeds go in and how the scorer indexes them.

    ``owned_eids`` is this trainer's slice of the NEW edge-ID space (the
    machine's contiguous range split across its trainers). On the typed
    path (``schema`` + ``etype_of_edge``) the owned pool is pre-grouped per
    relation and every scheduled batch carries a single etype.
    """

    def __init__(self, node_sampler: DistributedSampler,
                 e_src: np.ndarray, e_dst: np.ndarray,
                 owned_eids: np.ndarray, batch_edges: int, num_negs: int, *,
                 neg_mode: str = "uniform",
                 etype_of_edge: Optional[np.ndarray] = None,
                 schema: Optional[HeteroSchema] = None,
                 neg_pools: Optional[Sequence[np.ndarray]] = None,
                 exclude_batch_positives: bool = False,
                 seed: int = 0):
        want = self.required_node_batch(batch_edges, num_negs, neg_mode)
        if node_sampler.batch_size != want:
            raise ValueError(
                f"node sampler batch_size {node_sampler.batch_size} != "
                f"required endpoint capacity {want} "
                f"(= 2*{batch_edges}{'' if neg_mode == 'in-batch' else f' + {batch_edges}*{num_negs}'})")
        self.node_sampler = node_sampler
        self.e_src = np.asarray(e_src, dtype=np.int64)
        self.e_dst = np.asarray(e_dst, dtype=np.int64)
        self.owned_eids = np.asarray(owned_eids, dtype=np.int64)
        self.batch_edges = int(batch_edges)
        self.num_negs = int(num_negs)
        self.neg_mode = neg_mode
        self.schema = schema
        self.etype_of_edge = etype_of_edge
        self.typed = schema is not None and etype_of_edge is not None
        num_nodes = node_sampler.book.num_nodes
        self.negatives = NegativeSampler(
            num_nodes, num_negs, mode=neg_mode, seed=seed + 1,
            pools=neg_pools,
            exclude_batch_positives=exclude_batch_positives)
        if self.typed:
            et = self.etype_of_edge[self.owned_eids]
            self._etype_pools: List[np.ndarray] = [
                self.owned_eids[et == r] for r in range(schema.num_etypes)]
        else:
            self._etype_pools = [self.owned_eids]

    # ------------------------------------------------------------------
    @staticmethod
    def required_node_batch(batch_edges: int, num_negs: int,
                            neg_mode: str = "uniform") -> int:
        """Static endpoint seed capacity for (B, K): the node batch size
        the wrapped sampler (and the model's capacity formulas) must use."""
        if neg_mode == "in-batch":
            return 2 * batch_edges
        return 2 * batch_edges + batch_edges * num_negs

    @property
    def batches_per_epoch(self) -> int:
        return sum(len(p) // self.batch_edges for p in self._etype_pools)

    def schedule(self, rng: np.random.Generator, epoch: int,
                 start_batch: int = 0) -> Iterator[tuple]:
        """Stage 1 for edges: permute each relation's owned positives, cut
        into fixed-size batches, shuffle the batch order across relations.
        Untyped runs have one pool (relation -1). Drop-last per pool, like
        the node schedule.

        ``start_batch`` fast-forwards for recovery replay (DESIGN.md §10):
        every permutation is drawn in full — identical rng consumption —
        and only the first ``start_batch`` emissions are skipped, so the
        surviving batches (including their schedule-position-keyed
        negative sampling) are byte-identical to a live run's."""
        B = self.batch_edges
        batches: List[tuple[int, np.ndarray]] = []
        for r, pool in enumerate(self._etype_pools):
            perm = rng.permutation(len(pool))
            for b in range(len(pool) // B):
                batches.append((r if self.typed else -1,
                                pool[perm[b * B:(b + 1) * B]]))
        order = rng.permutation(len(batches))
        for b in order[start_batch:]:
            et, eids = batches[int(b)]
            yield (epoch, int(b), et, eids)

    # ------------------------------------------------------------------
    def sample_edges(self, eids: np.ndarray, etype: int = -1,
                     batch_index: int = -1, epoch: int = -1
                     ) -> EdgeMiniBatch:
        """Build one padded EdgeMiniBatch for positive edges ``eids``."""
        eids = np.asarray(eids, dtype=np.int64)
        B, K = self.batch_edges, self.num_negs
        n_pos = len(eids)
        assert 0 < n_pos <= B, (n_pos, B)
        # pad positives by repeating the first edge (masked out of the loss)
        full = np.empty(B, dtype=np.int64)
        full[:n_pos] = eids
        full[n_pos:] = eids[0]
        u, v = self.e_src[full], self.e_dst[full]
        pair_mask = np.zeros(B, dtype=bool)
        pair_mask[:n_pos] = True
        if self.typed:
            edge_etypes = self.etype_of_edge[full].astype(np.int32)
        else:
            edge_etypes = np.zeros(B, dtype=np.int32)

        neg_dst, in_batch_idx = self.negatives.sample(
            u, v, etype, epoch=epoch, batch_index=batch_index)
        pos_u = np.arange(B, dtype=np.int32)
        pos_v = B + np.arange(B, dtype=np.int32)
        if self.neg_mode == "in-batch":
            seeds = np.concatenate([u, v])
            neg_v = (B + in_batch_idx).astype(np.int32)
        else:
            seeds = np.concatenate([u, v, neg_dst.ravel()])
            neg_v = (2 * B + np.arange(B * K, dtype=np.int32)).reshape(B, K)
        mb = self.node_sampler.sample(seeds, batch_index=batch_index,
                                      epoch=epoch)
        return EdgeMiniBatch(mb=mb, pos_u=pos_u, pos_v=pos_v, neg_v=neg_v,
                             pair_mask=pair_mask, pos_eids=full,
                             pos_src=u, pos_dst=v, neg_dst=neg_dst,
                             edge_etypes=edge_etypes, etype=int(etype))
