"""DistDGLv2 on XLA — distributed hybrid CPU/GPU GNN training, reproduced.

The supported public surface is ``repro.api`` (DESIGN.md §8); its names
are re-exported here lazily (PEP 562), so ``from repro import DistGraph``
works without paying any import cost for subpackages you don't touch.
Subsystem internals stay importable under their own paths
(``repro.core.*``, ``repro.graph``, ``repro.models``, ...).
"""
__all__ = [
    "DistGraph", "DistTensor", "DistEmbedding", "SparseAdamConfig",
    "NodeDataLoader", "EdgeDataLoader", "NodeBatch", "EdgeBatch",
    "DistGNNTrainer", "TrainJobConfig",
]


def __getattr__(name: str):
    if name in __all__:
        from . import api
        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(__all__) | set(globals()))
