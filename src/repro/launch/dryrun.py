"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination against the production mesh, prove memory fit, and
extract the roofline terms from the compiled artifact.

Run:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 pairs, 1-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Results are appended to benchmarks/results/dryrun.json (resumable).
"""
# The VERY FIRST lines — before ANY other import (jax locks the device
# count on first init): 512 placeholder CPU devices for the 2x16x16 mesh.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCH_IDS, get_config                   # noqa: E402
from ..models.lm import (abstract_params, make_decode_step,   # noqa: E402
                         make_prefill_step, make_train_step)
from ..models.lm.config import LMConfig                       # noqa: E402
from ..optim import adamw_init                                # noqa: E402
from ..sharding import AxisRules, param_pspecs, set_rules     # noqa: E402
from .input_specs import (SHAPES, cache_len_for,              # noqa: E402
                          effective_window, input_specs)
from .mesh import make_production_mesh                        # noqa: E402

RESULTS_PATH = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/results/dryrun.json")

_COLLECTIVE_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],\s{}/#_\.\*=\-]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w\.\-]+)\s*,\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:call|closed_call)\(.*?to_apply=%?([\w\.\-]+)")
_COND_RE = re.compile(
    r"conditional\(.*?(?:branch_computations=\{([^}]*)\}|"
    r"true_computation=%?([\w\.\-]+), false_computation=%?([\w\.\-]+))")
_CONST_RE = re.compile(r"constant\((\d+)\)")


_INSTR_START_RE = re.compile(r"^(?:ROOT\s+)?%?[\w\.\-]+\s*=\s")


def _split_computations(hlo_text: str) -> dict:
    """name -> list of instruction strings (continuation lines merged —
    the HLO pretty-printer wraps long instructions, putting e.g. the
    ``condition=``/``body=`` of a while on follow-up lines)."""
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and "->" in line and "{" in line:
            m = _COMP_HDR_RE.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is None or not stripped or stripped == "}":
            continue
        if _INSTR_START_RE.match(stripped) or not comps[cur]:
            comps[cur].append(stripped)
        else:
            comps[cur][-1] += " " + stripped
    return comps


def _line_bytes(result_str: str) -> int:
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(result_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        nbytes += n * _DTYPE_BYTES[dt]
    return nbytes


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum per-device result bytes of every collective op, by type,
    **multiplied by enclosing while-loop trip counts**.

    XLA cost analysis (and a naive text scan) counts a scan body once; with
    layer stacks scanned, a per-layer all-gather would be undercounted by
    num_layers. We split the module into computations, walk the call graph
    from ENTRY through call/closed_call/while/conditional edges, take the
    largest integer constant in each while's condition region as its trip
    count, and multiply nested collectives accordingly.

    The post-SPMD module is the per-device program, so shapes are
    per-device. all-gather results count the *gathered* size (bytes landing
    in this device's memory ≈ bytes crossing its links in a ring).
    """
    comps = _split_computations(hlo_text)
    if not comps:
        return {"total": 0, "count": 0}

    # entry = last computation in the module text (XLA convention: ENTRY
    # last); safer: the one not referenced by anyone
    referenced = set()
    edges = {}   # comp -> list of (callee, multiplier)
    trip_cache = {}

    def trip_count(cond_name: str) -> int:
        if cond_name not in trip_cache:
            consts = [int(c) for line in comps.get(cond_name, [])
                      for c in _CONST_RE.findall(line)]
            trip_cache[cond_name] = max(consts) if consts else 1
        return trip_cache[cond_name]

    for name, lines in comps.items():
        out_edges = []
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                # prefer XLA's own annotation on the while instruction
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else trip_count(cond)
                out_edges.append((body, trips))
                referenced.add(body)
                referenced.add(cond)
                continue
            m = _CALL_RE.search(line)
            if m:
                out_edges.append((m.group(1), 1))
                referenced.add(m.group(1))
                continue
            m = _COND_RE.search(line)
            if m:
                branches = []
                if m.group(1):
                    branches = [b.strip().lstrip("%") for b in
                                m.group(1).split(",")]
                else:
                    branches = [m.group(2), m.group(3)]
                for b in branches:
                    if b:
                        out_edges.append((b, 1))
                        referenced.add(b)
        edges[name] = out_edges

    entries = [n for n in comps if n not in referenced]
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}

    seen = set()

    def walk(name: str, mult: int, depth: int = 0):
        if depth > 64 or (name, mult) in seen:
            return
        seen.add((name, mult))
        for line in comps.get(name, []):
            m = _COLLECTIVE_RE.search(line)
            if m and "-done" not in line.split("=")[0]:
                kind = m.group(2).lower()
                out[kind] += _line_bytes(m.group(1)) * mult
                out["count"] += mult
        for callee, k in edges.get(name, []):
            walk(callee, mult * k, depth + 1)

    for e in entries:
        walk(e, 1)
    out["total"] = sum(out[k] for k in ("all-gather", "all-reduce",
                                        "reduce-scatter", "all-to-all",
                                        "collective-permute"))
    return out


def _sharding_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def cache_pspecs(cfg: LMConfig, cache_abs, batch: int, rules: AxisRules):
    """PartitionSpecs for the serve cache.

    pjit input shardings must divide evenly, so axes are chosen greedily:
    batch over the batch axes when divisible (else the ring/seq dim over
    "data"); "model" goes to the kv-head dim when divisible, else to
    head_dim, else nowhere.
    """
    ba = rules.batch_axes
    m = rules.model_axis
    dsize = 32 if len(ba) == 2 else 16     # ("pod","data") = 2*16
    msize = 16

    def div(x, n):
        return x % n == 0

    def spec_for(path, leaf):
        name = path[-1] if path else ""
        shape = leaf.shape
        nd = len(shape)
        if name in ("k", "v", "xk", "xv"):
            # (L|ns, B, W, KV, hd)
            bspec = ba if div(shape[1], dsize) else None
            wspec = "data" if bspec is None and div(shape[2], 16) else None
            kvspec = m if div(shape[3], msize) else None
            hdspec = m if kvspec is None and div(shape[4], msize) else None
            return P(None, bspec, wspec, kvspec, hdspec)
        if name in ("ssm", "tail_ssm"):
            # (..., B, H, P, N)
            lead = [None] * (nd - 4)
            bspec = ba if div(shape[nd - 4], dsize) else None
            hspec = m if div(shape[nd - 3], msize) else None
            return P(*lead, bspec, hspec, None, None)
        if name in ("conv", "tail_conv"):
            lead = [None] * (nd - 3)
            bspec = ba if div(shape[nd - 3], dsize) else None
            return P(*lead, bspec, None, None)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abs)
    specs = [spec_for([str(getattr(k, "key", k)) for k in path], leaf)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspecs(batch_abs, rules: AxisRules):
    ba = rules.batch_axes

    def spec(leaf):
        nd = len(leaf.shape)
        return P(ba, *([None] * (nd - 1)))
    return jax.tree.map(spec, batch_abs)


@dataclasses.dataclass
class DryRunResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    seconds: float
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    memory: dict = dataclasses.field(default_factory=dict)
    error: str = ""

    def to_json(self):
        return dataclasses.asdict(self)


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            save_hlo: str = "", window_override=None,
            parallel: str = "tp", microbatches: int = 1,
            extra_tag: str = "") -> DryRunResult:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    if parallel == "fsdp":
        # §Perf: pure ZeRO-3 data parallelism. Single pod: batch over both
        # axes, params over both. Multi-pod: batch over (pod,data), params
        # over all three, remat residuals sequence-sharded over "model".
        if multi_pod:
            rules = AxisRules(batch_axes=("pod", "data"), fsdp_axis=None,
                              seq_shard_activations=True, pure_fsdp=True,
                              fsdp_param_axes=("pod", "data", "model"))
        else:
            rules = AxisRules(batch_axes=("data", "model"), fsdp_axis=None,
                              seq_shard_activations=False, pure_fsdp=True)
        extra_tag = extra_tag or "+fsdp"
    else:
        rules = AxisRules(
            batch_axes=("pod", "data") if multi_pod else ("data",),
            fsdp_axis=("pod", "data") if multi_pod else "data")
    set_rules(rules)
    cfg = get_config(arch)
    w = window_override if window_override is not None else \
        effective_window(cfg, shape_name)
    if w is not None:
        cfg = dataclasses.replace(cfg, sliding_window=w)
    spec = input_specs(cfg, shape_name)
    mesh_tag = ("2x16x16" if multi_pod else "16x16") + extra_tag

    try:
        params_abs = abstract_params(cfg)
        pspecs = param_pspecs(params_abs, fsdp=cfg.fsdp, rules=rules)
        psh = _sharding_tree(mesh, pspecs)

        with mesh:
            if spec["kind"] == "train":
                from ..optim.optimizers import AdamWState
                # optimizer moments shard like their parameters
                osh = AdamWState(
                    step=NamedSharding(mesh, P()),
                    mu=_sharding_tree(mesh, pspecs),
                    nu=_sharding_tree(mesh, pspecs))
                opt_abs = AdamWState(
                    step=jax.ShapeDtypeStruct((), jnp.int32),
                    mu=jax.tree.map(
                        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32),
                        params_abs),
                    nu=jax.tree.map(
                        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32),
                        params_abs))
                bsh = _sharding_tree(mesh, batch_pspecs(spec["batch"], rules))
                fn = make_train_step(cfg, microbatches=microbatches)
                jitted = jax.jit(fn, in_shardings=(psh, osh, bsh),
                                 out_shardings=(psh, osh, None))
                lowered = jitted.lower(params_abs, opt_abs, spec["batch"])
            elif spec["kind"] == "prefill":
                bsh = _sharding_tree(mesh, batch_pspecs(spec["batch"], rules))
                fn = make_prefill_step(cfg, spec["cache_len"])
                jitted = jax.jit(fn, in_shardings=(psh, bsh))
                lowered = jitted.lower(params_abs, spec["batch"])
            else:  # decode
                b = SHAPES[shape_name]["batch"]
                csp = cache_pspecs(cfg, spec["cache"], b, rules)
                csh = _sharding_tree(mesh, csp)
                tsh = NamedSharding(mesh, P(rules.batch_axes if b > 1
                                            else None, None))
                fn = make_decode_step(cfg)
                jitted = jax.jit(fn, in_shardings=(psh, csh, tsh),
                                 out_shardings=(None, csh))
                lowered = jitted.lower(params_abs, spec["cache"],
                                       spec["tokens"])

            compiled = lowered.compile()

        cost = compiled.cost_analysis() or {}
        mem = compiled.memory_analysis()
        mem_d = {}
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                if hasattr(mem, attr):
                    mem_d[attr] = int(getattr(mem, attr))
        hlo = compiled.as_text()
        coll = collective_bytes_from_hlo(hlo)
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
        res = DryRunResult(
            arch=arch, shape=shape_name, mesh=mesh_tag, ok=True,
            seconds=round(time.time() - t0, 1),
            flops_per_device=float(cost.get("flops", 0.0)),
            bytes_per_device=float(cost.get("bytes accessed", 0.0)),
            collectives=coll, memory=mem_d)
    except Exception as e:   # noqa: BLE001 — report, don't crash the sweep
        res = DryRunResult(arch=arch, shape=shape_name, mesh=mesh_tag,
                           ok=False, seconds=round(time.time() - t0, 1),
                           error=f"{type(e).__name__}: {e}\n"
                                 f"{traceback.format_exc()[-1500:]}")
    return res


def load_results(path=RESULTS_PATH) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_result(res: DryRunResult, path=RESULTS_PATH):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    all_res = load_results(path)
    all_res[f"{res.arch}|{res.shape}|{res.mesh}"] = res.to_json()
    with open(path, "w") as f:
        json.dump(all_res, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", default="")
    args = ap.parse_args()

    pairs = ([(a, s) for a in ARCH_IDS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    existing = load_results()
    for arch, shape in pairs:
        key = f"{arch}|{shape}|{'2x16x16' if args.multi_pod else '16x16'}"
        if not args.force and existing.get(key, {}).get("ok"):
            print(f"[skip cached] {key}")
            continue
        print(f"[dryrun] {key} ...", flush=True)
        res = run_one(arch, shape, multi_pod=args.multi_pod,
                      save_hlo=args.save_hlo)
        save_result(res)
        if res.ok:
            print(f"  OK in {res.seconds}s  flops/dev={res.flops_per_device:.3e} "
                  f"bytes/dev={res.bytes_per_device:.3e} "
                  f"coll={res.collectives.get('total', 0):.3e}B "
                  f"mem={res.memory}")
        else:
            print(f"  FAIL in {res.seconds}s: {res.error.splitlines()[0]}")


if __name__ == "__main__":
    main()
