"""ShapeDtypeStruct stand-ins for every (architecture × input shape) pair —
weak-type-correct, shardable, zero allocation.

Input shapes (assigned):
    train_4k     seq=4096    global_batch=256   -> train_step
    prefill_32k  seq=32768   global_batch=32    -> prefill_step
    decode_32k   seq=32768   global_batch=128   -> serve_step (1 token,
                                                  KV/SSM cache of seq)
    long_500k    seq=524288  global_batch=1     -> serve_step; sub-quadratic
                                                  attention required (dense
                                                  archs switch to a sliding
                                                  window; SSM/hybrid native)

For vlm the image patch stub occupies ``num_image_tokens`` of the sequence
budget; for audio the encoder consumes the stubbed frame embeddings and the
decoder consumes ``seq`` tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm.config import LMConfig

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# sliding window used by quadratic-attention archs on long_500k
LONG_CONTEXT_WINDOW = 8192


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def needs_window(cfg: LMConfig, shape_name: str) -> bool:
    """Dense/MoE/VLM/audio attention is quadratic — long_500k runs their
    sliding-window variant. SSM is attention-free; hybrid's shared
    attention also gets the window (see DESIGN.md §Arch-applicability)."""
    return shape_name == "long_500k" and cfg.arch_type != "ssm"


def effective_window(cfg: LMConfig, shape_name: str) -> Optional[int]:
    if needs_window(cfg, shape_name):
        return LONG_CONTEXT_WINDOW
    return cfg.sliding_window


def cache_len_for(cfg: LMConfig, shape_name: str) -> int:
    seq = SHAPES[shape_name]["seq"]
    w = effective_window(cfg, shape_name)
    return min(seq, w) if w else seq


def input_specs(cfg: LMConfig, shape_name: str) -> dict:
    """Returns {"kind", "args": tuple of pytrees of ShapeDtypeStruct}."""
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    kind = info["kind"]
    tok = jnp.int32

    def batch_for(seq_len):
        batch = {"tokens": sds((b, seq_len), tok)}
        if cfg.arch_type == "vlm":
            batch["tokens"] = sds((b, seq_len - cfg.num_image_tokens), tok)
            batch["image_embeds"] = sds(
                (b, cfg.num_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.arch_type == "audio":
            batch["encoder_embeds"] = sds(
                (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        return batch

    if kind == "train":
        return {"kind": kind, "batch": batch_for(s)}
    if kind == "prefill":
        return {"kind": kind, "batch": batch_for(s),
                "cache_len": cache_len_for(cfg, shape_name)}
    if kind == "decode":
        from ..models.lm.decode import init_cache
        w = cache_len_for(cfg, shape_name)
        cache = jax.eval_shape(
            lambda: init_cache(cfg, b, w))
        return {"kind": kind, "cache": cache,
                "tokens": sds((b, 1), tok),
                "cache_len": w}
    raise ValueError(kind)
