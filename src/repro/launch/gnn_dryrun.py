"""Dry-run of the paper's own GNN train step on the production mesh
(extra, beyond the 40 assigned pairs — quantifies why DistDGLv2's
contribution is host-side; see EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.gnn_dryrun [--arch graphsage]
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse            # noqa: E402

import jax                 # noqa: E402
import jax.numpy as jnp    # noqa: E402
import numpy as np         # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import get_config                   # noqa: E402
from ..core.sampler.mfg import capacities          # noqa: E402
from ..models.gnn import apply_gnn, init_gnn, nc_loss  # noqa: E402
from ..optim import adamw_init, adamw_update       # noqa: E402
from .dryrun import collective_bytes_from_hlo      # noqa: E402
from .mesh import make_production_mesh             # noqa: E402


def run(arch: str = "graphsage", trainers: int = 256,
        multi_pod: bool = False):
    cfg = get_config(arch)
    caps = capacities(cfg.batch_size, cfg.fanouts)
    params = jax.eval_shape(lambda: init_gnn(cfg, jax.random.key(0)))
    opt = jax.eval_shape(adamw_init, params)
    t = trainers

    blocks = [dict(edge_src=jax.ShapeDtypeStruct((t, ce), jnp.int32),
                   edge_dst=jax.ShapeDtypeStruct((t, ce), jnp.int32),
                   edge_mask=jax.ShapeDtypeStruct((t, ce), jnp.bool_),
                   edge_types=jax.ShapeDtypeStruct((t, ce), jnp.int32))
              for _, ce in caps]
    batch = dict(
        input_feats=jax.ShapeDtypeStruct((t, caps[0][0], cfg.in_dim),
                                         jnp.float32),
        labels=jax.ShapeDtypeStruct((t, cfg.batch_size), jnp.int64),
        seed_mask=jax.ShapeDtypeStruct((t, cfg.batch_size), jnp.bool_),
        blocks=blocks)

    def step(params, opt, stacked):
        def loss_fn(p):
            return jax.vmap(lambda b: nc_loss(
                apply_gnn(cfg, p, b), b["labels"], b["seed_mask"]))(
                    stacked).mean()
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw_update(params, grads, opt, lr=1e-3)
        return params, opt, loss

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh.axis_names
    bsh = jax.tree.map(
        lambda l: NamedSharding(mesh, P(axes, *([None] * (len(l.shape) - 1)))),
        batch)
    with mesh:
        c = jax.jit(step, in_shardings=(None, None, bsh),
                    out_shardings=(None, None, None)).lower(
                        params, opt, batch).compile()
    m = c.memory_analysis()
    coll = collective_bytes_from_hlo(c.as_text())
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"{arch}: {n_params/1e6:.2f}M params, {t} trainers on "
          f"{'2x16x16' if multi_pod else '16x16'}")
    print(f"  temp={m.temp_size_in_bytes/1e9:.2f}GB "
          f"args={m.argument_size_in_bytes/1e9:.2f}GB")
    print(f"  collectives={coll['total']/1e6:.2f}MB/step/device "
          f"(all-reduce={coll['all-reduce']/1e6:.2f}MB)")
    return coll


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="graphsage",
                    choices=["graphsage", "gat", "rgcn"])
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    run(args.arch, multi_pod=args.multi_pod)
