"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips (v5e pod).
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 CPU device; only the dry-run
launcher sets XLA_FLAGS for 512 placeholder devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (tests / local runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
