"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Three terms per (arch × shape × mesh), each "seconds per step if this
resource were the only bottleneck":

  compute    = FLOPs / (chips × 197e12)           [bf16 peak, v5e]
  memory     = HBM bytes / (chips × 819e9)
  collective = collective bytes per device / 50e9 [per-link ICI]

Sources & caveats (measured on this harness, documented honestly):

* ``compiled.cost_analysis()`` on the CPU backend counts a ``while`` body
  ONCE — with every layer stack scanned, its flops/bytes are low by ~the
  layer count. The HLO-derived numbers are therefore reported as
  ``*_hlo`` reference columns, and the primary compute/memory terms are
  ANALYTIC:
    - compute: 8·N_active·D for train (fwd 2 + bwd 4 + full-remat re-fwd 2),
      2·N_active·D for prefill/decode, D = tokens per step.
    - memory (per device): train: 22 B/param (bf16 read+write, bf16 grad,
      f32 m/v read+write) × N/chips + remat-residual traffic
      (4·L·tokens_loc·d_model bytes); decode: 2·N/chips + KV/state cache
      read+write; prefill: 2·N/chips + cache write + activation traffic.
* collective bytes ARE loop-aware: the dry-run walks the post-SPMD call
  graph and multiplies each collective by its enclosing while trip counts
  (XLA's ``known_trip_count``), so a per-layer all-gather counts L times.
  Shapes in the partitioned module are per-device.

Dominant term = max. MODEL_FLOPS ratio vs the HLO count flags where XLA's
single-iteration accounting sits (reported, not used for dominance).

Usage:
    PYTHONPATH=src python -m repro.launch.roofline            # table
    PYTHONPATH=src python -m repro.launch.roofline --markdown
"""
from __future__ import annotations

import argparse
import json
import os

from ..configs import get_config
from .input_specs import SHAPES, cache_len_for

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s per ICI link

RESULTS_PATH = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/results/dryrun.json")


def tokens_per_step(shape: str) -> int:
    info = SHAPES[shape]
    return info["batch"] * (1 if info["kind"] == "decode" else info["seq"])


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    n = cfg.active_param_count()
    d = tokens_per_step(shape)
    factor = 8 if SHAPES[shape]["kind"] == "train" else 2
    return factor * n * d


def cache_bytes(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    info = SHAPES[shape]
    b = info["batch"]
    w = cache_len_for(cfg, shape)
    kv, hd = cfg.num_kv_heads, cfg.hd
    total = 0.0
    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        total += cfg.num_layers * b * w * kv * hd * 2 * 2      # k+v bf16
    if cfg.arch_type == "hybrid":
        sites = cfg.num_layers // cfg.hybrid_attn_every
        total += sites * b * w * kv * hd * 2 * 2
    if cfg.ssm_state:
        total += (cfg.num_layers * b * cfg.ssm_heads * cfg.ssm_head_dim
                  * cfg.ssm_state * 4)
    return total


def memory_bytes(arch: str, shape: str, chips: int) -> float:
    cfg = get_config(arch)
    info = SHAPES[shape]
    n = cfg.param_count()
    d_tokens = tokens_per_step(shape)
    kind = info["kind"]
    if kind == "train":
        weight_traffic = 22.0 * n / chips
        act = 4.0 * cfg.num_layers * (d_tokens / chips * max(
            1, 16)) * cfg.d_model * 2 / 16  # residuals, seq-sharded /16
        return weight_traffic + act
    if kind == "prefill":
        act = 4.0 * cfg.num_layers * d_tokens / chips * cfg.d_model * 2
        return 2.0 * n / chips + cache_bytes(arch, shape) / chips + act
    # decode: every step touches all (sharded) weights + the whole cache
    return 2.0 * n / chips + 2.0 * cache_bytes(arch, shape) / chips


def analyze(entry: dict, chips: int) -> dict:
    arch, shape = entry["arch"], entry["shape"]
    mf = model_flops(arch, shape)
    t_compute = mf / (chips * PEAK_FLOPS)
    t_memory = memory_bytes(arch, shape, chips) / HBM_BW
    t_coll = entry["collectives"].get("total", 0) / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    hlo_flops = entry["flops_per_device"]
    step_time = max(terms.values())
    mfu = (mf / chips / PEAK_FLOPS) / step_time if step_time else 0.0
    return dict(arch=arch, shape=shape, mesh=entry["mesh"],
                t_compute=t_compute, t_memory=t_memory,
                t_collective=t_coll, dominant=dominant,
                model_flops=mf,
                hlo_flops_per_dev=hlo_flops,
                hlo_bytes_per_dev=entry["bytes_per_device"],
                useful_flops_ratio=(mf / chips) / hlo_flops if hlo_flops else 0,
                roofline_mfu=mfu,
                coll_counts=entry["collectives"])


def load(path=RESULTS_PATH) -> dict:
    with open(path) as f:
        return json.load(f)


def improvement_hint(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        return ("compute-bound: cut remat re-forward (policy remat), "
                "reduce MoE capacity waste, or grow the mesh")
    if d == "memory":
        return ("HBM-bound: shrink optimizer/cache traffic (shard further, "
                "quantize cache, fuse reads) or raise arithmetic intensity")
    return ("collective-bound: reshard to cut per-layer all-gathers "
            "(sequence-parallel boundaries, a2a expert dispatch, overlap "
            "collectives with compute)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    data = load()
    chips = 512 if args.mesh.startswith("2x") else 256
    rows = []
    for key, e in sorted(data.items()):
        if not e.get("ok") or e["mesh"] != args.mesh:
            continue
        rows.append(analyze(e, chips))
    if args.markdown:
        print("| arch | shape | compute s | memory s | collective s | "
              "dominant | roofline-MFU | coll GB/dev |")
        print("|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {r['t_compute']:.4f} | "
                  f"{r['t_memory']:.4f} | {r['t_collective']:.4f} | "
                  f"**{r['dominant']}** | {r['roofline_mfu']:.2f} | "
                  f"{r['coll_counts'].get('total', 0) / 1e9:.1f} |")
    else:
        for r in rows:
            print(f"{r['arch']:22s} {r['shape']:12s} "
                  f"C={r['t_compute']:.4f}s M={r['t_memory']:.4f}s "
                  f"X={r['t_collective']:.4f}s -> {r['dominant']:10s} "
                  f"MFU={r['roofline_mfu']:.2f}")
            print(f"   hint: {improvement_hint(r)}")


if __name__ == "__main__":
    main()
