"""GNN serving launcher: online ego-network predictions + offline pass.

Stands up an :class:`repro.api.InferenceServer` over a partitioned graph
and drives it with an open-loop request load (Poisson arrivals at
``--rate`` requests/s for ``--duration`` seconds), then prints latency
percentiles, throughput, micro-batch occupancy and cache hit rates —
the same numbers ``benchmarks/serving_bench.py`` records.

    PYTHONPATH=src python -m repro.launch.gnn_serve --arch graphsage \
        --dataset product-sim --scale 10 --rate 200 --duration 2

    # full-graph layer-wise embedding pass instead of online serving
    PYTHONPATH=src python -m repro.launch.gnn_serve --arch graphsage \
        --offline --scale 10
"""
from __future__ import annotations

import argparse
import json
import time


def build_parser() -> argparse.ArgumentParser:
    """The serving CLI. Every flag here must be documented in the
    top-level README's flag table (tests/test_docs.py enforces it)."""
    ap = argparse.ArgumentParser(prog="repro.launch.gnn_serve")
    ap.add_argument("--arch", default="graphsage",
                    choices=["graphsage", "gat", "rgcn"],
                    help="GNN architecture to serve")
    ap.add_argument("--dataset", default="product-sim",
                    help="named synthetic dataset (repro.graph.datasets)")
    ap.add_argument("--scale", type=int, default=10,
                    help="dataset scale exponent (graph has ~2^scale nodes)")
    ap.add_argument("--machines", type=int, default=2,
                    help="simulated machines (level-1 partitions)")
    ap.add_argument("--hetero", action="store_true",
                    help="typed relations end-to-end (schema'd dataset)")
    ap.add_argument("--batch-size", type=int, default=8,
                    help="seeds per §2 capacity block (requests larger "
                         "than this are chunked)")
    ap.add_argument("--rate", type=float, default=100.0,
                    help="open-loop request rate (requests/s, Poisson "
                         "arrivals)")
    ap.add_argument("--duration", type=float, default=2.0,
                    help="load-generation window in seconds")
    ap.add_argument("--request-size", type=int, default=1,
                    help="seed nodes per predict request")
    ap.add_argument("--micro-batch-window", type=float, default=2.0,
                    help="scheduler coalescing window in milliseconds")
    ap.add_argument("--micro-batch-capacity", type=int, default=8,
                    help="max chunks stacked into one forward tick")
    ap.add_argument("--cache-budget-mb", type=float, default=4.0,
                    help="serving feature-cache budget (0 disables)")
    ap.add_argument("--replication", type=int, default=1,
                    help="KVStore feature-plane replica count — reads "
                         "fail over byte-identically when an owner is "
                         "down (DESIGN.md §12)")
    ap.add_argument("--max-rpc-retries", type=int, default=8,
                    help="per-destination transient-RPC retry budget "
                         "before a peer is treated as dead")
    ap.add_argument("--hedge-ms", type=float, default=None,
                    help="hedged reads: race a replica after this many ms "
                         "without a primary response (needs "
                         "--replication >= 2; default off)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline budget: chunks still "
                         "queued past it are shed (DeadlineExceeded) "
                         "instead of served late (default off)")
    ap.add_argument("--max-pending-chunks", type=int, default=None,
                    help="admission control: reject requests "
                         "(ServerOverloaded) once this many chunks are "
                         "queued (default off)")
    ap.add_argument("--offline", action="store_true",
                    help="run the full-graph layer-wise embedding pass "
                         "(repro.api.offline_embeddings) and exit")
    ap.add_argument("--chunk-size", type=int, default=0,
                    help="offline pass: nodes per layer-wise chunk "
                         "(0 = model batch size)")
    ap.add_argument("--seed", type=int, default=0,
                    help="parameters + request-trace seed")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed load (CI smoke)")
    return ap


def _build_world(args):
    import dataclasses

    import jax
    import numpy as np

    from ..api import DistGraph
    from ..configs import get_config
    from ..graph import get_dataset
    from ..models.gnn import init_gnn

    cfg = get_config(args.arch)
    ds = get_dataset(args.dataset, scale=args.scale)
    cfg = dataclasses.replace(cfg, in_dim=ds.feats.shape[1],
                              num_classes=ds.num_classes,
                              batch_size=min(cfg.batch_size,
                                             args.batch_size),
                              num_rels=ds.graph.num_etypes)
    if args.hetero:
        if ds.schema is None:
            raise SystemExit(f"--hetero needs a schema'd dataset "
                             f"(e.g. mag-hetero), got {args.dataset}")
        fanouts = [{rel: f for rel in ds.schema.etypes}
                   for f in cfg.fanouts]
        cfg = dataclasses.replace(cfg, fanouts=fanouts)
    g = DistGraph(ds, num_machines=args.machines, trainers_per_machine=1,
                  hetero=args.hetero, seed=args.seed,
                  replication=args.replication,
                  max_rpc_retries=args.max_rpc_retries,
                  hedge_ms=args.hedge_ms)
    params = init_gnn(cfg, jax.random.PRNGKey(args.seed))
    return g, cfg, params, np


def run_offline(args) -> dict:
    from ..api import offline_embeddings
    g, cfg, params, np = _build_world(args)
    t0 = time.perf_counter()
    embs = offline_embeddings(g, cfg, params,
                              chunk_size=args.chunk_size or None)
    dt = time.perf_counter() - t0
    out = {"mode": "offline", "num_nodes": int(g.num_nodes()),
           "layers": [list(e.shape) for e in embs],
           "wall_s": round(dt, 4),
           "nodes_per_s": round(g.num_nodes() * cfg.num_layers / dt, 1)}
    print(json.dumps(out, indent=2))
    return out


def run_serving(args) -> dict:
    from ..api import DeadlineExceeded, InferenceServer, ServerOverloaded
    from ..core.kvstore import CacheConfig
    g, cfg, params, np = _build_world(args)
    cache = (CacheConfig.from_mb(args.cache_budget_mb)
             if args.cache_budget_mb > 0 else None)
    rng = np.random.default_rng(args.seed)
    n_req = (8 if args.smoke
             else max(1, int(args.rate * args.duration)))
    gaps = (np.zeros(n_req) if args.smoke
            else rng.exponential(1.0 / args.rate, size=n_req))
    nid_trace = rng.integers(0, g.num_nodes(),
                             size=(n_req, args.request_size))

    with InferenceServer(
            g, cfg, params, cache=cache,
            micro_batch_capacity=args.micro_batch_capacity,
            micro_batch_window_ms=args.micro_batch_window,
            sampler_seed=args.seed, deadline_ms=args.deadline_ms,
            max_pending_chunks=args.max_pending_chunks) as srv:
        # one warmup request compiles the tick program outside the
        # measured window
        srv.predict(nid_trace[0])
        if srv.cache is not None:
            srv.cache.reset_stats()
        handles = []
        rejected = 0
        t0 = time.perf_counter()
        for i in range(n_req):
            time.sleep(float(gaps[i]))
            try:
                handles.append(srv.submit(nid_trace[i]))
            except ServerOverloaded:
                rejected += 1     # admission control shed the request
        served, degraded, shed = 0, 0, 0
        for h in handles:
            try:
                h.result(timeout=120)
                served += 1
                degraded += int(h.degraded)
            except DeadlineExceeded:
                shed += 1
        wall = time.perf_counter() - t0
        done = [h for h in handles if h.latency_s is not None]
        lat = (np.sort(np.asarray([h.latency_s for h in done]))
               if done else np.array([float("nan")]))
        stats = srv.stats()

    out = {"mode": "serving", "requests": n_req,
           "rate_req_s": args.rate, "wall_s": round(wall, 4),
           "throughput_req_s": round(n_req / wall, 1),
           "p50_ms": round(float(lat[len(lat) // 2]) * 1e3, 3),
           "p99_ms": round(float(lat[min(len(lat) - 1,
                                         int(len(lat) * 0.99))]) * 1e3, 3),
           "served": served, "degraded": degraded,
           "shed": shed, "rejected": rejected,
           "mean_tick_occupancy": round(stats["mean_tick_occupancy"], 2),
           "cache": stats["cache"]}
    print(json.dumps(out, indent=2))
    return out


def main(argv=None):
    args = build_parser().parse_args(argv)
    if args.offline:
        return run_offline(args)
    return run_serving(args)


if __name__ == "__main__":
    main()
