"""LM serving launcher: batched prefill + decode with the ring-buffer
cache. This entry point serves TOKEN models only; GNN ego-network serving
lives in ``repro.launch.gnn_serve`` (``--task gnn`` here forwards there).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    # GNN serving is a different launcher (ego-network sampling + KVStore
    # feature pulls, not a token cache): forward before the LM-specific
    # flags below reject the command line
    for i, a in enumerate(argv):
        if a == "--task=gnn" or (a == "--task" and
                                 argv[i + 1:i + 2] == ["gnn"]):
            from . import gnn_serve
            skip = 1 if a == "--task=gnn" else 2
            return gnn_serve.main(argv[:i] + argv[i + skip:])
    ap = argparse.ArgumentParser(
        description="LM/VLM/audio token serving (prefill + decode). "
                    "GNN serving: repro.launch.gnn_serve or --task gnn.")
    ap.add_argument("--task", choices=["lm", "gnn"], default="lm",
                    help="lm serves token models here; gnn forwards to "
                         "repro.launch.gnn_serve")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    from ..configs import get_config, smoke_variant
    from ..models.lm import init_params, make_decode_step, make_prefill_step

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    cache_len = args.cache_len or (args.prompt_len + args.gen + 8)
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.key(0))

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)))}
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.num_image_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))
        cache_len += cfg.num_image_tokens
    if cfg.arch_type == "audio":
        batch["encoder_embeds"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encoder_seq, cfg.d_model)), jnp.dtype(cfg.dtype))

    prefill = jax.jit(make_prefill_step(cfg, cache_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    key = jax.random.key(1)

    def sample(logits, key):
        logits = logits[:, :cfg.vocab_size]
        if args.temperature <= 0:
            return logits.argmax(-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(
            key, logits / args.temperature)[:, None].astype(jnp.int32)

    tok = sample(logits, key)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        key, sub = jax.random.split(key)
        logits, cache = decode(params, cache, tok)
        tok = sample(logits, sub)
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"[prefill] {args.batch}x{args.prompt_len} in {t_prefill:.2f}s")
    print(f"[decode]  {args.gen - 1} steps in {t_dec:.2f}s "
          f"({args.batch * (args.gen - 1) / max(t_dec, 1e-9):.1f} tok/s)")
    print("[sample generations]")
    for row in gen[:2]:
        print("  ", row[:24].tolist())


if __name__ == "__main__":
    main()
