"""Training launcher.

Two families:
  * GNN (the paper's workloads):
        python -m repro.launch.train --arch graphsage --dataset product-sim \
            --machines 2 --trainers-per-machine 2 --epochs 5
    heterogeneous (typed relations end-to-end, RGCN on a schema'd dataset):
        python -m repro.launch.train --arch rgcn --dataset mag-hetero \
            --hetero --rel-fanout cites=10 --rel-fanout writes=5 --epochs 3
  * LM (assigned architectures, reduced or full):
        python -m repro.launch.train --arch qwen2-0.5b --smoke --steps 20

LM full configs need a pod; on this host use --smoke (reduced variant) or
the dry-run for the production mesh.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def run_gnn(args):
    import jax
    from ..configs import get_config
    from ..graph import get_dataset
    from ..api import (DistGNNTrainer, FaultInjector, TrainJobConfig,
                       TrainerDeath)
    from ..core.kvstore import CacheConfig, NetworkModel

    kill_at = None
    if args.inject_fault:
        try:
            e, _, b = args.inject_fault.partition(":")
            kill_at = (int(e), int(b))
        except ValueError:
            raise SystemExit(f"--inject-fault expects EPOCH:BATCH, "
                             f"got {args.inject_fault!r}")
    if (kill_at or args.recover or args.checkpoint_interval) \
            and not args.checkpoint_dir:
        raise SystemExit("--inject-fault / --recover / "
                         "--checkpoint-interval need --checkpoint-dir")

    cfg = get_config(args.arch)
    ds = get_dataset(args.dataset, scale=args.scale)
    import dataclasses
    # link prediction: the model's output is an embedding (dim = hidden),
    # not class logits, and batch_size counts POSITIVE EDGES per batch
    out_dim = (cfg.hidden_dim if args.task == "link_prediction"
               else ds.num_classes)
    cfg = dataclasses.replace(cfg, in_dim=ds.feats.shape[1],
                              num_classes=out_dim,
                              batch_size=min(cfg.batch_size, args.batch_size),
                              num_rels=ds.graph.num_etypes)
    if args.hetero:
        if ds.schema is None:
            raise SystemExit(f"--hetero needs a schema'd dataset "
                             f"(e.g. mag-hetero), got {args.dataset}")
        # per-relation fanouts: every relation gets the layer fanout unless
        # overridden with --rel-fanout <relation>=<k> (0 disables sampling
        # that relation)
        overrides = {}
        for spec in args.rel_fanout or []:
            rel, sep, k = spec.partition("=")
            if not sep or not k.isdigit():
                raise SystemExit(f"--rel-fanout expects <relation>=<int>, "
                                 f"got {spec!r}")
            if rel not in ds.schema.etypes:
                raise SystemExit(f"unknown relation {rel!r}; dataset "
                                 f"relations: {list(ds.schema.etypes)}")
            overrides[rel] = int(k)
        fanouts = [{rel: overrides.get(rel, f) for rel in ds.schema.etypes}
                   for f in cfg.fanouts]
        cfg = dataclasses.replace(cfg, fanouts=fanouts)
        from ..graph import HeteroCSRGraph
        counts = HeteroCSRGraph(ds.graph, ds.schema).type_counts()
        print(f"[hetero] schema: {list(ds.schema.ntypes)} / "
              f"{list(ds.schema.canonical_etypes)}")
        print(f"[hetero] counts: {counts}")
        print(f"[hetero] per-relation fanouts: {fanouts}")
    cache = (CacheConfig.from_mb(args.cache_budget_mb,
                                 policy=args.cache_policy)
             if args.cache_budget_mb > 0 else None)
    injector = None
    if kill_at or args.rpc_fault_rate:
        injector = FaultInjector(seed=args.fault_seed, kill_at=kill_at,
                                 rpc_failure_rate=args.rpc_fault_rate)
    job = TrainJobConfig(
        num_machines=args.machines,
        trainers_per_machine=args.trainers_per_machine,
        partition_method=args.partition, sync=args.sync,
        non_stop=not args.no_nonstop, cache=cache,
        task=args.task, num_negs=args.num_negs, score_fn=args.score_fn,
        neg_mode=args.neg_mode, neg_exclude=args.neg_exclude,
        sample_workers=args.sample_workers,
        packed_staging=not args.no_packed_staging,
        impl=args.impl,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_interval=args.checkpoint_interval,
        fault_injector=injector,
        replication=args.replication,
        max_rpc_retries=args.max_rpc_retries,
        hedge_ms=args.hedge_ms,
        network=NetworkModel(sleep=args.simulate_network))
    tr = DistGNNTrainer(ds, cfg, job)
    print(f"[train] {args.arch}/{args.task} on {args.dataset}: "
          f"{tr.num_trainers} trainers, {tr.batches_per_epoch} batches/epoch, "
          f"seed locality {tr.locality['mean_local_frac']:.2f}")
    metric = "mrr" if args.task == "link_prediction" else "acc"
    e = 0
    if args.recover:
        meta = tr.recover(args.checkpoint_dir)
        e = meta["epoch"]
        print(f"[recover] resuming at epoch {e}, "
              f"batch {meta['batch_index']} (global step "
              f"{meta['global_step']}) from {args.checkpoint_dir}")
    while e < args.epochs:
        try:
            m = tr.train_epoch(e)
        except TrainerDeath as death:
            # elastic recovery (DESIGN.md §10): the dead trainer's world is
            # torn down and a replacement is built from the same job spec
            # (sans injector — the fault schedule already fired), restored
            # from the last consistent checkpoint, and fast-forwarded to
            # its coordinate. Training resumes byte-identically.
            print(f"[fault] trainer killed at epoch {death.epoch}, "
                  f"batch {death.batch_index} — reviving from checkpoint")
            tr.stop()
            if not os.path.exists(os.path.join(args.checkpoint_dir,
                                               "state.json")):
                print("[recover] no checkpoint written yet — "
                      "restarting from epoch 0")
                tr = DistGNNTrainer(ds, cfg, dataclasses.replace(
                    job, fault_injector=None))
                e = 0
                continue
            t0 = time.perf_counter()
            tr = DistGNNTrainer(ds, cfg, dataclasses.replace(
                job, fault_injector=None))
            meta = tr.recover(args.checkpoint_dir)
            e = meta["epoch"]
            print(f"[recover] {time.perf_counter() - t0:.2f}s — resuming "
                  f"at epoch {e}, batch {meta['batch_index']}")
            continue
        print(f"[epoch {e}] loss={m['loss']:.4f} {metric}={m['acc']:.3f} "
              f"time={m['time_s']:.2f}s")
        e += 1
    if args.task == "link_prediction":
        val = tr.evaluate_lp()
        print(f"[final] val_mrr={val['mrr']:.3f} "
              f"hits@10={val.get('hits@10', float('nan')):.3f} "
              f"stats={json.dumps(tr.sampling_stats())}")
    else:
        val = tr.evaluate(ds.val_nids)
        print(f"[final] val_acc={val:.3f} "
              f"stats={json.dumps(tr.sampling_stats())}")
    tr.stop()


def run_lm(args):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from ..configs import get_config, smoke_variant
    from ..data import TokenStream
    from ..models.lm import init_train_state, make_train_step

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    step = jax.jit(make_train_step(cfg, lr=args.lr))
    params, opt = init_train_state(cfg, seed=0)
    stream = TokenStream(vocab=cfg.vocab_size, batch=args.batch_size,
                         seq=args.seq_len, seed=0, cfg=cfg,
                         packed=not args.no_packed_staging)
    t0 = time.time()
    for i, batch in enumerate(stream):
        if i >= args.steps:
            break
        params, opt, m = step(params, opt, batch)
        if (i + 1) % max(args.steps // 10, 1) == 0:
            print(f"[step {i+1}] loss={float(m['loss']):.4f} "
                  f"ce={float(m['ce']):.4f} gnorm={float(m['grad_norm']):.2f}")
    dt = time.time() - t0
    toks = args.steps * args.batch_size * args.seq_len
    print(f"[done] {args.steps} steps, {toks/dt:.0f} tok/s")
    stream.stop()


def build_parser() -> argparse.ArgumentParser:
    """The launcher CLI. Every flag here must be documented in the
    top-level README's flag table (tests/test_docs.py enforces it)."""
    ap = argparse.ArgumentParser(prog="repro.launch.train")
    ap.add_argument("--arch", required=True,
                    help="model: graphsage|gat|rgcn or an LM arch id")
    ap.add_argument("--dataset", default="product-sim",
                    help="named synthetic dataset (repro.graph.datasets)")
    ap.add_argument("--scale", type=int, default=12,
                    help="dataset scale exponent (graph has ~2^scale nodes)")
    ap.add_argument("--machines", type=int, default=2,
                    help="simulated machines (level-1 partitions)")
    ap.add_argument("--trainers-per-machine", type=int, default=2,
                    help="trainers per machine (level-2 split)")
    ap.add_argument("--partition", default="metis",
                    choices=["metis", "random"],
                    help="graph partitioner (random = Euler baseline)")
    ap.add_argument("--epochs", type=int, default=3,
                    help="GNN training epochs")
    ap.add_argument("--steps", type=int, default=20,
                    help="LM training steps")
    ap.add_argument("--batch-size", type=int, default=8,
                    help="GNN: seeds per batch (positive edges for "
                         "link prediction); LM: sequences per step")
    ap.add_argument("--seq-len", type=int, default=128,
                    help="LM sequence length")
    ap.add_argument("--lr", type=float, default=3e-4,
                    help="LM learning rate")
    ap.add_argument("--task", default="node_classification",
                    choices=["node_classification", "link_prediction"],
                    help="GNN workload: node classification or edge "
                         "mini-batch link prediction (§6)")
    ap.add_argument("--num-negs", type=int, default=16,
                    help="link prediction: uniform negatives per "
                         "positive edge (static (B, K) shape; too few "
                         "can collapse the BCE score head — see "
                         "DESIGN.md §6)")
    ap.add_argument("--score-fn", default="dot",
                    choices=["dot", "distmult"],
                    help="link-prediction scoring head (distmult learns "
                         "one diagonal relation embedding per etype)")
    ap.add_argument("--neg-mode", default="uniform",
                    choices=["uniform", "in-batch"],
                    help="negative sampling: fresh uniform nodes (own "
                         "ego-networks) or in-batch corrupted dsts")
    ap.add_argument("--neg-exclude", action="store_true",
                    help="re-draw negatives that collide with a positive "
                         "pair of the same batch (false-negative filter)")
    ap.add_argument("--hetero", action="store_true",
                    help="typed-relation path: per-relation fanouts, "
                         "per-ntype KVStore policies (schema'd datasets)")
    ap.add_argument("--rel-fanout", action="append", metavar="REL=K",
                    help="override one relation's fanout (repeatable)")
    ap.add_argument("--cache-budget-mb", type=float, default=0.0,
                    help="per-trainer hot-vertex feature cache budget in "
                         "MB (0 disables the cache)")
    ap.add_argument("--cache-policy", default="clock",
                    choices=["clock", "lru"],
                    help="feature-cache eviction policy")
    ap.add_argument("--impl", default=None,
                    choices=["auto", "ref", "pallas"],
                    help="kernel implementation for the GNN aggregations "
                         "and sparse-Adam (auto = Pallas on TPU, jnp/NumPy "
                         "oracle elsewhere; default keeps the model "
                         "config's choice)")
    ap.add_argument("--no-packed-staging", action="store_true",
                    help="ship each batch array to the device separately "
                         "instead of the packed single-device_put staging "
                         "(DESIGN.md §9; bytes are identical either way)")
    ap.add_argument("--sample-workers", type=int, default=1,
                    help="sampling-stage worker threads per trainer "
                         "(batches are byte-identical for any value; "
                         "see DESIGN.md §7)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for consistent training checkpoints "
                         "(params + optimizer + KVStore shards with row "
                         "versions + cache snapshots; DESIGN.md §10)")
    ap.add_argument("--checkpoint-interval", type=int, default=0,
                    help="global steps between checkpoints (0 disables; "
                         "needs --checkpoint-dir)")
    ap.add_argument("--recover", action="store_true",
                    help="restore the --checkpoint-dir checkpoint before "
                         "training and fast-forward the deterministic "
                         "schedule to its (epoch, batch) coordinate")
    ap.add_argument("--inject-fault", metavar="EPOCH:BATCH", default=None,
                    help="chaos testing: kill the trainer right before "
                         "consuming this batch, then auto-revive a "
                         "replacement from the last checkpoint "
                         "(byte-identical resumed training)")
    ap.add_argument("--rpc-fault-rate", type=float, default=0.0,
                    help="chaos testing: probability each feature/gradient "
                         "RPC fails transiently (retried with backoff; "
                         "bytes are unchanged by retries)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the injected failure schedule "
                         "(deterministic chaos)")
    ap.add_argument("--replication", type=int, default=1,
                    help="KVStore feature-plane replica count: each "
                         "partition's shard also lives on its r-1 ring "
                         "successors; reads fail over byte-identically "
                         "when the owner is down (DESIGN.md §12)")
    ap.add_argument("--max-rpc-retries", type=int, default=8,
                    help="per-destination transient-RPC retry budget "
                         "before a peer is treated as dead")
    ap.add_argument("--hedge-ms", type=float, default=None,
                    help="hedged reads: race a replica after this many ms "
                         "without a primary response (needs "
                         "--replication >= 2; default off)")
    ap.add_argument("--smoke", action="store_true",
                    help="LM: reduced same-family config for CPU smoke runs")
    ap.add_argument("--sync", action="store_true",
                    help="disable the async pipeline (unpipelined baseline)")
    ap.add_argument("--no-nonstop", action="store_true",
                    help="drain the pipeline between epochs (ablation)")
    ap.add_argument("--simulate-network", action="store_true",
                    help="enable the network cost model's real sleeps")
    return ap


def main():
    args = build_parser().parse_args()
    from ..configs import GNN_ARCHS
    if args.arch in GNN_ARCHS:
        run_gnn(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
