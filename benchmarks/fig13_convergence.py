"""Fig. 13 analogue: convergence of DistDGLv2's split-sampling vs global
uniform sampling vs ClusterGCN-style partition-restricted sampling.

DistDGLv2's claim (§5.6.1, §6.3): because each trainer samples uniformly
from its seed split and neighbor sampling crosses partition boundaries,
the collective gradient estimate is unbiased — so convergence matches
single-pool uniform sampling. ClusterGCN-style training drops cross-
partition edges, biasing neighbor aggregation and converging worse.

We emulate ClusterGCN by partitioning with zero HALO tolerance: sampled
neighbors outside the seed's partition are filtered out.
"""
from __future__ import annotations

import numpy as np

from .common import csv_line, small_cfg
from repro.core.kvstore import DistKVStore, PartitionPolicy
from repro.core.partition import hierarchical_partition, split_training_set
from repro.core.sampler import DistributedSampler
from repro.graph import get_dataset
from repro.models.gnn import apply_gnn, init_gnn, nc_accuracy, nc_loss
from repro.optim import adamw_init, adamw_update

import jax
import jax.numpy as jnp


def _train(ds, cfg, mode: str, epochs: int, seed=0):
    hp = hierarchical_partition(ds.graph, 8, 1, split_mask=ds.split_mask,
                                seed=seed)
    book = hp.book
    feats_new = ds.feats[book.new2old_node]
    labels_new = ds.labels[book.new2old_node]
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets)})
    store.init_data("feat", feats_new.shape[1:], np.float32, "node",
                    full_array=feats_new)
    client = store.client(0)
    train_new = book.old2new_node[ds.train_nids]
    n_trainers = 8
    if mode == "global-uniform":
        seed_sets = [np.sort(train_new)]
    else:
        seed_sets = split_training_set(hp, train_new)
    # equal optimizer steps per epoch across modes (sync-SGD semantics):
    # the split modes do (per-trainer seeds // bs) * trainers steps
    per_trainer = len(train_new) // n_trainers // cfg.batch_size
    steps_cap = max(per_trainer, 1) * n_trainers
    samplers = [DistributedSampler(book, hp.partitions, cfg.fanouts,
                                   cfg.batch_size, machine=i % 8,
                                   seed=seed + i)
                for i in range(len(seed_sets))]

    params = init_gnn(cfg, jax.random.key(seed))
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        def loss_fn(p):
            logits = apply_gnn(cfg, p, batch)
            return nc_loss(logits, batch["labels"], batch["seed_mask"])
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw_update(params, grads, opt, lr=3e-3)
        return params, opt, loss

    rng = np.random.default_rng(seed)
    val = ds.val_nids
    val_new = book.old2new_node[val]
    curve = []
    for e in range(epochs):
        for seeds_all, smp in zip(seed_sets, samplers):
            perm = rng.permutation(len(seeds_all))
            n_b = len(seeds_all) // cfg.batch_size
            if mode == "global-uniform":
                n_b = min(n_b, steps_cap)
            for b in range(max(n_b, 1)):
                sel = perm[b * cfg.batch_size:(b + 1) * cfg.batch_size]
                if len(sel) < cfg.batch_size:
                    continue
                chunk = seeds_all[sel]
                mb = smp.sample(chunk, labels=labels_new[chunk])
                if mode == "cluster-gcn":
                    _restrict_to_partition(mb, book)
                mb.input_feats = client.pull("feat", mb.input_gids)
                batch = _dev(mb)
                params, opt, _ = step(params, opt, batch)
        # eval
        accs = []
        for b in range(min(10, len(val_new) // cfg.batch_size)):
            chunk = val_new[b * cfg.batch_size:(b + 1) * cfg.batch_size]
            mb = samplers[0].sample(chunk, labels=labels_new[chunk])
            mb.input_feats = client.pull("feat", mb.input_gids)
            logits = apply_gnn(cfg, params, _dev(mb))
            accs.append(float(nc_accuracy(logits, jnp.asarray(mb.labels),
                                          jnp.asarray(mb.seed_mask))))
        curve.append(float(np.mean(accs)))
    return curve


def _dev(mb):
    return dict(input_feats=mb.input_feats, labels=mb.labels,
                seed_mask=mb.seed_mask,
                blocks=[dict(edge_src=b.edge_src, edge_dst=b.edge_dst,
                             edge_mask=b.edge_mask, edge_types=b.edge_types)
                        for b in mb.blocks])


def _restrict_to_partition(mb, book):
    """ClusterGCN emulation: drop edges whose src is outside the seed's
    partition (the dst partition)."""
    for blk in mb.blocks:
        src_part = book.nid2part(blk.src_gids[blk.edge_src])
        dst_part = book.nid2part(blk.src_gids[blk.edge_dst])
        keep = src_part == dst_part
        blk.edge_mask &= keep


def run(scale=12, epochs=5):
    # power-law graph: 8-way min-cut still crosses ~60-70% of edges, so
    # ClusterGCN-style edge dropping visibly biases aggregation
    ds = get_dataset("product-sim", scale=12)
    cfg = small_cfg(in_dim=ds.feats.shape[1], batch=32)
    rows = []
    for mode in ("distdglv2", "global-uniform", "cluster-gcn"):
        curve = _train(ds, cfg, mode, epochs)
        rows.append((mode, curve))
        csv_line(f"fig13/{mode}", 0.0,
                 "acc_curve=" + "|".join(f"{a:.3f}" for a in curve))
    return rows


if __name__ == "__main__":
    run()
