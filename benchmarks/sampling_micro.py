"""Sampling-front microbenchmark (the PR 4 perf acceptance): emits
``BENCH_sampling.json`` so the perf trajectory accumulates in CI.

Three measurements, all on the table2 configs:

  * **worker scaling** — batches/s of the sampling front (schedule →
    sample stages, network cost model sleeping like table2) for
    ``--sample-workers`` in {1, 2, 4}, plus a byte-identity cross-check
    (the DESIGN.md §7 invariance, measured where it matters);
  * **vectorized vs loop subsample** — the batched random-key selection
    against the per-seed ``rng.choice`` loop it replaced;
  * **typed request coalescing** — remote sampling requests per layer on
    the mag-hetero typed path (one per owner, carrying every relation)
    vs the per-relation dispatch it replaced.

Run:  PYTHONPATH=src python -m benchmarks.sampling_micro [--smoke]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time

import numpy as np

from .common import NET, csv_line
from repro.core.kvstore import (DistKVStore, NetworkModel, PartitionPolicy,
                                Transport)
from repro.core.partition import (build_typed_partition,
                                  hierarchical_partition,
                                  split_training_set)
from repro.core.pipeline import MinibatchPipeline
from repro.core.sampler import DistributedSampler
from repro.core.sampler.neighbor import (_subsample_positions,
                                         _subsample_positions_loop)
from repro.graph import get_dataset


def _homo_world(scale: int):
    ds = get_dataset("product-sim", scale=scale)
    hp = hierarchical_partition(ds.graph, 2, 1, split_mask=ds.split_mask,
                                seed=0)
    book = hp.book
    feats_new = ds.feats[book.new2old_node]
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets)})
    store.init_data("feat", feats_new.shape[1:], np.float32, "node",
                    full_array=feats_new)
    # the whole training set (not one trainer's split): the micro measures
    # the sampling front, so more batches = a steadier number
    seeds = book.old2new_node[ds.train_nids]
    return ds, hp, store, seeds


def worker_scaling(scale: int, workers=(1, 2, 4), epochs: int = 2,
                   batch: int = 32) -> dict:
    """Batches/s of the sampling front vs pool size, network sleeps on
    (the table2 regime: RPC latency is what the pool overlaps)."""
    ds, hp, store, seeds = _homo_world(scale)
    rows = []
    hashes = set()
    for w in workers:
        tp = Transport(NetworkModel(**NET))
        sampler = DistributedSampler(hp.book, hp.partitions, [10, 5], batch,
                                     machine=0, transport=tp, seed=3)
        pipe = MinibatchPipeline(sampler, store.client(0), "feat", seeds,
                                 sync=False, non_stop=False,
                                 to_device=False, seed=4, sample_workers=w)
        h = hashlib.sha256()
        n = 0
        t0 = time.perf_counter()
        for e in range(epochs):
            for mb in pipe.epoch(e):
                n += 1
                for b in mb.blocks:
                    h.update(np.ascontiguousarray(b.src_gids).tobytes())
                    h.update(np.ascontiguousarray(b.edge_src).tobytes())
        dt = time.perf_counter() - t0
        pipe.stop()
        hashes.add(h.hexdigest())
        bps = n / dt
        rows.append(dict(workers=w, batches=n, time_s=dt, batches_per_s=bps,
                         remote_requests=tp.stats()["remote_requests"]))
        csv_line(f"sampling/workers_{w}", dt * 1e6 / max(n, 1),
                 f"batches_per_s={bps:.1f}")
    if len(hashes) != 1:
        raise AssertionError(
            f"worker counts produced {len(hashes)} distinct streams — "
            f"the DESIGN.md §7 invariance is broken")
    base = rows[0]["batches_per_s"]
    out = dict(rows=rows, byte_identical=True)
    for r in rows:
        r["speedup_vs_w1"] = r["batches_per_s"] / base
    csv_line("sampling/speedup_w4_vs_w1",
             rows[-1]["speedup_vs_w1"] * 100.0, "percent")
    return out


def subsample_micro(n_seeds: int = 2000, deg: int = 60, fanout: int = 10,
                    reps: int = 5) -> dict:
    """The vectorized random-key subsample vs the per-seed choice loop."""
    degs = np.full(n_seeds, deg, dtype=np.int64)
    starts = np.arange(n_seeds, dtype=np.int64) * deg

    def bench(fn):
        rng = np.random.default_rng(0)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn(starts, degs, fanout, rng)
            best = min(best, time.perf_counter() - t0)
        return best

    t_vec = bench(_subsample_positions)
    t_loop = bench(_subsample_positions_loop)
    csv_line("sampling/subsample_vectorized", t_vec * 1e6,
             f"seeds={n_seeds};deg={deg};fanout={fanout}")
    csv_line("sampling/subsample_loop", t_loop * 1e6,
             f"speedup={t_loop / t_vec:.1f}x")
    return dict(n_seeds=n_seeds, deg=deg, fanout=fanout,
                vectorized_s=t_vec, loop_s=t_loop,
                speedup=t_loop / t_vec)


def coalescing(scale: int, batches: int = 5) -> dict:
    """Remote sampling requests on the typed path: the coalesced dispatch
    issues one request per owner per layer; ``relation_requests`` counts
    what the per-relation dispatch it replaced would have issued."""
    ds = get_dataset("mag-hetero", scale=scale)
    hp = hierarchical_partition(ds.graph, 2, 1, split_mask=ds.split_mask,
                                seed=0)
    book = hp.book
    typed = build_typed_partition(
        book, ds.schema, ds.graph.ntypes[book.new2old_node],
        ds.graph.etypes[book.new2old_edge])
    fanouts = [{rel: 4 for rel in ds.schema.etypes}] * 2
    tp = Transport(NetworkModel())
    s = DistributedSampler(book, hp.partitions, fanouts, 16, machine=0,
                           transport=tp, seed=5, schema=ds.schema,
                           ntype_of_node=typed.ntype_of_node)
    seeds = book.old2new_node[ds.train_nids][:16]
    for i in range(batches):
        s.sample(seeds, batch_index=i, epoch=0)
    st = s.stats
    out = dict(num_etypes=ds.schema.num_etypes,
               owner_requests=st.owner_requests,
               relation_requests=st.relation_requests,
               coalescing_factor=st.request_coalescing_factor,
               transport_remote_requests=tp.stats()["remote_requests"])
    csv_line("sampling/coalescing_factor", st.request_coalescing_factor,
             f"owner_requests={st.owner_requests};"
             f"relation_requests={st.relation_requests}")
    return out


def run(scale: int = 12, out_path: str = "BENCH_sampling.json",
        smoke: bool = False) -> dict:
    if smoke:
        scale = min(scale, 9)
    result = {
        "config": {"scale": scale, "smoke": smoke, "net": dict(NET)},
        "worker_scaling": worker_scaling(scale,
                                         epochs=1 if smoke else 4,
                                         batch=8 if smoke else 32),
        "subsample": subsample_micro(
            n_seeds=300 if smoke else 2000, reps=2 if smoke else 5),
        "coalescing": coalescing(min(scale, 10)),
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[sampling_micro] wrote {out_path}")
    return result


def main():
    ap = argparse.ArgumentParser(prog="benchmarks.sampling_micro")
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--out", default="BENCH_sampling.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small scale for CI: same measurements, tiny run")
    args = ap.parse_args()
    run(scale=args.scale, out_path=args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
