"""Online-serving benchmark: emits ``BENCH_serving.json`` so the serving
latency/throughput trajectory accumulates in CI.

Two experiments over :class:`repro.api.InferenceServer`:

  * **rate sweep** — open-loop Poisson request load at increasing rates;
    per rate: p50/p99 request latency, delivered throughput, and mean
    micro-batch tick occupancy (the §2-block coalescing the window buys
    as load grows).
  * **cache warm vs cold** — identical request trace against a server
    with NO feature cache versus one whose long-lived cache has already
    served the trace once, on a transport that really sleeps per remote
    RPC (``NetworkModel(sleep=True)``). Warm p50 must come in below cold
    p50 — remote feature pulls leave the request critical path.

Run:  PYTHONPATH=src python -m benchmarks.serving_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.api import DistGraph, InferenceServer
from repro.core.kvstore import CacheConfig, NetworkModel
from repro.graph import get_dataset
from repro.models.gnn import GNNConfig, init_gnn

from .common import csv_line


def _world(scale: int, network: NetworkModel = None):
    ds = get_dataset("product-sim", scale=scale)
    cfg = GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                    hidden_dim=16, num_classes=ds.num_classes,
                    fanouts=[3, 2], batch_size=8)
    g = DistGraph(ds, num_machines=2, trainers_per_machine=1, seed=0,
                  network=network)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    return g, cfg, params


def _trace(rng, n_req: int, rate: float, num_nodes: int):
    return (rng.exponential(1.0 / rate, size=n_req),
            rng.integers(0, num_nodes, size=(n_req, 1)))


def _drive(srv: InferenceServer, gaps, nids) -> dict:
    """Replay one open-loop trace; per-request latency percentiles."""
    handles = []
    t0 = time.perf_counter()
    for gap, req in zip(gaps, nids):
        time.sleep(float(gap))
        handles.append(srv.submit(req))
    for h in handles:
        h.result(timeout=300)
    wall = time.perf_counter() - t0
    lat = np.sort([h.latency_s for h in handles])
    n = len(lat)
    return {"requests": n,
            "throughput_req_s": round(n / wall, 1),
            "p50_ms": round(float(lat[n // 2]) * 1e3, 3),
            "p99_ms": round(float(lat[min(n - 1, int(n * 0.99))]) * 1e3,
                            3)}


def run(scale: int = 10, out_path: str = "BENCH_serving.json",
        smoke: bool = False) -> dict:
    if smoke:
        scale = min(scale, 10)
    n_req = 16 if smoke else 48
    rng = np.random.default_rng(0)

    # -- rate sweep (warm cache, compute-bound transport) ---------------
    rates = [50.0, 400.0] if smoke else [50.0, 200.0, 800.0]
    g, cfg, params = _world(scale)
    sweep = []
    with InferenceServer(g, cfg, params, cache=CacheConfig.from_mb(4),
                         micro_batch_capacity=8,
                         micro_batch_window_ms=2.0) as srv:
        srv.predict([0])                      # compile outside the window
        for rate in rates:
            gaps, nids = _trace(rng, n_req, rate, g.num_nodes())
            srv.predict(nids[0])              # touch trace rows once
            row = {"rate_req_s": rate, **_drive(srv, gaps, nids),
                   "mean_tick_occupancy": round(
                       srv.stats()["mean_tick_occupancy"], 2)}
            sweep.append(row)
            csv_line(f"serving/rate_{int(rate)}", row["p50_ms"] * 1e3,
                     f"p99_ms={row['p99_ms']};"
                     f"tput={row['throughput_req_s']}")

    # -- cache warm vs cold (transport really sleeps per remote RPC) ----
    net = NetworkModel(latency_s=5e-3, sleep=True)
    rate = 200.0
    gaps, nids = _trace(np.random.default_rng(1), n_req, rate,
                        g.num_nodes())
    g2, cfg2, params2 = _world(scale, network=net)
    with InferenceServer(g2, cfg2, params2, cache=None) as srv:
        srv.predict(nids[0])
        cold = _drive(srv, gaps, nids)
    g3, cfg3, params3 = _world(scale, network=net)
    with InferenceServer(g3, cfg3, params3,
                         cache=CacheConfig.from_mb(4)) as srv:
        _drive(srv, np.zeros_like(gaps), nids)   # warm the cache in place
        srv.cache.reset_stats()
        warm = _drive(srv, gaps, nids)
        hit = srv.cache.stats()
        warm["cache_hit_rate"] = round(
            hit["hits"] / max(hit["hits"] + hit["misses"], 1), 4)
    csv_line("serving/cold_p50", cold["p50_ms"] * 1e3,
             f"p99_ms={cold['p99_ms']}")
    csv_line("serving/warm_p50", warm["p50_ms"] * 1e3,
             f"p99_ms={warm['p99_ms']};hit={warm['cache_hit_rate']}")

    result = {"config": {"scale": scale, "smoke": smoke, "n_req": n_req,
                         "rpc_latency_ms": net.latency_s * 1e3,
                         "backend": jax.default_backend()},
              "rate_sweep": sweep,
              "cache": {"cold": cold, "warm": warm}}
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[serving_bench] wrote {out_path}")
    assert warm["p50_ms"] < cold["p50_ms"], \
        (f"warm cache should beat cold feature pulls: "
         f"warm p50 {warm['p50_ms']}ms >= cold p50 {cold['p50_ms']}ms")
    return result


def main():
    ap = argparse.ArgumentParser(prog="benchmarks.serving_bench")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="small scale + shorter trace for CI")
    args = ap.parse_args()
    run(scale=args.scale, out_path=args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
