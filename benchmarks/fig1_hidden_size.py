"""Fig. 1 analogue: model accuracy vs hidden size.

The paper uses this to argue large hidden sizes are needed (so
model-parallel P3-style approaches lose to data parallelism). We sweep
hidden sizes on the clustered synthetic dataset and report val accuracy.
"""
from __future__ import annotations

from .common import csv_line, make_trainer, small_cfg
from repro.graph import get_dataset


def run(epochs=4):
    ds = get_dataset("cluster-sim", num_nodes=6000, num_blocks=12)
    rows = []
    for hidden in (8, 32, 128):
        cfg = small_cfg(in_dim=ds.feats.shape[1], hidden=hidden, batch=32)
        tr = make_trainer(ds, cfg, network=False)
        for e in range(epochs):
            tr.train_epoch(e)
        acc = tr.evaluate(ds.val_nids)
        tr.stop()
        rows.append((hidden, acc))
        csv_line(f"fig1/hidden={hidden}", 0.0, f"val_acc={acc:.3f}")
    return rows


if __name__ == "__main__":
    run()
