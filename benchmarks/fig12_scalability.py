"""Fig. 12 analogue: scaling trainers with fixed per-trainer batch size.

On a real cluster trainers run in parallel; on this single-core host we
run them serially and report the *synchronous epoch time* as the max over
trainers of their serial time (what the barrier would wait for), plus the
measured simulated-network cost. Method stated in EXPERIMENTS.md; the
validated claim is that per-epoch time stays ~flat as trainers (and with
them, total work per epoch) scale — i.e. weak-scaling efficiency through
the locality-aware split, not raw strong-scaling numbers.
"""
from __future__ import annotations

import time

import numpy as np

from .common import csv_line, make_trainer, small_cfg
from repro.graph import get_dataset


def run(scale=13, trainer_counts=(1, 2, 4, 8), epochs=2):
    ds = get_dataset("product-sim", scale=scale)
    rows = []
    base_rate = None
    for t_count in trainer_counts:
        machines = max(1, t_count // 2)
        tpm = t_count // machines
        cfg = small_cfg(batch=32)
        tr = make_trainer(ds, cfg, machines=machines, tpm=tpm)
        # serial run measures the sum over trainers; the synchronous
        # parallel epoch is bounded by the slowest trainer
        per_trainer = []
        for e in range(epochs):
            t0 = time.perf_counter()
            m = tr.train_epoch(e)
            per_trainer.append((time.perf_counter() - t0) / t_count)
        tr.stop()
        est_epoch = float(np.median(per_trainer))
        samples = tr.batches_per_epoch * cfg.batch_size * t_count
        rate = samples / (est_epoch * t_count)
        base_rate = base_rate or rate
        rows.append((t_count, est_epoch, rate))
        csv_line(f"fig12/trainers={t_count}", est_epoch * 1e6,
                 f"samples_per_s_per_trainer={rate:.0f};"
                 f"weak_scaling_eff={rate / base_rate:.2f}")
    return rows


if __name__ == "__main__":
    run()
