"""Availability benchmark: emits ``BENCH_availability.json`` — the serving
availability curve under sustained owner outages (DESIGN.md §12).

For each injected owner-down fraction (0, 1/k, 2/k of the KVStore owners
inside a whole-run :class:`~repro.api.OwnerDownWindow`) and each
replication factor, an :class:`~repro.api.InferenceServer` serves a fixed
seeded request trace and the bench records what the availability contract
actually delivered:

  * ``success_frac``  — requests served fresh (byte-exact answers);
  * ``degraded_frac`` — requests served best-effort (stale cache /
                        zero-fill rows behind the logits, flagged on the
                        handle) because every copy of an owner was down;
  * ``shed_frac``     — requests shed (deadline expired / admission);
  * ``failed_frac``   — requests whose handle raised (expected 0: a
                        sustained outage degrades, it must not error);
  * ``p50_ms`` / ``p99_ms`` — served-request latency percentiles.

The curve to eyeball: at replication r=2 the success fraction stays 1.0
through single-owner outages (reads fail over byte-identically), while
r=1 trades exactly the down owners' rows for degraded answers — and
nothing ever becomes an unhandled error.

Run:  PYTHONPATH=src python -m benchmarks.availability_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.api import (DistGraph, FaultInjector, InferenceServer,
                       OwnerDownWindow)
from repro.core.kvstore import CacheConfig
from repro.graph import get_dataset
from repro.models.gnn import GNNConfig, init_gnn

from .common import csv_line

FOREVER = 10 ** 9


def _world(scale: int, machines: int, replication: int):
    ds = get_dataset("product-sim", scale=scale)
    g = DistGraph(ds, num_machines=machines, trainers_per_machine=1,
                  seed=0, replication=replication)
    cfg = GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                    hidden_dim=16, num_classes=ds.num_classes,
                    fanouts=[3, 2], batch_size=8)
    return g, cfg, init_gnn(cfg, jax.random.PRNGKey(0))


def _down_owners(frac: float, machines: int, seed: int) -> list:
    """Seeded choice of floor(frac*k) REMOTE owners (taking down the
    serving machine's own shard is invisible to it — local reads never
    touch the network, which is the shared-memory fast path, not an
    availability story)."""
    k = int(round(frac * machines))
    if k == 0:
        return []
    rng = np.random.default_rng(seed)
    remote = np.arange(1, machines)
    return sorted(rng.choice(remote, size=min(k, len(remote)),
                             replace=False).tolist())


def _serve_point(g, cfg, params, nid_trace, deadline_ms) -> dict:
    with InferenceServer(g, cfg, params,
                         cache=CacheConfig(budget_bytes=1 << 20,
                                           prewarm=False),
                         deadline_ms=deadline_ms) as srv:
        handles = [srv.submit(nids) for nids in nid_trace]
        success = degraded = shed = failed = 0
        lat = []
        for h in handles:
            try:
                h.result(timeout=120)
                if h.degraded:
                    degraded += 1
                else:
                    success += 1
                lat.append(h.latency_s)
            except Exception as exc:
                from repro.api import DeadlineExceeded
                if isinstance(exc, DeadlineExceeded):
                    shed += 1
                else:
                    failed += 1
        n = len(handles)
        lat = np.sort(np.asarray(lat)) if lat else np.array([float("nan")])
        st = g.transport.stats()
        return {"success_frac": success / n, "degraded_frac": degraded / n,
                "shed_frac": shed / n, "failed_frac": failed / n,
                "p50_ms": round(float(lat[len(lat) // 2]) * 1e3, 3),
                "p99_ms": round(float(
                    lat[min(len(lat) - 1, int(len(lat) * 0.99))]) * 1e3, 3),
                "failovers": st["failovers"],
                "degraded_pulls": st["degraded_pulls"],
                "owner_down_failures": st["owner_down_failures"]}


def run(scale: int = 10, out_path: str = "BENCH_availability.json",
        smoke: bool = False) -> dict:
    machines = 4
    n_req = 16 if smoke else 64
    fractions = [0.0, 0.25, 0.5]
    replications = [1, 2]
    deadline_ms = 5000.0   # generous: shed only pathological requests

    rows = []
    for r in replications:
        for frac in fractions:
            g, cfg, params = _world(scale, machines, r)
            rng = np.random.default_rng(42)
            nid_trace = rng.integers(0, g.num_nodes(), size=(n_req, 2))
            owners = _down_owners(frac, machines, seed=13)
            if owners:
                g.transport.fault_injector = FaultInjector(
                    seed=13, owner_down=[
                        OwnerDownWindow(owner=o, start=0, end=FOREVER)
                        for o in owners])
            t0 = time.perf_counter()
            point = _serve_point(g, cfg, params, nid_trace, deadline_ms)
            point.update({"replication": r, "down_fraction": frac,
                          "down_owners": owners, "requests": n_req,
                          "wall_s": round(time.perf_counter() - t0, 3)})
            rows.append(point)
            csv_line(f"availability/r{r}_down{frac:.2f}",
                     point["p50_ms"] * 1e3,
                     f"success={point['success_frac']:.2f};"
                     f"degraded={point['degraded_frac']:.2f};"
                     f"shed={point['shed_frac']:.2f};"
                     f"p99_ms={point['p99_ms']}")

    result = {"config": {"scale": scale, "smoke": smoke,
                         "machines": machines, "requests": n_req,
                         "deadline_ms": deadline_ms,
                         "backend": jax.default_backend()},
              "points": rows}
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[availability_bench] wrote {out_path}")
    # the contract the chaos suite pins, re-checked at bench scale: an
    # outage NEVER surfaces as an unhandled request error, and full
    # replication keeps single-owner outages fully transparent
    assert all(p["failed_frac"] == 0.0 for p in rows), \
        "an owner outage surfaced as a request failure"
    for p in rows:
        if p["replication"] == 2 and p["down_fraction"] <= 0.25:
            assert p["success_frac"] == 1.0, \
                f"r=2 failed to mask a single-owner outage: {p}"
    return result


def main():
    ap = argparse.ArgumentParser(prog="benchmarks.availability_bench")
    ap.add_argument("--out", default="BENCH_availability.json")
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="fewer requests for CI")
    args = ap.parse_args()
    run(scale=args.scale, out_path=args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
