"""Elastic-recovery benchmark: emits ``BENCH_recovery.json`` so the
fault-tolerance cost trajectory accumulates in CI.

For each checkpoint interval, one trainer runs with a seeded
:class:`~repro.api.FaultInjector` that kills it mid-epoch; a replacement
trainer is built, ``recover()``-ed from the last consistent checkpoint and
fast-forwarded to the death coordinate (DESIGN.md §10). Measured per
interval:

  * ``restore_s``     — checkpoint load + fast-forward arming time;
  * ``replay_batches``— batches between the last checkpoint and the death
                        coordinate (the deterministic-replay work);
  * ``recovery_s``    — restore + replay wall-clock until the killed run's
                        position is regained;
  * ``bytes_identical`` — whether the recovered run's final parameters are
                        byte-identical to the uninterrupted baseline's
                        (the whole point; always expected True).

Run:  PYTHONPATH=src python -m benchmarks.recovery_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

from repro.api import DistGNNTrainer, FaultInjector, TrainJobConfig, TrainerDeath
from repro.graph import get_dataset
from repro.models.gnn import GNNConfig

from .common import csv_line


def _param_bytes(params) -> list:
    return [np.asarray(x).tobytes() for x in jax.tree_util.tree_leaves(params)]


def _world(scale: int):
    ds = get_dataset("product-sim", scale=scale)
    cfg = GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                    hidden_dim=16, num_classes=ds.num_classes,
                    fanouts=[3, 2], batch_size=8)
    return ds, cfg


def _job(**kw) -> TrainJobConfig:
    return TrainJobConfig(num_machines=2, trainers_per_machine=1, seed=0,
                          **kw)


def run(scale: int = 10, out_path: str = "BENCH_recovery.json",
        smoke: bool = False) -> dict:
    if smoke:
        scale = min(scale, 10)
    epochs = 2
    ds, cfg = _world(scale)

    # uninterrupted baseline: the byte-identity reference
    tr = DistGNNTrainer(ds, cfg, _job())
    for e in range(epochs):
        tr.train_epoch(e)
    baseline = _param_bytes(tr.params)
    bpe = tr.batches_per_epoch
    tr.stop()
    kill_at = (1, max(bpe // 2, 1))   # mid-epoch death in the last epoch

    intervals = [1, 2, 4] if smoke else [1, 2, 4, 8]
    rows = []
    for interval in intervals:
        with tempfile.TemporaryDirectory() as tmp:
            ck = os.path.join(tmp, "ck")
            inj = FaultInjector(seed=7, kill_at=kill_at)
            victim = DistGNNTrainer(ds, cfg, _job(
                checkpoint_dir=ck, checkpoint_interval=interval,
                fault_injector=inj))
            try:
                for e in range(epochs):
                    victim.train_epoch(e)
                raise AssertionError("fault schedule never fired")
            except TrainerDeath:
                pass
            victim.stop()

            t0 = time.perf_counter()
            revived = DistGNNTrainer(ds, cfg, _job())
            meta = revived.recover(ck)
            restore_s = time.perf_counter() - t0
            replay = ((kill_at[0] - meta["epoch"]) * bpe
                      + kill_at[1] - meta["batch_index"])
            # replay up to (and past) the death coordinate, then finish
            for e in range(meta["epoch"], epochs):
                revived.train_epoch(e)
            recovery_s = time.perf_counter() - t0
            identical = _param_bytes(revived.params) == baseline
            revived.stop()
        row = {"checkpoint_interval": interval,
               "restore_s": restore_s,
               "replay_batches": int(replay),
               "recovery_s": recovery_s,
               "bytes_identical": bool(identical)}
        rows.append(row)
        csv_line(f"recovery/interval_{interval}", recovery_s * 1e6,
                 f"restore_s={restore_s:.3f};replay={replay};"
                 f"identical={identical}")

    result = {"config": {"scale": scale, "smoke": smoke, "epochs": epochs,
                         "batches_per_epoch": int(bpe),
                         "kill_at": list(kill_at),
                         "backend": jax.default_backend()},
              "intervals": rows}
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[recovery_bench] wrote {out_path}")
    assert all(r["bytes_identical"] for r in rows), \
        "recovered parameters diverged from the uninterrupted baseline"
    return result


def main():
    ap = argparse.ArgumentParser(prog="benchmarks.recovery_bench")
    ap.add_argument("--out", default="BENCH_recovery.json")
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="small scale + fewer intervals for CI")
    args = ap.parse_args()
    run(scale=args.scale, out_path=args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
