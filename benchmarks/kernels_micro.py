"""Kernel microbenchmarks.

The Pallas kernels target TPU; on this CPU host ``interpret=True`` is an
emulator (not a performance path), so the timed numbers are for the jnp
reference implementations (what actually runs on CPU) — the Pallas path is
timed once at small size purely to prove it executes. Roofline numbers for
the kernels on TPU come from the dry-run tables instead.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import csv_line
from repro.kernels import edge_softmax, gather_rows, segment_sum


def _bench(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    e, f, n = 16384, 128, 4096
    msg = jnp.asarray(rng.standard_normal((e, f)), jnp.float32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    mask = jnp.asarray(rng.random(e) > 0.2)
    seg = jax.jit(lambda m, d, k: segment_sum(m, d, k, n, impl="ref"))
    csv_line("kernels/segment_sum_ref", _bench(seg, msg, dst, mask),
             f"E={e};F={f};N={n}")

    table = jnp.asarray(rng.standard_normal((65536, 128)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 65536, 8192), jnp.int32)
    gat = jax.jit(lambda t, i: gather_rows(t, i, impl="ref"))
    csv_line("kernels/gather_ref", _bench(gat, table, idx), "V=65536;F=128")

    sc = jnp.asarray(rng.standard_normal((e, 4)), jnp.float32)
    es = jax.jit(lambda s, d, m: edge_softmax(s, d, m, n, impl="ref"))
    csv_line("kernels/edge_softmax_ref", _bench(es, sc, dst, mask),
             f"E={e};H=4;N={n}")

    # prove the Pallas path executes (interpret mode, small size)
    t = _bench(lambda m, d, k: segment_sum(m[:256], d[:256], k[:256], 128,
                                           impl="pallas"), msg, dst, mask,
               iters=3)
    csv_line("kernels/segment_sum_pallas_interpret", t,
             "emulated;correctness-only")
    return True


if __name__ == "__main__":
    run()
