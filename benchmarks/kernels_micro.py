"""Kernel + device-staging microbenchmarks: emits ``BENCH_kernels.json``
so the perf trajectory accumulates in CI.

Four measurements:

  * **packed vs per-array staging** — one realistic mini-batch host tree
    (feats + seeds + labels + 2 blocks x 4 arrays) shipped to the device
    by the packed single-``device_put`` path (DESIGN.md §9) vs the legacy
    per-array loop, plus a byte-identity cross-check;
  * **fused vs unfused aggregation** — ``fused_gather_aggregate`` /
    ``fused_edge_softmax_aggregate`` against the two/three-step
    compositions they replaced (jnp ref path — what actually runs on this
    CPU host; the Pallas path is interpret-emulated, so it is executed at
    small size purely for the parity proof, not timed for speed);
  * **fused sparse-Adam** — the ``DistEmbedding`` row-sparse update, ref
    (in-place NumPy) timing plus a Pallas-vs-ref bitwise cross-check;
  * the legacy per-kernel jnp rows (segment_sum / gather / edge_softmax).

Run:  PYTHONPATH=src python -m benchmarks.kernels_micro [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import csv_line
from repro.kernels import (edge_softmax, fused_edge_softmax_aggregate,
                           fused_edge_softmax_aggregate_ref,
                           fused_gather_aggregate, fused_gather_aggregate_ref,
                           gather_rows, segment_sum, sparse_adam_apply)
from repro.kernels.pack import device_stage, flatten_tree


def _bench(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _batch_tree(rng, n_in=1200, batch=32, e=1600, f=100, layers=2) -> dict:
    """A host tree shaped like a node mini-batch's device-prefetch input."""
    blk = lambda: dict(                                      # noqa: E731
        edge_src=rng.integers(0, n_in, e).astype(np.int64),
        edge_dst=rng.integers(0, batch * 10, e).astype(np.int64),
        edge_mask=np.ones(e, bool),
        edge_types=np.zeros(e, np.int32))
    return dict(input_feats=rng.standard_normal((n_in, f)).astype(np.float32),
                seeds=rng.integers(0, n_in, batch).astype(np.int64),
                seed_mask=np.ones(batch, bool),
                labels=rng.integers(0, 16, batch).astype(np.int64),
                blocks=[blk() for _ in range(layers)])


def staging_micro(smoke: bool = False) -> dict:
    """Packed one-shot staging vs per-array device_put on one batch tree.
    Timed to the point the PIPELINE stage blocks on (transfer complete);
    the packed path's jitted unpack runs lazily in the consumer, so it is
    timed separately."""
    rng = np.random.default_rng(0)
    tree = _batch_tree(rng, n_in=300 if smoke else 1200,
                       e=400 if smoke else 1600)
    flat, _ = flatten_tree(tree)
    iters = 10 if smoke else 50

    def stage(packed):
        out = device_stage(tree, packed=packed)
        jax.block_until_ready(out.buffers if packed
                              else jax.tree.leaves(out))
        return out

    t_per_array = _bench(lambda: stage(False), iters=iters)
    t_packed = _bench(lambda: stage(True), iters=iters)
    t_unpack = _bench(lambda: jax.tree.leaves(stage(True).unpack()),
                      iters=iters) - t_packed

    # byte identity between the two staging paths
    a = stage(True).unpack()
    b = stage(False)
    fa, _ = flatten_tree(jax.tree.map(np.asarray, a))
    fb, _ = flatten_tree(jax.tree.map(np.asarray, b))
    identical = (set(fa) == set(fb)
                 and all(fa[k].dtype == fb[k].dtype
                         and np.array_equal(fa[k], fb[k]) for k in fa))
    if not identical:
        raise AssertionError("packed staging changed the batch bytes")

    nbytes = sum(v.nbytes for v in flat.values())
    speed = t_per_array / max(t_packed, 1e-9)
    csv_line("kernels/staging_per_array", t_per_array,
             f"arrays={len(flat)};bytes={nbytes}")
    csv_line("kernels/staging_packed", t_packed,
             f"speedup={speed:.2f}x;device_puts=1")
    csv_line("kernels/staging_unpack", max(t_unpack, 0.0),
             "consumer-side;jitted static slices")
    return dict(num_arrays=len(flat), total_bytes=nbytes,
                per_array_us=t_per_array, packed_us=t_packed,
                unpack_us=max(t_unpack, 0.0), speedup=speed,
                byte_identical=True)


def fused_micro(smoke: bool = False) -> dict:
    """Fused layer tails vs the unfused compositions they replaced (jnp
    path, jitted either way), plus the Pallas interpret parity proof."""
    rng = np.random.default_rng(1)
    e, f, n, v = (2048, 32, 512, 1024) if smoke else (16384, 128, 4096, 8192)
    h = jnp.asarray(rng.standard_normal((v, f)), jnp.float32)
    src = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    mask = jnp.asarray(rng.random(e) > 0.2)

    unfused = jax.jit(lambda h, s, d, m: segment_sum(h[s], d, m, n,
                                                     impl="ref"))
    fused = jax.jit(lambda h, s, d, m: fused_gather_aggregate(
        h, s, d, m, n, impl="ref"))
    t_unf = _bench(unfused, h, src, dst, mask)
    t_fus = _bench(fused, h, src, dst, mask)
    csv_line("kernels/gather_aggregate_unfused", t_unf, f"E={e};F={f};N={n}")
    csv_line("kernels/gather_aggregate_fused_ref", t_fus,
             f"speedup={t_unf / max(t_fus, 1e-9):.2f}x")

    heads, dh = 4, max(f // 4, 1)
    hp = jnp.asarray(rng.standard_normal((v, heads, dh)), jnp.float32)
    sc = jnp.asarray(rng.standard_normal((e, heads)), jnp.float32)

    def unfused_att(hp, sc, s, d, m):
        alpha = edge_softmax(sc, d, m, n, impl="ref")
        msg = (hp[s] * alpha[:, :, None]).reshape(e, -1)
        return segment_sum(msg, d, m, n, impl="ref")

    fused_att = jax.jit(lambda hp, sc, s, d, m: fused_edge_softmax_aggregate(
        hp, sc, s, d, m, n, impl="ref"))
    t_unf_a = _bench(jax.jit(unfused_att), hp, sc, src, dst, mask)
    t_fus_a = _bench(fused_att, hp, sc, src, dst, mask)
    csv_line("kernels/edge_softmax_aggregate_unfused", t_unf_a,
             f"E={e};H={heads};dh={dh};N={n}")
    csv_line("kernels/edge_softmax_aggregate_fused_ref", t_fus_a,
             f"speedup={t_unf_a / max(t_fus_a, 1e-9):.2f}x")

    # Pallas interpret parity proof (emulated, small, correctness-only)
    k = 256
    pf = fused_gather_aggregate(h[:k], src[:k] % k, dst[:k] % 64, mask[:k],
                                64, impl="pallas")
    rf = fused_gather_aggregate_ref(h[:k], src[:k] % k, dst[:k] % 64,
                                    mask[:k], 64)
    pa = fused_edge_softmax_aggregate(hp[:k], sc[:k], src[:k] % k,
                                      dst[:k] % 64, mask[:k], 64,
                                      impl="pallas")
    ra = fused_edge_softmax_aggregate_ref(hp[:k], sc[:k], src[:k] % k,
                                          dst[:k] % 64, mask[:k], 64)
    ok = (np.allclose(pf, rf, atol=1e-5)
          and np.allclose(pa, ra, atol=1e-4))
    if not ok:
        raise AssertionError("pallas/ref fused-kernel parity failed")
    csv_line("kernels/fused_pallas_interpret_parity", 1.0, "emulated;ok")
    return dict(gather_aggregate=dict(unfused_us=t_unf, fused_ref_us=t_fus),
                edge_softmax_aggregate=dict(unfused_us=t_unf_a,
                                            fused_ref_us=t_fus_a),
                pallas_parity=True)


def sparse_adam_micro(smoke: bool = False) -> dict:
    """The DistEmbedding row-sparse Adam: ref timing + a Pallas bitwise
    cross-check (the byte-identity contract the oracle tests pin)."""
    rng = np.random.default_rng(2)
    n, d, r = (512, 16, 64) if smoke else (16384, 64, 1024)
    kw = dict(beta1=0.9, beta2=0.999, lr=1e-2, eps=1e-8)

    def world():
        return (rng.standard_normal((n, d)).astype(np.float32),
                np.zeros((n, d), np.float32), np.zeros((n, d), np.float32),
                np.zeros(n, np.int64))

    w, m, v, t = world()
    rows = np.unique(rng.integers(0, n, r))
    g = rng.standard_normal((len(rows), d)).astype(np.float32)
    iters = 5 if smoke else 50
    t0 = time.perf_counter()
    for _ in range(iters):
        sparse_adam_apply(w, m, v, rows, g, t, impl="ref", **kw)
    t_ref = (time.perf_counter() - t0) / iters * 1e6
    csv_line("kernels/sparse_adam_ref", t_ref, f"N={n};D={d};R={len(rows)}")

    # bitwise: pallas (interpret) vs ref from the same start state
    w1, m1, v1, t1 = world()
    w2, m2, v2, t2 = w1.copy(), m1.copy(), v1.copy(), t1.copy()
    for _ in range(3):
        rs = np.unique(rng.integers(0, n, min(r, 32)))
        gs = rng.standard_normal((len(rs), d)).astype(np.float32)
        sparse_adam_apply(w1, m1, v1, rs, gs, t1, impl="ref", **kw)
        sparse_adam_apply(w2, m2, v2, rs, gs, t2, impl="pallas", **kw)
    bitwise = (np.array_equal(w1, w2) and np.array_equal(m1, m2)
               and np.array_equal(v1, v2))
    if not bitwise:
        raise AssertionError("sparse-Adam pallas/ref bitwise parity failed")
    csv_line("kernels/sparse_adam_pallas_bitwise", 1.0, "emulated;bit-exact")
    return dict(n=n, d=d, r=int(len(rows)), ref_us=t_ref, pallas_bitwise=True)


def base_kernels(smoke: bool = False) -> dict:
    """The original per-kernel jnp rows (kept for trajectory continuity)."""
    rng = np.random.default_rng(0)
    e, f, n = (2048, 32, 512) if smoke else (16384, 128, 4096)
    msg = jnp.asarray(rng.standard_normal((e, f)), jnp.float32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    mask = jnp.asarray(rng.random(e) > 0.2)
    seg = jax.jit(lambda m, d, k: segment_sum(m, d, k, n, impl="ref"))
    t_seg = _bench(seg, msg, dst, mask)
    csv_line("kernels/segment_sum_ref", t_seg, f"E={e};F={f};N={n}")

    table = jnp.asarray(rng.standard_normal((65536, 128)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 65536, 8192), jnp.int32)
    gat = jax.jit(lambda t, i: gather_rows(t, i, impl="ref"))
    t_gat = _bench(gat, table, idx)
    csv_line("kernels/gather_ref", t_gat, "V=65536;F=128")

    sc = jnp.asarray(rng.standard_normal((e, 4)), jnp.float32)
    es = jax.jit(lambda s, d, m: edge_softmax(s, d, m, n, impl="ref"))
    t_es = _bench(es, sc, dst, mask)
    csv_line("kernels/edge_softmax_ref", t_es, f"E={e};H=4;N={n}")
    return dict(segment_sum_us=t_seg, gather_us=t_gat, edge_softmax_us=t_es)


def run(out_path: str = "BENCH_kernels.json", smoke: bool = False) -> dict:
    result = {
        "config": {"smoke": smoke, "backend": jax.default_backend()},
        "staging": staging_micro(smoke),
        "fused": fused_micro(smoke),
        "sparse_adam": sparse_adam_micro(smoke),
        "base": base_kernels(smoke),
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[kernels_micro] wrote {out_path}")
    return result


def main():
    ap = argparse.ArgumentParser(prog="benchmarks.kernels_micro")
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI: same measurements, tiny run")
    args = ap.parse_args()
    run(out_path=args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
