"""Fig. 14 analogue: the cumulative optimization ladder.

random partition + no pipeline (Euler-ish)
  -> +multi-constraint METIS partition
  -> +2-level partition (trainer-local seed clustering)
  -> +asynchronous mini-batch pipeline
  -> +non-stop pipeline
  -> +multi-worker sampling pools (4 sampler threads per trainer)

The paper reports 1.62x for METIS and 4.7x cumulative on OGBN-PRODUCT with
4 machines / 100 Gbps; absolute ratios here are machine-dependent. Each
rung reports BOTH wall-clock and its mechanism metric (remote bytes pulled
for the partition rungs; per-epoch time for the pipeline rungs), because at
this scale some mechanism wins sit inside the timing noise.
"""
from __future__ import annotations

from .common import csv_line, make_trainer, small_cfg, time_epochs
from repro.graph import get_dataset

LADDER = [
    ("random+sync", dict(method="random", use_level2=False, sync=True,
                         non_stop=False)),
    ("+metis", dict(method="metis", use_level2=False, sync=True,
                    non_stop=False)),
    ("+2level", dict(method="metis", use_level2=True, sync=True,
                     non_stop=False)),
    ("+async", dict(method="metis", use_level2=True, sync=False,
                    non_stop=False)),
    ("+nonstop", dict(method="metis", use_level2=True, sync=False,
                      non_stop=True)),
    # PR 4: multi-worker sampling pools (§5.5's "multiple sampling
    # workers per trainer") on top of the full pipeline ladder
    ("+sampleworkers", dict(method="metis", use_level2=True, sync=False,
                            non_stop=True, sample_workers=4)),
]


def run(scale=13, epochs=4):
    # planted-community graph (the regime where min-edge-cut pays, like the
    # paper's products graph); 4 machines x 1 trainer as in §6
    ds = get_dataset("cluster-sim", num_nodes=1 << scale, num_blocks=32)
    cfg = small_cfg(in_dim=64, batch=64)
    base_t = None
    rows = []
    for name, kw in LADDER:
        tr = make_trainer(ds, cfg, machines=4, tpm=1, **kw)
        t = time_epochs(tr, epochs=epochs)
        stats = tr.sampling_stats()
        tr.stop()
        base_t = base_t or t
        remote_mb = stats["transport"]["remote_bytes"] / 1e6
        rows.append((name, t, base_t / t, remote_mb))
        csv_line(f"fig14/{name}", t * 1e6,
                 f"speedup={base_t / t:.2f}x;remote_MB={remote_mb:.1f};"
                 f"remote_seed_frac={stats['remote_seed_frac']:.2f}")
    return rows


if __name__ == "__main__":
    run()
