"""Fig. 2 analogue: full-graph vs mini-batch training time-to-accuracy.

Full-graph: whole-graph GCN-style forward per optimizer step (the
aggregation runs over every edge via the segment-sum kernel path).
Mini-batch: the sampled pipeline. The paper's claim: mini-batch reaches
the target accuracy an order of magnitude faster on medium graphs and
also converges to >= accuracy.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import csv_line, make_trainer, small_cfg
from repro.graph import get_dataset, to_coo
from repro.kernels import segment_sum
from repro.optim import adamw_init, adamw_update


def _fullgraph_train(ds, hidden=64, steps=60, lr=1e-2, seed=0):
    g = ds.graph
    src, dst = to_coo(g)
    feats = jnp.asarray(ds.feats)
    labels = jnp.asarray(ds.labels)
    train_mask = jnp.asarray(ds.split_mask == 1)
    val_mask = jnp.asarray(ds.split_mask == 2)
    e_src = jnp.asarray(src, jnp.int32)
    e_dst = jnp.asarray(dst, jnp.int32)
    e_mask = jnp.ones(len(src), bool)
    deg = jnp.maximum(jax.ops.segment_sum(jnp.ones(len(src)), e_dst,
                                          num_segments=g.num_nodes), 1.0)
    rng = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(rng, 3)
    d_in, classes = ds.feats.shape[1], ds.num_classes
    params = {
        "w1s": jax.random.normal(k1, (d_in, hidden)) * 0.05,
        "w1n": jax.random.normal(k2, (d_in, hidden)) * 0.05,
        "w2s": jax.random.normal(k3, (hidden, classes)) * 0.05,
        "w2n": jax.random.normal(k3, (hidden, classes)) * 0.05,
    }
    opt = adamw_init(params)

    def fwd(p, h):
        agg = segment_sum(h[e_src], e_dst, e_mask, g.num_nodes) / deg[:, None]
        h1 = jax.nn.relu(h @ p["w1s"] + agg @ p["w1n"])
        agg2 = segment_sum(h1[e_src], e_dst, e_mask, g.num_nodes) / deg[:, None]
        return h1 @ p["w2s"] + agg2 @ p["w2n"]

    @jax.jit
    def step(p, opt):
        def loss_fn(p):
            logits = fwd(p, feats)
            logp = jax.nn.log_softmax(logits)
            nll = -jnp.take_along_axis(logp, labels[:, None], 1)[:, 0]
            return jnp.where(train_mask, nll, 0).sum() / train_mask.sum()
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p, opt = adamw_update(p, grads, opt, lr=lr)
        return p, opt, loss

    @jax.jit
    def val_acc(p):
        pred = fwd(p, feats).argmax(-1)
        return jnp.where(val_mask, pred == labels, 0).sum() / val_mask.sum()

    t0 = time.perf_counter()
    accs = []
    for s in range(steps):
        params, opt, loss = step(params, opt)
        if (s + 1) % 10 == 0:
            accs.append(float(val_acc(params)))
    return time.perf_counter() - t0, accs


def run(scale=12, epochs=10):
    ds = get_dataset("product-sim", scale=scale)
    t_full, acc_full = _fullgraph_train(ds)
    cfg = small_cfg(in_dim=ds.feats.shape[1])
    tr = make_trainer(ds, cfg, network=False)
    t0 = time.perf_counter()
    for e in range(epochs):
        tr.train_epoch(e)
    acc_mb = tr.evaluate(ds.val_nids)
    t_mb = time.perf_counter() - t0
    tr.stop()
    csv_line("fig2/full-graph", t_full * 1e6,
             f"final_val_acc={acc_full[-1]:.3f}")
    csv_line("fig2/mini-batch", t_mb * 1e6, f"final_val_acc={acc_mb:.3f}")
    return dict(full=(t_full, acc_full[-1]), mini=(t_mb, acc_mb))


if __name__ == "__main__":
    run()
