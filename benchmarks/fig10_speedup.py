"""Fig. 10/11 analogue: DistDGLv2 (full system) vs DistDGL-like and
Euler-like baselines, per model (GraphSAGE / GAT / RGCN).

Baseline mapping (per §6.1 of the paper):
  * Euler-like    — random partitioning, no locality-aware split, no
                    pipeline ("parallelizes completely with
                    multiprocessing" — here: the sync path);
  * DistDGL-like  — METIS partitioning + co-located data (level 1) but no
                    2-level split and no asynchronous pipeline;
  * DistDGLv2     — everything on.

The paper's Fig. 10 shows 2–3x over DistDGL-GPU and ~18x over Euler; the
CPU/GPU split does not exist on this host, so the validated claim is the
relative ordering Euler < DistDGL < DistDGLv2 per model.
"""
from __future__ import annotations

from .common import (csv_line, hetero_cfg, lp_cfg, make_trainer, small_cfg,
                     time_epochs)
from repro.graph import get_dataset

MODES = [
    ("euler-like", dict(method="random", use_level2=False, sync=True,
                        non_stop=False)),
    ("distdgl-like", dict(method="metis", use_level2=False, sync=True,
                          non_stop=False)),
    ("distdglv2", dict(method="metis", use_level2=True, sync=False,
                       non_stop=True)),
    # cache ablation column: the full system plus the per-trainer
    # hot-vertex feature cache (64 MB, CLOCK) absorbing remote pulls
    ("distdglv2+cache", dict(method="metis", use_level2=True, sync=False,
                             non_stop=True, cache_mb=64.0)),
]


def run(scale=13, epochs=3):
    rows = []
    # rgcn-hetero: the typed-relation path end-to-end (per-relation
    # fanouts, per-ntype KVStore policies) on the mag-hetero heterograph;
    # graphsage-lp: edge-mini-batch link prediction (§6's second task) —
    # two scales down because LP schedules every owned edge each epoch
    for arch, ds_name, rels in [("graphsage", "product-sim", 1),
                                ("gat", "product-sim", 1),
                                ("rgcn", "mag-sim", 4),
                                ("rgcn-hetero", "mag-hetero", None),
                                ("graphsage-lp", "product-sim", 1)]:
        task_kw = {}
        if arch == "graphsage-lp":
            ds = get_dataset(ds_name, scale=scale - 2)
            cfg = lp_cfg(ds, batch_edges=64)
            task_kw = dict(task="link_prediction", num_negs=4)
        else:
            ds = get_dataset(ds_name, scale=scale)
            # mag-sim has the paper's papers100M-like 1% train split: use a
            # batch the per-trainer split can sustain
            bs = 16 if ds_name.startswith("mag") else 32
            if arch == "rgcn-hetero":
                cfg = hetero_cfg(ds, batch=bs)
            else:
                cfg = small_cfg(arch=arch, in_dim=ds.feats.shape[1],
                                rels=rels, hidden=64, batch=bs)
        base = None
        for name, kw in MODES:
            tr = make_trainer(ds, cfg, **kw, **task_kw)
            t = time_epochs(tr, epochs=epochs)
            base = base or t
            rows.append((arch, name, t, base / t))
            csv_line(f"fig10/{arch}/{name}", t * 1e6,
                     f"speedup_vs_euler={base / t:.2f}x")
    return rows


if __name__ == "__main__":
    run()
