"""Table 2 analogue: end-to-end pipeline time breakdown — partitioning,
partition load/save, training-data load, and train time, plus the
per-stage busy/starved/backpressured breakdown of the async mini-batch
pipeline (what the paper's Fig. 7 stages actually cost)."""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from .common import csv_line, make_trainer, small_cfg
from repro.checkpoint import save_kvstore, load_kvstore
from repro.graph import get_dataset


def run(scale=12, epochs=2):
    t0 = time.perf_counter()
    ds = get_dataset("product-sim", scale=scale)
    t_load = time.perf_counter() - t0

    cfg = small_cfg(in_dim=ds.feats.shape[1])
    tr = make_trainer(ds, cfg)           # partitions inside
    t_part = tr.partition_time_s

    with tempfile.TemporaryDirectory() as tmp:
        t0 = time.perf_counter()
        save_kvstore(tr.store, tmp)
        load_kvstore(tr.store, tmp)
        t_ckpt = time.perf_counter() - t0

    t0 = time.perf_counter()
    for e in range(epochs):
        tr.train_epoch(e)
    t_train = time.perf_counter() - t0
    stage_stats = tr.pipelines[0].stats_report()
    tr.stop()

    csv_line("table2/load_data", t_load * 1e6)
    csv_line("table2/partition", t_part * 1e6)
    csv_line("table2/save_load_partition", t_ckpt * 1e6)
    csv_line("table2/train", t_train * 1e6, f"epochs={epochs}")
    for name, st in stage_stats.items():
        csv_line(f"table2/stage/{name}",
                 st["busy_s"] * 1e6 / max(st["items"], 1),
                 f"items={st['items']};starved_s={st['wait_in_s']:.3f};"
                 f"backpressure_s={st['wait_out_s']:.3f}")
    return dict(load=t_load, partition=t_part, ckpt=t_ckpt, train=t_train,
                stages=stage_stats)


if __name__ == "__main__":
    run()
