"""Table 2 analogue: end-to-end pipeline time breakdown — partitioning,
partition load/save, training-data load, and train time, plus the
per-stage busy/starved/backpressured breakdown of the async mini-batch
pipeline (what the paper's Fig. 7 stages actually cost).  The full
per-stage detail also lands in ``BENCH_table2.json`` for CI.

Workloads:
  * ``table2/...``          — homogeneous GraphSAGE on product-sim;
  * ``table2/hetero/...``   — typed-relation RGCN on the mag-hetero
    heterograph (per-relation fanouts, per-ntype KVStore policies), the
    paper's OGBN-MAG-class configuration;
  * ``table2/linkpred/...`` — edge-mini-batch link prediction (the paper's
    second task, §6) through the same async pipeline, with async-vs-sync
    and cache-on/off ablation columns;
  * ``table2/stage/device_prefetch_*`` — the device-staging columns:
    the device-prefetch stage's per-batch busy time under packed one-shot
    staging (DESIGN.md §9) vs the legacy per-array ``device_put`` loop.

Run:  PYTHONPATH=src python -m benchmarks.table2_breakdown [--smoke]
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np

from .common import csv_line, hetero_cfg, lp_cfg, make_trainer, small_cfg
from repro.checkpoint import save_kvstore, load_kvstore
from repro.graph import get_dataset


def _breakdown(tag: str, ds, cfg, t_load: float, epochs: int,
               cache_mb: float = 0.0, **tr_kw) -> dict:
    tr = make_trainer(ds, cfg, cache_mb=cache_mb, **tr_kw)   # partitions inside
    t_part = tr.partition_time_s

    with tempfile.TemporaryDirectory() as tmp:
        t0 = time.perf_counter()
        save_kvstore(tr.store, tmp)
        load_kvstore(tr.store, tmp)
        t_ckpt = time.perf_counter() - t0

    t0 = time.perf_counter()
    for e in range(epochs):
        tr.train_epoch(e)
    t_train = time.perf_counter() - t0
    # loader-level observability (repro.api): stage times, cache hit rate
    # and sampler coalescing come from loader.stats_report() — no reaching
    # into trainer internals
    loader_rep = tr.loaders[0].stats_report()
    stage_stats = loader_rep["stages"]
    sampling = tr.sampling_stats()
    tr.stop()

    csv_line(f"{tag}/load_data", t_load * 1e6)
    csv_line(f"{tag}/partition", t_part * 1e6)
    csv_line(f"{tag}/save_load_partition", t_ckpt * 1e6)
    csv_line(f"{tag}/train", t_train * 1e6, f"epochs={epochs}")
    # remote request COUNT (not just bytes): the per-owner coalescing of
    # the typed dispatch shows up here (coalescing_factor = per-relation
    # requests each issued request replaced; 1.0 on untyped runs)
    req = sampling["sampler_requests"]
    csv_line(f"{tag}/remote_requests",
             float(sampling["transport"]["remote_requests"]),
             f"coalescing_factor={req['coalescing_factor']:.1f};"
             f"owner_requests={req['owner_requests']}")
    for name, st in stage_stats.items():
        csv_line(f"{tag}/stage/{name}",
                 st["busy_s"] * 1e6 / max(st["items"], 1),
                 f"items={st['items']};starved_s={st['wait_in_s']:.3f};"
                 f"backpressure_s={st['wait_out_s']:.3f};"
                 f"workers={st.get('workers', 1)}")
    if loader_rep["cache"] is not None:
        csv_line(f"{tag}/loader/cache_hit_rate",
                 loader_rep["cache"]["hit_rate"] * 100.0,
                 f"hits={loader_rep['cache']['hits']};"
                 f"misses={loader_rep['cache']['misses']}")
    if "edges_per_etype" in sampling:
        per = sampling["edges_per_etype"]
        csv_line(f"{tag}/edges_per_etype", float(sum(per.values())),
                 ";".join(f"{k}={v}" for k, v in per.items()))
    return dict(load=t_load, partition=t_part, ckpt=t_ckpt, train=t_train,
                stages=stage_stats, sampling=sampling)


def _cache_ablation(tag: str, ds, cfg, epochs: int, off: dict,
                    cache_mb: float = 64.0, **tr_kw) -> dict:
    """Cache-on vs cache-off column: same workload with a per-trainer
    hot-vertex cache; the paper-style metric is the remote-traffic
    reduction relative to the uncached run (prewarm pulls included in the
    cache-on total, so the saving reported is net)."""
    on = _breakdown(f"{tag}/cache_on", ds, cfg, 0.0, epochs,
                    cache_mb=cache_mb, **tr_kw)
    b_off = off["sampling"]["transport"]["remote_bytes"]
    tp_on = on["sampling"]["transport"]
    reduction = 1.0 - tp_on["remote_bytes"] / max(b_off, 1)
    csv_line(f"{tag}/cache/remote_bytes_off", float(b_off))
    csv_line(f"{tag}/cache/remote_bytes_on", float(tp_on["remote_bytes"]),
             f"budget_mb={cache_mb}")
    csv_line(f"{tag}/cache/saved_remote_bytes",
             float(tp_on["saved_remote_bytes"]),
             f"hit_rate={tp_on['cache_hit_rate']:.3f}")
    csv_line(f"{tag}/cache/remote_traffic_reduction", reduction * 100.0,
             "percent_vs_cache_off")
    return dict(remote_bytes_off=b_off,
                remote_bytes_on=tp_on["remote_bytes"],
                saved=tp_on["saved_remote_bytes"], reduction=reduction)


def _linkpred_rows(scale: int, cache_mb: float) -> dict:
    """Link-prediction rows (§6's second task): the full breakdown on the
    async path, an async-vs-sync train column, and the cache-on/off
    ablation — all through the edge-mini-batch pipeline. Runs one scale
    down from the node rows: LP schedules EVERY owned edge per epoch."""
    ds = get_dataset("product-sim", scale=scale)
    cfg = lp_cfg(ds, batch_edges=64)
    kw = dict(task="link_prediction", num_negs=4)
    out = {"async": _breakdown("table2/linkpred", ds, cfg, 0.0, 1, **kw)}

    tr = make_trainer(ds, cfg, sync=True, non_stop=False, **kw)
    t0 = time.perf_counter()
    tr.train_epoch(0)
    t_sync = time.perf_counter() - t0
    tr.stop()
    speed = t_sync / max(out["async"]["train"], 1e-9)
    csv_line("table2/linkpred/train_sync", t_sync * 1e6,
             f"async_speedup={speed:.2f}x")
    out["sync_train"] = t_sync

    out["cache"] = _cache_ablation("table2/linkpred", ds, cfg, 1,
                                   out["async"], cache_mb=cache_mb, **kw)
    return out


def _worker_scaling_rows(scale: int) -> dict:
    """Sampling-front batches/s vs --sample-workers on the table2
    product-sim config (the PR 4 acceptance number); full detail lands in
    BENCH_sampling.json via benchmarks.sampling_micro."""
    from .sampling_micro import worker_scaling
    out = worker_scaling(scale)
    for r in out["rows"]:
        csv_line(f"table2/sample_workers/{r['workers']}",
                 r["time_s"] * 1e6 / max(r["batches"], 1),
                 f"batches_per_s={r['batches_per_s']:.1f};"
                 f"speedup_vs_w1={r['speedup_vs_w1']:.2f}x")
    return out


def _staging_rows(scale: int, epochs: int = 1) -> dict:
    """Device-staging columns: the device-prefetch stage's per-batch busy
    time with packed one-shot staging (a single transfer of the uint8
    arena, DESIGN.md §9) vs the legacy per-array loop it
    replaced — measured where it runs, as a pipeline stage with
    ``to_device=True``.  The pipeline runs ``sync=True`` (inline stages):
    staging cost is host+PCIe work, and measuring it under the async
    threads would fold the *other* stages' GIL pressure into the number."""
    from .sampling_micro import _homo_world
    from repro.core.kvstore import NetworkModel, Transport
    from repro.core.pipeline import MinibatchPipeline
    from repro.core.sampler import DistributedSampler

    ds, hp, store, seeds = _homo_world(scale)
    pipes = {}
    for packed in (False, True):
        sampler = DistributedSampler(hp.book, hp.partitions, [10, 5], 8,
                                     machine=0,
                                     transport=Transport(NetworkModel()),
                                     seed=3)
        key = "packed" if packed else "per_array"
        pipes[key] = MinibatchPipeline(sampler, store.client(0), "feat",
                                       seeds, batch_size=8, sync=True,
                                       non_stop=False, to_device=True,
                                       packed=packed, seed=4)
    # epoch 0 is warmup (allocator + spec/unpack caches); sync mode
    # rebuilds the pipeline per epoch, so each epoch's stats are
    # independent.  The two arms run back-to-back WITHIN each round so
    # machine-throughput drift hits both equally, and each arm reports
    # its best round (min is the noise-robust statistic for a fixed
    # workload).
    rows = {k: None for k in pipes}
    for e in range(max(epochs, 4) + 1):
        for key, pipe in pipes.items():
            for _mb, _dev in pipe.epoch(e):
                pass
            st = pipe.stats_report()["device_prefetch"]
            us = st["busy_s"] * 1e6 / max(st["items"], 1)
            if e > 0 and (rows[key] is None
                          or us < rows[key]["us_per_batch"]):
                rows[key] = dict(us_per_batch=us, items=st["items"],
                                 busy_s=st["busy_s"])
    for pipe in pipes.values():
        pipe.stop()
    speed = (rows["per_array"]["us_per_batch"]
             / max(rows["packed"]["us_per_batch"], 1e-9))
    rows["packed_speedup"] = speed
    csv_line("table2/stage/device_prefetch_per_array",
             rows["per_array"]["us_per_batch"],
             f"items={rows['per_array']['items']}")
    csv_line("table2/stage/device_prefetch_packed",
             rows["packed"]["us_per_batch"],
             f"items={rows['packed']['items']};"
             f"packed_speedup={speed:.2f}x")
    return rows


def run(scale=12, epochs=2, cache_mb=64.0,
        out_path: str = "BENCH_table2.json", smoke: bool = False):
    if smoke:
        # scale 11 is the floor: the homogeneous config needs >=32 train
        # seeds per trainer (2 machines x 2 trainers)
        scale, epochs = min(scale, 11), 1
    t0 = time.perf_counter()
    ds = get_dataset("product-sim", scale=scale)
    t_load = time.perf_counter() - t0
    cfg = small_cfg(in_dim=ds.feats.shape[1])
    out = {"config": {"scale": scale, "epochs": epochs, "smoke": smoke}}
    out["homogeneous"] = _breakdown("table2", ds, cfg, t_load, epochs)
    out["homogeneous_cache"] = _cache_ablation(
        "table2", ds, cfg, epochs, out["homogeneous"], cache_mb=cache_mb)
    out["sample_workers"] = _worker_scaling_rows(scale)
    out["device_staging"] = _staging_rows(scale, epochs=epochs)

    t0 = time.perf_counter()
    ds_h = get_dataset("mag-hetero", scale=scale)
    t_load_h = time.perf_counter() - t0
    cfg_h = hetero_cfg(ds_h)
    out["hetero"] = _breakdown("table2/hetero", ds_h, cfg_h, t_load_h, epochs)
    out["hetero_cache"] = _cache_ablation(
        "table2/hetero", ds_h, cfg_h, epochs, out["hetero"],
        cache_mb=cache_mb)

    out["linkpred"] = _linkpred_rows(scale - 1, cache_mb)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2,
                  default=lambda o: o.item() if isinstance(o, np.generic)
                  else str(o))
    print(f"[table2_breakdown] wrote {out_path}")
    return out


def main():
    ap = argparse.ArgumentParser(prog="benchmarks.table2_breakdown")
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--out", default="BENCH_table2.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small scale for CI: same columns, tiny run")
    args = ap.parse_args()
    run(scale=args.scale, epochs=args.epochs, out_path=args.out,
        smoke=args.smoke)


if __name__ == "__main__":
    main()
