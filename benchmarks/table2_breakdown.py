"""Table 2 analogue: end-to-end pipeline time breakdown — partitioning,
partition load/save, training-data load, and train time, plus the
per-stage busy/starved/backpressured breakdown of the async mini-batch
pipeline (what the paper's Fig. 7 stages actually cost).

Two workloads:
  * ``table2/...``        — homogeneous GraphSAGE on product-sim;
  * ``table2/hetero/...`` — typed-relation RGCN on the mag-hetero
    heterograph (per-relation fanouts, per-ntype KVStore policies), the
    paper's OGBN-MAG-class configuration.
"""
from __future__ import annotations

import tempfile
import time

from .common import csv_line, hetero_cfg, make_trainer, small_cfg
from repro.checkpoint import save_kvstore, load_kvstore
from repro.graph import get_dataset


def _breakdown(tag: str, ds, cfg, t_load: float, epochs: int) -> dict:
    tr = make_trainer(ds, cfg)           # partitions inside
    t_part = tr.partition_time_s

    with tempfile.TemporaryDirectory() as tmp:
        t0 = time.perf_counter()
        save_kvstore(tr.store, tmp)
        load_kvstore(tr.store, tmp)
        t_ckpt = time.perf_counter() - t0

    t0 = time.perf_counter()
    for e in range(epochs):
        tr.train_epoch(e)
    t_train = time.perf_counter() - t0
    stage_stats = tr.pipelines[0].stats_report()
    sampling = tr.sampling_stats()
    tr.stop()

    csv_line(f"{tag}/load_data", t_load * 1e6)
    csv_line(f"{tag}/partition", t_part * 1e6)
    csv_line(f"{tag}/save_load_partition", t_ckpt * 1e6)
    csv_line(f"{tag}/train", t_train * 1e6, f"epochs={epochs}")
    for name, st in stage_stats.items():
        csv_line(f"{tag}/stage/{name}",
                 st["busy_s"] * 1e6 / max(st["items"], 1),
                 f"items={st['items']};starved_s={st['wait_in_s']:.3f};"
                 f"backpressure_s={st['wait_out_s']:.3f}")
    if "edges_per_etype" in sampling:
        per = sampling["edges_per_etype"]
        csv_line(f"{tag}/edges_per_etype", float(sum(per.values())),
                 ";".join(f"{k}={v}" for k, v in per.items()))
    return dict(load=t_load, partition=t_part, ckpt=t_ckpt, train=t_train,
                stages=stage_stats)


def run(scale=12, epochs=2):
    t0 = time.perf_counter()
    ds = get_dataset("product-sim", scale=scale)
    t_load = time.perf_counter() - t0
    cfg = small_cfg(in_dim=ds.feats.shape[1])
    out = {"homogeneous": _breakdown("table2", ds, cfg, t_load, epochs)}

    t0 = time.perf_counter()
    ds_h = get_dataset("mag-hetero", scale=scale)
    t_load_h = time.perf_counter() - t0
    cfg_h = hetero_cfg(ds_h)
    out["hetero"] = _breakdown("table2/hetero", ds_h, cfg_h, t_load_h, epochs)
    return out


if __name__ == "__main__":
    run()
