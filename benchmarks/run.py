"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Select with --only; default runs
everything at reduced scale (a few minutes on one core). The roofline
section reads benchmarks/results/dryrun.json produced by
``python -m repro.launch.dryrun --all``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# allow `python benchmarks/run.py` as well as `python -m benchmarks.run`
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

BENCHES = ["fig1", "fig2", "fig10", "fig12", "fig13", "fig14", "table2",
           "sampling", "kernels", "recovery", "serving", "availability",
           "roofline"]


def bench_roofline():
    path = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")
    if not os.path.exists(path):
        print("roofline/SKIP,0.0,run `python -m repro.launch.dryrun --all`")
        return
    from repro.launch.roofline import analyze
    with open(path) as f:
        data = json.load(f)
    for key, e in sorted(data.items()):
        if not e.get("ok"):
            print(f"roofline/{key},0.0,FAILED:{e.get('error','')[:60]}")
            continue
        chips = 512 if e["mesh"].startswith("2x") else 256
        r = analyze(e, chips)
        step = max(r["t_compute"], r["t_memory"], r["t_collective"])
        print(f"roofline/{key},{step * 1e6:.1f},"
              f"dominant={r['dominant']};mfu={r['roofline_mfu']:.2f};"
              f"useful={r['useful_flops_ratio']:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None, choices=BENCHES)
    args = ap.parse_args()
    todo = args.only or BENCHES
    print("name,us_per_call,derived")
    for name in todo:
        t0 = time.time()
        try:
            if name == "roofline":
                bench_roofline()
            else:
                mod = {
                    "fig1": "fig1_hidden_size",
                    "fig2": "fig2_minibatch_vs_fullgraph",
                    "fig10": "fig10_speedup",
                    "fig12": "fig12_scalability",
                    "fig13": "fig13_convergence",
                    "fig14": "fig14_ablation",
                    "table2": "table2_breakdown",
                    "sampling": "sampling_micro",
                    "kernels": "kernels_micro",
                    "recovery": "recovery_bench",
                    "serving": "serving_bench",
                    "availability": "availability_bench",
                }[name]
                __import__(f"benchmarks.{mod}", fromlist=["run"]).run()
        except Exception:
            print(f"{name}/ERROR,0.0,{traceback.format_exc(limit=1)!r}")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
