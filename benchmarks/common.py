"""Shared helpers for the paper-figure benchmarks.

All GNN benchmarks run on synthetic graphs scaled to this host (see
repro.graph.datasets) with the network cost model *enabled* (real sleeps)
so pipeline-overlap numbers are honest wall-clock, and they exercise the
full stack: partitioner -> KVStore -> samplers -> async pipelines -> jitted
train steps.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.kvstore import CacheConfig, NetworkModel
from repro.graph import get_dataset
from repro.models.gnn import GNNConfig
from repro.api import DistGNNTrainer, TrainJobConfig

# Simulated network. The paper's cluster had 100 Gbps NICs feeding 8 GPUs
# per machine; this host drives its trainers with ONE core, so compute is
# ~100x slower while a realistically-simulated network would be full speed
# — which would (wrongly) hide every locality effect the paper measures.
# We scale the link down proportionally (2 Gbps + 3 ms RPC) so the
# network:compute ratio is in the paper's regime; mechanism metrics
# (remote bytes / remote fraction) are reported alongside wall-clock.
NET = dict(latency_s=3e-3, bandwidth_Bps=2.5e8, sleep=True)


def small_cfg(arch="graphsage", in_dim=100, classes=16, batch=32,
              fanouts=(10, 5), hidden=64, rels=1):
    return GNNConfig(arch=arch, in_dim=in_dim, hidden_dim=hidden,
                     num_classes=classes, fanouts=list(fanouts),
                     batch_size=batch, num_rels=rels)


def hetero_cfg(ds, batch=16, fanouts=(5, 3), hidden=64):
    """Typed-relation RGCN config for a schema'd dataset: each layer gets
    per-relation fanouts (the layer fanout for every relation)."""
    rel_fanouts = [{rel: f for rel in ds.schema.etypes} for f in fanouts]
    return GNNConfig(arch="rgcn", in_dim=ds.feats.shape[1], hidden_dim=hidden,
                     num_classes=ds.num_classes, fanouts=rel_fanouts,
                     batch_size=batch, num_rels=ds.schema.num_etypes)


def lp_cfg(ds, arch="graphsage", batch_edges=16, fanouts=(10, 5), hidden=32):
    """Link-prediction config: batch_size counts POSITIVE EDGES and the
    output dim is the embedding dim (num_classes doubles as emb size)."""
    return GNNConfig(arch=arch, in_dim=ds.feats.shape[1], hidden_dim=hidden,
                     num_classes=hidden, fanouts=list(fanouts),
                     batch_size=batch_edges)


def make_trainer(ds, cfg, *, machines=2, tpm=2, method="metis",
                 use_level2=True, sync=False, non_stop=True, seed=0,
                 network=True, cache_mb=0.0, cache_policy="clock",
                 task="node_classification", num_negs=4, score_fn="dot",
                 sample_workers=1):
    job = TrainJobConfig(
        num_machines=machines, trainers_per_machine=tpm,
        partition_method=method, use_level2=use_level2, sync=sync,
        non_stop=non_stop, seed=seed,
        task=task, num_negs=num_negs, score_fn=score_fn,
        sample_workers=sample_workers,
        cache=(CacheConfig.from_mb(cache_mb, policy=cache_policy)
               if cache_mb > 0 else None),
        network=NetworkModel(**NET) if network else None)
    return DistGNNTrainer(ds, cfg, job)


def time_epochs(trainer, epochs=3, warmup=1):
    times = []
    for e in range(epochs + warmup):
        m = trainer.train_epoch(e)
        if e >= warmup:
            times.append(m["time_s"])
    trainer.stop()
    return float(np.median(times))


def csv_line(name, us_per_call, derived=""):
    print(f"{name},{us_per_call:.1f},{derived}")
