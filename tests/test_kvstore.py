import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.kvstore import (DistEmbedding, DistKVStore, NetworkModel,
                                PartitionPolicy, Transport)


@pytest.fixture
def store():
    pol = PartitionPolicy("node", np.array([0, 10, 25, 40]))
    s = DistKVStore({"node": pol})
    full = np.arange(40 * 3, dtype=np.float32).reshape(40, 3)
    s.init_data("feat", (3,), np.float32, "node", full_array=full)
    return s, full


def test_pull_roundtrip(store):
    s, full = store
    c = s.client(1)
    ids = np.array([0, 5, 12, 24, 39, 12])
    assert np.allclose(c.pull("feat", ids), full[ids])


def test_pull_does_not_alias_source(store):
    s, full = store
    full[0] = 999.0            # mutate the caller's array
    assert not np.allclose(s.client(0).pull("feat", np.array([0]))[0], 999.0)


def test_push_sum_and_assign(store):
    s, full = store
    c = s.client(0)
    c.push("feat", np.array([2, 12]), np.full((2, 3), 10, np.float32),
           reduce="sum")
    assert np.allclose(s.gather_all("feat")[2], full[2] + 10)
    c.push("feat", np.array([2]), np.zeros((1, 3), np.float32),
           reduce="assign")
    assert np.allclose(s.gather_all("feat")[2], 0.0)


def test_transport_accounting(store):
    s, _ = store
    s.transport.reset()
    c = s.client(1)
    c.pull("feat", np.array([0, 12]))   # one remote row, one local
    st_ = s.transport.stats()
    assert st_["remote_bytes"] == 12 and st_["local_bytes"] == 12
    assert st_["remote_requests"] == 1


def test_local_fraction(store):
    s, _ = store
    c = s.client(1)
    assert c.local_fraction("feat", np.array([12, 13, 0, 39])) == 0.5


def test_sparse_embedding_updates_only_touched_rows(store):
    s, _ = store
    emb = DistEmbedding(s, "emb", 40, 4, "node", seed=0)
    c = s.client(0)
    w0 = s.gather_all("emb").copy()
    emb.push_grad(c, np.array([1, 1, 30]), np.ones((3, 4), np.float32))
    w1 = s.gather_all("emb")
    changed = np.nonzero(np.abs(w1 - w0).sum(1) > 0)[0]
    assert set(changed.tolist()) == {1, 30}
    # duplicate ids coalesce to a single Adam step for that row
    assert s.servers[0].local_view("emb__t")[1] == 1


def test_sparse_embedding_adam_direction(store):
    s, _ = store
    emb = DistEmbedding(s, "e2", 40, 4, "node", seed=1)
    c = s.client(0)
    w0 = s.gather_all("e2").copy()
    emb.push_grad(c, np.array([5]), np.ones((1, 4), np.float32))
    w1 = s.gather_all("e2")
    assert (w1[5] < w0[5]).all()       # positive grad -> decrease


@settings(max_examples=25, deadline=None)
@given(ids=st.lists(st.integers(0, 39), min_size=1, max_size=64))
def test_pull_property(ids):
    pol = PartitionPolicy("node", np.array([0, 10, 25, 40]))
    s = DistKVStore({"node": pol})
    full = np.random.default_rng(0).standard_normal((40, 5)).astype(np.float32)
    s.init_data("feat", (5,), np.float32, "node", full_array=full)
    ids = np.array(ids)
    for m in range(3):
        assert np.allclose(s.client(m).pull("feat", ids), full[ids])


def test_network_model_cost():
    nm = NetworkModel(latency_s=1e-3, bandwidth_Bps=1e9)
    assert nm.cost(1e9) == pytest.approx(1.001)
