"""Chaos suite: elastic fault tolerance via deterministic replay
(DESIGN.md §10).

The headline contract: kill a trainer mid-epoch, revive a replacement
from the last consistent checkpoint, fast-forward the deterministic
schedule to the death coordinate — and the finished run's parameters are
BYTE-IDENTICAL to an uninterrupted run's, across node-classification and
link-prediction workloads on both homogeneous and typed graphs. Transient
RPC faults are the second axis: retried pulls/pushes must change nothing
about the training bytes, and a peer that never answers surfaces as
``RPCRetriesExhausted`` rather than a hang.
"""
import time

import jax
import numpy as np
import pytest

from repro.api import (DistGNNTrainer, FaultInjector, RPCRetriesExhausted,
                       TrainJobConfig, TrainerDeath)
from repro.core.kvstore import (CacheConfig, DistKVStore, PartitionPolicy)
from repro.graph import get_dataset
from repro.models.gnn import GNNConfig

FANOUTS_TYPED = {"cites": 4, "writes": 3, "rev_writes": 2, "employs": 2}
EPOCHS = 2


@pytest.fixture(scope="module")
def homo_ds():
    return get_dataset("product-sim", scale=10)


@pytest.fixture(scope="module")
def hetero_ds():
    return get_dataset("mag-hetero", scale=10)


def _cfg(ds, task: str, typed: bool) -> GNNConfig:
    # LP heads score embeddings: num_classes is the output embedding dim
    out = 16 if task == "link_prediction" else ds.num_classes
    if typed:
        return GNNConfig(arch="rgcn", in_dim=ds.feats.shape[1],
                         hidden_dim=16, num_classes=out,
                         fanouts=[dict(FANOUTS_TYPED)] * 2, batch_size=8,
                         num_rels=ds.schema.num_etypes)
    return GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                     hidden_dim=16, num_classes=out, fanouts=[3, 2],
                     batch_size=8)


def _job(task: str, **kw) -> TrainJobConfig:
    # the hot-vertex cache is ON so recovery also exercises the cache
    # snapshot restore (a stale restored cache would break byte-identity)
    return TrainJobConfig(num_machines=2, trainers_per_machine=1,
                          task=task, num_negs=4, seed=5,
                          cache=CacheConfig.from_mb(8), **kw)


def _pbytes(params) -> list:
    return [np.asarray(x).tobytes()
            for x in jax.tree_util.tree_leaves(params)]


def _metrics(tr, ds):
    if tr.task == "link_prediction":
        return tr.evaluate_lp(num_batches=4)
    return tr.evaluate(ds.val_nids)


# ---- the headline: kill mid-epoch, revive, byte-identical ---------------

@pytest.mark.parametrize("task,typed", [
    ("node_classification", False),
    ("node_classification", True),
    ("link_prediction", False),
    ("link_prediction", True),
], ids=["nc-homo", "nc-typed", "lp-homo", "lp-typed"])
def test_kill_revive_byte_identical(task, typed, homo_ds, hetero_ds,
                                    tmp_path):
    ds = hetero_ds if typed else homo_ds
    cfg = _cfg(ds, task, typed)

    # uninterrupted reference run
    base_tr = DistGNNTrainer(ds, cfg, _job(task))
    bpe = base_tr.batches_per_epoch
    assert bpe >= 2, "world too small to die mid-epoch"
    for e in range(EPOCHS):
        base_tr.train_epoch(e)
    base_params = _pbytes(base_tr.params)
    base_eval = _metrics(base_tr, ds)
    base_tr.stop()

    # victim: seeded death mid-way through the LAST epoch
    ck = str(tmp_path / "ck")
    kill = (EPOCHS - 1, max(bpe // 2, 1))
    victim = DistGNNTrainer(ds, cfg, _job(
        task, checkpoint_dir=ck, checkpoint_interval=2,
        fault_injector=FaultInjector(seed=11, kill_at=kill)))
    with pytest.raises(TrainerDeath) as death:
        for e in range(EPOCHS):
            victim.train_epoch(e)
    assert (death.value.epoch, death.value.batch_index) == kill
    victim.stop()

    # replacement trainer: same job spec, restored + fast-forwarded
    revived = DistGNNTrainer(ds, cfg, _job(task))
    meta = revived.recover(ck)
    assert (meta["epoch"], meta["batch_index"]) <= kill
    for e in range(meta["epoch"], EPOCHS):
        revived.train_epoch(e)
    assert _pbytes(revived.params) == base_params, \
        "recovered run's parameters diverged from the uninterrupted run"
    assert _metrics(revived, ds) == base_eval
    revived.stop()


def test_recover_rejects_mismatched_world(homo_ds, tmp_path):
    """Replay is only byte-exact in an identically-configured world —
    anything else must refuse, not silently diverge."""
    ds = homo_ds
    cfg = _cfg(ds, "node_classification", False)
    ck = str(tmp_path / "ck")
    tr = DistGNNTrainer(ds, cfg, _job("node_classification"))
    tr.save_checkpoint(ck, epoch=0, batch_index=1)
    tr.stop()

    other = DistGNNTrainer(ds, cfg, TrainJobConfig(
        num_machines=2, trainers_per_machine=1, seed=6))   # seed != 5
    with pytest.raises(ValueError, match="seed"):
        other.recover(ck)
    other.stop()

    same = DistGNNTrainer(ds, cfg, _job("node_classification"))
    same.recover(ck)
    with pytest.raises(ValueError, match="epoch"):
        same.train_epoch(1)          # must resume at the saved epoch 0
    same.stop()


# ---- transient RPC faults ----------------------------------------------

def test_transient_rpc_faults_leave_bytes_unchanged(homo_ds):
    """Retried pulls are invisible to training: same final parameters as
    the fault-free run, with the retry/backoff accounting proving faults
    actually fired."""
    ds = homo_ds
    cfg = _cfg(ds, "node_classification", False)
    runs = {}
    for tag, inj in (("clean", None),
                     ("faulty", FaultInjector(seed=3,
                                              rpc_failure_rate=0.15))):
        tr = DistGNNTrainer(ds, cfg, _job("node_classification",
                                          fault_injector=inj))
        tr.train_epoch(0)
        runs[tag] = _pbytes(tr.params)
        stats = tr.transport.stats()
        if tag == "faulty":
            assert stats["rpc_failures"] > 0
            assert stats["rpc_retries"] == stats["rpc_failures"]
        else:
            assert stats["rpc_failures"] == 0 == stats["rpc_retries"]
        tr.stop()
    assert runs["clean"] == runs["faulty"]


def test_rpc_retries_exhausted_surfaces(homo_ds):
    """A peer that never answers is a dead peer: after MAX_RPC_RETRIES
    the failure propagates out of the pipeline instead of hanging."""
    ds = homo_ds
    cfg = _cfg(ds, "node_classification", False)
    # no cache: its construction-time pre-warm pulls would already trip
    # the injector before the epoch (and outside this assertion) begins
    tr = DistGNNTrainer(ds, cfg, TrainJobConfig(
        num_machines=2, trainers_per_machine=1, seed=5,
        fault_injector=FaultInjector(seed=0, rpc_failure_rate=1.0)))
    with pytest.raises(RPCRetriesExhausted):
        tr.train_epoch(0)
    tr.stop()


def test_push_retry_never_double_applies():
    """The mutation-safety half of the retry contract: the transport
    charge is retried, the server-side apply happens exactly once — a
    'sum' reduction under 5 forced transient faults lands once."""
    pol = PartitionPolicy("node", np.array([0, 10, 20]))
    s = DistKVStore({"node": pol})
    full = np.zeros((20, 2), dtype=np.float32)
    s.init_data("feat", (2,), np.float32, "node", full_array=full)
    s.transport.fault_injector = FaultInjector(
        seed=0, rpc_failure_rate=1.0, ops=("push",), max_rpc_failures=5)
    c = s.client(1)                       # part-0 rows are remote from m1
    c.push("feat", np.array([3]), np.ones((1, 2), np.float32),
           reduce="sum")
    np.testing.assert_array_equal(s.gather_all("feat")[3], [1.0, 1.0])
    stats = s.transport.stats()
    assert stats["rpc_failures"] == 5 and stats["rpc_retries"] == 5


def test_fault_injector_deterministic_and_scoped():
    a = FaultInjector(seed=42, rpc_failure_rate=0.5)
    b = FaultInjector(seed=42, rpc_failure_rate=0.5)
    assert ([a.rpc_should_fail("pull") for _ in range(64)]
            == [b.rpc_should_fail("pull") for _ in range(64)])
    # op scoping: sampler-dispatch traffic (op="data") is outside the
    # default schedule, so feature-path injection can't crash pipelines
    c = FaultInjector(seed=1, rpc_failure_rate=1.0)
    assert not c.rpc_should_fail("data")
    assert c.stats()["rpc_faults_injected"] == 0


def test_trainer_death_is_one_shot():
    inj = FaultInjector(seed=0, kill_at=(2, 5))
    inj.check_death(0, 0)
    inj.check_death(2, 4)                 # wrong coordinate: no fire
    with pytest.raises(TrainerDeath):
        inj.check_death(2, 5)
    inj.check_death(2, 5)                 # replayed coordinate: survivor
    assert inj.stats()["death_fired"]


# ---- recovery wall-clock ------------------------------------------------

@pytest.mark.slow
def test_recovery_cheaper_than_retraining(homo_ds, tmp_path):
    """Fault tolerance must pay for itself: restoring the checkpoint and
    replaying the tail of one epoch beats retraining from scratch. Best
    of 2 runs per side; a scheduling hiccup gets one retry with min-of-4
    and a 5% allowance (the test_pipeline wall-clock pattern)."""
    ds = homo_ds
    cfg = _cfg(ds, "node_classification", False)
    ck = str(tmp_path / "ck")
    inj = FaultInjector(seed=11, kill_at=(1, 2))
    victim = DistGNNTrainer(ds, cfg, _job(
        "node_classification", checkpoint_dir=ck, checkpoint_interval=2,
        fault_injector=inj))
    with pytest.raises(TrainerDeath):
        for e in range(EPOCHS):
            victim.train_epoch(e)
    victim.stop()

    def recover_once():
        t0 = time.perf_counter()
        tr = DistGNNTrainer(ds, cfg, _job("node_classification"))
        meta = tr.recover(ck)
        for e in range(meta["epoch"], EPOCHS):
            tr.train_epoch(e)
        dt = time.perf_counter() - t0
        tr.stop()
        return dt

    def retrain_once():
        t0 = time.perf_counter()
        tr = DistGNNTrainer(ds, cfg, _job("node_classification"))
        for e in range(EPOCHS):
            tr.train_epoch(e)
        dt = time.perf_counter() - t0
        tr.stop()
        return dt

    rec = min(recover_once() for _ in range(2))
    ret = min(retrain_once() for _ in range(2))
    if rec >= ret:
        rec = min(rec, *(recover_once() for _ in range(2)))
        ret = min(ret, *(retrain_once() for _ in range(2)))
        assert rec < ret * 1.05, (rec, ret)
    else:
        assert rec < ret
