"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.segment_sum.kernel import segment_sum_pallas
from repro.kernels.segment_sum.ref import segment_sum_ref
from repro.kernels.gather.kernel import gather_rows_pallas
from repro.kernels.edge_softmax.kernel import edge_softmax_pallas
from repro.kernels.edge_softmax.ref import edge_softmax_ref
from repro.kernels import (fused_edge_softmax_aggregate,
                           fused_edge_softmax_aggregate_ref,
                           fused_gather_aggregate, fused_gather_aggregate_ref)

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("e,f,n", [
    (64, 16, 8), (100, 7, 13), (512, 128, 128), (1000, 60, 77),
    (64, 256, 300), (1, 1, 1), (513, 129, 257),
])
@pytest.mark.parametrize("dtype", [np.float32])
def test_segment_sum_sweep(e, f, n, dtype):
    msg = RNG.standard_normal((e, f)).astype(dtype)
    dst = RNG.integers(0, n, e).astype(np.int32)
    mask = RNG.random(e) > 0.3
    a = segment_sum_ref(jnp.asarray(msg), jnp.asarray(dst),
                        jnp.asarray(mask), n)
    b = segment_sum_pallas(jnp.asarray(msg), jnp.asarray(dst),
                           jnp.asarray(mask), n)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_segment_sum_bf16():
    e, f, n = 256, 64, 32
    msg = (RNG.standard_normal((e, f)) / 8).astype(jnp.bfloat16)
    dst = RNG.integers(0, n, e).astype(np.int32)
    mask = np.ones(e, bool)
    a = segment_sum_ref(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n)
    b = segment_sum_pallas(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=0.1, atol=0.5)


@pytest.mark.parametrize("v,f,n", [(50, 16, 7), (200, 300, 64),
                                   (1000, 128, 1), (16, 1024, 33)])
def test_gather_sweep(v, f, n):
    t = RNG.standard_normal((v, f)).astype(np.float32)
    idx = RNG.integers(0, v, n).astype(np.int32)
    out = gather_rows_pallas(jnp.asarray(t), jnp.asarray(idx))
    np.testing.assert_array_equal(np.asarray(out), t[idx])


@pytest.mark.parametrize("e,h,n", [(100, 2, 13), (600, 4, 128), (64, 1, 200),
                                   (512, 8, 64)])
def test_edge_softmax_sweep(e, h, n):
    s = RNG.standard_normal((e, h)).astype(np.float32) * 3
    dst = RNG.integers(0, n, e).astype(np.int32)
    mask = RNG.random(e) > 0.25
    a = edge_softmax_ref(jnp.asarray(s), jnp.asarray(dst), jnp.asarray(mask), n)
    b = edge_softmax_pallas(jnp.asarray(s), jnp.asarray(dst),
                            jnp.asarray(mask), n)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # per-destination normalization
    sums = np.zeros((n, h))
    np.add.at(sums, dst[mask], np.asarray(a)[mask])
    nonempty = np.zeros(n, bool)
    nonempty[dst[mask]] = True
    np.testing.assert_allclose(sums[nonempty], 1.0, atol=1e-5)


# ---------------------------------------------------------------------------
# fused minibatch-tail kernels (ISSUE 6): Pallas interpret vs jnp oracle,
# and the oracle vs the exact gather+segment-sum composition the layers
# used to inline (the golden byte-identity anchor)
# ---------------------------------------------------------------------------

def _edges(rng, e, src_n, dst_n):
    src = rng.integers(0, src_n, e).astype(np.int32)
    dst = rng.integers(0, dst_n, e).astype(np.int32)
    mask = rng.random(e) > 0.3
    return jnp.asarray(src), jnp.asarray(dst), jnp.asarray(mask)


@pytest.mark.parametrize("e,f,src_n,dst_n", [
    (64, 16, 32, 8), (200, 33, 77, 50), (512, 128, 256, 128),
    (1, 1, 1, 1), (300, 64, 100, 1),
])
def test_fused_gather_aggregate_parity(e, f, src_n, dst_n):
    rng = np.random.default_rng(e + f)
    h = jnp.asarray(rng.standard_normal((src_n, f)).astype(np.float32))
    src, dst, mask = _edges(rng, e, src_n, dst_n)
    ref = fused_gather_aggregate(h, src, dst, mask, dst_n, impl="ref")
    pal = fused_gather_aggregate(h, src, dst, mask, dst_n, impl="pallas")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                               rtol=1e-5, atol=1e-5)
    # the oracle IS the unfused composition the layers used to inline —
    # bitwise, so the layer-level golden tests can pin parameter bytes
    unfused = segment_sum_ref(h[src], dst, mask, dst_n)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(unfused))


@pytest.mark.parametrize("e,h_heads,dh,src_n,dst_n", [
    (100, 2, 8, 40, 13), (600, 4, 8, 200, 128), (64, 1, 16, 30, 200),
])
def test_fused_edge_softmax_aggregate_parity(e, h_heads, dh, src_n, dst_n):
    rng = np.random.default_rng(e)
    hp = jnp.asarray(
        rng.standard_normal((src_n, h_heads, dh)).astype(np.float32))
    scores = jnp.asarray(
        rng.standard_normal((e, h_heads)).astype(np.float32) * 3)
    src, dst, mask = _edges(rng, e, src_n, dst_n)
    ref = fused_edge_softmax_aggregate(hp, scores, src, dst, mask, dst_n,
                                       impl="ref")
    pal = fused_edge_softmax_aggregate(hp, scores, src, dst, mask, dst_n,
                                       impl="pallas")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                               rtol=1e-5, atol=1e-5)
    # oracle == the unfused edge_softmax -> weight -> segment_sum chain
    att = edge_softmax_ref(scores, dst, mask, dst_n)
    msg = (hp[src] * att[:, :, None]).reshape(e, h_heads * dh)
    unfused = segment_sum_ref(msg, dst, mask, dst_n)
    assert ref.shape == (dst_n, h_heads * dh)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(unfused))


def test_fused_dispatch_validates_impl():
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.standard_normal((4, 2)).astype(np.float32))
    src, dst, mask = _edges(rng, 6, 4, 3)
    with pytest.raises(ValueError, match="impl"):
        fused_gather_aggregate(h, src, dst, mask, 3, impl="cuda")
    # auto resolves to the oracle off-TPU: byte-identical to impl="ref"
    np.testing.assert_array_equal(
        np.asarray(fused_gather_aggregate(h, src, dst, mask, 3)),
        np.asarray(fused_gather_aggregate(h, src, dst, mask, 3,
                                          impl="ref")))
    assert fused_gather_aggregate is not fused_gather_aggregate_ref
    assert fused_edge_softmax_aggregate is not fused_edge_softmax_aggregate_ref


@settings(max_examples=15, deadline=None)
@given(e=st.integers(1, 150), f=st.integers(1, 32), src_n=st.integers(1, 60),
       dst_n=st.integers(1, 40), seed=st.integers(0, 99))
def test_fused_gather_aggregate_property(e, f, src_n, dst_n, seed):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.standard_normal((src_n, f)).astype(np.float32))
    src, dst, mask = _edges(rng, e, src_n, dst_n)
    ref = fused_gather_aggregate(h, src, dst, mask, dst_n, impl="ref")
    pal = fused_gather_aggregate(h, src, dst, mask, dst_n, impl="pallas")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                               rtol=1e-4, atol=1e-4)
    # mass conservation: masked messages contribute nothing
    np.testing.assert_allclose(
        np.asarray(ref).sum(0),
        np.asarray(h)[np.asarray(src)][np.asarray(mask)].sum(0),
        rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(e=st.integers(1, 200), n=st.integers(1, 60), f=st.integers(1, 40),
       seed=st.integers(0, 99))
def test_segment_sum_property(e, n, f, seed):
    rng = np.random.default_rng(seed)
    msg = rng.standard_normal((e, f)).astype(np.float32)
    dst = rng.integers(0, n, e).astype(np.int32)
    mask = rng.random(e) > 0.5
    a = segment_sum_ref(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n)
    b = segment_sum_pallas(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)
    # masked-out edges contribute nothing: total mass check
    np.testing.assert_allclose(np.asarray(a).sum(0), msg[mask].sum(0),
                               rtol=1e-4, atol=1e-4)
