"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.segment_sum.kernel import segment_sum_pallas
from repro.kernels.segment_sum.ref import segment_sum_ref
from repro.kernels.gather.kernel import gather_rows_pallas
from repro.kernels.edge_softmax.kernel import edge_softmax_pallas
from repro.kernels.edge_softmax.ref import edge_softmax_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("e,f,n", [
    (64, 16, 8), (100, 7, 13), (512, 128, 128), (1000, 60, 77),
    (64, 256, 300), (1, 1, 1), (513, 129, 257),
])
@pytest.mark.parametrize("dtype", [np.float32])
def test_segment_sum_sweep(e, f, n, dtype):
    msg = RNG.standard_normal((e, f)).astype(dtype)
    dst = RNG.integers(0, n, e).astype(np.int32)
    mask = RNG.random(e) > 0.3
    a = segment_sum_ref(jnp.asarray(msg), jnp.asarray(dst),
                        jnp.asarray(mask), n)
    b = segment_sum_pallas(jnp.asarray(msg), jnp.asarray(dst),
                           jnp.asarray(mask), n)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_segment_sum_bf16():
    e, f, n = 256, 64, 32
    msg = (RNG.standard_normal((e, f)) / 8).astype(jnp.bfloat16)
    dst = RNG.integers(0, n, e).astype(np.int32)
    mask = np.ones(e, bool)
    a = segment_sum_ref(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n)
    b = segment_sum_pallas(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=0.1, atol=0.5)


@pytest.mark.parametrize("v,f,n", [(50, 16, 7), (200, 300, 64),
                                   (1000, 128, 1), (16, 1024, 33)])
def test_gather_sweep(v, f, n):
    t = RNG.standard_normal((v, f)).astype(np.float32)
    idx = RNG.integers(0, v, n).astype(np.int32)
    out = gather_rows_pallas(jnp.asarray(t), jnp.asarray(idx))
    np.testing.assert_array_equal(np.asarray(out), t[idx])


@pytest.mark.parametrize("e,h,n", [(100, 2, 13), (600, 4, 128), (64, 1, 200),
                                   (512, 8, 64)])
def test_edge_softmax_sweep(e, h, n):
    s = RNG.standard_normal((e, h)).astype(np.float32) * 3
    dst = RNG.integers(0, n, e).astype(np.int32)
    mask = RNG.random(e) > 0.25
    a = edge_softmax_ref(jnp.asarray(s), jnp.asarray(dst), jnp.asarray(mask), n)
    b = edge_softmax_pallas(jnp.asarray(s), jnp.asarray(dst),
                            jnp.asarray(mask), n)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # per-destination normalization
    sums = np.zeros((n, h))
    np.add.at(sums, dst[mask], np.asarray(a)[mask])
    nonempty = np.zeros(n, bool)
    nonempty[dst[mask]] = True
    np.testing.assert_allclose(sums[nonempty], 1.0, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(e=st.integers(1, 200), n=st.integers(1, 60), f=st.integers(1, 40),
       seed=st.integers(0, 99))
def test_segment_sum_property(e, n, f, seed):
    rng = np.random.default_rng(seed)
    msg = rng.standard_normal((e, f)).astype(np.float32)
    dst = rng.integers(0, n, e).astype(np.int32)
    mask = rng.random(e) > 0.5
    a = segment_sum_ref(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n)
    b = segment_sum_pallas(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)
    # masked-out edges contribute nothing: total mass check
    np.testing.assert_allclose(np.asarray(a).sum(0), msg[mask].sum(0),
                               rtol=1e-4, atol=1e-4)
