"""Minimal stand-in for the ``hypothesis`` API surface these tests use.

Loaded by ``conftest.py`` only when the real library is missing (offline
hosts): deterministic seeded random sampling replaces Hypothesis's guided
search and shrinking, which is enough to keep the property tests running
as randomized regression tests. CI installs the real package from
``requirements-dev.txt`` and never sees this module.

Supported surface: ``@settings(max_examples=, deadline=)``, ``@given`` with
strategy kwargs or a single positional ``st.data()``, and the strategies
``integers``, ``floats``, ``booleans``, ``lists``, ``sampled_from``,
``data`` (with ``data.draw``).
"""
from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np


class Strategy:
    def __init__(self, sample_fn, label="strategy"):
        self._sample = sample_fn
        self._label = label

    def sample(self, rng: np.random.Generator):
        return self._sample(rng)

    def __repr__(self):
        return f"<shim {self._label}>"


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)),
                    f"integers({min_value},{max_value})")


def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> Strategy:
    return Strategy(
        lambda rng: float(min_value + rng.random() * (max_value - min_value)),
        "floats")


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans")


def sampled_from(options) -> Strategy:
    options = list(options)
    return Strategy(lambda rng: options[int(rng.integers(0, len(options)))],
                    "sampled_from")


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    def sample(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.sample(rng) for _ in range(n)]
    return Strategy(sample, f"lists[{min_size},{max_size}]")


class _DataObject:
    """The object a ``st.data()`` strategy hands to the test body."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def draw(self, strategy: Strategy, label=None):
        return strategy.sample(self._rng)


class _DataStrategy(Strategy):
    def __init__(self):
        super().__init__(lambda rng: _DataObject(rng), "data")


def data() -> Strategy:
    return _DataStrategy()


_DEFAULT_MAX_EXAMPLES = 50


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            # deterministic per-test seed stream, independent of run order
            base = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = np.random.default_rng((base, i))
                pos = tuple(s.sample(rng) for s in arg_strategies)
                kws = {k: s.sample(rng) for k, s in kw_strategies.items()}
                fn(*args, *pos, **kwargs, **kws)
        # hide the wrapped signature: pytest must not treat the strategy
        # parameters as fixtures (real hypothesis does the same)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


def install() -> types.ModuleType:
    """Register this shim as ``hypothesis`` (+``hypothesis.strategies``)."""
    import sys
    hyp = types.ModuleType("hypothesis")
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "lists", "sampled_from",
                 "data"):
        setattr(strategies, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strategies
    hyp.__is_shim__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies
    return hyp
