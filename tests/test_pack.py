"""Packed one-shot device staging (DESIGN.md §9, ISSUE 6).

The contract under test: ``pack -> single device transfer -> unpack`` is
*byte-identical* to per-array ``jax.device_put`` of the same tree — same
dtypes (jax's x64 canonicalization applied host-side), same shapes, same
bytes — with ``None`` leaves restored, the arena laid out so every dtype
segment is itemsize-aligned, and the spec/offset table a pure function of
the batch's (path, shape, dtype) set.
"""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.pack import (PackSpec, PackedBatch, device_stage,
                                flatten_tree, pack, unflatten_tree, unpack)

ops = importlib.import_module("repro.kernels.pack.ops")

RNG = np.random.default_rng(11)


def _batch_tree(seed=0):
    """A MiniBatch-shaped tree covering every staged dtype family,
    including an x64 leaf (canonicalized) and None leaves."""
    rng = np.random.default_rng(seed)
    return dict(
        input_feats=rng.standard_normal((16, 32)).astype(np.float32),
        seeds=rng.integers(0, 100, 16).astype(np.int64),
        seed_mask=rng.integers(0, 2, 16).astype(bool),
        labels=None,
        blocks=[dict(edge_src=rng.integers(0, 50, 40).astype(np.int32),
                     edge_dst=rng.integers(0, 16, 40).astype(np.int32),
                     edge_mask=rng.integers(0, 2, 40).astype(bool),
                     edge_types=None),
                dict(edge_src=rng.integers(0, 50, 80).astype(np.int32),
                     edge_dst=rng.integers(0, 50, 80).astype(np.int32),
                     edge_mask=rng.integers(0, 2, 80).astype(bool),
                     edge_types=rng.integers(0, 4, 80).astype(np.int64))])


def _flat_bytes(tree):
    flat, nones = flatten_tree(jax.tree.map(np.asarray, tree))
    return ({k: (v.dtype, v.shape, v.tobytes()) for k, v in flat.items()},
            nones)


def test_roundtrip_byte_identical_to_per_array():
    tree = _batch_tree()
    staged = device_stage(tree, packed=True)
    assert isinstance(staged, PackedBatch)
    per_array = device_stage(tree, packed=False)
    assert _flat_bytes(staged.unpack()) == _flat_bytes(per_array)
    # None leaves resurface in place
    out = staged.unpack()
    assert out["labels"] is None
    assert out["blocks"][0]["edge_types"] is None
    # the staged form is ONE device buffer: the uint8 arena
    assert staged.buffers.dtype == jnp.uint8
    assert staged.buffers.shape == (staged.total_bytes(),)


def test_unpack_cached_and_getitem():
    staged = device_stage(_batch_tree(1), packed=True)
    assert staged.unpack() is staged.unpack()
    np.testing.assert_array_equal(staged["seeds"],
                                  staged.unpack()["seeds"])


def test_arena_segments_itemsize_aligned_and_disjoint():
    spec, arena = pack(_batch_tree(2))
    assert arena.dtype == np.uint8 and arena.nbytes == spec.total_bytes()
    end = 0
    seen_itemsize = None
    for dt, boff, n in spec.arena_layout:
        item = np.dtype(dt).itemsize
        assert boff % item == 0, f"segment {dt} misaligned at byte {boff}"
        assert boff == end, "segments must tile the arena with no gaps"
        end = boff + n * item
        # descending-itemsize order is what makes alignment automatic
        assert seen_itemsize is None or item <= seen_itemsize
        seen_itemsize = item
    assert end == spec.total_bytes()


def test_spec_is_pure_function_of_fields_and_cached():
    t = _batch_tree(3)
    spec_a, _ = pack(t)
    # same shapes/dtypes under a different dict insertion order -> the
    # SAME cached spec object (the lru_cache key is the sorted field set)
    reordered = dict(reversed(list(t.items())))
    spec_b, _ = pack(reordered)
    assert spec_a is spec_b
    # a different shape is a different spec
    t2 = _batch_tree(3)
    t2["input_feats"] = t2["input_feats"][:, :16].copy()
    spec_c, _ = pack(t2)
    assert spec_c is not spec_a


def test_x64_leaves_canonicalized_like_jax():
    tree = dict(a=np.arange(7, dtype=np.int64),
                b=np.linspace(0, 1, 5).astype(np.float64),
                c=np.arange(3, dtype=np.uint64))
    out = device_stage(tree, packed=True).unpack()
    ref = jax.tree.map(jax.device_put, tree)
    for k in tree:
        assert out[k].dtype == ref[k].dtype, k
        assert np.asarray(out[k]).tobytes() == np.asarray(ref[k]).tobytes()


def test_unpack_traceable_inside_outer_jit():
    """The donation path: unpack_flat must fuse into a jitted consumer."""
    tree = dict(x=RNG.standard_normal((8, 4)).astype(np.float32),
                n=RNG.integers(0, 9, 8).astype(np.int32))
    spec, arena = pack(tree)

    @jax.jit
    def consume(buf):
        flat = ops.unpack_flat(spec, buf)
        return flat["x"].sum(axis=1) + flat["n"].astype(np.float32)

    got = consume(jax.device_put(arena))
    want = tree["x"].sum(axis=1) + tree["n"].astype(np.float32)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


def test_flatten_unflatten_inverse():
    tree = _batch_tree(4)
    flat, nones = flatten_tree(tree)
    rebuilt = unflatten_tree(flat, nones)
    assert _flat_bytes(rebuilt) == _flat_bytes(tree)
    assert isinstance(rebuilt["blocks"], list) and len(rebuilt["blocks"]) == 2


_DTYPES = [np.float32, np.int32, np.int64, np.bool_, np.uint8]


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_pack_roundtrip_property(data):
    """Random trees: any mix of dtypes/shapes/Nones round-trips to the
    exact per-array staging bytes."""
    seed = data.draw(st.integers(0, 1000))
    rng = np.random.default_rng(seed)
    n_fields = data.draw(st.integers(1, 8))
    tree = {}
    for i in range(n_fields):
        kind = data.draw(st.integers(0, len(_DTYPES)))
        if kind == len(_DTYPES):
            tree[f"f{i}"] = None
            continue
        nd = data.draw(st.integers(0, 2))
        shape = tuple(data.draw(st.integers(1, 9)) for _ in range(nd))
        dt = _DTYPES[kind]
        if dt is np.bool_:
            arr = rng.integers(0, 2, shape).astype(bool)
        elif np.issubdtype(dt, np.floating):
            arr = rng.standard_normal(shape).astype(dt)
        else:
            arr = rng.integers(0, 100, shape).astype(dt)
        tree[f"f{i}"] = arr
    if all(v is None for v in tree.values()):
        tree["anchor"] = np.zeros(1, np.float32)
    staged = device_stage(tree, packed=True)
    per_array = device_stage(tree, packed=False)
    assert _flat_bytes(staged.unpack()) == _flat_bytes(per_array)
    spec = staged.spec
    assert spec.total_bytes() == sum(
        n * np.dtype(dt).itemsize for dt, _, n in spec.arena_layout)


def test_scalar_and_zero_dim_leaves():
    tree = dict(s=np.float32(2.5), z=np.array(7, dtype=np.int32))
    out = device_stage(tree, packed=True).unpack()
    assert out["s"].shape == () and float(out["s"]) == 2.5
    assert out["z"].shape == () and int(out["z"]) == 7
