"""Unit/property tests for the LM building blocks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.lm.layers import (attention, cache_update, decode_attention,
                                    rmsnorm, rope)
from repro.models.lm.ssm import ssd_chunked, ssd_decode_step

RNG = np.random.default_rng(0)


def test_rmsnorm_unit_scale():
    x = jnp.asarray(RNG.standard_normal((4, 8, 16)), jnp.float32)
    y = rmsnorm(x, jnp.ones((16,)))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_rope_preserves_norm_and_relative_position():
    d = 32
    q = jnp.asarray(RNG.standard_normal((1, 6, 2, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 6, 2, d)), jnp.float32)
    pos = jnp.arange(6)
    qr, kr = rope(q, pos, 1e4), rope(k, pos, 1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(qr), axis=-1),
                               np.linalg.norm(np.asarray(q), axis=-1),
                               rtol=1e-5)
    # relative-position property: shifting both by c leaves q·k unchanged
    qr2, kr2 = rope(q, pos + 17, 1e4), rope(k, pos + 17, 1e4)
    dot1 = np.einsum("bqhd,bkhd->bhqk", np.asarray(qr), np.asarray(kr))
    dot2 = np.einsum("bqhd,bkhd->bhqk", np.asarray(qr2), np.asarray(kr2))
    np.testing.assert_allclose(dot1, dot2, atol=1e-4)


@pytest.mark.parametrize("chunk", [3, 8, 64])
def test_attention_chunk_invariance(chunk):
    q = jnp.asarray(RNG.standard_normal((2, 17, 4, 8)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 17, 2, 8)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 17, 2, 8)), jnp.float32)
    base = attention(q, k, v, causal=True, chunk=64)
    out = attention(q, k, v, causal=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out), atol=1e-5)


def test_attention_causal_mask():
    """Changing future tokens must not change past outputs."""
    q = jnp.asarray(RNG.standard_normal((1, 8, 2, 4)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 8, 2, 4)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 8, 2, 4)), jnp.float32)
    out1 = attention(q, k, v, causal=True, chunk=4)
    k2 = k.at[:, 5:].set(99.0)
    v2 = v.at[:, 5:].set(-7.0)
    out2 = attention(q, k2, v2, causal=True, chunk=4)
    np.testing.assert_allclose(np.asarray(out1)[:, :5],
                               np.asarray(out2)[:, :5], atol=1e-5)


def test_attention_sliding_window():
    s, w = 12, 4
    q = jnp.asarray(RNG.standard_normal((1, s, 1, 4)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, s, 1, 4)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, s, 1, 4)), jnp.float32)
    out = attention(q, k, v, causal=True, window=w, chunk=4)
    # position i must ignore keys < i-w+1: perturb an old key
    k2 = k.at[:, 0].set(50.0)
    v2 = v.at[:, 0].set(50.0)
    out2 = attention(q, k2, v2, causal=True, window=w, chunk=4)
    np.testing.assert_allclose(np.asarray(out)[:, w:],
                               np.asarray(out2)[:, w:], atol=1e-5)
    # but position 0 must see it
    assert not np.allclose(np.asarray(out)[:, 0], np.asarray(out2)[:, 0])


@settings(max_examples=15, deadline=None)
@given(w=st.integers(4, 10), steps=st.integers(1, 14))
def test_ring_cache_decode_matches_window_attention(w, steps):
    """Decode through a ring cache of size w == windowed full attention."""
    d, h = 4, 2
    rng = np.random.default_rng(steps * 31 + w)
    ks = jnp.asarray(rng.standard_normal((1, steps, h, d)), jnp.float32)
    vs = jnp.asarray(rng.standard_normal((1, steps, h, d)), jnp.float32)
    qs = jnp.asarray(rng.standard_normal((1, steps, h, d)), jnp.float32)
    full = attention(qs, ks, vs, causal=True, window=w, chunk=4)
    kc = jnp.zeros((1, w, h, d))
    vc = jnp.zeros((1, w, h, d))
    for t in range(steps):
        kc, vc = cache_update(kc, vc, ks[:, t:t + 1], vs[:, t:t + 1],
                              jnp.asarray(t))
        out = decode_attention(qs[:, t:t + 1], kc, vc, jnp.asarray(t),
                               window=w)
        np.testing.assert_allclose(np.asarray(out)[0, 0],
                                   np.asarray(full)[0, t], atol=2e-5)


def test_ssd_chunked_matches_naive_recurrence():
    b, l, h, p, n = 1, 12, 2, 4, 3
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.random((b, l, h)) * 0.5 + 0.1, jnp.float32)
    a_log = jnp.asarray(rng.standard_normal((h,)) * 0.2, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    D = jnp.asarray(rng.standard_normal((h,)), jnp.float32)
    y_chunk, S_chunk = ssd_chunked(x, dt, a_log, B, C, D, chunk=5)
    # naive recurrence via the decode step
    S = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(l):
        y, S = ssd_decode_step(S, x[:, t], dt[:, t], a_log, B[:, t], C[:, t], D)
        ys.append(y)
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_chunk), np.asarray(S), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(chunk=st.integers(2, 9), l=st.integers(3, 20))
def test_ssd_chunk_size_invariance(chunk, l):
    b, h, p, n = 1, 2, 3, 2
    rng = np.random.default_rng(chunk * 100 + l)
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.random((b, l, h)) * 0.4 + 0.1, jnp.float32)
    a_log = jnp.zeros((h,))
    B = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    D = jnp.zeros((h,))
    y1, s1 = ssd_chunked(x, dt, a_log, B, C, D, chunk=chunk)
    y2, s2 = ssd_chunked(x, dt, a_log, B, C, D, chunk=l)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)
