"""Distribution-layer tests that need >1 device: run in a subprocess with
XLA_FLAGS so they don't disturb this process's 1-device jax.

Covers: sharded-MoE == local oracle (incl. non-divisible expert counts),
sharding rule derivation, mesh construction, and a mini dry-run
(lower+compile of a reduced arch on an 8-device mesh).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code],
                       env={**os.environ, "PYTHONPATH": SRC},
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    return r.stdout


def test_moe_sharded_matches_local_oracle():
    _run("""
        import repro.sharding.rules as R
        from repro.sharding import AxisRules, set_rules
        from repro.models.lm.config import LMConfig
        from repro.models.lm.moe import _moe_local, moe_block
        from repro.models.lm.model import _moe_params
        R.AXIS_SIZES.update({"data": 2, "model": 4})
        set_rules(AxisRules(batch_axes=("data",), model_axis_size=4))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for ne in (8, 6):   # divisible and padded expert counts
            cfg = LMConfig(name="t", arch_type="moe", num_layers=1,
                           d_model=32, num_heads=4, num_kv_heads=2, d_ff=0,
                           vocab_size=64, num_experts=ne, experts_per_tok=2,
                           moe_d_ff=16, dtype="float32")
            p = _moe_params(cfg, jax.random.key(0), jnp.float32)
            x = jax.random.normal(jax.random.key(1), (4, 8, 32))
            ref, _ = jax.jit(lambda p, x: _moe_local(p, x, cfg))(p, x)
            with mesh:
                out, _ = jax.jit(lambda p, x: moe_block(p, x, cfg))(p, x)
            err = float(jnp.abs(ref - out).max())
            assert err < 1e-4, (ne, err)
            def loss(p, x):
                with mesh:
                    o, a = moe_block(p, x, cfg)
                return (o ** 2).sum() + a
            g = jax.jit(jax.grad(loss))(p, x)
            assert all(np.isfinite(np.asarray(l)).all()
                       for l in jax.tree.leaves(g))
        print("OK")
    """)


def test_mini_dryrun_lowers_on_mesh():
    _run("""
        import dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.sharding.rules as R
        from repro.sharding import AxisRules, set_rules, param_pspecs
        from repro.configs import get_config, smoke_variant
        from repro.models.lm import abstract_params, make_train_step
        from repro.optim.optimizers import AdamWState
        R.AXIS_SIZES.update({"data": 2, "model": 4})
        set_rules(AxisRules(batch_axes=("data",), model_axis_size=4))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(smoke_variant(get_config("llama3-8b")),
                                  num_layers=2, remat=True)
        params_abs = abstract_params(cfg)
        ps = param_pspecs(params_abs, fsdp=True)
        sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        psh = sh(ps)
        osh = AdamWState(step=NamedSharding(mesh, P()), mu=psh, nu=psh)
        opt_abs = AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape,
                                                           jnp.float32),
                            params_abs),
            nu=jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape,
                                                           jnp.float32),
                            params_abs))
        batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32)}
        bsh = {"tokens": NamedSharding(mesh, P("data", None))}
        with mesh:
            c = jax.jit(make_train_step(cfg),
                        in_shardings=(psh, osh, bsh),
                        out_shardings=(psh, osh, None)).lower(
                            params_abs, opt_abs, batch).compile()
        assert c.cost_analysis() is not None
        print("OK", c.memory_analysis().temp_size_in_bytes)
    """)


def test_sharded_train_step_matches_single_device():
    """Numerical equivalence: one train step on the mesh == on one device."""
    _run("""
        import dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.sharding.rules as R
        from repro.sharding import AxisRules, set_rules, param_pspecs
        from repro.configs import get_config, smoke_variant
        from repro.models.lm import init_train_state, make_train_step
        R.AXIS_SIZES.update({"data": 2, "model": 4})
        set_rules(AxisRules(batch_axes=("data",), model_axis_size=4))
        cfg = dataclasses.replace(smoke_variant(get_config("qwen3-8b")),
                                  num_layers=2)
        step = make_train_step(cfg, lr=1e-3)
        params, opt = init_train_state(cfg, seed=0)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (4, 64)))}
        p1, _, m1 = jax.jit(step)(params, opt, batch)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ps = param_pspecs(params, fsdp=False)
        sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        with mesh:
            p2, _, m2 = jax.jit(step, in_shardings=(sh(ps), None, None),
                                out_shardings=(sh(ps), None, None))(
                                    params, opt, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, (
            float(m1["loss"]), float(m2["loss"]))
        d = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 5e-2, d
        print("OK", float(m1["loss"]), float(m2["loss"]))
    """)


def test_moe_a2a_dispatch_matches_local_oracle():
    _run("""
        import dataclasses
        import repro.sharding.rules as R
        from repro.sharding import AxisRules, set_rules
        from repro.models.lm.config import LMConfig
        from repro.models.lm.moe import _moe_local, moe_block
        from repro.models.lm.model import _moe_params
        R.AXIS_SIZES.update({"data": 2, "model": 4})
        set_rules(AxisRules(batch_axes=("data",), model_axis_size=4))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for ne in (8, 6):
            cfg = LMConfig(name="t", arch_type="moe", num_layers=1,
                           d_model=32, num_heads=4, num_kv_heads=2, d_ff=0,
                           vocab_size=64, num_experts=ne, experts_per_tok=2,
                           moe_d_ff=16, dtype="float32",
                           moe_dispatch="a2a", moe_capacity_factor=8.0)
            p = _moe_params(cfg, jax.random.key(0), jnp.float32)
            x = jax.random.normal(jax.random.key(1), (4, 8, 32))
            ref, _ = jax.jit(lambda p, x: _moe_local(p, x, cfg))(p, x)
            with mesh:
                out, _ = jax.jit(lambda p, x: moe_block(p, x, cfg))(p, x)
            err = float(jnp.abs(ref - out).max())
            assert err < 1e-4, (ne, err)
        print("OK")
    """)


def test_pure_fsdp_mode_lowers():
    _run("""
        import dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.sharding.rules as R
        from repro.sharding import AxisRules, set_rules, param_pspecs
        from repro.configs import get_config, smoke_variant
        from repro.models.lm import abstract_params, make_train_step
        from repro.optim.optimizers import AdamWState
        R.AXIS_SIZES.update({"data": 2, "model": 4})
        set_rules(AxisRules(batch_axes=("data", "model"), fsdp_axis=None,
                            seq_shard_activations=False, pure_fsdp=True,
                            model_axis_size=4))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(smoke_variant(get_config("llama3-8b")),
                                  num_layers=2, d_model=256)
        params_abs = abstract_params(cfg)
        ps = param_pspecs(params_abs)
        sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        psh = sh(ps)
        osh = AdamWState(step=NamedSharding(mesh, P()), mu=psh, nu=psh)
        opt_abs = AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape,
                                                           jnp.float32),
                            params_abs),
            nu=jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape,
                                                           jnp.float32),
                            params_abs))
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        bsh = {"tokens": NamedSharding(mesh, P(("data", "model"), None))}
        with mesh:
            c = jax.jit(make_train_step(cfg),
                        in_shardings=(psh, osh, bsh),
                        out_shardings=(psh, osh, None)).lower(
                            params_abs, opt_abs, batch).compile()
        print("OK", c.memory_analysis().temp_size_in_bytes)
    """)


def test_production_mesh_shapes():
    _run("""
        import os
        from repro.launch.mesh import make_production_mesh
        # 8 placeholder devices can't build 256; just validate the axis spec
        try:
            make_production_mesh()
        except Exception as e:
            assert "256" in str(e) or "devices" in str(e).lower()
        print("OK")
    """)
