"""Per-assigned-architecture smoke tests: REDUCED same-family variants
(≤3 layers, d_model ≤ 512, ≤4 experts) run one forward + one train step +
a prefill/decode consistency check on CPU, asserting shapes and no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct —
no allocation), per the harness contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.models.lm import (decode_step, forward, init_params,
                             init_train_state, make_train_step, prefill)

RNG = np.random.default_rng(0)
B, S = 2, 24


def _batch(cfg):
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)))}
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = jnp.asarray(
            RNG.standard_normal((B, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.arch_type == "audio":
        batch["encoder_embeds"] = jnp.asarray(
            RNG.standard_normal((B, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train(arch_id):
    cfg = smoke_variant(get_config(arch_id))
    batch = _batch(cfg)
    params = init_params(cfg, jax.random.key(0))
    logits, aux = forward(cfg, params, batch["tokens"],
                          image_embeds=batch.get("image_embeds"),
                          encoder_embeds=batch.get("encoder_embeds"))
    exp_s = S + (cfg.num_image_tokens if cfg.arch_type == "vlm" else 0)
    assert logits.shape == (B, exp_s, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()

    step = jax.jit(make_train_step(cfg, lr=1e-3))
    p, opt = init_train_state(cfg)
    p, opt, m = step(p, opt, batch)
    assert np.isfinite(float(m["loss"]))
    leaves = jax.tree.leaves(p)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_decode_matches_forward(arch_id):
    cfg = smoke_variant(get_config(arch_id))
    batch = _batch(cfg)
    params = init_params(cfg, jax.random.key(1))
    tokens = batch["tokens"]
    total = S + (cfg.num_image_tokens if cfg.arch_type == "vlm" else 0)
    _, cache = prefill(cfg, params, tokens, cache_len=total + 8,
                       image_embeds=batch.get("image_embeds"),
                       encoder_embeds=batch.get("encoder_embeds"))
    nxt = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, 1)))
    dec_logits, _ = decode_step(cfg, params, cache, nxt)
    ext, _ = forward(cfg, params, jnp.concatenate([tokens, nxt], 1),
                     image_embeds=batch.get("image_embeds"),
                     encoder_embeds=batch.get("encoder_embeds"))
    err = np.abs(np.asarray(dec_logits) - np.asarray(ext)[:, -1]).max()
    assert err < 5e-3, (arch_id, err)


def test_exact_assigned_hyperparameters():
    """The full configs must carry the exact assignment numbers."""
    expect = {
        "zamba2-7b": dict(num_layers=81, d_model=3584, num_heads=32,
                          num_kv_heads=32, d_ff=14336, vocab_size=32000,
                          ssm_state=64, arch_type="hybrid"),
        "qwen3-32b": dict(num_layers=64, d_model=5120, num_heads=64,
                          num_kv_heads=8, d_ff=25600, vocab_size=151936,
                          qk_norm=True, arch_type="dense"),
        "llama3-8b": dict(num_layers=32, d_model=4096, num_heads=32,
                          num_kv_heads=8, d_ff=14336, vocab_size=128256,
                          arch_type="dense"),
        "whisper-base": dict(num_layers=6, d_model=512, num_heads=8,
                             num_kv_heads=8, d_ff=2048, vocab_size=51865,
                             arch_type="audio"),
        "mamba2-2.7b": dict(num_layers=64, d_model=2560, num_heads=0,
                            d_ff=0, vocab_size=50280, ssm_state=128,
                            arch_type="ssm"),
        "granite-moe-3b-a800m": dict(num_layers=32, d_model=1536,
                                     num_heads=24, num_kv_heads=8,
                                     vocab_size=49155, num_experts=40,
                                     experts_per_tok=8, arch_type="moe"),
        "qwen2-0.5b": dict(num_layers=24, d_model=896, num_heads=14,
                           num_kv_heads=2, d_ff=4864, vocab_size=151936,
                           qkv_bias=True, arch_type="dense"),
        "qwen3-moe-235b-a22b": dict(num_layers=94, d_model=4096,
                                    num_heads=64, num_kv_heads=4,
                                    vocab_size=151936, num_experts=128,
                                    experts_per_tok=8, qk_norm=True,
                                    arch_type="moe"),
        "pixtral-12b": dict(num_layers=40, d_model=5120, num_heads=32,
                            num_kv_heads=8, d_ff=14336, vocab_size=131072,
                            arch_type="vlm"),
        "qwen3-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12288, vocab_size=151936,
                         qk_norm=True, arch_type="dense"),
    }
    for aid, fields in expect.items():
        cfg = get_config(aid)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (aid, k, getattr(cfg, k), v)
        assert cfg.citation
