"""Unit tests for the dry-run analysis tooling (HLO collective parser,
roofline model) — these guard the §Roofline methodology."""
import numpy as np
import pytest

from repro.launch.dryrun import (_split_computations,
                                 collective_bytes_from_hlo)
from repro.launch.roofline import (cache_bytes, memory_bytes, model_flops,
                                   tokens_per_step)

HLO = """\
HloModule test

%region_body (p: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
  %ag = f32[8,4]{1,0} all-gather(%x), replica_groups={}
  ROOT %t = (s32[], f32[8,4]) tuple(%i, %ag)
}

%region_cond (p: (s32[], f32[8,4])) -> pred[] {
  %c = s32[] constant(5)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,4]) -> f32[8,4] {
  %ar = f32[8,4]{1,0} all-reduce(%a), to_apply=%add
  %w = (s32[], f32[8,4]) while(%init), condition=%region_cond, body=%region_body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[8,4]{1,0} get-tuple-element(%w), index=1
}
"""


def test_split_computations():
    comps = _split_computations(HLO)
    assert set(comps) == {"region_body", "region_cond", "main"}


def test_collective_loop_multiplication():
    out = collective_bytes_from_hlo(HLO)
    # all-reduce once: 8*4*4 = 128 B; all-gather in a 5-trip loop: 5*128
    assert out["all-reduce"] == 128
    assert out["all-gather"] == 5 * 128
    assert out["total"] == 6 * 128
    assert out["count"] == 6


def test_collective_tuple_result():
    hlo = """\
ENTRY %m (a: f32[2,2]) -> f32[2,2] {
  %a2a = (f32[2,2]{1,0}, f32[2,2]{1,0}, /*index=2*/f32[2,2]{1,0}) all-to-all(%a, %b, %c)
  ROOT %r = f32[2,2]{1,0} get-tuple-element(%a2a), index=0
}
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-to-all"] == 3 * 16


def test_tokens_per_step():
    assert tokens_per_step("train_4k") == 256 * 4096
    assert tokens_per_step("decode_32k") == 128
    assert tokens_per_step("long_500k") == 1


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-2.7b",
                                  "qwen3-moe-235b-a22b"])
def test_roofline_model_terms_positive(arch):
    for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        assert model_flops(arch, shape) > 0
        assert memory_bytes(arch, shape, 256) > 0
        assert cache_bytes(arch, shape) >= 0


def test_moe_active_flops_less_than_total():
    from repro.configs import get_config
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.active_param_count() < 0.15 * cfg.param_count()
