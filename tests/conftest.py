import os
import sys

# allow `pytest tests/` from the repo root without PYTHONPATH
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Offline hosts don't have hypothesis (see requirements-dev.txt); install a
# minimal API-compatible shim so the property-test modules stay collectible.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback
    _hypothesis_fallback.install()
