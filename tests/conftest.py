import os
import sys

# allow `pytest tests/` from the repo root without PYTHONPATH
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
