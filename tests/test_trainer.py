import numpy as np
import pytest

from repro.core.kvstore import CacheConfig
from repro.graph import get_dataset
from repro.models.gnn import GNNConfig
from repro.training import DistGNNTrainer, TrainJobConfig


@pytest.fixture(scope="module")
def ds():
    return get_dataset("product-sim", scale=11)


def _cfg(ds, arch="graphsage", rels=1):
    return GNNConfig(arch=arch, in_dim=ds.feats.shape[1], hidden_dim=32,
                     num_classes=ds.num_classes, fanouts=[5, 5],
                     batch_size=32, num_rels=rels)


def test_end_to_end_training_learns(ds):
    tr = DistGNNTrainer(ds, _cfg(ds), TrainJobConfig(
        num_machines=2, trainers_per_machine=2))
    hist = [tr.train_epoch(e) for e in range(5)]
    acc = tr.evaluate(ds.val_nids)
    tr.stop()
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7
    assert acc > 0.4
    assert np.isfinite([h["loss"] for h in hist]).all()


def test_sync_and_async_same_convergence(ds):
    """The async pipeline must not change the training math, only timing."""
    accs = {}
    for sync in (True, False):
        tr = DistGNNTrainer(ds, _cfg(ds), TrainJobConfig(
            num_machines=2, trainers_per_machine=1, sync=sync,
            non_stop=not sync, seed=3))
        for e in range(4):
            m = tr.train_epoch(e)
        accs[sync] = tr.evaluate(ds.val_nids)
        tr.stop()
    assert abs(accs[True] - accs[False]) < 0.2, accs


def test_random_partition_still_correct(ds):
    tr = DistGNNTrainer(ds, _cfg(ds), TrainJobConfig(
        num_machines=2, trainers_per_machine=1, partition_method="random"))
    m0 = tr.train_epoch(0)
    m1 = tr.train_epoch(1)
    tr.stop()
    assert np.isfinite([m0["loss"], m1["loss"]]).all()


def test_metis_locality_beats_random(ds):
    """Seed locality is high for ANY method — the ID-range split (§5.6.1)
    exploits the contiguous relabeling by design. The METIS win shows up in
    sampling-dispatch and feature-pull remoteness (edge cut)."""
    locs = {}
    for method in ("metis", "random"):
        tr = DistGNNTrainer(ds, _cfg(ds), TrainJobConfig(
            num_machines=4, trainers_per_machine=1,
            partition_method=method))
        tr.train_epoch(0)
        locs[method] = tr.sampling_stats()
        tr.stop()
    assert (locs["metis"]["remote_seed_frac"]
            < locs["random"]["remote_seed_frac"] - 0.05)
    assert (locs["metis"]["transport"]["remote_bytes"]
            < locs["random"]["transport"]["remote_bytes"])


def test_rgcn_hetero_training():
    ds = get_dataset("mag-sim", scale=13)   # train_frac=0.01 needs scale
    cfg = _cfg(ds, arch="rgcn", rels=4)
    tr = DistGNNTrainer(ds, cfg, TrainJobConfig(
        num_machines=2, trainers_per_machine=1))
    assert tr.batches_per_epoch >= 1
    h = [tr.train_epoch(e)["loss"] for e in range(4)]
    tr.stop()
    assert h[-1] < h[0]


def test_cache_cuts_remote_traffic_without_changing_math():
    """ISSUE 2 acceptance: on mag-hetero with a 64 MB per-trainer budget
    the hot-vertex cache must save remote bytes and cut total remote
    traffic by >= 30% vs cache-off — with byte-identical training."""
    ds = get_dataset("mag-hetero", scale=10)
    fo = {"cites": 5, "writes": 3, "rev_writes": 2, "employs": 2}
    cfg = GNNConfig(arch="rgcn", in_dim=ds.feats.shape[1], hidden_dim=16,
                    num_classes=ds.num_classes, fanouts=[fo] * 2,
                    batch_size=8, num_rels=ds.schema.num_etypes)
    out = {}
    for tag, cache in (("off", None), ("on", CacheConfig.from_mb(64))):
        tr = DistGNNTrainer(ds, cfg, TrainJobConfig(
            num_machines=2, trainers_per_machine=1, cache=cache))
        losses = [tr.train_epoch(e)["loss"] for e in range(2)]
        stats = tr.sampling_stats()
        tr.stop()
        out[tag] = (losses, stats)
    assert out["on"][0] == out["off"][0], "cache changed the training math"
    tp_on = out["on"][1]["transport"]
    b_off = out["off"][1]["transport"]["remote_bytes"]
    assert tp_on["saved_remote_bytes"] > 0
    assert tp_on["remote_bytes"] < 0.7 * b_off, (tp_on["remote_bytes"], b_off)
    assert out["on"][1]["cache"]["hit_rate"] > 0.5


def test_zero_batches_raises():
    small = get_dataset("product-sim", scale=9)
    cfg = GNNConfig(arch="graphsage", in_dim=small.feats.shape[1],
                    hidden_dim=16, num_classes=small.num_classes,
                    fanouts=[3], batch_size=4096)
    with pytest.raises(ValueError):
        DistGNNTrainer(small, cfg, TrainJobConfig(
            num_machines=2, trainers_per_machine=1))
