"""Hot-vertex feature cache (kvstore.cache): correctness is byte-identity
with the uncached read path under every policy/budget/access pattern; the
rest is accounting (hits, saved bytes), the byte budget, admission,
eviction order, halo pre-warm, and versioned invalidation.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.kvstore import (CacheConfig, DistKVStore, FeatureCache,
                                PartitionPolicy, halo_access_counts)
from repro.core.partition import build_partitions
from repro.core.partition.multilevel import partition_graph
from repro.graph import rmat_graph

N, F = 60, 5
OFFSETS = np.array([0, 20, 45, 60])
ROW_BYTES = F * 4


def _store(seed=0):
    pol = PartitionPolicy("node", OFFSETS)
    s = DistKVStore({"node": pol})
    full = np.random.default_rng(seed).standard_normal((N, F)).astype(np.float32)
    s.init_data("feat", (F,), np.float32, "node", full_array=full)
    return s, full


def _cached_client(store, machine=0, **cfg_kw):
    cfg_kw.setdefault("budget_bytes", 1 << 20)
    cache = FeatureCache(CacheConfig(**cfg_kw), store)
    cache.register(store, "feat")
    return store.client(machine).attach_cache(cache), cache


# ---------------------------------------------------------------------------
# correctness: cached == uncached, always
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_cached_pull_byte_identical_property(data):
    policy = data.draw(st.sampled_from(["clock", "lru"]))
    budget_rows = data.draw(st.integers(1, N))
    machine = data.draw(st.integers(0, 2))
    n_pulls = data.draw(st.integers(1, 8))
    store, full = _store(seed=data.draw(st.integers(0, 50)))
    client, cache = _cached_client(store, machine, policy=policy,
                                   budget_bytes=budget_rows * ROW_BYTES)
    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    for _ in range(n_pulls):
        ids = rng.integers(0, N, size=int(rng.integers(1, 40)))
        got = client.pull("feat", ids)
        assert np.array_equal(got, full[ids])
        st_ = cache.stats()
        assert st_["used_bytes"] <= budget_rows * ROW_BYTES


def test_budget_is_respected_and_eviction_counted():
    store, full = _store()
    client, cache = _cached_client(store, budget_bytes=4 * ROW_BYTES)
    ids = np.arange(20, 45)           # 25 remote rows for machine 0
    assert np.array_equal(client.pull("feat", ids), full[ids])
    assert np.array_equal(client.pull("feat", ids), full[ids])
    st_ = cache.stats()
    assert st_["used_bytes"] <= 4 * ROW_BYTES
    assert st_["rows"]["feat"] <= 4
    assert st_["evictions"] > 0


def test_local_rows_never_cached_or_counted():
    store, full = _store()
    client, cache = _cached_client(store, machine=1)
    local = np.arange(20, 45)          # machine 1 owns [20, 45)
    client.pull("feat", local)
    client.pull("feat", local)
    st_ = cache.stats()
    assert st_["hits"] == 0 and st_["misses"] == 0
    assert st_["rows"]["feat"] == 0
    assert store.transport.stats()["saved_remote_bytes"] == 0


def test_transport_accounting_saved_bytes_match_hits():
    store, full = _store()
    client, cache = _cached_client(store)
    remote = np.array([20, 21, 45, 46, 21])
    client.pull("feat", remote)                      # all misses
    tp0 = store.transport.stats()
    client.pull("feat", remote)                      # all hits
    tp1 = store.transport.stats()
    assert tp1["cache_hits"] - tp0["cache_hits"] == len(remote)
    assert (tp1["saved_remote_bytes"] - tp0["saved_remote_bytes"]
            == len(remote) * ROW_BYTES)
    assert tp1["remote_bytes"] == tp0["remote_bytes"]
    assert 0 < tp1["remote_traffic_reduction"] <= 1


def test_admission_threshold_delays_caching():
    store, full = _store()
    client, cache = _cached_client(store, admit_after=2)
    ids = np.array([20, 45])
    client.pull("feat", ids)                        # 1st miss: not admitted
    assert cache.stats()["rows"]["feat"] == 0
    client.pull("feat", ids)                        # 2nd miss: admitted
    assert cache.stats()["rows"]["feat"] == 2
    tp0 = store.transport.stats()["remote_bytes"]
    client.pull("feat", ids)                        # now hits
    assert store.transport.stats()["remote_bytes"] == tp0


def test_lru_evicts_least_recently_used():
    store, full = _store()
    client, cache = _cached_client(store, policy="lru",
                                   budget_bytes=2 * ROW_BYTES)
    client.pull("feat", np.array([20, 21]))         # cache: {20, 21}
    client.pull("feat", np.array([20]))             # touch 20 -> LRU is 21
    client.pull("feat", np.array([22]))             # evicts 21
    tp0 = store.transport.stats()["remote_bytes"]
    client.pull("feat", np.array([20, 22]))         # both still cached
    assert store.transport.stats()["remote_bytes"] == tp0
    client.pull("feat", np.array([21]))             # 21 is gone -> refetch
    assert store.transport.stats()["remote_bytes"] == tp0 + ROW_BYTES


def test_clock_gives_second_chance():
    store, full = _store()
    client, cache = _cached_client(store, policy="clock",
                                   budget_bytes=2 * ROW_BYTES)
    client.pull("feat", np.array([20, 21]))         # both ref'd on insert? no:
    client.pull("feat", np.array([20]))             # hit sets 20's ref bit
    client.pull("feat", np.array([22]))             # hand skips 20, evicts 21
    tp0 = store.transport.stats()["remote_bytes"]
    client.pull("feat", np.array([20]))             # survived
    assert store.transport.stats()["remote_bytes"] == tp0


# ---------------------------------------------------------------------------
# pre-warm from the partition book
# ---------------------------------------------------------------------------

def test_halo_access_counts_brute_force():
    g = rmat_graph(8, edge_factor=6, seed=2)
    parts = partition_graph(g, 3, seed=0)
    book, gps = build_partitions(g, parts)
    for gp in gps:
        gids, counts = halo_access_counts(gp)
        assert len(gids) == gp.n_halo
        # brute force: count local in-edges per halo vertex
        want = {}
        for e, s in enumerate(gp.indices):
            if s >= gp.n_core:
                gid = int(gp.local2global[s])
                want[gid] = want.get(gid, 0) + 1
        got = dict(zip(gids.tolist(), counts.tolist()))
        # every halo vertex is referenced by >= 1 local edge
        assert {g_ for g_, c in got.items() if c > 0} == set(want)
        for g_, c in want.items():
            assert got[g_] == c
        assert (np.diff(counts) <= 0).all()          # hottest first
        # halo vertices are remote by construction
        assert (book.nid2part(gids) != gp.part_id).all()


def test_prewarm_fills_hottest_rows_and_saves_traffic():
    g = rmat_graph(8, edge_factor=6, seed=2)
    parts = partition_graph(g, 3, seed=0)
    book, gps = build_partitions(g, parts)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, F)).astype(np.float32)
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets)})
    store.init_data("feat", (F,), np.float32, "node",
                    full_array=feats[book.new2old_node])
    cache = FeatureCache(CacheConfig(budget_bytes=8 * ROW_BYTES,
                                     prewarm_min_count=1), store)
    cache.register(store, "feat")
    client = store.client(0).attach_cache(cache)
    gids, counts = halo_access_counts(gps[0])
    admitted = cache.warm(client, "feat", gids, counts)
    assert admitted == min(8, len(gids))
    # the hottest halo rows now hit without remote traffic
    tp0 = store.transport.stats()["remote_bytes"]
    got = client.pull("feat", gids[:admitted])
    assert np.array_equal(got, feats[book.new2old_node[gids[:admitted]]])
    assert store.transport.stats()["remote_bytes"] == tp0


def test_budget_shared_across_tensors():
    store, full = _store()
    full2 = np.arange(N * F, dtype=np.float32).reshape(N, F)
    store.init_data("feat2", (F,), np.float32, "node", full_array=full2)
    cache = FeatureCache(CacheConfig(budget_bytes=6 * ROW_BYTES), store)
    cache.register(store, "feat")
    cache.register(store, "feat2")
    client = store.client(0).attach_cache(cache)
    for _ in range(2):
        client.pull("feat", np.arange(20, 30))
        client.pull("feat2", np.arange(30, 40))
    st_ = cache.stats()
    assert st_["used_bytes"] <= 6 * ROW_BYTES
    assert sum(st_["rows"].values()) <= 6
    # both tensors keep serving exact bytes under contention
    assert np.array_equal(client.pull("feat", np.arange(20, 30)),
                          full[20:30])
    assert np.array_equal(client.pull("feat2", np.arange(30, 40)),
                          full2[30:40])


def test_late_registered_tensor_not_starved():
    """A tensor registered after the budget filled must still be able to
    grow: budget pressure evicts from the LARGEST tensor, not always from
    the inserting one."""
    store, full = _store()
    full2 = np.arange(N * F, dtype=np.float32).reshape(N, F)
    store.init_data("feat2", (F,), np.float32, "node", full_array=full2)
    cache = FeatureCache(CacheConfig(budget_bytes=8 * ROW_BYTES), store)
    cache.register(store, "feat")
    client = store.client(0).attach_cache(cache)
    client.pull("feat", np.arange(20, 28))          # budget now full
    assert cache.stats()["rows"]["feat"] == 8
    cache.register(store, "feat2")
    for _ in range(2):
        client.pull("feat2", np.arange(30, 34))
    st_ = cache.stats()
    assert st_["rows"]["feat2"] >= 3, st_["rows"]
    assert st_["used_bytes"] <= 8 * ROW_BYTES
    tp0 = store.transport.stats()["remote_bytes"]
    client.pull("feat2", np.arange(30, 34))         # hits now
    assert store.transport.stats()["remote_bytes"] == tp0


def test_prewarm_min_count_filters_unlikely_rows():
    store, full = _store()
    cache = FeatureCache(CacheConfig(budget_bytes=1 << 20,
                                     prewarm_min_count=2), store)
    cache.register(store, "feat")
    client = store.client(0).attach_cache(cache)
    gids = np.array([20, 21, 22, 45])
    counts = np.array([5, 2, 1, 1])     # count-1 rows: likely never pulled
    admitted = cache.warm(client, "feat", gids, counts)
    assert admitted == 2
    assert cache.stats()["rows"]["feat"] == 2


def test_checkpoint_restore_invalidates_cached_mutable_rows():
    """load_kvstore is a write like any other: caches must refuse their
    pre-restore copies of mutable rows (DESIGN.md §5)."""
    import tempfile

    from repro.checkpoint import load_kvstore, save_kvstore
    from repro.core.kvstore import DistEmbedding

    store = DistKVStore({"node": PartitionPolicy("node", OFFSETS)})
    emb = DistEmbedding(store, "emb", N, 4, "node", seed=0)
    cache = FeatureCache(CacheConfig(budget_bytes=1 << 20), store)
    cache.register(store, "emb")
    client = store.client(1).attach_cache(cache)
    ids = np.array([0])                  # remote to machine 1
    with tempfile.TemporaryDirectory() as tmp:
        save_kvstore(store, tmp)         # checkpoint at t0
        emb.push_grad(store.client(0), ids, np.ones((1, 4), np.float32))
        cached = client.pull("emb", ids)          # caches the post-push row
        load_kvstore(store, tmp)                  # back to t0 bytes
        assert cache.stats()["rows"]["emb"] == 0  # restore flushed entries
        restored = client.pull("emb", ids)
        assert np.array_equal(restored[0], store.gather_all("emb")[0])
        assert not np.array_equal(restored, cached)


def test_checkpoint_restore_flushes_cached_immutable_rows():
    """Restores may rewrite even immutable tensors' bytes; caches must
    not keep serving the pre-restore rows (no version table to refuse
    them — the restore flushes live caches instead)."""
    import tempfile

    from repro.checkpoint import load_kvstore, save_kvstore

    store, full = _store()
    client, cache = _cached_client(store)
    ids = np.array([20, 45])
    with tempfile.TemporaryDirectory() as tmp:
        save_kvstore(store, tmp)
        before = client.pull("feat", ids)         # cached
        # out-of-band rewrite (another run's checkpoint would do this)
        for srv in store.servers:
            srv.local_view("feat")[...] += 1.0
        load_kvstore(store, tmp)                  # restores ORIGINAL bytes
        assert cache.stats()["rows"]["feat"] == 0
        assert np.array_equal(client.pull("feat", ids), full[ids])


def test_write_to_cached_unversioned_tensor_raises():
    """Any client's write to a tensor some trainer caches without a
    version table is refused BEFORE mutating server state."""
    store, full = _store()
    client, cache = _cached_client(store, machine=0)
    client.pull("feat", np.array([20]))
    other = store.client(2)              # no cache attached at all
    for writer in (client, other):
        with pytest.raises(ValueError, match="mutable"):
            writer.push("feat", np.array([20]),
                        np.ones((1, F), np.float32))
    assert np.array_equal(store.gather_all("feat"), full)  # untouched


def test_config_validation():
    with pytest.raises(ValueError):
        CacheConfig(policy="fifo")
    with pytest.raises(ValueError):
        CacheConfig(budget_bytes=0)
    store, _ = _store()
    cache = FeatureCache(CacheConfig(budget_bytes=4), store)  # < one row
    with pytest.raises(ValueError):
        cache.register(store, "feat")
