import numpy as np
import pytest

from repro.graph import (get_dataset, list_datasets, rmat_graph, to_coo,
                         to_undirected, planted_partition_graph)


def test_rmat_basic():
    g = rmat_graph(10, edge_factor=8, seed=0)
    assert g.num_nodes == 1024
    assert g.num_edges > 1024
    src, dst = to_coo(g)
    assert (src < g.num_nodes).all() and (dst < g.num_nodes).all()
    # power-law-ish: max degree far above mean
    deg = g.out_degree()
    assert deg.max() > 10 * deg.mean()


def test_undirected_symmetry():
    g = rmat_graph(8, edge_factor=4, seed=1, undirected=True)
    src, dst = to_coo(g)
    fw = set(zip(src.tolist(), dst.tolist()))
    assert all((d, s) in fw for s, d in fw)


def test_subgraph_edges_subset():
    g = rmat_graph(9, edge_factor=6, seed=2)
    nodes = np.arange(100, 300)
    sub, pos = g.subgraph(nodes)
    assert sub.num_nodes == 200
    src, dst = to_coo(sub)
    # every subgraph edge maps to a real original edge
    osrc, odst = to_coo(g)
    orig = set(zip(osrc.tolist(), odst.tolist()))
    for s, d in zip(nodes[src].tolist(), nodes[dst].tolist()):
        assert (s, d) in orig


@pytest.mark.parametrize("name", ["product-sim", "cluster-sim"])
def test_datasets(name):
    kw = {"scale": 9} if name == "product-sim" else {"num_nodes": 1500,
                                                     "num_blocks": 8}
    ds = get_dataset(name, **kw)
    n = ds.graph.num_nodes
    assert ds.feats.shape[0] == n and ds.labels.shape == (n,)
    assert len(ds.train_nids) > 0
    assert set(np.unique(ds.split_mask)) <= {0, 1, 2, 3}
    # splits disjoint by construction of mask
    assert len(np.intersect1d(ds.train_nids, ds.val_nids)) == 0


def test_planted_partition_community_structure():
    g = planted_partition_graph(2000, 4, p_in=12, p_out=1, seed=0)
    assert g.num_edges > 2000
