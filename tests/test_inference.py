"""Online inference service + offline layer-wise pass (ISSUE 8,
DESIGN.md §11):

  * serving oracle — ``InferenceServer.predict`` returns byte-identical
    logits to an eval-mode ``NodeDataLoader`` forward over the same
    nodes (homogeneous and typed, cache on and off): serving reuses the
    eval sampling protocol via ``sample_ego_networks``, so this is a
    structural contract, not a coincidence;
  * micro-batching — concurrent requests coalesced into one stacked
    tick return the same bytes as the same requests served
    one-at-a-time (row independence of the vmapped forward);
  * offline pass — ``offline_embeddings`` matches a direct
    full-neighbor mini-batch forward on every node exactly, and its
    bytes are invariant to the layer-wise chunk size (property test);
  * robustness — concurrent requests during cache eviction and
    ``DistEmbedding.push_grad`` version bumps never observe stale rows;
    transient RPC faults mid-request retry transparently with
    byte-identical responses.
"""
import threading

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import (DistGraph, InferenceServer, NodeDataLoader,
                       offline_embeddings)
from repro.core.kvstore import (CacheConfig, DistEmbedding, FaultInjector,
                                FeatureCache)
from repro.core.sampler import (DistributedSampler, full_neighbor_fanouts,
                                sample_ego_networks)
from repro.graph import get_dataset
from repro.models.gnn import GNNConfig, apply_gnn, init_gnn

FANOUTS_TYPED = {"cites": 5, "writes": 3, "rev_writes": 2, "employs": 2}


@pytest.fixture(scope="module")
def homo_g():
    ds = get_dataset("product-sim", scale=10)
    return DistGraph(ds, num_machines=2, trainers_per_machine=1, seed=0)


@pytest.fixture(scope="module")
def hetero_g():
    ds = get_dataset("mag-hetero", scale=10)
    return DistGraph(ds, num_machines=2, trainers_per_machine=1,
                     hetero=True, seed=0)


def _cap_in_degree(g, k: int):
    """Keep at most ``k`` in-edges per node (earliest in edge order).

    mag-hetero's citation hubs reach in-degree in the hundreds, and the
    full-neighbor §2 capacities MULTIPLY across layers (cap_edge =
    cap_dst * sum(D_r)) — a two-layer full-neighbor mini-batch oracle
    over the raw graph would pad to millions of edge slots. Bounding the
    in-degree keeps that oracle exact AND small; the offline pass itself
    never needs this (its one-layer blocks scale linearly)."""
    from repro.graph.csr import CSRGraph

    dst = g.indices
    order = np.argsort(dst, kind="stable")
    sd = dst[order]
    new_run = np.r_[True, sd[1:] != sd[:-1]]
    run_start = np.maximum.accumulate(
        np.where(new_run, np.arange(len(sd)), 0))
    keep = np.zeros(len(dst), dtype=bool)
    keep[order] = (np.arange(len(sd)) - run_start) < k
    src = np.repeat(np.arange(g.num_nodes, dtype=np.int64),
                    np.diff(g.indptr))
    new_indptr = np.zeros(g.num_nodes + 1, dtype=np.int64)
    new_indptr[1:] = np.cumsum(np.bincount(src[keep],
                                           minlength=g.num_nodes))
    return CSRGraph(indptr=new_indptr, indices=g.indices[keep],
                    edge_ids=np.arange(int(keep.sum()), dtype=np.int64),
                    etypes=None if g.etypes is None else g.etypes[keep],
                    ntypes=g.ntypes, num_etypes=g.num_etypes,
                    num_ntypes=g.num_ntypes)


@pytest.fixture(scope="module")
def hetero_capped_g():
    import dataclasses as dc
    ds = get_dataset("mag-hetero", scale=7)
    ds = dc.replace(ds, graph=_cap_in_degree(ds.graph, 6))
    return DistGraph(ds, num_machines=2, trainers_per_machine=1,
                     hetero=True, seed=0)


def _model(g, hetero=False):
    if hetero:
        halved = {r: max(1, f // 2) for r, f in FANOUTS_TYPED.items()}
        cfg = GNNConfig(arch="rgcn", in_dim=g.ds.feats.shape[1],
                        hidden_dim=8, num_classes=int(g.ds.num_classes),
                        fanouts=[FANOUTS_TYPED, halved], batch_size=4,
                        num_rels=g.ds.graph.num_etypes)
    else:
        cfg = GNNConfig(arch="graphsage", in_dim=g.ds.feats.shape[1],
                        hidden_dim=8, num_classes=int(g.ds.num_classes),
                        fanouts=[3, 2], batch_size=4)
    return cfg, init_gnn(cfg, jax.random.PRNGKey(0))


def _eval_oracle(g, cfg, params, nids, sampler_seed):
    """Eval-mode loader forward: the serving ground truth."""
    loader = NodeDataLoader(g, nids, cfg.fanouts,
                            batch_size=cfg.batch_size, mode="eval",
                            sampler_seed=sampler_seed)
    etype_id = g.schema.etype_id if g.hetero else None
    out = [np.asarray(apply_gnn(cfg, params, nb.model_input(),
                                etype_id=etype_id))
           for nb in loader]
    return np.concatenate(out, axis=0)


# ---------------------------------------------------------------------------
# serving oracle: served bytes == eval-mode forward bytes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cached", [False, True], ids=["nocache", "cache"])
@pytest.mark.parametrize("kind", ["homo", "hetero"])
def test_served_matches_eval_loader(kind, cached, homo_g, hetero_g):
    g = homo_g if kind == "homo" else hetero_g
    cfg, params = _model(g, hetero=kind == "hetero")
    nids = g.node_split()[: 3 * cfg.batch_size]
    oracle = _eval_oracle(g, cfg, params, nids, sampler_seed=7)
    cache = CacheConfig(budget_bytes=1 << 20) if cached else None
    with InferenceServer(g, cfg, params, cache=cache,
                         sampler_seed=7) as srv:
        served = srv.predict(nids)
    assert served.shape == oracle.shape
    assert served.tobytes() == oracle.tobytes()


def test_single_node_requests_match_adhoc_protocol(homo_g):
    """Each 1-node request is chunk 0 of its own trace: byte-identical
    to running the shared ad-hoc protocol (``sample_ego_networks``, the
    eval loader's machinery) on just that node and applying the model
    directly."""
    g = homo_g
    cfg, params = _model(g)
    sampler = DistributedSampler(g.book, g.partitions, cfg.fanouts,
                                 cfg.batch_size, machine=g.machine,
                                 transport=None, seed=3)
    client = g.new_client()
    with InferenceServer(g, cfg, params, sampler_seed=3) as srv:
        for nid in g.node_split()[:5]:
            mb = next(sample_ego_networks(sampler, client, g.feat_name,
                                          np.array([nid]),
                                          drop_last=False))
            blocks = [dict(edge_src=b.edge_src, edge_dst=b.edge_dst,
                           edge_mask=b.edge_mask, edge_types=b.edge_types)
                      for b in mb.blocks]
            oracle = np.asarray(apply_gnn(cfg, params, dict(
                input_feats=mb.input_feats, blocks=blocks)))
            assert srv.predict([nid]).tobytes() == oracle[:1].tobytes()


def test_shared_cache_instance_and_stats(homo_g):
    """A pre-built FeatureCache can be shared with a server; stats expose
    tick occupancy and the cache counters, and reset_stats() zeroes the
    counters without dropping rows."""
    g = homo_g
    cfg, params = _model(g)
    cache = FeatureCache(CacheConfig(budget_bytes=1 << 20), g.store)
    nids = g.node_split()[: 2 * cfg.batch_size]
    oracle = _eval_oracle(g, cfg, params, nids, sampler_seed=0)
    with InferenceServer(g, cfg, params, cache=cache) as srv:
        assert srv.cache is cache
        first = srv.predict(nids)
        st0 = srv.stats()
        assert st0["requests"] == 1 and st0["ticks"] >= 1
        assert st0["cache"]["hits"] + st0["cache"]["misses"] > 0
        rows0 = st0["cache"]["rows"]
        cache.reset_stats()
        st1 = cache.stats()
        assert st1["hits"] == st1["misses"] == 0
        assert st1["rows"] == rows0          # rows survived the reset
        again = srv.predict(nids)
    assert first.tobytes() == oracle.tobytes() == again.tobytes()


# ---------------------------------------------------------------------------
# micro-batching: concurrent == sequential bytes
# ---------------------------------------------------------------------------

def test_micro_batched_equals_sequential(homo_g):
    g = homo_g
    cfg, params = _model(g)
    rng = np.random.default_rng(5)
    requests = [rng.integers(0, g.num_nodes(), size=n)
                for n in (1, 3, 4, 7, 1, 4, 2, 9)]

    # sequential ground truth: capacity-1 ticks, one request at a time
    with InferenceServer(g, cfg, params, micro_batch_capacity=1,
                         sampler_seed=0) as srv:
        seq = [srv.predict(r) for r in requests]

    # concurrent: N threads race into a wide coalescing window
    with InferenceServer(g, cfg, params, micro_batch_capacity=8,
                         micro_batch_window_ms=25.0,
                         sampler_seed=0) as srv:
        out = [None] * len(requests)

        def issue(i):
            out[i] = srv.predict(requests[i])

        threads = [threading.Thread(target=issue, args=(i,))
                   for i in range(len(requests))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = srv.stats()
    assert stats["ticks"] <= stats["chunks"]
    for got, want in zip(out, seq):
        assert got.tobytes() == want.tobytes()


@pytest.mark.slow
def test_micro_batch_window_coalesces(homo_g):
    """With pre-staged concurrent submits and a generous window, the
    scheduler packs multiple chunks per tick (wall-clock sensitive:
    best-of-2 to ride out scheduler hiccups)."""
    g = homo_g
    cfg, params = _model(g)
    requests = [np.array([i]) for i in range(8)]

    def run() -> int:
        with InferenceServer(g, cfg, params, micro_batch_capacity=8,
                             micro_batch_window_ms=200.0) as srv:
            srv.predict([0])                      # compile first
            handles = [srv.submit(r) for r in requests]
            for h in handles:
                h.result(timeout=60)
            return srv.ticks - 1                  # minus the warmup tick
    ticks = min(run() for _ in range(2))
    assert ticks < len(requests)


# ---------------------------------------------------------------------------
# offline layer-wise pass
# ---------------------------------------------------------------------------

def _direct_full_neighbor(g, cfg, params, nids, batch_size=4):
    """Oracle: ordinary mini-batch forward with full-neighbor fanouts."""
    import dataclasses
    full = full_neighbor_fanouts(g.partitions, cfg.num_layers,
                                 schema=g.schema if g.hetero else None)
    cfg_full = dataclasses.replace(cfg, fanouts=full,
                                   batch_size=batch_size)
    return _eval_oracle(g, cfg_full, params, nids, sampler_seed=0)


@pytest.mark.parametrize("kind", ["homo", "hetero"])
def test_offline_embeddings_match_minibatch_forward(kind, homo_g,
                                                    hetero_capped_g):
    g = homo_g if kind == "homo" else hetero_capped_g
    cfg, params = _model(g, hetero=kind == "hetero")
    embs = offline_embeddings(g, cfg, params, chunk_size=8,
                              prefix=f"emb_{kind}_")
    assert len(embs) == cfg.num_layers
    assert embs[-1].shape == (g.num_nodes(), cfg.num_classes)
    check = np.arange(16, dtype=np.int64)
    direct = _direct_full_neighbor(g, cfg, params, check)
    assert np.array_equal(embs[-1][check], direct)


def test_offline_embeddings_cover_every_node(homo_g):
    """drop_last=False chunking: the ragged tail chunk is still written
    back, so rows exist for ALL nodes including the last partial chunk."""
    g = homo_g
    cfg, params = _model(g)
    # chunk size that does NOT divide the node count
    embs = offline_embeddings(g, cfg, params, chunk_size=7,
                              prefix="emb_tail_")
    tail = np.arange(g.num_nodes() - 5, g.num_nodes(), dtype=np.int64)
    direct = _direct_full_neighbor(g, cfg, params,
                                   np.pad(tail, (0, 3), mode="edge"))
    assert np.array_equal(embs[-1][tail], direct[: len(tail)])


@settings(max_examples=4, deadline=None)
@given(chunk_size=st.integers(min_value=2, max_value=16))
def test_offline_chunk_size_invariance(chunk_size):
    """Embedding bytes are a function of (graph, params) only — never of
    how the layer-wise pass chunks the node set. (chunk_size=1 is
    rejected by contract: it would land the segment sum on XLA's
    small-array codepath, which reassociates floats.)"""
    w = _small_world()
    embs = offline_embeddings(w["g"], w["cfg"], w["params"],
                              chunk_size=chunk_size,
                              prefix=f"emb_c{chunk_size}_")
    all_nids = np.arange(w["g"].num_nodes(), dtype=np.int64)
    got = np.ascontiguousarray(embs[-1][all_nids])
    assert got.tobytes() == w["baseline"].tobytes()


# hypothesis @given cannot take pytest fixtures; a memoized module-level
# world is built on first use and shared read-only across examples
_SMALL: dict = {}


def _small_world() -> dict:
    if not _SMALL:
        ds = get_dataset("product-sim", scale=8)
        g = DistGraph(ds, num_machines=2, trainers_per_machine=1, seed=0)
        cfg = GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                        hidden_dim=8, num_classes=int(ds.num_classes),
                        fanouts=[3, 2], batch_size=4)
        params = init_gnn(cfg, jax.random.PRNGKey(0))
        base = offline_embeddings(g, cfg, params,
                                  chunk_size=cfg.batch_size,
                                  prefix="emb_base_")
        all_nids = np.arange(g.num_nodes(), dtype=np.int64)
        _SMALL.update(g=g, cfg=cfg, params=params,
                      baseline=np.ascontiguousarray(base[-1][all_nids]))
    return _SMALL


# ---------------------------------------------------------------------------
# robustness: eviction + version bumps + transient faults
# ---------------------------------------------------------------------------

def test_concurrent_serving_never_observes_stale_rows(homo_g):
    """N reader threads issue predicts through a TINY cache (constant
    eviction churn) while a writer bumps a mutable embedding tensor
    registered in the SAME cache: served bytes stay byte-identical to
    the quiescent oracle, and embedding reads are never torn and never
    go backwards (version-checked rows, DESIGN.md §5)."""
    g = homo_g
    cfg, params = _model(g)
    emb_dim = 4
    store = g.store
    if "serve_emb" not in store.tensor_names():
        store.init_data("serve_emb", (emb_dim,), np.float32, "node",
                        mutable=True)
    writer_client = g.new_client()
    n_versions = 30
    ids = np.arange(0, g.num_nodes(), 7, dtype=np.int64)

    # tiny budget => continuous admission/eviction churn under load
    cache = FeatureCache(CacheConfig(budget_bytes=8192, admit_after=1),
                         store)
    cache.register(store, g.feat_name)
    cache.register(store, "serve_emb")

    rng = np.random.default_rng(11)
    requests = [rng.integers(0, g.num_nodes(), size=4) for _ in range(12)]
    with InferenceServer(g, cfg, params, sampler_seed=1) as quiet:
        oracle = [quiet.predict(r) for r in requests]

    errors = []

    def writer():
        v = np.zeros((len(ids), emb_dim), np.float32)
        for version in range(1, n_versions + 1):
            v[:] = version
            writer_client.push("serve_emb", ids, v, reduce="assign")

    def reader(idx):
        try:
            client = g.new_client().attach_cache(cache)
            last = 0.0
            with_srv = readers_srv[idx]
            for rep in range(3):
                for i, req in enumerate(requests):
                    got = with_srv.predict(req)
                    assert got.tobytes() == oracle[i].tobytes()
                rows = client.pull("serve_emb", ids[:8])
                # never torn: a row is one version end to end
                assert (rows == rows[:, :1]).all()
                # never stale: versions only move forward
                assert rows.max() >= last
                last = rows.max()
        except BaseException as e:       # surfaced after join
            errors.append(e)

    n_readers = 3
    readers_srv = [InferenceServer(g, cfg, params, cache=cache,
                                   sampler_seed=1)
                   for _ in range(n_readers)]
    try:
        threads = [threading.Thread(target=reader, args=(i,))
                   for i in range(n_readers)]
        wt = threading.Thread(target=writer)
        for t in threads + [wt]:
            t.start()
        for t in threads + [wt]:
            t.join()
    finally:
        for srv in readers_srv:
            srv.close()
    assert not errors, errors[0]
    # final read sees the final version exactly
    final = g.new_client().attach_cache(cache).pull("serve_emb", ids[:4])
    assert (final == n_versions).all()


def test_rpc_fault_mid_request_retries_transparently(homo_g):
    """A transient pull fault injected mid-request is retried inside the
    KVStore client: the response bytes are identical and the only trace
    is retry accounting on the transport."""
    g = homo_g
    cfg, params = _model(g)
    nids = g.node_split()[: 2 * cfg.batch_size]
    with InferenceServer(g, cfg, params, sampler_seed=2) as srv:
        clean = srv.predict(nids)
    before = g.transport.stats()["rpc_failures"]
    g.transport.fault_injector = FaultInjector(
        seed=13, rpc_failure_rate=0.4, ops=("pull",),
        max_rpc_failures=6)
    try:
        with InferenceServer(g, cfg, params, sampler_seed=2) as srv:
            faulted = srv.predict(nids)
    finally:
        g.transport.fault_injector = None
    stats = g.transport.stats()
    assert stats["rpc_failures"] > before       # faults really fired
    assert stats["rpc_retries"] >= stats["rpc_failures"] - before
    assert faulted.tobytes() == clean.tobytes()


def test_server_lifecycle_and_errors(homo_g):
    g = homo_g
    cfg, params = _model(g)
    srv = InferenceServer(g, cfg, params)
    with pytest.raises(ValueError):
        srv.submit([])
    srv.close()
    with pytest.raises(RuntimeError):
        srv.submit([0])
    with pytest.raises(ValueError):
        InferenceServer(g, cfg, params, micro_batch_capacity=0)
    with pytest.raises(ValueError):
        offline_embeddings(g, cfg, params, chunk_size=1)
