import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import get_dataset, rmat_graph, planted_partition_graph, to_coo
from repro.core.partition import (balance_report, build_partitions, edge_cut,
                                  halo_stats, hierarchical_partition,
                                  locality_report, make_constraints,
                                  partition_graph, random_partition,
                                  split_training_set)


@pytest.fixture(scope="module")
def ds():
    return get_dataset("product-sim", scale=11)


def test_partition_beats_random_on_clustered():
    g = planted_partition_graph(4000, 16, seed=1)
    parts = partition_graph(g, 8, seed=0)
    rand = random_partition(g, 8, seed=0)
    assert edge_cut(g, parts) < 0.5 * edge_cut(g, rand)


def test_multiconstraint_balance(ds):
    vw = make_constraints(ds.graph, ds.split_mask)
    parts = partition_graph(ds.graph, 4, vwgts=vw, seed=0)
    rep = balance_report(ds.graph, parts, vw)
    # vertices / edges / train nodes all within 1.6x of ideal on power-law
    assert (rep[:3] < 1.6).all(), rep


def test_every_node_exactly_one_core_partition(ds):
    parts = partition_graph(ds.graph, 4, seed=0)
    book, gps = build_partitions(ds.graph, parts)
    assert sum(p.n_core for p in gps) == ds.graph.num_nodes
    assert book.node_offsets[-1] == ds.graph.num_nodes
    # contiguous, disjoint ranges
    assert (np.diff(book.node_offsets) >= 0).all()


def test_every_edge_exactly_once_with_halo(ds):
    g = ds.graph
    parts = partition_graph(g, 4, seed=0)
    book, gps = build_partitions(g, parts)
    assert sum(p.num_local_edges for p in gps) == g.num_edges
    # reconstruct edge set in new-id space
    src_old, dst_old = to_coo(g)
    orig = set(zip(book.old2new_node[src_old].tolist(),
                   book.old2new_node[dst_old].tolist()))
    recon = set()
    for p in gps:
        lo = book.node_offsets[p.part_id]
        dst_loc = np.repeat(np.arange(p.n_core), np.diff(p.indptr))
        recon.update(zip(p.local2global[p.indices].tolist(),
                         (dst_loc + lo).tolist()))
    assert recon == orig


def test_id_lookup_roundtrip(ds):
    parts = partition_graph(ds.graph, 4, seed=0)
    book, _ = build_partitions(ds.graph, parts)
    nids = np.arange(ds.graph.num_nodes, dtype=np.int64)
    p = book.nid2part(nids)
    loc = book.nid2local(nids, p)
    assert (book.node_offsets[p] + loc == nids).all()


def test_training_split_equal_counts_and_disjoint(ds):
    hp = hierarchical_partition(ds.graph, 4, 2, split_mask=ds.split_mask,
                                seed=0)
    train_new = hp.book.old2new_node[ds.train_nids]
    seeds = split_training_set(hp, train_new)
    assert len(seeds) == 8
    assert len({len(s) for s in seeds}) == 1            # sync-SGD equal count
    allseeds = np.concatenate(seeds)
    assert len(np.unique(allseeds)) == len(allseeds)    # disjoint
    assert set(allseeds.tolist()) <= set(train_new.tolist())
    rep = locality_report(hp, seeds)
    # METIS split should localize far more than the 1/4 random expectation
    assert rep["mean_local_frac"] > 0.5


def test_id_range_split_localizes_even_random_partitions(ds):
    """§5.6.1: the contiguous relabeling makes the ID-range split assign
    mostly-local seeds for ANY partitioning — including random. (The METIS
    win is in neighbor/feature locality, asserted in test_trainer.)"""
    hp = hierarchical_partition(ds.graph, 4, 1, split_mask=ds.split_mask,
                                method="random", seed=0)
    train_new = hp.book.old2new_node[ds.train_nids]
    seeds = split_training_set(hp, train_new)
    rep = locality_report(hp, seeds)
    assert rep["mean_local_frac"] > 0.5


def test_halo_stats(ds):
    parts = partition_graph(ds.graph, 4, seed=0)
    _, gps = build_partitions(ds.graph, parts)
    st_ = halo_stats(gps)
    assert st_["halo"] > 0 and st_["core"] == ds.graph.num_nodes


@settings(max_examples=20, deadline=None)
@given(n=st.integers(20, 300), k=st.integers(2, 6), seed=st.integers(0, 5))
def test_partition_property_total_and_range(n, k, seed):
    g = rmat_graph(5, edge_factor=3, seed=seed)  # 32 nodes
    parts = partition_graph(g, k, seed=seed)
    assert parts.shape == (g.num_nodes,)
    assert parts.min() >= 0 and parts.max() < k
