"""DistEmbedding's sharded sparse-Adam against a dense NumPy oracle
(ISSUE 2): the distributed row-sparse update, split across KVStore
servers, must be indistinguishable from a single-machine dense Adam that
touches the same rows — touched rows identical, untouched rows
bit-identical — and must stay visible through the hot-vertex cache.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.kvstore import (CacheConfig, DistEmbedding, DistKVStore,
                                FeatureCache, PartitionPolicy,
                                SparseAdamConfig)

NUM, DIM = 40, 4
OFFSETS = np.array([0, 10, 25, 40])


class DenseAdamOracle:
    """Single-table row-sparse Adam, the exact update DistEmbedding's
    servers apply shard-by-shard (same float32 expressions, same
    duplicate-coalescing), on one dense table."""

    def __init__(self, w0: np.ndarray, cfg: SparseAdamConfig):
        self.w = w0.copy()
        self.m = np.zeros_like(w0, dtype=np.float32)
        self.v = np.zeros_like(w0, dtype=np.float32)
        self.t = np.zeros(len(w0), dtype=np.int64)
        self.cfg = cfg

    def push(self, ids: np.ndarray, grad: np.ndarray) -> None:
        ids = np.asarray(ids, dtype=np.int64)
        uniq, inv = np.unique(ids, return_inverse=True)
        g = np.zeros((len(uniq), grad.shape[1]), dtype=np.float32)
        np.add.at(g, inv, grad.astype(np.float32))
        cfg, rows = self.cfg, uniq
        self.t[rows] += 1
        tr = self.t[rows].astype(np.float32)[:, None]
        self.m[rows] = cfg.beta1 * self.m[rows] + (1 - cfg.beta1) * g
        self.v[rows] = cfg.beta2 * self.v[rows] + (1 - cfg.beta2) * g * g
        mhat = self.m[rows] / (1 - cfg.beta1 ** tr)
        vhat = self.v[rows] / (1 - cfg.beta2 ** tr)
        self.w[rows] -= (cfg.lr * mhat / (np.sqrt(vhat) + cfg.eps)
                         ).astype(self.w.dtype)


def _world(seed=0, impl="auto"):
    store = DistKVStore({"node": PartitionPolicy("node", OFFSETS)})
    emb = DistEmbedding(store, "emb", NUM, DIM, "node", seed=seed,
                        impl=impl)
    oracle = DenseAdamOracle(store.gather_all("emb"), emb.optim)
    return store, emb, oracle


def _push_seq(rng, steps):
    for _ in range(steps):
        n = int(rng.integers(1, 12))
        ids = rng.integers(0, NUM, size=n)
        yield ids, rng.standard_normal((n, DIM)).astype(np.float32)


@pytest.mark.parametrize("impl", ["auto", "ref", "pallas"])
def test_sparse_adam_matches_dense_oracle_bitwise(impl):
    store, emb, oracle = _world(impl=impl)
    client = store.client(0)
    rng = np.random.default_rng(7)
    touched = set()
    for ids, grad in _push_seq(rng, steps=25):
        emb.push_grad(client, ids, grad)
        oracle.push(ids, grad)
        touched.update(ids.tolist())
    got = store.gather_all("emb")
    assert np.array_equal(got, oracle.w), "tables diverged from the oracle"
    assert np.array_equal(store.gather_all("emb__m"), oracle.m)
    assert np.array_equal(store.gather_all("emb__v"), oracle.v)
    assert np.array_equal(store.gather_all("emb__t"), oracle.t)
    untouched = sorted(set(range(NUM)) - touched)
    if untouched:   # never-pushed rows: no drift whatsoever
        assert (oracle.t[untouched] == 0).all()
        assert np.array_equal(got[untouched], oracle.w[untouched])


def test_untouched_rows_bit_identical_to_init():
    store, emb, oracle = _world(seed=3)
    w0 = store.gather_all("emb").copy()
    client = store.client(1)
    ids = np.array([2, 11, 11, 38])
    emb.push_grad(client, ids, np.ones((4, DIM), np.float32))
    got = store.gather_all("emb")
    untouched = np.setdiff1d(np.arange(NUM), ids)
    assert np.array_equal(got[untouched], w0[untouched])
    assert not np.array_equal(got[np.unique(ids)], w0[np.unique(ids)])


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_sparse_adam_oracle_property(data):
    seed = data.draw(st.integers(0, 100))
    steps = data.draw(st.integers(1, 10))
    machine = data.draw(st.integers(0, 2))
    store, emb, oracle = _world(seed=seed)
    client = store.client(machine)
    rng = np.random.default_rng(seed + 1)
    for ids, grad in _push_seq(rng, steps):
        emb.push_grad(client, ids, grad)
        oracle.push(ids, grad)
    assert np.array_equal(store.gather_all("emb"), oracle.w)


def test_cached_pull_after_push_sees_updated_rows():
    """The cache-interaction contract: a pull AFTER a push must return the
    post-update row, whether the pushing client shares the cache (eager
    invalidation) or not (version refusal)."""
    for pusher_machine in (0, 1):       # 1 == the caching client itself
        store, emb, oracle = _world()
        cache = FeatureCache(CacheConfig(budget_bytes=1 << 20), store)
        cache.register(store, "emb")
        reader = store.client(1).attach_cache(cache)
        pusher = store.client(pusher_machine)
        if pusher_machine == 1:
            pusher.attach_cache(cache)
        ids = np.array([0, 5, 30])      # all remote to machine 1
        before = reader.pull("emb", ids)          # populates the cache
        assert np.array_equal(reader.pull("emb", ids), before)  # hit path
        grad = np.full((3, DIM), 2.0, np.float32)
        emb.push_grad(pusher, ids, grad)
        oracle.push(ids, grad)
        after = reader.pull("emb", ids)
        assert np.array_equal(after, oracle.w[ids]), "stale cached rows!"
        assert not np.array_equal(after, before)
        # and the refreshed rows are served from cache again afterwards
        tp0 = store.transport.stats()["remote_bytes"]
        assert np.array_equal(reader.pull("emb", ids), after)
        assert store.transport.stats()["remote_bytes"] == tp0
