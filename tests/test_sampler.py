import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import get_dataset, to_coo
from repro.core.partition import hierarchical_partition
from repro.core.sampler import (DistributedSampler, capacities,
                                to_block_device, to_block_reference)


@pytest.fixture(scope="module")
def world():
    ds = get_dataset("product-sim", scale=10)
    hp = hierarchical_partition(ds.graph, 4, 1, split_mask=ds.split_mask,
                                seed=0)
    return ds, hp


def test_capacities_shape():
    caps = capacities(32, [10, 5])
    # input-layer first; target layer last
    assert caps[-1] == (32 + 32 * 5, 32 * 5)
    assert caps[0][0] == caps[-1][0] + caps[-1][0] * 10


def test_minibatch_invariants(world):
    ds, hp = world
    book = hp.book
    train_new = book.old2new_node[ds.train_nids]
    s = DistributedSampler(book, hp.partitions, [10, 5], 64, machine=0, seed=0)
    seeds = train_new[:64]
    mb = s.sample(seeds)
    # dst prefix rule across layers
    b0, b1 = mb.blocks
    assert np.array_equal(b1.src_gids[:64], seeds)
    assert np.array_equal(b0.src_gids[:b1.num_src], b1.src_gids[:b1.num_src])
    for b in mb.blocks:
        if b.num_edges:
            assert b.edge_src[:b.num_edges].max() < b.num_src
            assert b.edge_dst[:b.num_edges].max() < b.num_dst
        assert not b.edge_mask[b.num_edges:].any()


def test_sampled_edges_are_real(world):
    ds, hp = world
    book = hp.book
    src_old, dst_old = to_coo(ds.graph)
    es = set(zip(book.old2new_node[src_old].tolist(),
                 book.old2new_node[dst_old].tolist()))
    s = DistributedSampler(book, hp.partitions, [5], 32, machine=0, seed=1)
    seeds = book.old2new_node[ds.train_nids[:32]]
    mb = s.sample(seeds)
    b = mb.blocks[0]
    for i in range(b.num_edges):
        sg = int(b.src_gids[b.edge_src[i]])
        dg = int(b.src_gids[b.edge_dst[i]])
        assert (sg, dg) in es


def test_fanout_respected(world):
    ds, hp = world
    book = hp.book
    fanout = 7
    s = DistributedSampler(book, hp.partitions, [fanout], 32, machine=0,
                           seed=2)
    seeds = book.old2new_node[ds.train_nids[:32]]
    mb = s.sample(seeds)
    b = mb.blocks[0]
    counts = np.bincount(b.edge_dst[:b.num_edges], minlength=32)
    assert counts.max() <= fanout
    # per-seed neighbor draws unique (sampling w/o replacement)
    for d in range(32):
        nbrs = b.edge_src[:b.num_edges][b.edge_dst[:b.num_edges] == d]
        assert len(set(nbrs.tolist())) == len(nbrs)


def test_sampling_unbiasedness_hub(world):
    """A hub's neighbors should be drawn ~uniformly."""
    ds, hp = world
    book = hp.book
    g = ds.graph
    # pick the max in-degree node (new id space): use reverse degrees
    rev = g.reverse()
    hub_old = int(np.argmax(np.diff(rev.indptr)))
    deg = int(np.diff(rev.indptr)[hub_old])
    if deg < 20:
        pytest.skip("no hub")
    hub_new = int(book.old2new_node[hub_old])
    s = DistributedSampler(book, hp.partitions, [5], 1, machine=0, seed=3)
    counts = {}
    for _ in range(300):
        mb = s.sample(np.array([hub_new]))
        b = mb.blocks[0]
        for i in range(b.num_edges):
            counts[int(b.src_gids[b.edge_src[i]])] = counts.get(
                int(b.src_gids[b.edge_src[i]]), 0) + 1
    # coverage: many distinct neighbors seen
    assert len(counts) > min(deg, 5 * 30) * 0.5


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_to_block_device_matches_reference(data):
    rng_seed = data.draw(st.integers(0, 1000))
    rng = np.random.default_rng(rng_seed)
    n_seed = data.draw(st.integers(1, 8))
    n_edge = data.draw(st.integers(1, 32))
    seed_g = rng.integers(0, 50, n_seed).astype(np.int64)
    seed_g = np.unique(seed_g)  # seeds are unique in real batches
    n_seed = len(seed_g)
    seed_m = np.ones(n_seed, bool)
    eg = rng.integers(0, 50, n_edge).astype(np.int64)
    em = rng.random(n_edge) > 0.2
    cap = n_seed + n_edge
    u_r, n_r, es_r = to_block_reference(seed_g, seed_m, eg, em, cap)
    u_d, n_d, es_d = to_block_device(seed_g, seed_m, eg, em, cap_src=cap)
    assert n_r == int(n_d)
    assert np.array_equal(u_r[:n_r], np.asarray(u_d)[:n_r])
    assert np.array_equal(es_r[em], np.asarray(es_d)[em])
