"""Checkpoint layer: save/load round-trips must be bitwise, and restores
must be strict — a checkpoint that does not match its template raises
instead of silently coercing (DESIGN.md §10)."""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import (load_cache, load_kvstore, load_pytree,
                              save_cache, save_kvstore, save_pytree)
from repro.core.kvstore import (CacheConfig, DistEmbedding, DistKVStore,
                                FeatureCache, PartitionPolicy)


# ---- pytree round-trips -------------------------------------------------

def _tree(rng):
    """One pytree spanning the dtypes a train state actually holds."""
    return {
        "w": rng.standard_normal((4, 3)).astype(np.float32),
        "step": np.int64(7),
        "mask": rng.random(5) > 0.5,                       # bool leaf
        "acc": rng.standard_normal(6).astype(np.float64),  # x64 leaf
        "nested": [rng.standard_normal(2).astype(np.float32),
                   np.arange(3, dtype=np.int32)],
    }


def test_pytree_roundtrip_bitwise(tmp_path):
    rng = np.random.default_rng(0)
    tree = _tree(rng)
    save_pytree(tree, str(tmp_path))
    other = _tree(np.random.default_rng(1))    # template: same structure,
    out = load_pytree(other, str(tmp_path))    # different values
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype
        assert a.tobytes() == b.tobytes()      # bitwise, not allclose


def test_pytree_dtype_mismatch_raises(tmp_path):
    save_pytree({"w": np.ones(3, np.float64)}, str(tmp_path))
    with pytest.raises(ValueError, match="dtype"):
        load_pytree({"w": np.ones(3, np.float32)}, str(tmp_path))


def test_pytree_explicit_cast_coerces(tmp_path):
    save_pytree({"w": np.arange(3, dtype=np.float64) + 0.5}, str(tmp_path))
    out = load_pytree({"w": np.zeros(3, np.float32)}, str(tmp_path),
                      cast=True)
    assert out["w"].dtype == np.float32
    np.testing.assert_allclose(out["w"], [0.5, 1.5, 2.5])


def test_pytree_shape_mismatch_raises_even_with_cast(tmp_path):
    save_pytree({"w": np.ones((2, 3), np.float32)}, str(tmp_path))
    with pytest.raises(ValueError, match="shape"):
        load_pytree({"w": np.ones((3, 2), np.float32)}, str(tmp_path),
                    cast=True)


def test_pytree_missing_leaf_raises(tmp_path):
    save_pytree({"a": np.ones(2, np.float32)}, str(tmp_path))
    with pytest.raises(KeyError, match="missing"):
        load_pytree({"a": np.ones(2, np.float32),
                     "b": np.ones(2, np.float32)}, str(tmp_path))


def test_pytree_extra_leaf_raises(tmp_path):
    save_pytree({"a": np.ones(2, np.float32),
                 "b": np.ones(2, np.float32)}, str(tmp_path))
    with pytest.raises(KeyError, match="leaves the template"):
        load_pytree({"a": np.ones(2, np.float32)}, str(tmp_path))


def test_pytree_corrupt_manifest_raises(tmp_path):
    save_pytree({"a": np.ones(2, np.float32)}, str(tmp_path))
    with open(os.path.join(str(tmp_path), "manifest.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(ValueError):   # json.JSONDecodeError is a ValueError
        load_pytree({"a": np.ones(2, np.float32)}, str(tmp_path))
    assert issubclass(json.JSONDecodeError, ValueError)


# ---- KVStore shards + row versions --------------------------------------

@pytest.fixture
def world():
    pol = PartitionPolicy("node", np.array([0, 10, 25, 40]))
    s = DistKVStore({"node": pol})
    full = np.arange(40 * 3, dtype=np.float32).reshape(40, 3)
    s.init_data("feat", (3,), np.float32, "node", full_array=full)
    emb = DistEmbedding(s, "emb", 40, 4, "node", seed=3)
    return s, emb


def test_kvstore_roundtrip_with_versions(tmp_path, world):
    s, emb = world
    c = s.client(0)
    # advance the mutable table so versions are non-trivial
    emb.push_grad(c, np.array([1, 17, 30]), np.ones((3, 4), np.float32))
    w_ref = s.gather_all("emb").copy()
    f_ref = s.gather_all("feat").copy()
    v_ref = s.version_table("emb").copy()
    assert v_ref.max() > 0
    save_kvstore(s, str(tmp_path))

    # diverge: more pushes + a feature overwrite
    emb.push_grad(c, np.array([1, 5]), np.ones((2, 4), np.float32))
    c.push("feat", np.array([0]), np.full((1, 3), -9, np.float32),
           reduce="assign")
    assert not np.array_equal(s.version_table("emb"), v_ref)

    load_kvstore(s, str(tmp_path))
    assert s.gather_all("emb").tobytes() == w_ref.tobytes()
    assert s.gather_all("feat").tobytes() == f_ref.tobytes()
    # versions restore EXACTLY (not bumped past) — the cache-snapshot
    # validity contract (DESIGN.md §10)
    assert np.array_equal(s.version_table("emb"), v_ref)
    # optimizer state rides along with the shards
    assert int(s.servers[0].local_view("emb__t")[1]) == 1


def test_kvstore_restore_flushes_live_caches(tmp_path, world):
    s, emb = world
    cache = FeatureCache(CacheConfig.from_mb(1.0), store=s)
    cache.register(s, "feat")
    save_kvstore(s, str(tmp_path))
    rows = s.client(0).pull("feat", np.array([30, 31]))
    cache.insert("feat", np.array([30, 31]), rows, force=True)
    assert cache.lookup("feat", np.array([30]))[0].all()
    load_kvstore(s, str(tmp_path))   # a restore is a write like any other
    hit, _ = cache.lookup("feat", np.array([30, 31]))
    assert not hit.any()


# ---- FeatureCache snapshots ---------------------------------------------

def test_cache_state_roundtrip(tmp_path, world):
    s, emb = world
    c = s.client(0)
    emb.push_grad(c, np.array([2, 12]), np.ones((2, 4), np.float32))

    cache = FeatureCache(CacheConfig.from_mb(1.0), store=s)
    cache.register(s, "feat")
    cache.register(s, "emb")
    f_ids = np.array([11, 26, 35])
    e_ids = np.array([2, 12, 33])
    cache.insert("feat", f_ids, c.pull("feat", f_ids), force=True)
    cache.insert("emb", e_ids, c.pull("emb", e_ids), force=True)
    kv_dir, cache_dir = str(tmp_path / "kv"), str(tmp_path / "cache")
    save_kvstore(s, kv_dir)
    save_cache(cache, cache_dir)
    f_rows = cache.lookup("feat", f_ids)[1].copy()
    e_rows = cache.lookup("emb", e_ids)[1].copy()

    # a fresh trainer's empty cache, restored from the paired checkpoint
    cache2 = FeatureCache(CacheConfig.from_mb(1.0), store=s)
    cache2.register(s, "feat")
    cache2.register(s, "emb")
    load_kvstore(s, kv_dir)          # restores the version tables first
    admitted = load_cache(cache2, cache_dir)
    assert admitted == 6
    hit_f, rows_f = cache2.lookup("feat", f_ids)
    hit_e, rows_e = cache2.lookup("emb", e_ids)
    assert hit_f.all() and hit_e.all()
    assert rows_f.tobytes() == f_rows.tobytes()
    assert rows_e.tobytes() == e_rows.tobytes()


def test_cache_snapshot_refused_when_versions_moved(tmp_path, world):
    """A snapshot paired with checkpoint T must not be admitted against a
    store whose rows moved past T — stale rows are refused per-row."""
    s, emb = world
    c = s.client(0)
    cache = FeatureCache(CacheConfig.from_mb(1.0), store=s)
    cache.register(s, "emb")
    ids = np.array([4, 21])
    cache.insert("emb", ids, c.pull("emb", ids), force=True)
    cache_dir = str(tmp_path / "cache")
    save_cache(cache, cache_dir)

    # the store moves on WITHOUT a matching kvstore restore
    emb.push_grad(c, np.array([4]), np.ones((1, 4), np.float32))
    cache2 = FeatureCache(CacheConfig.from_mb(1.0), store=s)
    cache2.register(s, "emb")
    admitted = load_cache(cache2, cache_dir)
    assert admitted == 1             # row 21 still valid, row 4 refused
    hit, _ = cache2.lookup("emb", ids)
    assert hit.tolist() == [False, True]
