"""Link-prediction workload: edge mini-batches through the full stack.

Guards (ISSUE 3 acceptance):
  * dense NumPy MRR/Hits@k oracle agrees BITWISE with the jitted scoring
    head (integer-valued embeddings make f32 arithmetic exact);
  * edge batches are byte-identical cache-on vs cache-off on both the
    homogeneous and the typed path (negatives included);
  * negative-sampler property: no false negatives against the positive
    batch when exclusion is enabled, static (B, K) shapes always;
  * the async edge pipeline produces the same bytes as the sync baseline;
  * end-to-end: the trainer learns, on both tasks' datasets.
"""
import hashlib

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.kvstore import (CacheConfig, DistKVStore, FeatureCache,
                                PartitionPolicy, halo_access_counts)
from repro.core.partition import build_typed_partition, hierarchical_partition
from repro.core.pipeline import EdgeMinibatchPipeline
from repro.core.sampler import (DistributedSampler, EdgeBatchSampler,
                                NegativeSampler, edge_endpoints)
from repro.graph import get_dataset
from repro.models.gnn import (GNNConfig, init_lp_head, lp_metrics,
                              lp_pair_scores, lp_ranks)
from repro.training import DistGNNTrainer, TrainJobConfig

FANOUTS = {"cites": 4, "writes": 3, "rev_writes": 2, "employs": 2}


@pytest.fixture(scope="module")
def homo_world():
    ds = get_dataset("product-sim", scale=9)
    hp = hierarchical_partition(ds.graph, 2, 1, split_mask=ds.split_mask,
                                seed=0)
    return ds, hp


@pytest.fixture(scope="module")
def hetero_world():
    ds = get_dataset("mag-hetero", scale=9)
    hp = hierarchical_partition(ds.graph, 2, 1, split_mask=ds.split_mask,
                                seed=0)
    typed = build_typed_partition(
        hp.book, ds.schema, ds.graph.ntypes[hp.book.new2old_node],
        ds.graph.etypes[hp.book.new2old_edge])
    return ds, hp, typed


# ---------------------------------------------------------------------------
# MRR / Hits@k oracle — bitwise against the jitted scoring head
# ---------------------------------------------------------------------------

def _int_embeddings(rng, n, d):
    """Integer-valued f32: every product/sum below 2^24 is exact, so the
    jitted head and the NumPy oracle must agree to the last bit."""
    return rng.integers(-8, 9, size=(n, d)).astype(np.float32)


@pytest.mark.parametrize("score_fn", ["dot", "distmult"])
def test_mrr_oracle_bitwise(score_fn):
    rng = np.random.default_rng(42)
    B, K, d, R = 32, 5, 16, 4
    N = 2 * B + B * K
    h = _int_embeddings(rng, N, d)
    pos_u = np.arange(B, dtype=np.int32)
    pos_v = B + np.arange(B, dtype=np.int32)
    neg_v = (2 * B + np.arange(B * K, dtype=np.int32)).reshape(B, K)
    etypes = rng.integers(0, R, size=B).astype(np.int32)
    mask = np.ones(B, dtype=bool)
    mask[-3:] = False

    head = init_lp_head(score_fn, R, d)
    if score_fn == "distmult":
        head = {"rel_emb": np.asarray(
            rng.integers(-3, 4, size=(R, d)), dtype=np.float32)}

    scorer = jax.jit(lambda hh: (
        lp_pair_scores(hh, pos_u, pos_v, head=head, score_fn=score_fn,
                       etypes=etypes),
        lp_pair_scores(hh, pos_u, neg_v, head=head, score_fn=score_fn,
                       etypes=etypes)))
    pos_j, neg_j = scorer(h)
    ranks_j = np.asarray(jax.jit(lp_ranks)(pos_j, neg_j))
    metrics_j = jax.jit(lambda r: lp_metrics(r, mask))(ranks_j)

    # dense NumPy oracle
    hu = h[pos_u].astype(np.float32)
    if score_fn == "distmult":
        hu = hu * np.asarray(head["rel_emb"])[etypes]
    pos_o = (hu * h[pos_v]).sum(axis=1)
    neg_o = (hu[:, None, :] * h[neg_v]).sum(axis=2)
    assert np.array_equal(np.asarray(pos_j), pos_o), "pos scores not bitwise"
    assert np.array_equal(np.asarray(neg_j), neg_o), "neg scores not bitwise"

    ranks_o = 1 + (neg_o >= pos_o[:, None]).sum(axis=1)
    assert np.array_equal(ranks_j, ranks_o)

    r = ranks_o[mask].astype(np.float64)
    assert float(metrics_j["mrr"]) == pytest.approx((1.0 / r).mean(), abs=1e-6)
    for k in (1, 3, 10):
        assert float(metrics_j[f"hits@{k}"]) == pytest.approx(
            (r <= k).mean(), abs=1e-6)


# ---------------------------------------------------------------------------
# negative sampler: static shapes + exclusion property
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_negative_sampler_no_false_negatives(data):
    seed = data.draw(st.integers(0, 10_000))
    B = data.draw(st.integers(2, 24))
    K = data.draw(st.integers(1, 6))
    n = data.draw(st.integers(3, 40))
    mode = data.draw(st.sampled_from(["uniform", "in-batch"]))
    rng = np.random.default_rng(seed)
    pos_src = rng.integers(0, n, size=B).astype(np.int64)
    pos_dst = rng.integers(0, n, size=B).astype(np.int64)

    ns = NegativeSampler(n, K, mode=mode, seed=seed + 1,
                         exclude_batch_positives=True)
    neg, idx = ns.sample(pos_src, pos_dst, etype=-1)
    assert neg.shape == (B, K)
    assert (0 <= neg).all() and (neg < n).all()
    if mode == "in-batch":
        assert idx.shape == (B, K)
        assert np.array_equal(neg, pos_dst[idx])

    pos_keys = set((pos_src * n + pos_dst).tolist())
    cand = pos_dst if mode == "in-batch" else np.arange(n, dtype=np.int64)
    for i in range(B):
        # rows whose whole candidate set is positive cannot be excluded
        if all(int(pos_src[i] * n + c) in pos_keys for c in cand):
            continue
        for k in range(K):
            assert int(pos_src[i] * n + neg[i, k]) not in pos_keys, (
                f"false negative at ({i},{k}): "
                f"({pos_src[i]},{neg[i,k]}) is a batch positive")


def test_negative_pools_restrict_candidates():
    rng = np.random.default_rng(0)
    pool = np.array([100, 200, 300, 400], dtype=np.int64)
    ns = NegativeSampler(1000, 4, pools=[pool], seed=3)
    neg, _ = ns.sample(rng.integers(0, 1000, 8), rng.integers(0, 1000, 8),
                       etype=0)
    assert np.isin(neg, pool).all()


# ---------------------------------------------------------------------------
# edge scheduling over owned edges
# ---------------------------------------------------------------------------

def _edge_sampler(book, partitions, e_src, e_dst, owned, B=16, K=3,
                  fanouts=(5, 5), seed=5, **kw):
    node_bs = EdgeBatchSampler.required_node_batch(
        B, K, kw.get("neg_mode", "uniform"))
    s = DistributedSampler(book, partitions, list(fanouts), node_bs,
                           machine=0, seed=seed,
                           schema=kw.pop("sampler_schema", None),
                           ntype_of_node=kw.pop("ntype_of_node", None))
    return EdgeBatchSampler(s, e_src, e_dst, owned, B, K, seed=seed, **kw)


def test_schedule_covers_owned_edges_without_repeats(homo_world):
    ds, hp = homo_world
    book = hp.book
    e_src, e_dst = edge_endpoints(book, ds.graph)
    owned = np.arange(int(book.edge_offsets[0]), int(book.edge_offsets[1]),
                      dtype=np.int64)
    es = _edge_sampler(book, hp.partitions, e_src, e_dst, owned, B=64)
    rng = np.random.default_rng(1)
    seen = []
    for _e, _b, _et, eids in es.schedule(rng, 0):
        seen.append(eids)
        assert len(eids) == 64
    flat = np.concatenate(seen)
    assert len(flat) == len(np.unique(flat)), "an edge was scheduled twice"
    assert np.isin(flat, owned).all()
    assert len(seen) == es.batches_per_epoch == len(owned) // 64


def test_typed_schedule_single_etype_batches(hetero_world):
    ds, hp, typed = hetero_world
    book = hp.book
    e_src, e_dst = edge_endpoints(book, ds.graph)
    owned = np.arange(int(book.edge_offsets[0]), int(book.edge_offsets[1]),
                      dtype=np.int64)
    pools = [typed.type2node[ds.schema.dst_ntype_id(r)]
             for r in range(ds.schema.num_etypes)]
    es = _edge_sampler(book, hp.partitions, e_src, e_dst, owned, B=16,
                       fanouts=[dict(FANOUTS)] * 2,
                       sampler_schema=ds.schema,
                       ntype_of_node=typed.ntype_of_node,
                       etype_of_edge=typed.etype_of_edge, schema=ds.schema,
                       neg_pools=pools)
    rng = np.random.default_rng(2)
    etypes_seen = set()
    for _e, _b, et, eids in es.schedule(rng, 0):
        assert (typed.etype_of_edge[eids] == et).all(), \
            "typed batch mixes relations"
        etypes_seen.add(int(et))
        emb = es.sample_edges(eids, etype=et)
        assert emb.etype == et
        assert (emb.edge_etypes == et).all()
        # type-correct negatives: every corrupted dst has the relation's
        # declared dst node type
        want = ds.schema.dst_ntype_id(et)
        assert (typed.ntype_of_node[emb.neg_dst.ravel()] == want).all()
        break_after = 6
        if len(etypes_seen) >= break_after:
            break
    assert len(etypes_seen) >= 2, "schedule never rotated relations"


def test_edge_minibatch_layout(homo_world):
    ds, hp = homo_world
    book = hp.book
    e_src, e_dst = edge_endpoints(book, ds.graph)
    owned = np.arange(int(book.edge_offsets[0]), int(book.edge_offsets[1]),
                      dtype=np.int64)
    B, K = 16, 3
    es = _edge_sampler(book, hp.partitions, e_src, e_dst, owned, B=B, K=K)
    emb = es.sample_edges(owned[:B])
    # seed layout [u | v | negs]: the scorer's indices must recover the
    # exact gids the scheduler drew
    seeds = emb.mb.seeds
    assert np.array_equal(seeds[emb.pos_u], emb.pos_src)
    assert np.array_equal(seeds[emb.pos_v], emb.pos_dst)
    assert np.array_equal(seeds[emb.neg_v], emb.neg_dst)
    assert emb.pair_mask.all()
    assert emb.neg_v.shape == (B, K)
    # partial batch: padding masked, static shapes preserved
    emb2 = es.sample_edges(owned[:5])
    assert emb2.pair_mask.sum() == 5 and len(emb2.pair_mask) == B
    assert emb2.mb.seeds.shape == emb.mb.seeds.shape


# ---------------------------------------------------------------------------
# golden byte-identity: cache on/off, async/sync
# ---------------------------------------------------------------------------

def _edge_stream_hash(sampler_fn, pull_fn, cache_builder=None, batches=4):
    es = sampler_fn()
    cache = cache_builder() if cache_builder else None
    rng = np.random.default_rng(17)
    h = hashlib.sha256()
    sched = es.schedule(rng, 0)
    for _ in range(batches):
        _e, b, et, eids = next(sched)
        emb = es.sample_edges(eids, etype=et, batch_index=b)
        feats = pull_fn(emb, cache)
        _hash_edge_batch(h, emb)
        h.update(np.ascontiguousarray(feats).tobytes())
    return h.hexdigest(), cache


def _hash_edge_batch(h, emb):
    for blk in emb.blocks:
        for arr in (blk.src_gids, blk.edge_src, blk.edge_dst, blk.edge_mask,
                    blk.edge_types):
            h.update(np.ascontiguousarray(arr).tobytes())
    for arr in (emb.mb.seeds, emb.pos_eids, emb.pos_src, emb.pos_dst,
                emb.neg_dst, emb.neg_v, emb.edge_etypes, emb.pair_mask):
        h.update(np.ascontiguousarray(arr).tobytes())


def test_edge_batches_cache_on_off_identical_homo(homo_world):
    ds, hp = homo_world
    book = hp.book
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets)})
    feats_new = ds.feats[book.new2old_node]
    store.init_data("feat", feats_new.shape[1:], np.float32, "node",
                    full_array=feats_new)
    client = store.client(0)
    e_src, e_dst = edge_endpoints(book, ds.graph)
    owned = np.arange(int(book.edge_offsets[0]), int(book.edge_offsets[1]),
                      dtype=np.int64)

    def sampler_fn():
        return _edge_sampler(book, hp.partitions, e_src, e_dst, owned,
                             B=32, K=4, seed=31)

    def cache_builder():
        cache = FeatureCache(CacheConfig(budget_bytes=64 << 20), store)
        cache.register(store, "feat")
        client.attach_cache(cache)
        gids, counts = halo_access_counts(hp.partitions[0])
        cache.warm(client, "feat", gids, counts)
        return cache

    def pull_fn(emb, cache):
        client.cache = cache
        return client.pull("feat", emb.input_gids)

    h_off, _ = _edge_stream_hash(sampler_fn, pull_fn)
    h_on, cache = _edge_stream_hash(sampler_fn, pull_fn, cache_builder)
    assert h_on == h_off, "cache changed the edge-batch stream"
    assert cache.stats()["hits"] > 0, "cache never hit — test proves nothing"


def test_edge_batches_cache_on_off_identical_typed(hetero_world):
    ds, hp, typed = hetero_world
    book = hp.book
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets),
                         **typed.policies()})
    for t, nt in enumerate(typed.schema.ntypes):
        rows = ds.feats[book.new2old_node[typed.type2node[t]]]
        store.init_data(f"feat:{nt}", rows.shape[1:], np.float32,
                        f"node:{nt}", full_array=rows)
    client = store.client(0)
    e_src, e_dst = edge_endpoints(book, ds.graph)
    owned = np.arange(int(book.edge_offsets[0]), int(book.edge_offsets[1]),
                      dtype=np.int64)
    pools = [typed.type2node[ds.schema.dst_ntype_id(r)]
             for r in range(ds.schema.num_etypes)]

    def sampler_fn():
        return _edge_sampler(book, hp.partitions, e_src, e_dst, owned,
                             B=16, K=3, fanouts=[dict(FANOUTS)] * 2,
                             seed=33, sampler_schema=ds.schema,
                             ntype_of_node=typed.ntype_of_node,
                             etype_of_edge=typed.etype_of_edge,
                             schema=ds.schema, neg_pools=pools)

    def cache_builder():
        cache = FeatureCache(CacheConfig(budget_bytes=64 << 20), store)
        for nt in typed.schema.ntypes:
            cache.register(store, f"feat:{nt}")
        client.attach_cache(cache)
        gids, counts = halo_access_counts(hp.partitions[0])
        types, tids = typed.nid2typed(gids)
        for t, nt in enumerate(typed.schema.ntypes):
            m = types == t
            if m.any():
                cache.warm(client, f"feat:{nt}", tids[m], counts[m])
        return cache

    def pull_fn(emb, cache):
        client.cache = cache
        return client.pull_typed("feat", emb.input_gids, typed,
                                 ntypes=emb.input_ntypes)

    h_off, _ = _edge_stream_hash(sampler_fn, pull_fn)
    h_on, cache = _edge_stream_hash(sampler_fn, pull_fn, cache_builder)
    assert h_on == h_off, "cache changed the typed edge-batch stream"
    assert cache.stats()["hits"] > 0, "cache never hit — test proves nothing"


def test_edge_pipeline_async_matches_sync_bytes(homo_world):
    """The async pipeline must not change WHAT is produced, only when:
    one epoch of edge batches (features included) is byte-identical to
    the unpipelined baseline under identical seeds."""
    ds, hp = homo_world
    book = hp.book
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets)})
    feats_new = ds.feats[book.new2old_node]
    store.init_data("feat", feats_new.shape[1:], np.float32, "node",
                    full_array=feats_new)
    e_src, e_dst = edge_endpoints(book, ds.graph)
    owned = np.arange(int(book.edge_offsets[0]), int(book.edge_offsets[1]),
                      dtype=np.int64)[:512]

    def run(sync):
        es = _edge_sampler(book, hp.partitions, e_src, e_dst, owned,
                           B=32, K=2, seed=41)
        pipe = EdgeMinibatchPipeline(es, store.client(0), "feat",
                                     sync=sync, non_stop=False,
                                     to_device=False, seed=43)
        h = hashlib.sha256()
        n = 0
        for emb in pipe.epoch(0):
            _hash_edge_batch(h, emb)
            h.update(np.ascontiguousarray(emb.input_feats).tobytes())
            n += 1
        pipe.stop()
        return h.hexdigest(), n

    h_sync, n_sync = run(sync=True)
    h_async, n_async = run(sync=False)
    assert n_sync == n_async == 512 // 32
    assert h_sync == h_async, "async pipeline changed the edge stream"


# ---------------------------------------------------------------------------
# end-to-end trainer
# ---------------------------------------------------------------------------

def test_lp_trainer_learns(homo_world):
    ds, _ = homo_world
    cfg = GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                    hidden_dim=32, num_classes=32, fanouts=[5, 5],
                    batch_size=64)
    tr = DistGNNTrainer(ds, cfg, TrainJobConfig(
        num_machines=2, trainers_per_machine=1, task="link_prediction",
        num_negs=16, seed=7))
    assert tr.node_cfg.batch_size == 2 * 64 + 64 * 16
    # equal-size pools for every trainer, across machines (sync SGD)
    assert len({len(e) for e in tr.trainer_edges}) == 1
    # eval ranks against its own 49 uniform negatives (NOT the training
    # K=4, which would saturate hits@10); identical deterministic eval
    # before and after training isolates what training bought
    val0 = tr.evaluate_lp(num_batches=8)
    hist = [tr.train_epoch(e) for e in range(3)]
    val = tr.evaluate_lp(num_batches=8)
    tr.stop()
    losses = [h["loss"] for h in hist]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses
    assert 0.0 < val["mrr"] <= 1.0
    assert val["mrr"] > 1.2 * val0["mrr"], (val0, val)
    assert val["mrr"] > 0.11          # random sits at E[1/rank]=H(50)/50~.09
    assert val["hits@1"] <= val["hits@3"] <= val["hits@10"] <= 1.0
    assert val["hits@10"] < 1.0 or val["hits@1"] > 0.9, \
        "hits@10 saturated without near-perfect hits@1 — eval candidate " \
        "pool is degenerate"
    assert val["num_edges"] == 8 * 16   # eval batch_edges defaults to 16


def test_lp_trainer_hetero_distmult(hetero_world):
    ds, _, _ = hetero_world
    cfg = GNNConfig(arch="rgcn", in_dim=ds.feats.shape[1], hidden_dim=16,
                    num_classes=16, fanouts=[dict(FANOUTS)] * 2,
                    batch_size=16, num_rels=ds.schema.num_etypes)
    tr = DistGNNTrainer(ds, cfg, TrainJobConfig(
        num_machines=2, trainers_per_machine=1, task="link_prediction",
        num_negs=2, score_fn="distmult", neg_exclude=True, seed=9))
    assert tr.hetero
    assert all(es.negatives.exclude for es in tr.edge_samplers), \
        "neg_exclude not wired through to the negative samplers"
    m = tr.train_epoch(0)
    val = tr.evaluate_lp(num_batches=4)
    tr.stop()
    assert np.isfinite(m["loss"])
    assert 0.0 < val["mrr"] <= 1.0
    assert "rel_emb" in tr.params["lp"]
    assert not np.allclose(np.asarray(tr.params["lp"]["rel_emb"]), 1.0), \
        "distmult relation embeddings never trained"


def test_lp_rejects_bad_config():
    ds = get_dataset("product-sim", scale=9)
    cfg = GNNConfig(arch="graphsage", in_dim=ds.feats.shape[1],
                    hidden_dim=8, num_classes=8, fanouts=[3], batch_size=8)
    with pytest.raises(ValueError, match="unknown task"):
        DistGNNTrainer(ds, cfg, TrainJobConfig(task="edge_divination"))
    # mismatched node capacity is refused up front
    from repro.core.partition import hierarchical_partition as _hp
    hp = _hp(ds.graph, 2, 1, seed=0)
    e_src, e_dst = edge_endpoints(hp.book, ds.graph)
    s = DistributedSampler(hp.book, hp.partitions, [3], 10, machine=0)
    with pytest.raises(ValueError, match="endpoint capacity"):
        EdgeBatchSampler(s, e_src, e_dst, np.arange(100), 8, 4)
