"""The repro.api public surface (ISSUE 5 acceptance, DESIGN.md §8):

  * loader protocol — ``NodeDataLoader`` / ``EdgeDataLoader`` yield
    DGL-style triples whose batches are byte-for-byte what driving the
    pipelines directly produces (async and sync, homogeneous and typed,
    cache on and off — the same constructions test_sample_workers.py
    hashes), re-iteration advances epochs, ``len(loader)`` matches the
    schedule;
  * teardown — breaking out mid-epoch leaks no pool/feeder threads and
    does not poison the next epoch: after ``close()`` a full epoch is
    byte-identical to an uninterrupted run, and the raw pipeline refuses
    to mislabel an abandoned stream;
  * ``DistGraph`` — ``ndata`` pulls equal direct ``KVClient.pull`` /
    ``pull_typed``, ``node_split`` is disjoint and covers the training
    ids, ``edge_split`` equalizes owned ranges, ``DistTensor`` enforces
    read-only features and version-tracked writes;
  * surface hygiene — ``repro`` / ``repro.api`` export the documented
    names, old import paths warn, the API boundary check catches direct
    pipeline construction.
"""
import hashlib
import itertools
import threading

import numpy as np
import pytest

from repro.api import (DistEmbedding, DistGraph, DistTensor, EdgeBatch,
                       EdgeDataLoader, NodeBatch, NodeDataLoader)
from repro.core.kvstore import CacheConfig
from repro.core.pipeline import EdgeMinibatchPipeline, MinibatchPipeline
from repro.core.sampler import DistributedSampler, EdgeBatchSampler
from repro.graph import get_dataset

FANOUTS_TYPED = {"cites": 5, "writes": 3, "rev_writes": 2, "employs": 2}


@pytest.fixture(scope="module")
def homo_g():
    ds = get_dataset("product-sim", scale=10)
    return DistGraph(ds, num_machines=2, trainers_per_machine=1, seed=0)


@pytest.fixture(scope="module")
def hetero_g():
    ds = get_dataset("mag-hetero", scale=10)
    return DistGraph(ds, num_machines=2, trainers_per_machine=1,
                     hetero=True, seed=0)


def _hash_node_batches(mbs):
    h = hashlib.sha256()
    n = 0
    for mb in mbs:
        for b in mb.blocks:
            for arr in (b.src_gids, b.edge_src, b.edge_dst, b.edge_mask,
                        b.edge_types):
                h.update(np.ascontiguousarray(arr).tobytes())
        h.update(mb.seeds.tobytes())
        h.update(mb.seed_mask.tobytes())
        h.update(np.int64([mb.epoch, mb.batch_index]).tobytes())
        h.update(np.ascontiguousarray(mb.input_feats).tobytes())
        n += 1
    return h.hexdigest(), n


def _hash_edge_batches(embs):
    h = hashlib.sha256()
    n = 0
    for emb in embs:
        for b in emb.blocks:
            for arr in (b.src_gids, b.edge_src, b.edge_dst, b.edge_mask,
                        b.edge_types):
                h.update(np.ascontiguousarray(arr).tobytes())
        for arr in (emb.seeds, emb.pos_eids, emb.pos_src, emb.pos_dst,
                    emb.neg_dst, emb.neg_v, emb.edge_etypes, emb.pair_mask):
            h.update(np.ascontiguousarray(arr).tobytes())
        h.update(np.ascontiguousarray(emb.input_feats).tobytes())
        n += 1
    return h.hexdigest(), n


def _epoch_stream(loader_or_pipe, epochs=2):
    for e in range(epochs):
        yield from loader_or_pipe.epoch(e)


# ---------------------------------------------------------------------------
# byte-identity: loaders vs the pipelines they wrap
# ---------------------------------------------------------------------------

def test_node_loader_matches_pipeline_bytes(homo_g):
    g = homo_g
    seeds = g.train_nids[:256]
    labels = g.labels[seeds]

    def pipe_hash(sync):
        s = DistributedSampler(g.book, g.partitions, [10, 5], 32,
                               machine=0, seed=5)
        pipe = MinibatchPipeline(s, g.store.client(0), "feat", seeds,
                                 labels=labels, sync=sync, non_stop=False,
                                 to_device=False, seed=6)
        out = _hash_node_batches(_epoch_stream(pipe))
        pipe.stop()
        return out

    def loader_hash(sync):
        ld = NodeDataLoader(g, seeds, [10, 5], batch_size=32, labels=labels,
                            sync=sync, non_stop=False, seed=6,
                            sampler_seed=5)
        out = _hash_node_batches(
            b.minibatch for b in _epoch_stream(ld))
        ld.close()
        return out

    h_ref, n_ref = pipe_hash(sync=True)
    assert n_ref == 2 * (len(seeds) // 32) > 0
    for sync in (True, False):
        h, n = loader_hash(sync)
        assert n == n_ref
        assert h == h_ref, f"loader (sync={sync}) changed the node stream"


def test_typed_node_loader_matches_pipeline_and_cache_invariant(hetero_g):
    g = hetero_g
    seeds = g.train_nids[:96]
    labels = g.labels[seeds]
    fanouts = [dict(FANOUTS_TYPED)] * 2

    def pipe_hash():
        s = DistributedSampler(g.book, g.partitions, fanouts, 16, machine=0,
                               seed=15, schema=g.schema,
                               ntype_of_node=g.typed.ntype_of_node)
        pipe = MinibatchPipeline(s, g.store.client(0), "feat", seeds,
                                 labels=labels, sync=False, non_stop=False,
                                 to_device=False, seed=16, typed=g.typed)
        out = _hash_node_batches(_epoch_stream(pipe))
        pipe.stop()
        return out

    def loader_hash(cache):
        ld = NodeDataLoader(g, seeds, fanouts, batch_size=16, labels=labels,
                            sync=False, non_stop=False, seed=16,
                            sampler_seed=15, cache=cache)
        out = _hash_node_batches(b.minibatch for b in _epoch_stream(ld))
        ld.close()
        return out

    h_ref, n_ref = pipe_hash()
    assert n_ref > 0
    assert loader_hash(None) == (h_ref, n_ref)
    cache = g.feature_cache(CacheConfig.from_mb(64))
    h_on, n_on = loader_hash(cache)
    assert (h_on, n_on) == (h_ref, n_ref), "cache changed the typed stream"
    assert cache.stats()["hits"] > 0, "cache never hit — test proves nothing"


def test_edge_loader_matches_pipeline_bytes(homo_g):
    g = homo_g
    owned = g.trainer_view(0).edge_split()[:512]
    B, K = 32, 3

    def pipe_hash():
        node_bs = EdgeBatchSampler.required_node_batch(B, K)
        s = DistributedSampler(g.book, g.partitions, [5, 5], node_bs,
                               machine=0, seed=25)
        e_src, e_dst = g.edge_endpoints()
        es = EdgeBatchSampler(s, e_src, e_dst, owned, B, K, seed=26)
        pipe = EdgeMinibatchPipeline(es, g.store.client(0), "feat",
                                     sync=False, non_stop=False,
                                     to_device=False, seed=27)
        out = _hash_edge_batches(_epoch_stream(pipe))
        pipe.stop()
        return out

    def loader_hash(cache=None):
        ld = EdgeDataLoader(g, owned, [5, 5], batch_size=B, num_negs=K,
                            sync=False, non_stop=False, seed=27,
                            sampler_seed=25, edge_seed=26, cache=cache)
        out = _hash_edge_batches(b.minibatch for b in _epoch_stream(ld))
        ld.close()
        return out

    h_ref, n_ref = pipe_hash()
    assert n_ref == 2 * (len(owned) // B)
    assert loader_hash() == (h_ref, n_ref)
    cache = g.feature_cache(CacheConfig.from_mb(64))
    assert loader_hash(cache) == (h_ref, n_ref), \
        "cache changed the edge stream"
    assert cache.stats()["hits"] > 0


# ---------------------------------------------------------------------------
# golden byte-identity: packed staging + fused kernels vs the per-array /
# unfused path (ISSUE 6 acceptance)
# ---------------------------------------------------------------------------

def _device_tree_bytes(dev) -> dict:
    """{path: (dtype, shape, bytes)} of a staged device tree (PackedBatch
    or per-array dict alike)."""
    from repro.kernels.pack import PackedBatch, flatten_tree
    tree = dev.unpack() if isinstance(dev, PackedBatch) else dev
    flat, nones = flatten_tree(
        __import__("jax").tree.map(np.asarray, tree))
    out = {k: (str(v.dtype), v.shape, v.tobytes()) for k, v in flat.items()}
    out["__none__"] = nones
    return out


def _staged_stream(loader_cls, g, ids, fanouts, packed, **kw):
    ld = loader_cls(g, ids, fanouts, device_prefetch=True,
                    packed_staging=packed, sync=True, non_stop=False, **kw)
    out = [_device_tree_bytes(b.device) for b in ld.epoch(0)]
    ld.close()
    return out


@pytest.mark.parametrize("gfix", ["homo_g", "hetero_g"])
def test_packed_staging_byte_identity_node(gfix, request):
    g = request.getfixturevalue(gfix)
    seeds = g.train_nids[:64]
    fanouts = [dict(FANOUTS_TYPED)] * 2 if g.hetero else [5, 5]
    kw = dict(batch_size=16, labels=g.labels[seeds], seed=31,
              sampler_seed=32)
    packed = _staged_stream(NodeDataLoader, g, seeds, fanouts, True, **kw)
    per_arr = _staged_stream(NodeDataLoader, g, seeds, fanouts, False, **kw)
    assert len(packed) == len(per_arr) > 0
    assert packed == per_arr, "packed staging changed the device bytes"


@pytest.mark.parametrize("gfix", ["homo_g", "hetero_g"])
def test_packed_staging_byte_identity_edge(gfix, request):
    g = request.getfixturevalue(gfix)
    owned = g.edge_split()[:64]
    fanouts = [dict(FANOUTS_TYPED)] * 2 if g.hetero else [5, 5]
    kw = dict(batch_size=8, num_negs=3, seed=33, sampler_seed=34,
              edge_seed=35)
    packed = _staged_stream(EdgeDataLoader, g, owned, fanouts, True, **kw)
    per_arr = _staged_stream(EdgeDataLoader, g, owned, fanouts, False, **kw)
    assert len(packed) == len(per_arr) > 0
    assert packed == per_arr, "packed staging changed the device bytes"


def test_model_input_packed_contract(homo_g):
    from repro.kernels.pack import PackedBatch
    g = homo_g
    seeds = g.train_nids[:32]
    with NodeDataLoader(g, seeds, [5, 5], batch_size=16,
                        labels=g.labels[seeds], device_prefetch=True,
                        packed_staging=True, sync=True, non_stop=False,
                        seed=41) as ld:
        b = next(iter(ld))
        staged = b.model_input(packed=True)
        assert isinstance(staged, PackedBatch)
        # the unpacked model_input is a view of the SAME staged batch
        mi = b.model_input()
        assert set(mi) == set(NodeBatch._model_keys)
        assert np.array_equal(np.asarray(mi["input_feats"]),
                              np.asarray(staged["input_feats"]))
    # host-side loaders refuse the packed form
    with NodeDataLoader(g, seeds, [5, 5], batch_size=16,
                        labels=g.labels[seeds], seed=41) as ld:
        with pytest.raises(ValueError, match="packed"):
            next(iter(ld)).model_input(packed=True)


def _train_golden(ds, cfg, job_kw, epochs):
    import jax
    from repro.api import DistGNNTrainer, TrainJobConfig
    tr = DistGNNTrainer(ds, cfg, TrainJobConfig(
        num_machines=2, trainers_per_machine=1, seed=5, **job_kw))
    losses = [tr.train_epoch(e)["loss"] for e in range(epochs)]
    params = jax.tree_util.tree_leaves(tr.params)
    blob = b"".join(np.asarray(p).tobytes() for p in params)
    tr.stop()
    return losses, blob


@pytest.mark.parametrize("task,arch,dataset,scale,epochs", [
    ("node_classification", "graphsage", "product-sim", 11, 2),
    ("node_classification", "rgcn", "mag-sim", 13, 2),
    # LP schedules EVERY owned edge per epoch — smaller graphs keep the
    # golden runs short without weakening the bitwise pin
    ("link_prediction", "graphsage", "product-sim", 9, 1),
    ("link_prediction", "rgcn", "mag-sim", 10, 1),
])
def test_trainer_packed_fused_golden_bytes(task, arch, dataset, scale,
                                           epochs):
    """The acceptance pin: packed staging + the fused-kernel dispatch
    (``impl`` explicit) train to BIT-IDENTICAL losses and parameter bytes
    vs the per-array / pre-fusion path, on node+edge × homo+typed."""
    from repro.graph import get_dataset
    from repro.models.gnn import GNNConfig
    ds = get_dataset(dataset, scale=scale)
    cfg = GNNConfig(arch=arch, in_dim=ds.feats.shape[1], hidden_dim=16,
                    num_classes=(16 if task == "link_prediction"
                                 else ds.num_classes),
                    fanouts=[5, 5], batch_size=32,
                    num_rels=ds.graph.num_etypes)
    kw = dict(task=task)
    if task == "link_prediction":
        kw["num_negs"] = 3
    ref = _train_golden(ds, cfg, dict(packed_staging=False, impl="ref",
                                      **kw), epochs)
    new = _train_golden(ds, cfg, dict(packed_staging=True, impl="auto",
                                      **kw), epochs)
    assert new[0] == ref[0], f"losses diverged: {new[0]} vs {ref[0]}"
    assert new[1] == ref[1], "parameter bytes diverged"


# ---------------------------------------------------------------------------
# loader protocol: DGL triples, len, epoch advancement
# ---------------------------------------------------------------------------

def test_node_loader_yields_dgl_triples(homo_g):
    g = homo_g
    seeds = g.train_nids[:128]
    with NodeDataLoader(g, seeds, [5, 5], batch_size=32,
                        labels=g.labels[seeds], seed=3) as ld:
        assert len(ld) == len(seeds) // 32
        batch = next(iter(ld))
        assert isinstance(batch, NodeBatch)
        input_nodes, out_seeds, blocks = batch
        mb = batch.minibatch
        assert input_nodes is mb.input_gids
        assert out_seeds is mb.seeds
        assert blocks is mb.blocks
        mi = batch.model_input()
        assert set(mi) == {"input_feats", "labels", "seed_mask", "blocks"}
        assert np.array_equal(mi["input_feats"], mb.input_feats)
        assert len(mi["blocks"]) == 2


def test_edge_loader_yields_dgl_triples(homo_g):
    g = homo_g
    owned = g.edge_split()[:128]
    with EdgeDataLoader(g, owned, [5, 5], batch_size=16, num_negs=3,
                        seed=4) as ld:
        batch = next(iter(ld))
        assert isinstance(batch, EdgeBatch)
        input_nodes, pair_graph, blocks = batch
        emb = batch.minibatch
        assert input_nodes is emb.input_gids
        assert blocks is emb.blocks
        # the pair graph is the scoring-head view of the same batch
        assert np.array_equal(pair_graph.pos_u, emb.pos_u)
        assert np.array_equal(pair_graph.neg_v, emb.neg_v)
        assert np.array_equal(pair_graph.pair_mask, emb.pair_mask)
        assert pair_graph.batch_edges == 16 and pair_graph.num_negs == 3
        mi = batch.model_input()
        assert set(mi) == {"input_feats", "seed_mask", "pos_u", "pos_v",
                           "neg_v", "pair_mask", "edge_etypes", "blocks"}


def test_reiteration_advances_epochs_nonstop(homo_g):
    g = homo_g
    seeds = g.train_nids[:128]
    ld = NodeDataLoader(g, seeds, [5], batch_size=32,
                        labels=g.labels[seeds], seed=7, non_stop=True)
    first = list(ld)                       # epoch 0, clean StopIteration
    second = list(ld)                      # epoch 1 on the same pipeline
    assert len(first) == len(second) == len(ld) > 0
    assert all(b.epoch == 0 for b in first)
    assert all(b.epoch == 1 for b in second)
    # explicit epoch driving obeys the §7 consecutive-epoch contract
    with pytest.raises(ValueError, match="consecutive"):
        next(ld.epoch(9))
    third = list(ld.epoch(2))
    assert all(b.epoch == 2 for b in third)
    ld.close()
    # close() rewinds: iteration restarts from the abandoned epoch counter
    again = list(ld.epoch(0))
    assert all(b.epoch == 0 for b in again)
    ld.close()


# ---------------------------------------------------------------------------
# teardown on partial consumption
# ---------------------------------------------------------------------------

def _pipeline_threads():
    return [t for t in threading.enumerate()
            if t.is_alive() and t.name.startswith("minibatch")]


def test_partial_consumption_no_leak_and_byte_identical_epoch(homo_g):
    g = homo_g
    seeds = g.train_nids[:256]
    labels = g.labels[seeds]
    kw = dict(batch_size=32, labels=labels, seed=11, sampler_seed=12,
              non_stop=True, sample_workers=2)

    # reference: an uninterrupted epoch 0 from a fresh loader
    ref_ld = NodeDataLoader(g, seeds, [5, 5], **kw)
    h_ref, n_ref = _hash_node_batches(b.minibatch for b in iter(ref_ld))
    ref_ld.close()
    assert not _pipeline_threads(), "reference loader leaked threads"

    ld = NodeDataLoader(g, seeds, [5, 5], **kw)
    taken = list(itertools.islice(ld, 2))       # break out mid-epoch
    assert len(taken) == 2 < n_ref
    assert _pipeline_threads(), "non-stop pipeline should be live"
    ld.close()                                   # drains + joins + rewinds
    assert not _pipeline_threads(), \
        "close() left pool/feeder threads alive after partial consumption"
    # the abandoned epoch did not count: the next iteration re-serves
    # epoch 0, byte-identical to the uninterrupted run
    h2, n2 = _hash_node_batches(b.minibatch for b in iter(ld))
    assert (h2, n2) == (h_ref, n_ref)
    ld.close()
    assert not _pipeline_threads()


def test_iter_after_abandonment_auto_recovers(homo_g):
    g = homo_g
    seeds = g.train_nids[:256]
    ld = NodeDataLoader(g, seeds, [5], batch_size=32,
                        labels=g.labels[seeds], seed=13, non_stop=True)
    h_ref, n_ref = _hash_node_batches(b.minibatch for b in iter(ld))
    ld.close()
    # abandon mid-epoch, then iterate WITHOUT an explicit close(): the
    # loader rewinds itself and re-serves the same epoch byte-identically
    list(itertools.islice(ld, 1))
    h2, n2 = _hash_node_batches(b.minibatch for b in iter(ld))
    assert (h2, n2) == (h_ref, n_ref)
    ld.close()
    assert not _pipeline_threads()


def test_drain_to_epoch_boundary_keeps_pipeline_alive(homo_g):
    """The trainer's contract for unequal per-trainer batch counts (typed
    LP): draining an epoch iterator to its boundary finishes the epoch
    cleanly — no teardown, no rebuild, next epoch advances on the same
    live pipeline."""
    g = homo_g
    seeds = g.train_nids[:256]
    ld = NodeDataLoader(g, seeds, [5], batch_size=32,
                        labels=g.labels[seeds], seed=17, non_stop=True)
    it = ld.epoch(0)
    for _ in range(len(ld) - 1):          # consume all but the last batch
        next(it)
    for _ in it:                          # drain to the epoch boundary
        pass
    live = ld.pipeline._pipe
    assert live is not None
    nxt = list(ld.epoch(1))
    assert all(b.epoch == 1 for b in nxt)
    assert ld.pipeline._pipe is live, \
        "draining to the boundary must not tear the pipeline down"
    ld.close()


def test_pipeline_refuses_mislabeled_epoch_after_abandonment(homo_g):
    g = homo_g
    seeds = g.train_nids[:256]
    s = DistributedSampler(g.book, g.partitions, [5], 32, machine=0, seed=45)
    pipe = MinibatchPipeline(s, g.store.client(0), "feat", seeds,
                             sync=False, non_stop=True, to_device=False,
                             seed=46)
    it = pipe.epoch(0)
    next(it)                                  # abandon epoch 0 mid-stream
    with pytest.raises(ValueError, match="mid-epoch"):
        next(pipe.epoch(1))
    pipe.stop()                               # stop() rewinds the contract
    assert all(mb.epoch == 0 for mb in pipe.epoch(0))
    pipe.stop()


# ---------------------------------------------------------------------------
# DistGraph: ndata / DistTensor / splits
# ---------------------------------------------------------------------------

def test_ndata_pulls_equal_kvclient(homo_g):
    g = homo_g
    ids = np.linspace(0, g.num_nodes() - 1, 37, dtype=np.int64)
    feat = g.ndata["feat"]
    assert isinstance(feat, DistTensor)
    assert feat.shape == (g.num_nodes(), g.ds.feats.shape[1])
    assert len(feat) == g.num_nodes()
    client = g.store.client(0)
    assert np.array_equal(feat[ids], client.pull("feat", ids))
    assert np.array_equal(g.ndata["label"][ids],
                          client.pull("label", ids))
    assert set(g.ndata.keys()) == {"feat", "label"}
    assert "feat" in g.ndata and "nope" not in g.ndata
    with pytest.raises(KeyError):
        g.ndata["nope"]
    # features are read-only through the façade
    with pytest.raises(TypeError, match="read-only"):
        feat[ids[:2]] = np.zeros((2, feat.shape[1]), np.float32)


def test_ndata_typed_pulls_equal_pull_typed(hetero_g):
    g = hetero_g
    ids = np.linspace(0, g.num_nodes() - 1, 29, dtype=np.int64)
    client = g.store.client(0)
    fused = g.ndata["feat"]          # fused-ID view over the typed family
    assert np.array_equal(fused[ids],
                          client.pull_typed("feat", ids, g.typed))
    # per-ntype tensors are first-class keys too (type-local ids)
    nt0 = g.schema.ntypes[0]
    tl = np.arange(5, dtype=np.int64)
    assert np.array_equal(g.ndata[f"feat:{nt0}"][tl],
                          client.pull(f"feat:{nt0}", tl))


def test_dist_embedding_writable_through_ndata(homo_g):
    g = homo_g
    emb = DistEmbedding(g.store, "api_emb", g.num_nodes(), 8, "node",
                        seed=3)
    t = g.ndata["api_emb"]
    assert t.writable, "version-tracked embedding tables accept writes"
    ids = np.array([1, 5, 9], dtype=np.int64)
    before = t[ids]
    t[ids] = before + 1.0
    assert np.array_equal(t[ids], before + 1.0)
    # the embedding's own pull sees the same rows
    assert np.array_equal(emb.pull(g.client, ids), before + 1.0)


def test_node_split_disjoint_and_covers(homo_g):
    g = homo_g
    train = g.train_nids
    splits = g.node_splits(train)
    assert len(splits) == g.num_trainers
    sizes = {len(s) for s in splits}
    assert len(sizes) == 1, "sync SGD needs equal per-trainer seed counts"
    flat = np.concatenate(splits)
    assert len(flat) == len(np.unique(flat)), "splits overlap"
    assert np.isin(flat, train).all()
    # equal counts drop at most num_trainers-1 tail seeds
    assert len(flat) >= len(train) - (g.num_trainers - 1)
    for r in range(g.num_trainers):
        assert np.array_equal(g.trainer_view(r).node_split(train), splits[r])


def test_edge_split_equalized_owned_ranges(homo_g):
    g = homo_g
    splits = g.edge_splits()
    assert len(splits) == g.num_trainers
    assert len({len(s) for s in splits}) == 1, "pools not equalized"
    offs = g.book.edge_offsets
    T = g.trainers_per_machine
    for r, eids in enumerate(splits):
        m = r // T
        assert (eids >= offs[m]).all() and (eids < offs[m + 1]).all(), \
            f"trainer {r} schedules edges outside machine {m}'s owned range"
    flat = np.concatenate(splits)
    assert len(flat) == len(np.unique(flat)), "edge pools overlap"
    assert np.array_equal(g.trainer_view(1).edge_split(), splits[1])


def test_eval_loader_matches_direct_sampler(homo_g):
    g = homo_g
    nids = g.val_nids[:96]
    bs = 32
    ld = NodeDataLoader(g, nids, [5, 5], batch_size=bs,
                        labels=g.labels[nids], mode="eval", sampler_seed=99)
    got = list(ld)
    s = DistributedSampler(g.book, g.partitions, [5, 5], bs, machine=0,
                           seed=99)
    client = g.store.client(0)
    assert len(got) == len(nids) // bs
    for b, batch in enumerate(got):
        chunk = nids[b * bs:(b + 1) * bs]
        mb = s.sample(chunk, labels=g.labels[chunk], batch_index=b)
        assert np.array_equal(batch.seeds, mb.seeds)
        assert np.array_equal(batch.labels, mb.labels)
        assert np.array_equal(batch.input_feats,
                              client.pull("feat", mb.input_gids))
    # eval loaders spin up no pipeline threads and are re-iterable
    assert ld.pipeline is None
    assert len(list(ld)) == len(got)
    ld.close()


def test_loader_stats_report(homo_g):
    g = homo_g
    seeds = g.train_nids[:128]
    cache = g.feature_cache(CacheConfig.from_mb(8))
    ld = NodeDataLoader(g, seeds, [5, 5], batch_size=32,
                        labels=g.labels[seeds], seed=21, cache=cache,
                        non_stop=False)
    list(ld)
    rep = ld.stats_report()
    ld.close()
    assert rep["batches_per_epoch"] == len(ld)
    assert set(rep["stages"]) == {"sample", "cpu_prefetch",
                                  "device_prefetch"}
    assert rep["stages"]["sample"]["items"] == len(ld)
    assert rep["sampler"]["batches"] == len(ld)
    assert rep["sampler"]["coalescing_factor"] == 1.0   # untyped
    assert 0.0 <= rep["cache"]["hit_rate"] <= 1.0


# ---------------------------------------------------------------------------
# surface hygiene
# ---------------------------------------------------------------------------

def test_public_surface_exports():
    import repro
    import repro.api as api
    want = {"DistGraph", "DistTensor", "DistEmbedding", "NodeDataLoader",
            "EdgeDataLoader", "DistGNNTrainer", "TrainJobConfig"}
    assert want <= set(api.__all__)
    assert want <= set(repro.__all__)
    for name in want:
        assert getattr(repro, name) is getattr(api, name)
    # the lazy trainer re-export resolves to the real implementation
    from repro.training.trainer import DistGNNTrainer as impl
    assert api.DistGNNTrainer is impl
    with pytest.raises(AttributeError):
        api.no_such_name


def test_deprecated_training_import_warns():
    with pytest.warns(DeprecationWarning, match="repro.api"):
        from repro.training import DistGNNTrainer  # noqa: F401
    with pytest.warns(DeprecationWarning, match="repro.api"):
        from repro.training import TrainJobConfig  # noqa: F401
    # the implementation module itself stays warning-free
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        from repro.training.trainer import TrainJobConfig  # noqa: F401,F811


def test_api_boundary_checker_catches_planted_violation(tmp_path):
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    bad = tmp_path / "src" / "repro" / "training"
    bad.mkdir(parents=True)
    (bad / "rogue.py").write_text(
        "p = MinibatchPipeline(s, c, 'feat', seeds)\n", encoding="utf-8")
    errors = check_docs.check_api_boundary(tmp_path)
    assert errors and "rogue.py" in errors[0]
    # the class definition site and api/ itself stay exempt
    ok = tmp_path / "src" / "repro" / "api"
    ok.mkdir(parents=True)
    (ok / "loader.py").write_text(
        "p = EdgeMinibatchPipeline(es, c, 'feat')\n", encoding="utf-8")
    assert check_docs.check_api_boundary(tmp_path) == errors
    # the real tree is clean
    assert check_docs.check_api_boundary(
        Path(__file__).resolve().parent.parent) == []
