"""Typed-relation (heterograph) path: schema, typed partition policies,
per-relation sampling, relation-major MFG layout, per-ntype KVStore
routing, and the homogeneous-path identity guarantees."""
import hashlib

import numpy as np
import pytest

from repro.core.kvstore import (CacheConfig, DistKVStore, FeatureCache,
                                PartitionPolicy, halo_access_counts)
from repro.core.partition import (build_typed_partition,
                                  hierarchical_partition)
from repro.core.sampler import DistributedSampler, capacities, pad_typed_block
from repro.graph import (HeteroCSRGraph, HeteroSchema, fused_from_typed,
                         get_dataset, mag_graph)

# sha256 over 3 batches of the sampler (product-sim scale=10, 4 machines,
# fanouts [10, 5], batch 64, sampler seed 7). Captured from the pre-hetero
# seed code at PR 1; re-captured at PR 2 ONLY because the partitioner's
# balance hardening (multilevel._rebalance) legitimately moves vertices,
# which changes the ID relabeling feeding the sampler; re-captured ONCE
# more at PR 4 for the counter-based RNG refactor (DESIGN.md §7: draws now
# derive from (seed, epoch, batch) instead of one shared generator, and
# the subsample is a vectorized random-key draw). PR 4's worker-count /
# sync / replay invariance tests (test_sample_workers.py) pin the stream
# from here on — any future drift is a regression.
GOLDEN_HOMOGENEOUS = ("d37711b763072ef6c29d95c4a3383779"
                     "d22d1d6f56ce6389a9a7268118daa6f8")

FANOUTS = {"cites": 5, "writes": 3, "rev_writes": 2, "employs": 2}


@pytest.fixture(scope="module")
def hetero_world():
    ds = get_dataset("mag-hetero", scale=10)
    hp = hierarchical_partition(ds.graph, 2, 1, split_mask=ds.split_mask,
                                seed=0)
    book = hp.book
    typed = build_typed_partition(
        book, ds.schema, ds.graph.ntypes[book.new2old_node],
        ds.graph.etypes[book.new2old_edge])
    return ds, hp, typed


@pytest.fixture(scope="module")
def homo_world():
    ds = get_dataset("product-sim", scale=10)
    hp = hierarchical_partition(ds.graph, 4, 1, split_mask=ds.split_mask,
                                seed=0)
    return ds, hp


def _batch_hash(batches):
    h = hashlib.sha256()
    for mb in batches:
        for b in mb.blocks:
            for arr in (b.src_gids, b.edge_src, b.edge_dst, b.edge_mask,
                        b.edge_types):
                h.update(np.ascontiguousarray(arr).tobytes())
            h.update(np.int64([b.num_src, b.num_dst, b.num_edges]).tobytes())
        h.update(mb.seeds.tobytes())
        h.update(mb.seed_mask.tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# schema + graph view
# ---------------------------------------------------------------------------

def test_schema_validates_canonical_types():
    g, schema = mag_graph(8, seed=0)
    HeteroCSRGraph(g, schema)   # must not raise
    # corrupt one edge's type: an 'employs' edge whose src is a paper
    bad = g.etypes.copy()
    cites = np.nonzero(bad == schema.etype_id("cites"))[0]
    bad[cites[0]] = schema.etype_id("employs")
    import dataclasses
    g_bad = dataclasses.replace(g, etypes=bad)
    with pytest.raises(ValueError, match="employs"):
        HeteroCSRGraph(g_bad, schema)


def test_schema_rejects_duplicate_relations():
    with pytest.raises(ValueError):
        HeteroSchema(("a", "b"), (("a", "r", "b"), ("b", "r", "a")))


def test_relation_adjacency_partitions_the_fused_graph():
    g, schema = mag_graph(8, seed=1)
    hg = HeteroCSRGraph(g, schema)
    total = sum(hg.num_rel_edges(r) for r in range(schema.num_etypes))
    assert total == g.num_edges
    for r in range(schema.num_etypes):
        src, dst, pos = hg.relation_coo(r)
        assert (g.etypes[pos] == r).all()
        assert len(src) == len(dst) == len(pos)


def test_fused_from_typed_layout():
    g, schema = fused_from_typed(
        {"a": 3, "b": 2},
        [(("a", "r1", "b"), np.array([0, 1, 2]), np.array([0, 1, 0])),
         (("b", "r2", "a"), np.array([0]), np.array([2]))])
    assert g.num_nodes == 5 and g.num_edges == 4
    assert list(g.ntypes) == [0, 0, 0, 1, 1]
    # b-local id 0 -> fused 3
    src, dst, _ = HeteroCSRGraph(g, schema).relation_coo("r2")
    assert src.tolist() == [3] and dst.tolist() == [2]


# ---------------------------------------------------------------------------
# typed partition policies
# ---------------------------------------------------------------------------

def test_typed_id_roundtrip_and_policy_routing(hetero_world):
    ds, hp, typed = hetero_world
    book = hp.book
    n = book.num_nodes
    nids = np.random.default_rng(0).integers(0, n, size=500)
    types, tids = typed.nid2typed(nids)
    for t in range(typed.schema.num_ntypes):
        m = types == t
        if not m.any():
            continue
        back = typed.typed2nid(t, tids[m])
        assert np.array_equal(back, nids[m])
        # the per-type policy must agree with the fused book on ownership
        pol = typed.node_policies[f"node:{typed.schema.ntypes[t]}"]
        assert np.array_equal(pol.part_of(tids[m]), book.nid2part(nids[m]))


def test_typed_policies_cover_each_type_exactly(hetero_world):
    ds, hp, typed = hetero_world
    for t, nt in enumerate(typed.schema.ntypes):
        pol = typed.node_policies[f"node:{nt}"]
        assert pol.total == len(typed.type2node[t])
    for r, rel in enumerate(typed.schema.etypes):
        pol = typed.edge_policies[f"edge:{rel}"]
        assert pol.total == len(typed.type2edge[r])


def test_per_ntype_kvstore_pull_routes_to_right_policy(hetero_world):
    ds, hp, typed = hetero_world
    book = hp.book
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets),
                         **typed.policies()})
    for t, nt in enumerate(typed.schema.ntypes):
        rows = ds.feats[book.new2old_node[typed.type2node[t]]]
        store.init_data(f"feat:{nt}", rows.shape[1:], np.float32,
                        f"node:{nt}", full_array=rows)
        # each server holds exactly its partition's type-t rows
        pol = typed.node_policies[f"node:{nt}"]
        for p, srv in enumerate(store.servers):
            lo, hi = int(pol.offsets[p]), int(pol.offsets[p + 1])
            assert np.array_equal(srv.local_view(f"feat:{nt}"),
                                  rows[lo:hi])
    client = store.client(0)
    nids = np.random.default_rng(1).integers(0, book.num_nodes, size=300)
    got = client.pull_typed("feat", nids, typed)
    want = ds.feats[book.new2old_node[nids]]
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# per-relation sampling + relation-major blocks
# ---------------------------------------------------------------------------

def _typed_sampler(ds, hp, typed, fanouts, batch=32, seed=3):
    return DistributedSampler(hp.book, hp.partitions, fanouts, batch,
                              machine=0, seed=seed, schema=ds.schema,
                              ntype_of_node=typed.ntype_of_node)


def test_per_relation_fanout_caps_respected(hetero_world):
    ds, hp, typed = hetero_world
    s = _typed_sampler(ds, hp, typed, [dict(FANOUTS)] * 2)
    seeds = hp.book.old2new_node[ds.train_nids][:32]
    mb = s.sample(seeds)
    for b in mb.blocks:
        for r, rel in enumerate(ds.schema.etypes):
            sl = b.rel_slice(r)
            ed = b.edge_dst[sl][b.edge_mask[sl]]
            if len(ed):
                assert np.bincount(ed).max() <= FANOUTS[rel], rel
            # segment budget: live edges never spill past the static slots
            assert b.rel_counts[r] <= sl.stop - sl.start


def test_typed_edges_connect_declared_ntypes(hetero_world):
    ds, hp, typed = hetero_world
    s = _typed_sampler(ds, hp, typed, [dict(FANOUTS)] * 2)
    seeds = hp.book.old2new_node[ds.train_nids][:32]
    mb = s.sample(seeds)
    nt = typed.ntype_of_node
    for b in mb.blocks:
        for r, (snt, rel, dnt) in enumerate(ds.schema.canonical_etypes):
            sl = b.rel_slice(r)
            m = b.edge_mask[sl]
            if not m.any():
                continue
            assert (nt[b.src_gids[b.edge_src[sl][m]]]
                    == ds.schema.ntype_id(snt)).all(), rel
            assert (nt[b.src_gids[b.edge_dst[sl][m]]]
                    == ds.schema.ntype_id(dnt)).all(), rel
    # typed frontier bookkeeping: reported input types match the gid types
    assert np.array_equal(mb.input_ntypes, nt[mb.blocks[0].src_gids])


def test_edge_types_first_class_across_padding(hetero_world):
    ds, hp, typed = hetero_world
    s = _typed_sampler(ds, hp, typed, [dict(FANOUTS)])
    seeds = hp.book.old2new_node[ds.train_nids][:16]
    b = s.sample(seeds).blocks[0]
    for r in range(ds.schema.num_etypes):
        sl = b.rel_slice(r)
        assert (b.edge_types[sl] == r).all()   # padding slots included


def test_zero_fanout_relation_is_not_sampled(hetero_world):
    ds, hp, typed = hetero_world
    fo = dict(FANOUTS, cites=0)
    s = _typed_sampler(ds, hp, typed, [fo])
    seeds = hp.book.old2new_node[ds.train_nids][:16]
    b = s.sample(seeds).blocks[0]
    r = ds.schema.etype_id("cites")
    assert b.rel_counts[r] == 0
    assert b.rel_slice(r).stop == b.rel_slice(r).start   # zero static budget


def test_typed_padding_masked_out_of_aggregation():
    """Padded slots must not contribute: corrupting their edge_src/edge_dst
    with in-range garbage leaves the RGCN layer output unchanged, and the
    typed (rel_offsets) path agrees with the legacy etype-mask path."""
    import jax.numpy as jnp
    from repro.models.gnn.layers import rgcn_layer

    rng = np.random.default_rng(0)
    num_dst, num_rels = 4, 3
    rel_offsets = np.array([0, 8, 12, 20])
    src_gids = np.arange(10, dtype=np.int64)
    rel_es = [rng.integers(0, 10, size=k).astype(np.int32)
              for k in (5, 2, 7)]
    rel_ed = [rng.integers(0, num_dst, size=len(e)).astype(np.int32)
              for e in rel_es]
    blk = pad_typed_block(src_gids, rel_es, rel_ed, num_dst=num_dst,
                          cap_src=12, rel_offsets=rel_offsets)
    h = rng.standard_normal((12, 6)).astype(np.float32)
    params = {"w_rel": jnp.asarray(
                  rng.standard_normal((num_rels, 6, 5)).astype(np.float32)),
              "w_self": jnp.asarray(
                  rng.standard_normal((6, 5)).astype(np.float32)),
              "b": jnp.zeros((5,))}

    def as_dict(b):
        return dict(edge_src=jnp.asarray(b.edge_src),
                    edge_dst=jnp.asarray(b.edge_dst),
                    edge_mask=jnp.asarray(b.edge_mask),
                    edge_types=jnp.asarray(b.edge_types))

    out_typed = rgcn_layer(params, jnp.asarray(h), as_dict(blk), num_dst,
                           num_rels, rel_offsets=tuple(rel_offsets))
    out_legacy = rgcn_layer(params, jnp.asarray(h), as_dict(blk), num_dst,
                            num_rels)
    assert np.allclose(out_typed, out_legacy, atol=1e-5)

    # garbage in the padded slots — all in-range, only the mask protects us
    pad = ~blk.edge_mask
    blk.edge_src[pad] = rng.integers(0, 10, size=pad.sum())
    blk.edge_dst[pad] = rng.integers(0, num_dst, size=pad.sum())
    out_garbage = rgcn_layer(params, jnp.asarray(h), as_dict(blk), num_dst,
                             num_rels, rel_offsets=tuple(rel_offsets))
    assert np.allclose(out_typed, out_garbage, atol=1e-6)


# ---------------------------------------------------------------------------
# homogeneous identity: the refactor must not change untyped batches
# ---------------------------------------------------------------------------

def test_homogeneous_batches_match_pre_refactor_golden(homo_world):
    ds, hp = homo_world
    book = hp.book
    train_new = book.old2new_node[ds.train_nids]
    s = DistributedSampler(book, hp.partitions, [10, 5], 64, machine=0,
                           seed=7)
    batches = [s.sample(train_new[i * 64:(i + 1) * 64]) for i in range(3)]
    assert _batch_hash(batches) == GOLDEN_HOMOGENEOUS


def _feat_stream_hash(book, partitions, ds, sampler_fn, pull_fn,
                      cache_builder=None, batches=4, batch=32):
    """sha256 over ``batches`` mini-batches INCLUDING the pulled feature
    bytes — the cache-on stream must reproduce the cache-off stream bit
    for bit (ISSUE 2's extension of the PR 1 golden-hash guard)."""
    sampler = sampler_fn()
    cache = cache_builder() if cache_builder else None
    train_new = book.old2new_node[ds.train_nids]
    h = hashlib.sha256()
    for i in range(batches):
        mb = sampler.sample(train_new[i * batch:(i + 1) * batch])
        feats = pull_fn(mb, cache)
        for b in mb.blocks:
            for arr in (b.src_gids, b.edge_src, b.edge_dst, b.edge_mask,
                        b.edge_types):
                h.update(np.ascontiguousarray(arr).tobytes())
        h.update(mb.seeds.tobytes())
        h.update(np.ascontiguousarray(feats).tobytes())
    return h.hexdigest(), cache


def test_cache_on_off_byte_identical_homogeneous(homo_world):
    ds, hp = homo_world
    book = hp.book
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets)})
    feats_new = ds.feats[book.new2old_node]
    store.init_data("feat", feats_new.shape[1:], np.float32, "node",
                    full_array=feats_new)
    client = store.client(0)

    def sampler_fn():
        return DistributedSampler(book, hp.partitions, [10, 5], 32,
                                  machine=0, seed=21)

    def cache_builder():
        cache = FeatureCache(CacheConfig(budget_bytes=64 << 20), store)
        cache.register(store, "feat")
        client.attach_cache(cache)
        gids, counts = halo_access_counts(hp.partitions[0])
        cache.warm(client, "feat", gids, counts)
        return cache

    def pull_fn(mb, cache):
        client.cache = cache
        return client.pull("feat", mb.input_gids)

    h_off, _ = _feat_stream_hash(book, hp.partitions, ds, sampler_fn, pull_fn)
    h_on, cache = _feat_stream_hash(book, hp.partitions, ds, sampler_fn,
                                    pull_fn, cache_builder)
    assert h_on == h_off, "cache changed the homogeneous training stream"
    assert cache.stats()["hits"] > 0, "cache never hit — test proves nothing"


def test_cache_on_off_byte_identical_typed(hetero_world):
    ds, hp, typed = hetero_world
    book = hp.book
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets),
                         **typed.policies()})
    for t, nt in enumerate(typed.schema.ntypes):
        rows = ds.feats[book.new2old_node[typed.type2node[t]]]
        store.init_data(f"feat:{nt}", rows.shape[1:], np.float32,
                        f"node:{nt}", full_array=rows)
    client = store.client(0)

    def sampler_fn():
        return _typed_sampler(ds, hp, typed, [dict(FANOUTS)] * 2, seed=23)

    def cache_builder():
        cache = FeatureCache(CacheConfig(budget_bytes=64 << 20), store)
        for nt in typed.schema.ntypes:
            cache.register(store, f"feat:{nt}")
        client.attach_cache(cache)
        gids, counts = halo_access_counts(hp.partitions[0])
        types, tids = typed.nid2typed(gids)
        for t, nt in enumerate(typed.schema.ntypes):
            m = types == t
            if m.any():
                cache.warm(client, f"feat:{nt}", tids[m], counts[m])
        return cache

    def pull_fn(mb, cache):
        client.cache = cache
        return client.pull_typed("feat", mb.input_gids, typed,
                                 ntypes=mb.input_ntypes)

    h_off, _ = _feat_stream_hash(book, hp.partitions, ds, sampler_fn, pull_fn)
    h_on, cache = _feat_stream_hash(book, hp.partitions, ds, sampler_fn,
                                    pull_fn, cache_builder)
    assert h_on == h_off, "cache changed the typed training stream"
    assert cache.stats()["hits"] > 0, "cache never hit — test proves nothing"


def test_degenerate_schema_is_byte_identical_to_untyped(homo_world):
    """A single-relation dict fanout under the degenerate schema must take
    the typed code path yet produce the same bytes as the legacy int path
    (same rng consumption, same layout with R=1)."""
    ds, hp = homo_world
    book = hp.book
    train_new = book.old2new_node[ds.train_nids]
    schema = HeteroSchema.homogeneous()

    s_int = DistributedSampler(book, hp.partitions, [10, 5], 64, machine=0,
                               seed=11)
    s_typed = DistributedSampler(book, hp.partitions,
                                 [{"_E": 10}, {"_E": 5}], 64, machine=0,
                                 seed=11, schema=schema)
    assert s_typed.typed and not s_int.typed
    a = [s_int.sample(train_new[i * 64:(i + 1) * 64]) for i in range(3)]
    b = [s_typed.sample(train_new[i * 64:(i + 1) * 64]) for i in range(3)]
    assert _batch_hash(a) == _batch_hash(b)
    assert capacities(64, [10, 5]) == capacities(64, [{"_E": 10}, {"_E": 5}])


# ---------------------------------------------------------------------------
# end-to-end: trainer on the heterograph
# ---------------------------------------------------------------------------

def test_hetero_trainer_end_to_end():
    from repro.models.gnn import GNNConfig
    from repro.training import DistGNNTrainer, TrainJobConfig

    ds = get_dataset("mag-hetero", scale=10)
    cfg = GNNConfig(arch="rgcn", in_dim=ds.feats.shape[1], hidden_dim=16,
                    num_classes=ds.num_classes,
                    fanouts=[dict(FANOUTS)] * 2, batch_size=8,
                    num_rels=ds.schema.num_etypes)
    tr = DistGNNTrainer(ds, cfg, TrainJobConfig(num_machines=2,
                                                trainers_per_machine=1))
    assert tr.hetero
    m = tr.train_epoch(0)
    assert np.isfinite(m["loss"])
    stats = tr.sampling_stats()
    assert sum(stats["edges_per_etype"].values()) > 0
    tr.stop()
