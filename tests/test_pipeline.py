import time

import numpy as np
import pytest

from repro.core.pipeline import AsyncPipeline, Stage
from repro.core.pipeline.minibatch import MinibatchPipeline
from repro.core.kvstore import (DistKVStore, FaultInjector, NetworkModel,
                                PartitionPolicy, Transport,
                                TransientRPCError)
from repro.core.partition import hierarchical_partition, split_training_set
from repro.core.sampler import DistributedSampler
from repro.graph import get_dataset


def test_async_pipeline_preserves_order_and_results():
    stages = [Stage("double", lambda x: x * 2, depth=3),
              Stage("inc", lambda x: x + 1, depth=2)]
    out = list(AsyncPipeline(range(50), stages))
    assert out == [x * 2 + 1 for x in range(50)]


def test_async_pipeline_sync_mode_identical():
    stages = [Stage("sq", lambda x: x * x, depth=2)]
    a = list(AsyncPipeline(range(20), stages, sync=True))
    b = list(AsyncPipeline(range(20), stages, sync=False))
    assert a == b


def test_async_pipeline_overlaps_stage_latency():
    def slow(x):
        time.sleep(0.01)
        return x
    stages = [Stage("s1", slow, depth=4), Stage("s2", slow, depth=4)]
    t0 = time.perf_counter()
    consumed = 0
    for _ in AsyncPipeline(range(20), stages):
        time.sleep(0.01)   # consumer work
        consumed += 1
    dt = time.perf_counter() - t0
    assert consumed == 20
    # 3 overlapped 10ms stages for 20 items: ~0.2s+ramp, not 0.6s serial
    assert dt < 0.45, dt


def test_async_pipeline_error_propagates():
    def boom(x):
        if x == 3:
            raise ValueError("boom")
        return x
    with pytest.raises(ValueError):
        list(AsyncPipeline(range(10), [Stage("b", boom, depth=2)]))


def test_stop_joins_threads_blocked_on_full_queues():
    # Depth-1 queues + an abandoned consumer: every stage ends up blocked
    # on put() into a full queue. stop() must wake and join them all.
    stages = [Stage("a", lambda x: x, depth=1), Stage("b", lambda x: x, depth=1)]
    p = AsyncPipeline(range(100000), stages)
    it = iter(p)
    next(it)                    # start threads, then abandon the iterator
    time.sleep(0.1)             # queues fill; producers block on put()
    threads = list(p._threads)
    p.stop(timeout=5.0)
    assert all(not t.is_alive() for t in threads)


def test_stop_does_not_leak_thread_stuck_mid_stage_fn():
    # A worker still inside fn() when stop()'s join window expires must
    # still exit afterwards (its input get() re-checks the stop flag).
    import threading
    started = threading.Event()

    def slow(x):
        started.set()
        time.sleep(0.5)
        return x

    p = AsyncPipeline(range(10), [Stage("slow", slow, depth=1)])
    it = iter(p)
    next(it)
    started.clear()
    started.wait(timeout=2.0)          # a later item is mid-fn
    threads = list(p._threads)
    p.stop(timeout=0.05)               # expires while fn still sleeping
    time.sleep(1.0)                    # fn returns; worker must then exit
    assert all(not t.is_alive() for t in threads)


def test_stop_idempotent_and_safe_after_drain():
    p = AsyncPipeline(range(5), [Stage("x", lambda x: x, depth=2)])
    assert list(p) == list(range(5))
    p.stop()
    p.stop()


def test_stage_stats_recorded():
    p = AsyncPipeline(range(10), [Stage("w", lambda x: x, depth=2)])
    list(p)
    assert p.stats_report()["w"]["items"] == 10


def test_pool_preserves_order_under_out_of_order_completion():
    # Worker pool stress: per-item delays force completions far out of
    # order (item 0 is the slowest of each wave); the reassembly buffer
    # must still emit strictly in sequence.
    def jitter(x):
        time.sleep(0.012 - 0.003 * (x % 4))
        return x * 10
    stages = [Stage("pool", jitter, depth=2, workers=4),
              Stage("tail", lambda x: x + 1, depth=2)]
    p = AsyncPipeline(range(40), stages)
    assert list(p) == [x * 10 + 1 for x in range(40)]
    rep = p.stats_report()
    assert rep["pool"]["items"] == 40 and rep["pool"]["workers"] == 4
    assert rep["tail"]["items"] == 40 and rep["tail"]["workers"] == 1


@pytest.mark.slow
def test_pool_overlaps_item_latency():
    # 4 workers on a 10ms stage must beat the serial 0.3s floor clearly.
    # Wall-clock on a busy 1-core host is noisy: best of 2 runs, like
    # test_minibatch_pipeline_async_faster_than_sync.
    def slow(x):
        time.sleep(0.01)
        return x

    def run():
        t0 = time.perf_counter()
        out = list(AsyncPipeline(range(30),
                                 [Stage("s", slow, depth=4, workers=4)]))
        assert out == list(range(30))
        return time.perf_counter() - t0

    dt = min(run() for _ in range(2))
    assert dt < 0.25, dt   # serial would be >= 0.3s


def test_pool_reorder_buffer_bounded():
    # One very slow batch must not let the siblings race ahead without
    # bound (the ordering window): while item 0 blocks, at most
    # workers+depth items may complete, no matter how deep the source is.
    import threading
    release = threading.Event()

    def fn(x):
        if x == 0:
            release.wait(timeout=10)
        return x

    p = AsyncPipeline(range(5000), [Stage("s", fn, depth=2, workers=4)])
    p.start()
    time.sleep(0.5)                  # pool runs while item 0 is stuck
    done_ahead = p.stats["s"].items
    release.set()
    assert list(p) == list(range(5000))
    assert done_ahead <= 4 + 2, done_ahead   # the workers+depth window
    p.stop()


def test_pool_error_stops_sibling_workers():
    # After one worker errors, siblings must stop invoking fn (their side
    # effects would pollute transport accounting) instead of burning
    # through the rest of an unbounded schedule.
    import threading
    calls = [0]
    lock = threading.Lock()

    def boom(x):
        with lock:
            calls[0] += 1
        if x == 5:
            raise ValueError("boom")
        time.sleep(0.002)
        return x

    p = AsyncPipeline(range(100000), [Stage("b", boom, depth=2, workers=4)])
    with pytest.raises(ValueError):
        list(p)
    time.sleep(0.3)                  # grace for siblings to notice
    with lock:
        seen = calls[0]
    time.sleep(0.3)
    with lock:
        assert calls[0] <= seen + 4, "workers kept running fn after error"
    p.stop()


def test_pool_error_propagates():
    def boom(x):
        if x == 7:
            raise ValueError("boom")
        time.sleep(0.002)
        return x
    p = AsyncPipeline(range(50), [Stage("b", boom, depth=2, workers=4)])
    with pytest.raises(ValueError):
        list(p)
    p.stop()


def test_pool_stop_joins_threads():
    stages = [Stage("a", lambda x: x, depth=1, workers=3),
              Stage("b", lambda x: x, depth=1)]
    p = AsyncPipeline(range(100000), stages)
    it = iter(p)
    next(it)
    time.sleep(0.1)             # queues fill; workers block on put()
    threads = list(p._threads)
    p.stop(timeout=5.0)
    assert all(not t.is_alive() for t in threads)


def test_pool_sync_mode_ignores_workers():
    stages = [Stage("sq", lambda x: x * x, depth=2, workers=8)]
    assert (list(AsyncPipeline(range(20), stages, sync=True))
            == [x * x for x in range(20)])


@pytest.fixture(scope="module")
def world():
    ds = get_dataset("product-sim", scale=11)
    hp = hierarchical_partition(ds.graph, 2, 1, split_mask=ds.split_mask,
                                seed=0)
    book = hp.book
    feats_new = ds.feats[book.new2old_node]
    labels_new = ds.labels[book.new2old_node]
    tp = Transport(NetworkModel(sleep=True, latency_s=2e-3,
                                bandwidth_Bps=1e9))
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets)},
                        transport=tp)
    store.init_data("feat", feats_new.shape[1:], np.float32, "node",
                    full_array=feats_new)
    train_new = book.old2new_node[ds.train_nids]
    seeds = split_training_set(hp, train_new)[0]
    return ds, hp, store, tp, seeds, labels_new


def _run(world, sync, non_stop, epochs=3, consume_s=0.008):
    ds, hp, store, tp, seeds, labels_new = world
    sampler = DistributedSampler(hp.book, hp.partitions, [10, 5], 32,
                                 machine=0, transport=tp, seed=0)
    pipe = MinibatchPipeline(sampler, store.client(0), "feat", seeds,
                             labels=labels_new[seeds], sync=sync,
                             non_stop=non_stop, to_device=False, seed=1)
    t0 = time.perf_counter()
    got = []
    for e in range(epochs):
        for mb in pipe.epoch(e):
            time.sleep(consume_s)   # stands in for the jitted train step
            got.append(mb)
    dt = time.perf_counter() - t0
    pipe.stop()
    return dt, got


def test_minibatch_pipeline_same_count_all_modes(world):
    _, a = _run(world, True, False)
    _, b = _run(world, False, False)
    _, c = _run(world, False, True)
    assert len(a) == len(b) == len(c) > 0
    # every minibatch has features attached by the CPU prefetch stage
    assert all(m.input_feats is not None for m in a + b + c)


@pytest.mark.slow
def test_minibatch_pipeline_async_faster_than_sync(world):
    # Wall-clock comparison on a busy 1-core host is noisy: take the best
    # of 2 runs per mode; async must beat the serial loop. If a
    # scheduling hiccup inverts it, retry once with two more runs per
    # mode and a 5% noise allowance — min-of-4 makes the comparison
    # robust, and a genuine overlap regression (async degenerating to
    # serial plus thread overhead) loses by far more than 5% across all
    # runs, so the widened margin only forgives timer jitter, not the
    # property under test.
    t_sync = min(_run(world, True, False)[0] for _ in range(2))
    t_async = min(_run(world, False, True)[0] for _ in range(2))
    if t_async >= t_sync:
        t_sync = min([t_sync] + [_run(world, True, False)[0]
                                 for _ in range(2)])
        t_async = min([t_async] + [_run(world, False, True)[0]
                                   for _ in range(2)])
        assert t_async < t_sync * 1.05, (t_async, t_sync)
    else:
        assert t_async < t_sync


def test_pipeline_feature_correctness(world):
    ds, hp, store, tp, seeds, labels_new = world
    feats_new = ds.feats[hp.book.new2old_node]
    sampler = DistributedSampler(hp.book, hp.partitions, [5], 16,
                                 machine=0, seed=0)
    pipe = MinibatchPipeline(sampler, store.client(0), "feat", seeds,
                             labels=labels_new[seeds], sync=True,
                             non_stop=False, to_device=False)
    for mb in pipe.epoch(0):
        assert np.allclose(mb.input_feats, feats_new[mb.input_gids])
        break


# ---- injected mid-stream stage failures (DESIGN.md §10) -------------------

def _fault_world():
    """A private world per test: these tests poison the shared transport
    with a fault injector, so they must never touch the module fixture."""
    ds = get_dataset("product-sim", scale=10)
    hp = hierarchical_partition(ds.graph, 2, 1, split_mask=ds.split_mask,
                                seed=0)
    book = hp.book
    feats_new = ds.feats[book.new2old_node]
    labels_new = ds.labels[book.new2old_node]
    tp = Transport(NetworkModel(sleep=True, latency_s=2e-3,
                                bandwidth_Bps=1e9))
    store = DistKVStore({"node": PartitionPolicy("node", book.node_offsets)},
                        transport=tp)
    store.init_data("feat", feats_new.shape[1:], np.float32, "node",
                    full_array=feats_new)
    train_new = book.old2new_node[ds.train_nids]
    seeds = split_training_set(hp, train_new)[0]
    return hp, store, tp, seeds, labels_new


@pytest.mark.parametrize("workers", [2, 4])
def test_pool_worker_fault_drains_cleanly(workers):
    """An injected fault inside a pool worker mid-way through a NON-STOP
    schedule must surface to the consumer, stop the sibling workers, and
    leave zero pipeline threads after ``stop()`` — a crashed sampling
    worker must never wedge or leak the trainer's pipeline."""
    import threading
    hp, store, tp, seeds, labels_new = _fault_world()
    # ops=("data",): fault the sampler-dispatch RPCs, i.e. the SAMPLE
    # stage's own traffic (that path is deliberately not retried — only
    # pull/push are, so the fault surfaces as a worker crash)
    tp.fault_injector = FaultInjector(seed=2, rpc_failure_rate=1.0,
                                      ops=("data",),)
    sampler = DistributedSampler(hp.book, hp.partitions, [10, 5], 32,
                                 machine=0, transport=tp, seed=0)
    pipe = MinibatchPipeline(sampler, store.client(0), "feat", seeds,
                             labels=labels_new[seeds], non_stop=True,
                             to_device=False, seed=1,
                             sample_workers=workers)
    with pytest.raises(TransientRPCError):
        for _ in pipe.epoch(0):
            pass
    pipe.stop()
    leaked = [t.name for t in threading.enumerate()
              if t.name.startswith("minibatch")]
    assert not leaked, f"pipeline threads leaked after fault: {leaked}"


@pytest.mark.parametrize("workers", [2, 4])
def test_pool_worker_fault_stops_siblings(workers):
    """After one sampling worker crashes, siblings must stop issuing
    dispatch RPCs (their side effects would pollute transport accounting)
    instead of burning through the rest of the non-stop schedule."""
    hp, store, tp, seeds, labels_new = _fault_world()
    tp.fault_injector = FaultInjector(seed=2, rpc_failure_rate=1.0,
                                      ops=("data",))
    sampler = DistributedSampler(hp.book, hp.partitions, [10, 5], 32,
                                 machine=0, transport=tp, seed=0)
    pipe = MinibatchPipeline(sampler, store.client(0), "feat", seeds,
                             labels=labels_new[seeds], non_stop=True,
                             to_device=False, seed=1,
                             sample_workers=workers)
    with pytest.raises(TransientRPCError):
        for _ in pipe.epoch(0):
            pass
    time.sleep(0.3)                   # grace for siblings to notice
    n_then = tp.rpc_failures
    time.sleep(0.3)
    # each worker may finish the item it already held, nothing more
    assert tp.rpc_failures <= n_then + workers, \
        "sampling workers kept issuing RPCs after a sibling's fault"
    pipe.stop()


def test_pipeline_fault_free_run_unaffected_by_armed_injector():
    """An attached injector with a zero rate (or out-of-scope ops) is
    inert: batch bytes and transport accounting match a run with no
    injector at all — the golden hashes cannot move."""
    outs = []
    for inj in (None, FaultInjector(seed=9, rpc_failure_rate=0.0),
                FaultInjector(seed=9, rpc_failure_rate=1.0,
                              ops=("never",))):
        hp, store, tp, seeds, labels_new = _fault_world()
        tp.fault_injector = inj
        sampler = DistributedSampler(hp.book, hp.partitions, [5, 3], 16,
                                     machine=0, transport=tp, seed=0)
        pipe = MinibatchPipeline(sampler, store.client(0), "feat", seeds,
                                 labels=labels_new[seeds], non_stop=False,
                                 to_device=False, seed=1)
        got = [(mb.input_gids.tobytes(), mb.input_feats.tobytes())
               for mb in pipe.epoch(0)]
        pipe.stop()
        assert tp.rpc_failures == 0 and tp.rpc_retries == 0
        outs.append(got)
    assert outs[0] == outs[1] == outs[2]
