"""Optimizer / checkpoint / data-stream substrate tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.data import TokenStream
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         sgd_update)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0]), "b": jnp.asarray(2.0)}
    opt = adamw_init(params)

    def loss(p):
        return (p["w"] ** 2).sum() + p["b"] ** 2
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(params, g, opt, lr=0.05)
    assert float(loss(params)) < 1e-2


def test_adamw_moments_f32_for_bf16_params():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    opt = adamw_init(params)
    assert opt.mu["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    p2, _ = adamw_update(params, g, opt, lr=0.1)
    assert p2["w"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(np.sqrt(300), rel=1e-5)


def test_sgd_momentum():
    p = {"w": jnp.asarray(1.0)}
    m = {"w": jnp.asarray(0.0)}
    g = {"w": jnp.asarray(1.0)}
    p, m = sgd_update(p, g, lr=0.1, momentum_state=m, momentum=0.9)
    assert float(p["w"]) == pytest.approx(0.9)


def test_checkpoint_roundtrip():
    tree = {"layers": [{"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
                       {"w": np.ones((4,), np.float32)}],
            "step": np.asarray(7)}
    with tempfile.TemporaryDirectory() as tmp:
        save_pytree(tree, tmp)
        out = load_pytree(jax.tree.map(np.zeros_like, tree), tmp)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert np.array_equal(a, b)


def test_checkpoint_missing_leaf_raises():
    with tempfile.TemporaryDirectory() as tmp:
        save_pytree({"a": np.zeros(2)}, tmp)
        with pytest.raises(KeyError):
            load_pytree({"b": np.zeros(2)}, tmp)


def test_token_stream_shapes_and_structure():
    s = TokenStream(vocab=100, batch=4, seq=32, seed=0)
    batches = []
    for i, b in enumerate(s):
        if i >= 3:
            break
        batches.append(b)
    s.stop()
    for b in batches:
        assert b["tokens"].shape == (4, 32)
        assert int(b["tokens"].max()) < 100
    # markov structure: consecutive-token distribution must be non-uniform
    toks = np.concatenate([np.asarray(b["tokens"]).ravel() for b in batches])
    pairs = set(zip(toks[:-1].tolist(), toks[1:].tolist()))
    assert len(pairs) < 0.8 * (len(toks) - 1)


def test_token_stream_host_split_disjoint_schedule():
    a = TokenStream(vocab=50, batch=2, seq=16, seed=0, host_index=0,
                    host_count=2)
    b = TokenStream(vocab=50, batch=2, seq=16, seed=0, host_index=1,
                    host_count=2)
    xa = next(iter(a))["tokens"]
    xb = next(iter(b))["tokens"]
    a.stop(), b.stop()
    assert not np.array_equal(np.asarray(xa), np.asarray(xb))


def test_microbatched_train_step_equivalence():
    import jax
    from repro.configs import get_config, smoke_variant
    from repro.models.lm import init_train_state, make_train_step
    import jax.numpy as jnp
    cfg = smoke_variant(get_config("qwen2-0.5b"))
    p, opt = init_train_state(cfg, 0)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 24)))}
    p1, _, m1 = jax.jit(make_train_step(cfg, microbatches=1))(p, opt, batch)
    p4, _, m4 = jax.jit(make_train_step(cfg, microbatches=4))(p, opt, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-2
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-2
